#!/usr/bin/env python
"""ESSR static auditor CLI: jaxpr graph audit + repo AST lint.

Usage:
  python scripts/essr_lint.py --all              # both passes, gate vs baseline
  python scripts/essr_lint.py --ast              # AST lint only (fast, no jax)
  python scripts/essr_lint.py --jaxpr            # jaxpr audit only
  python scripts/essr_lint.py --all --json out.json
  python scripts/essr_lint.py --all --fix-baseline

Exit code is 0 iff the run has no *new* violations vs the committed baseline
(`ANALYSIS_baseline.json`, expected to be zero-violation). `--no-baseline`
gates on the absolute count instead. `--fix-baseline` rewrites the baseline
from this run and exits 0 — the escape hatch for local iteration, reviewed
like any other committed artifact.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_BASELINE = os.path.join(REPO_ROOT, "ANALYSIS_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="run both passes (default when no pass is chosen)")
    ap.add_argument("--jaxpr", action="store_true", help="jaxpr audit pass")
    ap.add_argument("--ast", action="store_true", help="AST lint pass")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the machine-readable report here")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline to diff against (default: "
                         f"{os.path.relpath(DEFAULT_BASELINE, REPO_ROOT)})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline; fail on any violation at all")
    ap.add_argument("--fix-baseline", action="store_true",
                    help="rewrite the baseline from this run and exit 0")
    ap.add_argument("--max-const-bytes", type=int, default=None,
                    help="ESSR104 byte budget for baked graph constants")
    args = ap.parse_args(argv)

    run_jaxpr = args.jaxpr or args.all or not (args.jaxpr or args.ast)
    run_ast = args.ast or args.all or not (args.jaxpr or args.ast)

    from repro.analysis.report import Report

    report = Report()
    if run_ast:
        from repro.analysis.ast_lint import run_ast_lint
        report.extend(run_ast_lint(REPO_ROOT))
    if run_jaxpr:
        from repro.analysis.jaxpr_audit import run_jaxpr_audit
        kwargs = {}
        if args.max_const_bytes is not None:
            kwargs["const_budget"] = args.max_const_bytes
        report.extend(run_jaxpr_audit(**kwargs))

    print(report.render())
    if args.json:
        d = os.path.dirname(os.path.abspath(args.json))
        os.makedirs(d, exist_ok=True)
        report.to_json(args.json)
        print(f"wrote {args.json}")

    if args.fix_baseline:
        report.to_json(args.baseline)
        print(f"baseline rewritten: {args.baseline} "
              f"({len(report.violations)} violation(s))")
        return 0

    if args.no_baseline or not os.path.exists(args.baseline):
        if not args.no_baseline:
            print(f"note: no baseline at {args.baseline}; gating on "
                  f"absolute count")
        return 1 if report.violations else 0

    baseline = Report.from_json(args.baseline)
    new = report.new_vs(baseline)
    if new:
        print(f"FAIL: {len(new)} new violation(s) vs baseline "
              f"{os.path.relpath(args.baseline, REPO_ROOT)}:")
        for v in new:
            print(f"  {v.code} {v.site}: {v.message}")
        return 1
    print(f"ok: no new violations vs baseline "
          f"({len(baseline.violations)} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
