#!/usr/bin/env python
"""ESSR static auditor CLI: jaxpr graph audit + repo AST lint + interval
range certification + static cost model.

Usage:
  python scripts/essr_lint.py --all              # every pass, gate vs baseline
  python scripts/essr_lint.py --ast              # AST lint only (fast, no jax)
  python scripts/essr_lint.py --jaxpr            # jaxpr audit only
  python scripts/essr_lint.py --range            # ESSR3xx range certification
  python scripts/essr_lint.py --cost             # static MAC/byte cost model
  python scripts/essr_lint.py --list-rules       # print the rule catalog
  python scripts/essr_lint.py --all --select ESSR301,ESSR302
  python scripts/essr_lint.py --all --ignore ESSR104
  python scripts/essr_lint.py --all --json out.json
  python scripts/essr_lint.py --all --fix-baseline

Exit code is 0 iff the run has no *new* violations vs the committed baseline
(`ANALYSIS_baseline.json`, expected to be zero-violation). `--no-baseline`
gates on the absolute count instead. `--fix-baseline` rewrites the baseline
from this run — including the range/cost metrics sections `bench_gate
--audit` diffs quantitatively — and exits 0; the escape hatch for local
iteration, reviewed like any other committed artifact. `--select`/`--ignore`
filter which rule codes can fire (metrics sections are unaffected).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_BASELINE = os.path.join(REPO_ROOT, "ANALYSIS_baseline.json")


def _parse_codes(arg, known):
    if not arg:
        return None
    codes = {c.strip().upper() for c in arg.split(",") if c.strip()}
    unknown = codes - set(known)
    if unknown:
        raise SystemExit(f"essr_lint: unknown rule code(s) "
                         f"{sorted(unknown)}; see --list-rules")
    return codes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass is chosen)")
    ap.add_argument("--jaxpr", action="store_true", help="jaxpr audit pass")
    ap.add_argument("--ast", action="store_true", help="AST lint pass")
    ap.add_argument("--range", action="store_true", dest="range_",
                    help="interval range certification pass (ESSR3xx)")
    ap.add_argument("--cost", action="store_true",
                    help="static MAC/byte cost pass (metrics only)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog (code, pass, description) "
                         "and exit")
    ap.add_argument("--select", metavar="CODE[,CODE]",
                    help="only these rule codes may fire")
    ap.add_argument("--ignore", metavar="CODE[,CODE]",
                    help="suppress these rule codes")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the machine-readable report here")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline to diff against (default: "
                         f"{os.path.relpath(DEFAULT_BASELINE, REPO_ROOT)})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline; fail on any violation at all")
    ap.add_argument("--fix-baseline", action="store_true",
                    help="rewrite the baseline from this run and exit 0")
    ap.add_argument("--max-const-bytes", type=int, default=None,
                    help="ESSR104 byte budget for baked graph constants")
    ap.add_argument("--bit-budget", type=int, default=None,
                    help="ESSR302 accumulator bit budget (default 32)")
    args = ap.parse_args(argv)

    from repro.analysis.report import RULE_REGISTRY, Report

    if args.list_rules:
        width = max(len(c) for c in RULE_REGISTRY)
        for code in sorted(RULE_REGISTRY):
            pass_name, desc = RULE_REGISTRY[code]
            print(f"{code:<{width}}  [{pass_name}] {desc}")
        return 0

    chosen = args.jaxpr or args.ast or args.range_ or args.cost
    run_all = args.all or not chosen
    run_jaxpr = args.jaxpr or run_all
    run_ast = args.ast or run_all
    run_range = args.range_ or run_all
    run_cost = args.cost or run_all

    select = _parse_codes(args.select, RULE_REGISTRY)
    ignore = _parse_codes(args.ignore, RULE_REGISTRY) or set()

    report = Report()
    if run_ast:
        from repro.analysis.ast_lint import run_ast_lint
        report.extend(run_ast_lint(REPO_ROOT))
    if run_jaxpr:
        from repro.analysis.jaxpr_audit import run_jaxpr_audit
        kwargs = {}
        if args.max_const_bytes is not None:
            kwargs["const_budget"] = args.max_const_bytes
        report.extend(run_jaxpr_audit(**kwargs))
    if run_range:
        from repro.analysis.range_infer import run_range_audit
        kwargs = {}
        if args.bit_budget is not None:
            kwargs["bit_budget"] = args.bit_budget
        violations, bitwidth = run_range_audit(**kwargs)
        report.extend(violations)
        report.merge_metrics("bitwidth", bitwidth)
    if run_cost:
        from repro.analysis.cost_model import run_cost_audit
        report.merge_metrics("static_costs", run_cost_audit())

    if select is not None or ignore:
        report.violations = [
            v for v in report.violations
            if (select is None or v.code in select) and v.code not in ignore]

    print(report.render())
    if args.json:
        d = os.path.dirname(os.path.abspath(args.json))
        os.makedirs(d, exist_ok=True)
        report.to_json(args.json)
        print(f"wrote {args.json}")

    if args.fix_baseline:
        report.to_json(args.baseline)
        print(f"baseline rewritten: {args.baseline} "
              f"({len(report.violations)} violation(s))")
        return 0

    if args.no_baseline or not os.path.exists(args.baseline):
        if not args.no_baseline:
            print(f"note: no baseline at {args.baseline}; gating on "
                  f"absolute count")
        return 1 if report.violations else 0

    baseline = Report.from_json(args.baseline)
    new = report.new_vs(baseline)
    if new:
        print(f"FAIL: {len(new)} new violation(s) vs baseline "
              f"{os.path.relpath(args.baseline, REPO_ROOT)}:")
        for v in new:
            print(f"  {v.code} {v.site}: {v.message}")
        return 1
    print(f"ok: no new violations vs baseline "
          f"({len(baseline.violations)} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
