#!/usr/bin/env bash
# Tier-1 smoke: the repo's verify command plus a 2-frame SREngine stream.
# Usage: bash scripts/smoke.sh   (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== static audit (jaxpr graph audit + AST lint vs baseline) =="
python scripts/essr_lint.py --all

echo "== pallas-backend frame smoke (interpret fallback on CPU) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.api import SREngine
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig

frame = degrade(jnp.asarray(random_image(0, 128, 128)), 2)
ref = SREngine.from_config(ESSRConfig(scale=2), seed=1)
pal = SREngine.from_config(ESSRConfig(scale=2), seed=1, backend="pallas")
r, p = ref.upscale(frame), pal.upscale(frame)
assert p.image.shape == (128, 128, 3)
# on CPU the auto interpret policy must fall back and say so
assert p.backend == "pallas-interpret", p.backend
np.testing.assert_allclose(np.asarray(r.image), np.asarray(p.image), atol=1e-5)
print("pallas smoke OK:", p.backend, p.counts)
PY

echo "== quantized serving smoke (fxp10 budget vs fp32 + pallas-int8 label) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.api import ExecutionPlan, SREngine
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig
from repro.train.losses import psnr_y

hr = jnp.asarray(random_image(0, 128, 128))
frame = degrade(hr, 2)
fp = SREngine.from_config(ESSRConfig(scale=2), seed=1)
q10 = SREngine.from_config(ESSRConfig(scale=2), seed=1,
                           plan=ExecutionPlan(quant="fxp10"))
r_fp, r_q = fp.upscale(frame), q10.upscale(frame)
assert r_q.backend == "ref-fxp10", r_q.backend
assert np.array_equal(r_q.ids, r_fp.ids)          # quant never moves routing
drop = float(psnr_y(r_fp.image, hr)) - float(psnr_y(r_q.image, hr))
assert drop < 0.6, f"fxp10 PSNR drop {drop:.3f} dB exceeds the paper budget"
q8 = SREngine.from_config(ESSRConfig(scale=2), seed=1, backend="pallas",
                          plan=ExecutionPlan(quant="int8"))
r8 = q8.upscale(frame)
assert r8.backend.endswith("-int8"), r8.backend
print(f"quant smoke OK: {r_q.backend} drop={drop:.3f}dB, {r8.backend}")
PY

echo "== fused-dispatch smoke (one frame, allclose vs host dispatch) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.api import ExecutionPlan, SREngine
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig

frame = degrade(jnp.asarray(random_image(0, 128, 128)), 2)
host = SREngine.from_config(ESSRConfig(scale=2), seed=1)
fused = SREngine.from_config(ESSRConfig(scale=2), seed=1,
                             plan=ExecutionPlan(dispatch="fused"))
rh, rf = host.upscale(frame), fused.upscale(frame)
assert rf.dispatch == "fused" and rh.dispatch == "host"
assert rf.spill_counts is not None and not any(rf.spill_counts)
assert np.array_equal(np.asarray(rf.ids), np.asarray(rh.ids))
np.testing.assert_allclose(np.asarray(rf.image), np.asarray(rh.image),
                           atol=1e-5)
# async double-buffered stream returns the same frames in order
r_async = list(fused.stream([frame, frame]))
assert len(r_async) == 2 and all(r.dispatch == "fused" for r in r_async)
print("fused smoke OK:", rf.counts, "spills", rf.spill_counts)
PY

echo "== group-fusion megakernel smoke (one launch per subnet, VMEM-resident) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.api import ExecutionPlan, SREngine
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig

frame = degrade(jnp.asarray(random_image(0, 128, 128)), 2)
layer = SREngine.from_config(ESSRConfig(scale=2), seed=1, backend="pallas")
group = SREngine.from_config(ESSRConfig(scale=2), seed=1, backend="pallas",
                             plan=ExecutionPlan(fusion="group"))
rl, rg = layer.upscale(frame), group.upscale(frame)
assert np.array_equal(np.asarray(rl.ids), np.asarray(rg.ids))
np.testing.assert_allclose(np.asarray(rl.image), np.asarray(rg.image),
                           atol=1e-5)
# quantized group fusion: int codes stay in VMEM across the whole chain and
# the result is BIT-EXACT vs the layer-fused integer stack
q_layer = SREngine.from_config(ESSRConfig(scale=2), seed=1, backend="pallas",
                               plan=ExecutionPlan(quant="int8"))
q_group = SREngine.from_config(ESSRConfig(scale=2), seed=1, backend="pallas",
                               plan=ExecutionPlan(quant="int8",
                                                  fusion="group"))
ql, qg = q_layer.upscale(frame), q_group.upscale(frame)
assert np.array_equal(np.asarray(ql.image), np.asarray(qg.image))
occ = rg.summary()["compiled_caches"]
assert {"fused_frame_fn", "fused_stream_frame_fn", "get_geometry"} <= set(occ)
print("megakernel smoke OK:", rg.counts, "cache occupancy:",
      {k: v["size"] for k, v in occ.items()})
PY

echo "== SREngine 2-frame stream smoke =="
python - <<'PY'
import jax.numpy as jnp
from repro.api import SREngine
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig

engine = SREngine.from_config(ESSRConfig(scale=2))
frames = [degrade(jnp.asarray(random_image(i, 128, 128)), 2) for i in range(2)]
results = list(engine.stream(frames))
assert len(results) == 2
assert all(r.image.shape == (128, 128, 3) for r in results)
summary = engine.summary()
assert summary["frames"] == 2
print("stream smoke OK:", summary)
PY

echo "== sharded patch-stream smoke (skips on single-device hosts) =="
python - <<'PY'
import numpy as np
import jax, jax.numpy as jnp
from repro.api import ExecutionPlan, SREngine
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig

n = jax.device_count()
if n < 2:
    print(f"sharded smoke skipped: {n} device(s) "
          "(run under XLA_FLAGS=--xla_force_host_platform_device_count=4)")
else:
    frame = degrade(jnp.asarray(random_image(0, 128, 128)), 2)
    single = SREngine.from_config(ESSRConfig(scale=2), seed=1)
    shardN = SREngine.from_config(ESSRConfig(scale=2), seed=1,
                                  plan=ExecutionPlan(shards=min(4, n)))
    r1, rn = single.upscale(frame), shardN.upscale(frame)
    np.testing.assert_allclose(np.asarray(r1.image), np.asarray(rn.image),
                               atol=1e-5)
    res = shardN.serve(frame)
    assert len(res.shard_counts) == shardN.plan.shards
    print("sharded smoke OK:", shardN.plan.shards, "shards,",
          "counts:", res.shard_counts)
PY

echo "== chaos smoke (seeded faults: determinism + tenant isolation) =="
python - <<'PY'
import numpy as np
from repro.api import ExecutionPlan, SREngine
from repro.core.adaptive import SwitchingConfig
from repro.models.essr import ESSRConfig, init_essr
from repro.runtime.guard import FaultPlan
import jax

CFG = ESSRConfig(scale=2)
params = init_essr(jax.random.PRNGKey(0), CFG)
sw = SwitchingConfig(frame_high=10**9, frame_low=0)
fp = FaultPlan(seed=7, poison_rate=0.5, poison_kinds=("nan", "inf"),
               backend_failure_rate=0.2, target_streams=(1,))

def frames(seed, n=4):
    rng = np.random.default_rng(seed)
    return [rng.random((64, 64, 3), np.float32) for _ in range(n)]

def chaos_run():
    plan = ExecutionPlan(dispatch="fused", streams=3, capacity=(0, 9, 9),
                         on_poison="raise", quarantine_ticks=1, faults=fp)
    eng = SREngine(params, CFG, plan=plan, switching=sw)
    outs = list(eng.serve_streams([frames(100 + s) for s in range(3)]))
    trace = [(o.stream_id, o.health, o.degraded) for o in outs]
    return trace, eng.summary()["degradations"]["by_kind"]

t1, k1 = chaos_run()
t2, k2 = chaos_run()
assert t1 == t2, "chaos run is not deterministic across identical seeds"
assert k1 == k2, (k1, k2)
# every yielded frame is clean: poisoned ticks are suppressed, one per
# recorded poison verdict, all on the targeted tenant
assert all(h == (0, 0, 0) for _, h, _ in t1), "a poisoned frame was served"
n_stream1 = sum(1 for sid, _, _ in t1 if sid == 1)
assert k1.get("poison", 0) >= 1 and n_stream1 == 4 - k1["poison"]
print("chaos smoke OK:", len(t1), "results,", k1)
PY

echo "smoke OK"
