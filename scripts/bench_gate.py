"""Bench-regression gate over the table11 patch-pipeline micro-config.

Re-runs the ref-backend micro benchmark (the same 480x270 -> x4 frames and
``--shards`` sweep that produced the committed ``BENCH_table11_throughput.json``)
and fails when the fresh numbers regress past a tolerance band:

  * correctness is a hard gate — every ``allclose`` flag must hold, at zero
    tolerance (a wrong-but-fast pipeline is a regression, not a win);
  * ``speedup_x`` (vectorized vs seed loop, measured back-to-back on the SAME
    machine) is the machine-portable throughput signal: it must stay within
    ``--tol`` of the committed ratio, or the host-loop removal has rotted;
  * absolute FPS is compared within the same band — wide by default because
    CI runners are not the machine that committed the JSON; tighten with
    ``--tol`` (or ``BENCH_GATE_TOL``) on a pinned perf box;
  * the quant sweep gates on BOTH axes: ``pallas_int8_bitexact`` is a hard
    zero-tolerance flag (the integer kernels drifting off the fake-quant
    lattice is a correctness bug), per-mode fps uses the same band, and
    ``snr_db_vs_fp32`` must stay within ``--snr-tol-db`` (default 3 dB) of
    the committed accuracy — a machine-portable signal, unlike absolute
    PSNR on random-init weights.

  * the dispatch sweep gates fused dispatch: output allclose to host
    dispatch is zero-tolerance (per backend/quant via
    ``dispatch_conformance``), and fused fps must not fall below host fps
    beyond the band — both measured back-to-back in the same run, so the
    ratio travels across machines; ``fused_speedup_x`` is banded against
    the committed value like ``speedup_x``.

  * the fusion sweep gates the group-fused subnet megakernel: its output
    must stay allclose to the layer-fused per-op stack (zero tolerance),
    its fps must not fall below the layer stack beyond the band (same-run
    interleaved measurement), and the STATIC ``feature_hbm_bytes`` of the
    traced group chain must stay at most half the layer chain's (a fixed
    0.5 floor — structural, machine-portable; the paper claims 0.79).

  * the multi-stream sweep gates continuous batching: the multiplexed
    outputs must match the solo engines (zero tolerance — capacity is
    pinned identically on both sides, so there is no legitimate drift),
    and the N-stream aggregate fused throughput must hold at least 0.9x of
    N solo engines — both measured back-to-back in the same run, so the
    ratio travels across machines; aggregate fps is additionally banded
    against the committed value like every fps row.

  * the resilience row gates the serving guard: guarded (in-graph health
    verdicts + sanitize) fps must hold at least 0.95x of unguarded fps
    (fixed floor — interleaved same-run ratio, so 5% travels across
    machines), the sanitize path must be bit-equal to verdicts-off on
    clean frames, and the seeded chaos run must finish crash-free with a
    degradation ledger identical across two identically-seeded runs —
    all three at zero tolerance.

The fresh JSON is written to ``--out`` for upload as a workflow artifact, so
every CI run leaves an inspectable perf record even when the gate passes.

``--audit`` adds the static-analysis leg in the same invocation: all four
`repro.analysis` passes run (jaxpr audit, AST lint, interval range
certification, static cost model) and the gate hard-fails on any violation
new vs the committed ``ANALYSIS_baseline.json`` — a graph hazard or an
ESSR3xx overflow proof-failure blocks merge exactly like a perf regression —
plus any quantitative regression of the baselined metrics: static MAC/HBM
traffic growing past ``--traffic-tol``, or any fused group's minimal
accumulator bit-width growing (overflow headroom shrinking).

    PYTHONPATH=src:. python scripts/bench_gate.py [--tol 0.5] [--shards 1,2,4]
    PYTHONPATH=src:. python scripts/bench_gate.py --audit
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [REPO, os.path.join(REPO, "src")]

COMMITTED = os.path.join(REPO, "BENCH_table11_throughput.json")
AUDIT_BASELINE = os.path.join(REPO, "ANALYSIS_baseline.json")


def run_audit(baseline_path: str, out_json: str,
              traffic_tol: float = 0.10) -> list:
    """The ``--audit`` leg: run all four static-analysis passes and return
    failure strings for (a) every violation new vs the committed baseline —
    including the range certifier's ESSR3xx overflow/bit-width proofs — and
    (b) every quantitative regression of the range/cost metrics sections:
    static MACs or HBM bytes growing past ``traffic_tol``, any fused group's
    minimal accumulator bit-width growing (overflow headroom shrinking), or
    a baselined entry point/group losing coverage. Static costs are
    structural (shape/dtype only), so this leg is machine-portable at a
    tight tolerance, unlike the measured-fps bands above."""
    from repro.analysis.ast_lint import run_ast_lint
    from repro.analysis.cost_model import run_cost_audit
    from repro.analysis.jaxpr_audit import run_jaxpr_audit
    from repro.analysis.range_infer import run_range_audit
    from repro.analysis.report import Report, gate_metrics

    report = Report(run_ast_lint(REPO))
    report.extend(run_jaxpr_audit())
    range_violations, bitwidth = run_range_audit()
    report.extend(range_violations)
    report.merge_metrics("bitwidth", bitwidth)
    report.merge_metrics("static_costs", run_cost_audit())
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    report.to_json(out_json)
    baseline = (Report.from_json(baseline_path)
                if os.path.exists(baseline_path) else Report())
    fails = [f"audit: new {v.code} at {v.site}: {v.message}"
             for v in report.new_vs(baseline)]
    fails.extend(f"audit: {msg}"
                 for msg in gate_metrics(report, baseline,
                                         traffic_tol=traffic_tol))
    return fails


def compare(committed: dict, fresh: dict, tol: float,
            snr_tol_db: float = 3.0) -> list:
    """Return a list of human-readable failure strings (empty == gate holds)."""
    fails = []

    def band(name: str, got: float, want: float):
        if got < want * (1.0 - tol):
            fails.append(f"{name}: {got:.3f} < committed {want:.3f} "
                         f"- {tol:.0%} band")

    for key, want_row in committed.get("frames", {}).items():
        got_row = fresh.get("frames", {}).get(key)
        if got_row is None:
            fails.append(f"frames[{key}]: missing from fresh run")
            continue
        if not got_row.get("allclose_vs_seed_loop", False):
            fails.append(f"frames[{key}]: vectorized pipeline no longer "
                         f"allclose to the seed loop reference")
        band(f"frames[{key}].after_vectorized.fps",
             got_row["after_vectorized"]["fps"],
             want_row["after_vectorized"]["fps"])
        band(f"frames[{key}].speedup_x",
             got_row["speedup_x"], want_row["speedup_x"])

    # -- dispatch sweep: fused single-dispatch vs host ----------------------
    want_d = committed.get("dispatch_sweep", {})
    got_d = fresh.get("dispatch_sweep", {})
    if want_d:
        if not got_d:
            fails.append("dispatch_sweep: missing from fresh run")
        else:
            if not got_d.get("fused", {}).get("allclose_vs_host", False):
                fails.append("dispatch_sweep: fused frame executable no "
                             "longer allclose to host dispatch")
            # fused dispatch must never be slower than host dispatch beyond
            # the tolerance band — measured on the SAME machine in the SAME
            # run (interleaved reps), so this ratio is machine-portable
            got_host = got_d.get("host", {}).get("fps", 0.0)
            got_fused = got_d.get("fused", {}).get("fps", 0.0)
            if got_fused < got_host * (1.0 - tol):
                fails.append(
                    f"dispatch_sweep: fused fps {got_fused:.3f} slower than "
                    f"host fps {got_host:.3f} beyond the {tol:.0%} band")
            band("dispatch_sweep.fused_speedup_x",
                 got_d.get("fused_speedup_x", 0.0),
                 want_d.get("fused_speedup_x", 0.0))
    for label, ok in committed.get("dispatch_conformance", {}).items():
        got_ok = fresh.get("dispatch_conformance", {}).get(label)
        if got_ok is None:
            fails.append(f"dispatch_conformance[{label}]: missing from "
                         f"fresh run")
        elif not got_ok:
            fails.append(f"dispatch_conformance[{label}]: fused output no "
                         f"longer matches host dispatch")

    # -- fusion sweep: group-fused megakernel vs layer-fused per-op stack --
    want_f = committed.get("fusion_sweep", {})
    got_f = fresh.get("fusion_sweep", {})
    if want_f:
        if not got_f:
            fails.append("fusion_sweep: missing from fresh run")
        else:
            if not got_f.get("group", {}).get("allclose_vs_layer", False):
                fails.append("fusion_sweep: group-fused megakernel output no "
                             "longer allclose to the layer-fused stack")
            # group fusion must never be slower than the per-op stack beyond
            # the band — interleaved same-run measurement, so the ratio is
            # machine-portable (mirrors the fused-vs-host dispatch gate)
            got_layer = got_f.get("layer", {}).get("fps", 0.0)
            got_group = got_f.get("group", {}).get("fps", 0.0)
            if got_group < got_layer * (1.0 - tol):
                fails.append(
                    f"fusion_sweep: group fps {got_group:.3f} slower than "
                    f"layer fps {got_layer:.3f} beyond the {tol:.0%} band")
            # the static feature-HBM reduction is structural (priced from the
            # traced graphs, not measured), so it gates at a FIXED floor:
            # features must cross HBM at most half as much as the per-op
            # stack — the portable form of the paper's 79% claim
            for key in ("feature_hbm_reduction", "feature_hbm_reduction_int8"):
                red = got_f.get(key, 0.0)
                if red < 0.5:
                    fails.append(
                        f"fusion_sweep.{key}: {red:.3f} < 0.5 floor "
                        f"(paper: 0.79 — group fusion stopped keeping "
                        f"features in VMEM)")
            band("fusion_sweep.group_speedup_x",
                 got_f.get("group_speedup_x", 0.0),
                 want_f.get("group_speedup_x", 0.0))

    # NOTE: the shard rows below compare fps against the committed JSON,
    # which was itself produced on a virtual-CPU mesh where shards > 1 run
    # SLOWER than one device (the committed "shard_overhead_x" > 1 records
    # exactly that, see docs/api.md). Host-mesh slowdown is therefore part
    # of the baseline, not a regression; on real accelerators regenerate
    # the baseline with --update before gating.
    for s, want_row in committed.get("shard_sweep", {}).items():
        got_row = fresh.get("shard_sweep", {}).get(s)
        if got_row is None:
            fails.append(f"shard_sweep[{s}]: missing from fresh run")
            continue
        if "skipped" in got_row or "skipped" in want_row:
            # fewer devices here than on the committing machine (or vice
            # versa): nothing comparable, and the run says so
            continue
        if not got_row.get("allclose_vs_1shard", False):
            fails.append(f"shard_sweep[{s}]: sharded output no longer "
                         f"allclose to the single-device path")
        band(f"shard_sweep[{s}].fps", got_row["fps"], want_row["fps"])

    # -- multi-stream sweep: N tenants in one fused dispatch vs N engines --
    want_m = committed.get("multi_stream", {})
    got_m = fresh.get("multi_stream", {})
    if want_m:
        if not got_m:
            fails.append("multi_stream: missing from fresh run")
        else:
            if not got_m.get("mux_aggregate", {}).get("allclose_vs_solo",
                                                      False):
                fails.append("multi_stream: multiplexed stream outputs no "
                             "longer match the solo engines (pinned "
                             "capacity, zero tolerance)")
            ratio = got_m.get("mux_vs_solo_x", 0.0)
            if ratio < 0.9:
                fails.append(
                    f"multi_stream: {got_m.get('streams')}-stream aggregate "
                    f"fused throughput is {ratio:.3f}x of "
                    f"{got_m.get('streams')} solo engines (floor 0.9x, "
                    f"same-run measurement)")
            band("multi_stream.mux_aggregate.fps",
                 got_m.get("mux_aggregate", {}).get("fps", 0.0),
                 want_m.get("mux_aggregate", {}).get("fps", 0.0))

    # -- resilience: guard tax band + zero-tolerance chaos conformance ----
    want_r = committed.get("resilience", {})
    got_r = fresh.get("resilience", {})
    if want_r:
        if not got_r:
            fails.append("resilience: missing from fresh run")
        else:
            ratio = got_r.get("guarded_vs_unguarded_x", 0.0)
            # fixed floor, not the machine band: the guard's verdict is
            # three in-graph reductions, and both sides of the ratio are
            # interleaved in the same run, so 5% travels across hosts
            if ratio < 0.95:
                fails.append(
                    f"resilience: guarded serving is {ratio:.3f}x of "
                    f"unguarded (floor 0.95x — the health verdict must "
                    f"stay under a 5% tax)")
            if not got_r.get("clean_bit_equal", False):
                fails.append("resilience: sanitize path perturbs CLEAN "
                             "frames (must be a bit-level no-op, zero "
                             "tolerance)")
            chaos = got_r.get("chaos", {})
            if not chaos.get("crash_free", False):
                fails.append(f"resilience: chaos run crashed the engine "
                             f"({chaos.get('by_kind')}) — no fault class "
                             f"may escape serve_streams (zero tolerance)")
            if not chaos.get("deterministic", False):
                fails.append("resilience: two identically-seeded chaos "
                             "runs diverged (degradations must be "
                             "deterministic, zero tolerance)")

    want_q = committed.get("quant_sweep", {})
    got_q = fresh.get("quant_sweep", {})
    if want_q:
        if not got_q.get("pallas_int8_bitexact", False):
            fails.append("quant_sweep: pallas int8 kernel chain no longer "
                         "bit-exact vs the integer-domain reference")
        for mode, want_row in want_q.get("modes", {}).items():
            got_row = got_q.get("modes", {}).get(mode)
            if got_row is None:
                fails.append(f"quant_sweep[{mode}]: missing from fresh run")
                continue
            band(f"quant_sweep[{mode}].fps", got_row["fps"], want_row["fps"])
            if got_row["snr_db_vs_fp32"] < want_row["snr_db_vs_fp32"] - snr_tol_db:
                fails.append(
                    f"quant_sweep[{mode}].snr_db_vs_fp32: "
                    f"{got_row['snr_db_vs_fp32']:.2f} < committed "
                    f"{want_row['snr_db_vs_fp32']:.2f} - {snr_tol_db:g} dB")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOL", "0.5")),
                    help="fractional regression band (default 0.5: fail only "
                         "below 50%% of the committed number — CI runners "
                         "are slower and noisier than the committing box)")
    ap.add_argument("--shards", default="1,2,4",
                    help="shard counts to sweep (matches the committed JSON)")
    ap.add_argument("--snr-tol-db", type=float,
                    default=float(os.environ.get("BENCH_GATE_SNR_TOL_DB",
                                                 "3.0")),
                    help="allowed drop of the quant sweep's snr_db_vs_fp32 "
                         "below the committed value (dB)")
    ap.add_argument("--committed", default=COMMITTED)
    ap.add_argument("--out",
                    default=os.path.join(REPO, "results", "bench_gate",
                                         "BENCH_table11_throughput.json"),
                    help="fresh JSON (uploaded as a CI artifact)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed JSON from this run instead "
                         "of gating (for refreshing the baseline)")
    ap.add_argument("--audit", action="store_true",
                    help="also run the static-analysis passes and fail on "
                         "any new violation vs ANALYSIS_baseline.json")
    ap.add_argument("--audit-baseline", default=AUDIT_BASELINE)
    ap.add_argument("--traffic-tol", type=float,
                    default=float(os.environ.get("BENCH_GATE_TRAFFIC_TOL",
                                                 "0.10")),
                    help="allowed fractional growth of the STATIC per-entry "
                         "MAC/HBM-byte costs vs the audit baseline (these "
                         "are structural, not measured, so the band is "
                         "tight)")
    args = ap.parse_args()

    with open(args.committed) as f:
        committed = json.load(f)

    from benchmarks.table11_throughput import bench_patch_pipeline
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    fresh = bench_patch_pipeline(
        out_json=args.committed if args.update else args.out,
        shard_counts=tuple(int(s) for s in args.shards.split(",")))
    if args.update:
        print(f"bench-gate: baseline {args.committed} updated")
        return 0

    fails = compare(committed, fresh, args.tol, snr_tol_db=args.snr_tol_db)
    if args.audit:
        audit_out = os.path.join(os.path.dirname(args.out),
                                 "ANALYSIS_report.json")
        audit_fails = run_audit(args.audit_baseline, audit_out,
                                traffic_tol=args.traffic_tol)
        print(f"bench-gate: audit {'FAIL' if audit_fails else 'OK'} "
              f"({len(audit_fails)} new finding(s), report={audit_out})")
        fails.extend(audit_fails)
    head = fresh["frames"]["smooth_all_bilinear"]["after_vectorized"]["fps"]
    print(f"bench-gate: fresh smooth-frame fps={head:.3f} "
          f"(committed {committed['frames']['smooth_all_bilinear']['after_vectorized']['fps']:.3f}), "
          f"tol={args.tol:.0%}, artifact={args.out}")
    if fails:
        print("bench-gate: REGRESSION", file=sys.stderr)
        for f_ in fails:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    print("bench-gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
