"""The static auditor must catch every hazard it claims to (one deliberate
fixture per ESSR code) and must find the shipped tree clean."""
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    RULES,
    Report,
    Violation,
    audit_jaxpr,
    check_recompile,
    lint_source,
    run_ast_lint,
    run_jaxpr_audit,
)

REPO_ROOT = __file__.rsplit("/tests/", 1)[0]


def codes(violations):
    return {v.code for v in violations}


# ---------------------------------------------------------------------------
# jaxpr audit fixtures (ESSR1xx) — each builds a graph with exactly the
# hazard its rule exists to catch
# ---------------------------------------------------------------------------

def test_essr101_host_callback_detected():
    def f(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1.0

    closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    vs = audit_jaxpr(closed, "fixture.callback")
    assert "ESSR101" in codes(vs)


def test_essr102_weak_typed_output_detected():
    # a bare python-scalar graph stays weak-typed end to end
    closed = jax.make_jaxpr(lambda t: t + 1.0)(1.0)
    vs = audit_jaxpr(closed, "fixture.weak")
    assert "ESSR102" in codes(vs)
    assert any("weak" in v.message for v in vs)


def test_essr102_wide_dtype_detected():
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0
        )(jnp.ones((4,), jnp.float32))
    vs = audit_jaxpr(closed, "fixture.f64")
    assert "ESSR102" in codes(vs)
    assert any("float64" in v.message for v in vs)


def test_essr103_nonunique_set_scatter_detected():
    def f(x, i):
        return x.at[i].set(1.0)          # set-scatter, indices not unique

    closed = jax.make_jaxpr(f)(jnp.zeros((8,), jnp.float32),
                               jnp.array([0, 0, 1]))
    vs = audit_jaxpr(closed, "fixture.scatter")
    assert "ESSR103" in codes(vs)


def test_essr103_clean_when_guaranteed():
    def f(x, i):
        return x.at[i].set(1.0, unique_indices=True, mode="drop")

    closed = jax.make_jaxpr(f)(jnp.zeros((8,), jnp.float32),
                               jnp.array([0, 1, 2]))
    assert "ESSR103" not in codes(audit_jaxpr(closed, "fixture.scatter_ok"))


def test_essr104_oversized_constant_detected():
    baked = jnp.zeros((64, 64), jnp.float32)        # 16 KiB closed over
    closed = jax.make_jaxpr(lambda x: x + baked)(jnp.zeros((64, 64)))
    vs = audit_jaxpr(closed, "fixture.const", const_budget=1024)
    assert "ESSR104" in codes(vs)
    assert "ESSR104" not in codes(
        audit_jaxpr(closed, "fixture.const", const_budget=1 << 20))


def test_essr105_static_threshold_recompile_detected():
    # the anti-pattern ExecutionPlan forbids: a threshold as a static arg
    @jax.jit
    def good(x, t):
        return jnp.where(x > t, x, 0.0)

    leaky = jax.jit(lambda x, t: jnp.where(x > t, x, 0.0),
                    static_argnums=(1,))
    x = jnp.arange(4.0)
    assert check_recompile(good, (x, 1.0), (x, 2.0), "fixture.good") == []
    vs = check_recompile(leaky, (x, 1.0), (x, 2.0), "fixture.leaky")
    assert codes(vs) == {"ESSR105"}


# ---------------------------------------------------------------------------
# AST lint fixtures (ESSR2xx) — synthetic modules at rule-scoped relpaths
# ---------------------------------------------------------------------------

def test_essr201_free_entry_point_detected():
    src = textwrap.dedent("""
        def run_inference(params, frame, cfg):
            return frame
    """)
    vs = lint_source(src, "src/repro/core/newmode.py")
    assert "ESSR201" in codes(vs)
    # same function is legal inside the api package, or when private
    assert lint_source(src, "src/repro/api/newmode.py") == []
    assert "ESSR201" not in codes(lint_source(
        src.replace("run_inference", "_run_inference"),
        "src/repro/core/newmode.py"))


def test_essr201_suppression_marker():
    src = textwrap.dedent("""
        # essr: allow[ESSR201] — grandfathered
        def run_inference(params, frame, cfg):
            return frame
    """)
    assert lint_source(src, "src/repro/core/legacy.py") == []


def test_essr206_stream_entry_point_detected():
    src = textwrap.dedent("""
        def serve_many(params, streams, cfg):
            return streams
    """)
    vs = lint_source(src, "src/repro/runtime/newmux.py")
    assert "ESSR206" in codes(vs)
    # an engine-riding free function is the same hazard
    assert "ESSR206" in codes(lint_source(textwrap.dedent("""
        def multiplex(engine, frame_streams):
            return frame_streams
    """), "src/repro/runtime/newmux.py"))
    # legal inside the api package, when private, or as a method
    assert lint_source(src, "src/repro/api/newmux.py") == []
    assert "ESSR206" not in codes(lint_source(
        src.replace("serve_many", "_serve_many"),
        "src/repro/runtime/newmux.py"))
    assert "ESSR206" not in codes(lint_source(textwrap.dedent("""
        class Mux:
            def serve(self, params, streams):
                return streams
    """), "src/repro/runtime/newmux.py"))
    # a stream bundle without params/engine is not a serving entry point
    assert "ESSR206" not in codes(lint_source(textwrap.dedent("""
        def zip_streams(streams):
            return streams
    """), "src/repro/runtime/newmux.py"))


def test_essr202_numpy_in_traced_body_detected():
    src = textwrap.dedent("""
        import numpy as np
        import jax

        @jax.jit
        def fwd(x):
            return np.asarray(x) + 1
    """)
    vs = lint_source(src, "src/repro/core/bad.py")
    assert "ESSR202" in codes(vs)
    # out of scope outside core/ and kernels/
    assert "ESSR202" not in codes(lint_source(src, "src/repro/api/ok.py"))
    # host-side helpers (never traced) are allowed to use numpy
    host = src.replace("@jax.jit\n", "")
    assert "ESSR202" not in codes(lint_source(host, "src/repro/core/ok.py"))


def test_essr203_time_in_traced_body_detected():
    src = textwrap.dedent("""
        import time
        import jax

        def body(x):
            t0 = time.perf_counter()
            return x * t0

        f = jax.jit(body)
    """)
    vs = lint_source(src, "src/repro/kernels/bad.py")
    assert "ESSR203" in codes(vs)


def test_essr204_host_sync_in_traced_body_detected():
    src = textwrap.dedent("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def fwd(x, n):
            y = (x * n).block_until_ready()
            return jax.device_get(y)
    """)
    vs = [v for v in lint_source(src, "src/repro/core/bad.py")
          if v.code == "ESSR204"]
    assert len(vs) == 2                  # both the sync and the transfer


def test_essr205_mutable_frozen_field_detected():
    src = textwrap.dedent("""
        import dataclasses
        from typing import List, Tuple

        @dataclasses.dataclass(frozen=True)
        class Plan:
            caps: List[int]
            name: str

        @dataclasses.dataclass(frozen=True)
        class GoodPlan:
            caps: Tuple[int, ...]

        @dataclasses.dataclass(frozen=True, eq=False)
        class IdentityHashed:
            caps: List[int]

        @dataclasses.dataclass
        class Mutable:
            caps: List[int]
    """)
    vs = [v for v in lint_source(src, "src/repro/api/plans.py")
          if v.code == "ESSR205"]
    assert len(vs) == 1                  # only Plan.caps: frozen + eq
    assert "Plan" in vs[0].message


def test_essr207_swallowed_exception_detected():
    src = textwrap.dedent("""
        def tick(streams):
            out = []
            for s in streams:
                try:
                    out.append(next(s))
                except Exception:
                    pass
            return out
    """)
    vs = lint_source(src, "src/repro/runtime/mux.py")
    assert codes(vs) == {"ESSR207"}
    assert "swallows" in vs[0].message
    # bare except and BaseException are equally broad
    assert "ESSR207" in codes(lint_source(
        src.replace("except Exception:", "except:"),
        "src/repro/runtime/mux.py"))
    assert "ESSR207" in codes(lint_source(
        src.replace("Exception", "BaseException"),
        "src/repro/api/serve.py"))


def test_essr207_recovery_and_scope():
    recorded = textwrap.dedent("""
        def tick(guard, streams):
            for i, s in enumerate(streams):
                try:
                    next(s)
                except Exception as e:
                    guard.record(i, "retire", repr(e))
    """)
    assert lint_source(recorded, "src/repro/runtime/mux.py") == []
    reraised = textwrap.dedent("""
        def tick(s):
            try:
                return next(s)
            except Exception:
                raise RuntimeError("tick failed")
    """)
    assert lint_source(reraised, "src/repro/runtime/mux.py") == []
    warned = textwrap.dedent("""
        import warnings
        def load(path):
            try:
                return open(path).read()
            except Exception as e:
                warnings.warn(f"unreadable: {e!r}")
    """)
    assert lint_source(warned, "src/repro/api/loader.py") == []
    # narrow handlers are out of scope even when silent
    narrow = textwrap.dedent("""
        def tick(s):
            try:
                return next(s)
            except StopIteration:
                pass
    """)
    assert lint_source(narrow, "src/repro/runtime/mux.py") == []
    # the rule only patrols the serving path
    swallowing = textwrap.dedent("""
        def probe(x):
            try:
                return x()
            except Exception:
                pass
    """)
    assert "ESSR207" not in codes(lint_source(
        swallowing, "src/repro/core/util.py"))
    # suppression marker works like every other ESSR2xx rule
    waived = textwrap.dedent("""
        def probe(x):
            try:
                return x()
            except Exception:  # essr: allow[ESSR207]
                pass
    """)
    assert lint_source(waived, "src/repro/runtime/probe.py") == []


def test_traced_names_resolved_through_partial_and_pallas():
    src = textwrap.dedent("""
        import functools
        import numpy as np
        import jax.experimental.pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = np.tanh(x_ref[...])

        def launch(x):
            return pl.pallas_call(
                functools.partial(kernel),
                out_shape=x)(x)
    """)
    vs = lint_source(src, "src/repro/kernels/bad.py")
    assert "ESSR202" in codes(vs)


# ---------------------------------------------------------------------------
# report machinery
# ---------------------------------------------------------------------------

def test_violation_rejects_unknown_code():
    with pytest.raises(ValueError):
        Violation("ESSR999", "x:1", "nope")


def test_report_roundtrip_and_baseline_diff(tmp_path):
    r = Report([Violation("ESSR202", "src/repro/core/a.py:3", "np op"),
                Violation("ESSR103", "entrypoint:fused", "scatter")])
    path = str(tmp_path / "report.json")
    r.to_json(path)
    back = Report.from_json(path)
    assert {v.key for v in back.violations} == {v.key for v in r.violations}
    assert back.counts()["ESSR202"] == 1 and back.counts()["ESSR101"] == 0

    # gate semantics: same sites pass, a new site fails, fixes never fail
    assert r.new_vs(back) == []
    grown = Report(r.violations
                   + [Violation("ESSR101", "entrypoint:new", "cb")])
    assert codes(grown.new_vs(back)) == {"ESSR101"}
    assert Report([]).new_vs(back) == []


def test_rule_catalog_covers_all_passes():
    assert len(RULES) == 16
    assert {c[:5] for c in RULES} == {"ESSR1", "ESSR2", "ESSR3"}
    # the registry is the single source: the rendered docs rows and the
    # committed docs catalog both carry every code
    from repro.analysis import rules_markdown
    md = rules_markdown()
    with open(f"{REPO_ROOT}/docs/api.md") as f:
        docs = f.read()
    for code in RULES:
        assert code in md
        assert code in docs, f"{code} missing from docs/api.md catalog"


# ---------------------------------------------------------------------------
# clean tree: the shipped repo audits to zero violations
# ---------------------------------------------------------------------------

def test_shipped_tree_passes_ast_lint():
    assert run_ast_lint(REPO_ROOT) == []


def test_shipped_entry_points_pass_jaxpr_audit():
    assert run_jaxpr_audit() == []
