"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ops import _flat_sfb
from repro.models.essr import ESSR_X4, essr_forward, init_essr

SHAPES = [(4, 8, 8), (8, 16, 16), (2, 34, 34)]       # (N, H, W) incl. halo size
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,h,w", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("relu", [False, True])
def test_bsconv_kernel(n, h, w, dtype, relu):
    k = jax.random.PRNGKey(0)
    cin, cout = 3, 18
    x = jax.random.uniform(k, (n, h, w, cin), dtype)
    pw = jax.random.normal(k, (cin, cout), dtype) * 0.2
    dw = jax.random.normal(k, (3, 3, cout), dtype) * 0.2
    pb, db = jnp.ones((cout,), dtype) * 0.1, jnp.ones((cout,), dtype) * 0.05
    a = ops.bsconv_fused(x, pw, pb, dw, db, relu=relu, block_patches=2)
    b = ref.bsconv_ref(x, pw, pb, dw, db, relu=relu)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n,h,w", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dsconv_kernel(n, h, w, dtype):
    k = jax.random.PRNGKey(1)
    cin, cout = 12, 48
    x = jax.random.uniform(k, (n, h, w, cin), dtype)
    dw = jax.random.normal(k, (3, 3, cin), dtype) * 0.2
    pw = jax.random.normal(k, (cin, cout), dtype) * 0.2
    db, pb = jnp.zeros((cin,), dtype), jnp.zeros((cout,), dtype)
    a = ops.dsconv_fused(x, dw, db, pw, pb, block_patches=2)
    b = ref.dsconv_ref(x, dw, db, pw, pb)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n,h,w", SHAPES)
def test_sfb_kernel(n, h, w):
    k = jax.random.PRNGKey(2)
    p = init_essr(k, ESSR_X4)
    x = jax.random.uniform(k, (n, h, w, 54))
    flat = _flat_sfb(p["sfbs"][0])
    a = ops.sfb_fused(x, flat, block_patches=2)
    b = ref.sfb_ref(x, flat)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,h,w", SHAPES)
def test_edge_kernel(n, h, w):
    k = jax.random.PRNGKey(3)
    x = jax.random.uniform(k, (n, h, w, 3))
    a = ops.edge_score_fused(x, block_patches=2)
    b = ref.edge_score_ref(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("width", [27, 54])
def test_whole_essr_through_kernels(width):
    """The GLNPU-scheduled kernel pipeline == the pure-JAX model."""
    k = jax.random.PRNGKey(4)
    p = init_essr(k, ESSR_X4)
    x = jax.random.uniform(k, (4, 16, 16, 3))
    a = ops.essr_forward_kernels(p, x, ESSR_X4, width=width)
    b = essr_forward(p, x, ESSR_X4, width=width)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [1, 5, 7])
def test_prime_batch_pads_instead_of_shrinking(n):
    """Batches not divisible by block_patches are padded and re-sliced —
    no assert trap, no silent block_patches walk-down to 1."""
    k = jax.random.PRNGKey(5)
    cin, cout = 3, 18
    x = jax.random.uniform(k, (n, 8, 8, cin))
    pw = jax.random.normal(k, (cin, cout)) * 0.2
    dw = jax.random.normal(k, (3, 3, cout)) * 0.2
    pb, db = jnp.zeros((cout,)), jnp.zeros((cout,))
    a = ops.bsconv_fused(x, pw, pb, dw, db, block_patches=4)
    np.testing.assert_allclose(np.asarray(a),
                               np.asarray(ref.bsconv_ref(x, pw, pb, dw, db)),
                               rtol=1e-4, atol=1e-5)
    s = ops.edge_score_fused(jax.random.uniform(k, (n, 8, 8, 3)),
                             block_patches=4)
    assert s.shape == (n,)


@pytest.mark.parametrize("n", [5, 7])
def test_whole_essr_kernels_prime_batch(n):
    k = jax.random.PRNGKey(6)
    p = init_essr(k, ESSR_X4)
    x = jax.random.uniform(k, (n, 8, 8, 3))
    a = ops.essr_forward_kernels(p, x, ESSR_X4, width=54, block_patches=4)
    b = essr_forward(p, x, ESSR_X4, width=54)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-3)


def test_c27_doubles_block_patches():
    """The 'configurable group of layer mapping': C27 moves 2x the patches
    per grid step at the same VMEM budget."""
    assert ops.default_block_patches(27) == 2 * ops.default_block_patches(54)
