"""Multi-stream continuous batching (`plan.streams` / SREngine.serve_streams).

Contract under test (docs/api.md "Multi-stream serving"):

  * N interleaved tenant streams through ONE fused dispatch per admission
    tick are bit-equal (ref backend) to serving each stream on its own solo
    engine — shared capacity pool, independent scatter-back;
  * round-robin admission under equal shares is fair: one frame per live
    tenant per tick, results in stream-id order within a tick;
  * under aggregate overload, per-stream C54 shares degrade in
    ``stream_shares`` proportion, raster-deterministically, never dropping
    frames;
  * per-stream switcher isolation: one tenant's overload never demotes
    another tenant's thresholds (share-weighted cost attribution);
  * ``plan.streams=1`` serve_streams is byte-identical to ``stream()``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ExecutionPlan, SREngine
from repro.core import subnet_policy as sp
from repro.core.adaptive import (StreamSwitcherBank, SwitchingConfig,
                                 per_stream_config)
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig, init_essr

CFG = ESSRConfig(scale=2)
HW = 64                                     # 64x64 LR -> 9 patches


def _stable_switching():
    return SwitchingConfig(frame_high=10**9, frame_low=0)


def _texture_frame(seed: int):
    """Degraded random texture: routes (almost) entirely C54."""
    return degrade(jnp.asarray(random_image(seed, 2 * HW, 2 * HW)), 2)


def _smooth_frame():
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, HW), jnp.linspace(0, 1, HW),
                          indexing="ij")
    return jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)


@pytest.fixture(scope="module")
def params():
    return init_essr(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def tenant_streams():
    return [[_texture_frame(s * 100 + i) for i in range(3)]
            for s in range(4)]


# -- bit-equality vs solo engines -------------------------------------------

def test_four_streams_bit_equal_to_solo(params, tenant_streams):
    # capacity pinned on both sides: with auto profiles the shared pool
    # lends a tenant the others' slack (statistical multiplexing — a mux
    # stream can spill LESS than its solo engine), which is the feature,
    # not a conformance target. Adequate pinned capacity removes spills
    # from both paths, so routing and images must match exactly.
    plan = ExecutionPlan(streams=4, dispatch="fused", capacity=(0, 9, 9))
    eng = SREngine(params, CFG, plan=plan, switching=_stable_switching())
    mux = list(eng.serve_streams(tenant_streams))
    assert len(mux) == 12
    for s in range(4):
        solo = SREngine(params, CFG,
                        plan=ExecutionPlan(dispatch="fused",
                                           capacity=(0, 9, 9)),
                        switching=_stable_switching())
        solo_results = list(solo.stream(tenant_streams[s]))
        mine = [r for r in mux if r.stream_id == s]
        assert len(mine) == len(solo_results) == 3
        for rm, rs in zip(mine, solo_results):
            assert bool(jnp.all(rm.image == rs.image))      # ref: bit-equal
            assert np.array_equal(np.asarray(rm.ids), np.asarray(rs.ids))
            assert rm.counts == rs.counts
            assert rm.dispatch == "fused"


def test_streams_quant_allclose_to_solo(params, tenant_streams):
    """The shared executable also shares the PTQ pack: quantized multi-stream
    serving matches the quantized solo path."""
    plan = ExecutionPlan(streams=2, dispatch="fused", quant="fxp10",
                         capacity=(0, 9, 9))
    eng = SREngine(params, CFG, plan=plan, switching=_stable_switching())
    mux = list(eng.serve_streams([tenant_streams[0][:2],
                                  tenant_streams[1][:2]]))
    assert eng.qpack is not None
    for s in range(2):
        solo = SREngine(params, CFG,
                        plan=ExecutionPlan(dispatch="fused", quant="fxp10",
                                           capacity=(0, 9, 9)),
                        switching=_stable_switching())
        solo_results = list(solo.stream(tenant_streams[s][:2]))
        mine = [r for r in mux if r.stream_id == s]
        for rm, rs in zip(mine, solo_results):
            assert bool(jnp.all(rm.image == rs.image))
            assert rm.backend == rs.backend == "ref-fxp10"


# -- admission model ---------------------------------------------------------

def test_round_robin_admission_order_and_fairness(params, tenant_streams):
    plan = ExecutionPlan(streams=4, dispatch="fused")
    eng = SREngine(params, CFG, plan=plan, switching=_stable_switching())
    mux = list(eng.serve_streams(tenant_streams))
    # one frame per tenant per tick, stream-id order within a tick
    assert [r.stream_id for r in mux] == [0, 1, 2, 3] * 3
    summ = eng.summary()
    assert {sid: rec["frames"] for sid, rec in summ["streams"].items()} == \
        {0: 3, 1: 3, 2: 3, 3: 3}


def test_ragged_streams_shrink_the_tick(params, tenant_streams):
    """An exhausted tenant leaves the admission tick; the rest keep serving
    (no dropped frames, no padding tenants)."""
    plan = ExecutionPlan(streams=3, dispatch="fused")
    eng = SREngine(params, CFG, plan=plan, switching=_stable_switching())
    streams = [tenant_streams[0][:3], tenant_streams[1][:1],
               tenant_streams[2][:2]]
    got = [r.stream_id for r in eng.serve_streams(streams)]
    assert got == [0, 1, 2, 0, 2, 0]
    assert eng.summary()["frames"] == 6


def test_mixed_shapes_in_one_tick_rejected(params):
    plan = ExecutionPlan(streams=2, dispatch="fused")
    eng = SREngine(params, CFG, plan=plan, switching=_stable_switching())
    bad = [[_texture_frame(0)], [_texture_frame(1)[:32]]]
    with pytest.raises(ValueError, match=r"one admission tick packs one "
                                         r"geometry"):
        list(eng.serve_streams(bad))


def test_single_iterable_apis_refuse_multi_stream_plans(params):
    plan = ExecutionPlan(streams=2, dispatch="fused")
    eng = SREngine(params, CFG, plan=plan)
    with pytest.raises(ValueError, match=r"serve_streams"):
        eng.serve(_texture_frame(0))
    with pytest.raises(ValueError, match=r"serve_streams"):
        list(eng.stream([_texture_frame(0)]))
    with pytest.raises(ValueError, match=r"serve_streams got 1 streams"):
        list(eng.serve_streams([[_texture_frame(0)]]))


def test_streams_one_serve_streams_is_stream(params, tenant_streams):
    """plan.streams=1 keeps today's single-tenant path byte-for-byte."""
    a = SREngine(params, CFG, plan=ExecutionPlan(dispatch="fused"),
                 switching=_stable_switching())
    b = SREngine(params, CFG, plan=ExecutionPlan(dispatch="fused"),
                 switching=_stable_switching())
    ra = list(a.serve_streams([tenant_streams[0]]))
    rb = list(b.stream(tenant_streams[0]))
    for x, y in zip(ra, rb):
        assert bool(jnp.all(x.image == y.image))
        assert x.stream_id is None and y.stream_id is None
        assert x.counts == y.counts and x.thresholds == y.thresholds
    assert a.summary().keys() == b.summary().keys()   # no streams section


# -- QoS: shares, overload degradation, isolation ----------------------------

def test_share_weighted_c54_degradation_is_deterministic(params,
                                                         tenant_streams):
    """Aggregate overload: each tenant's C54 slots degrade to its share of
    the budget (3:1 here), raster-deterministically, frames never dropped."""
    overload = SwitchingConfig(c54_per_sec_budget=8, fps=1,
                               frame_high=10**9, frame_low=0)
    plan = ExecutionPlan(streams=2, dispatch="fused",
                         stream_shares=(3.0, 1.0))
    runs = []
    for _ in range(2):
        eng = SREngine(params, CFG, plan=plan, switching=overload)
        res = list(eng.serve_streams([tenant_streams[0][:2],
                                      tenant_streams[1][:2]]))
        runs.append([(r.stream_id, r.counts, r.spill_counts) for r in res])
        # every admitted frame came back
        assert [r.stream_id for r in res] == [0, 1, 0, 1]
        for r in res:
            # shares 3:1 over budget 8 -> quotas (6, 2): C54 capped per
            # stream at its share; demoted patches run C27, not dropped
            quota = 6 if r.stream_id == 0 else 2
            native = r.counts[sp.C54] + r.spill_counts[sp.C54]  # wanted C54
            assert r.counts[sp.C54] == min(native, quota)
            assert sum(r.counts) == 9                 # nothing dropped
        # the privileged tenant keeps more of its C54 demand every tick
        assert all(a.counts[sp.C54] >= b.counts[sp.C54]
                   for a, b in zip(res[0::2], res[1::2]))
        assert any(r.spill_counts[sp.C54] > 0 for r in res)  # overload real
    assert runs[0] == runs[1]                         # deterministic


def test_per_stream_switcher_isolation(params, tenant_streams):
    """Tick deadlines are shared, but attribution is share-weighted: the
    heavy tenant is demoted, the light tenant's thresholds never move."""
    plan = ExecutionPlan(streams=2, dispatch="fused", t1=8.0, t2=40.0)
    eng = SREngine(params, CFG, plan=plan, switching=_stable_switching(),
                   deadline_s=1e-9)                   # every tick misses
    heavy = tenant_streams[0][:3]
    light = [_smooth_frame()] * 3
    res = list(eng.serve_streams([heavy, light]))
    h = [r for r in res if r.stream_id == 0]
    l = [r for r in res if r.stream_id == 1]
    assert all(r.deadline_missed for r in h)          # attributed heavy
    assert not any(r.deadline_missed for r in l)      # never blamed
    assert h[-1].thresholds > (8.0, 40.0)             # demoted
    assert l[-1].thresholds == (8.0, 40.0)            # untouched
    summ = eng.summary()
    assert summ["streams"][0]["deadline_misses"] == 3
    assert summ["streams"][1]["deadline_misses"] == 0


def test_stream_bank_attribution_unit():
    bank = StreamSwitcherBank(SwitchingConfig(t1=8, t2=40), streams=3,
                              shares=(1.0, 1.0, 2.0))
    assert bank.shares == (0.25, 0.25, 0.5)
    base = bank.thresholds
    # no miss: nobody demoted
    assert bank.note_tick(False, [100, 100, 200]) == (False, False, False)
    assert bank.thresholds == base
    # miss with cost exactly in share proportion: every live stream demotes
    assert bank.note_tick(True, [100, 100, 200]) == (True, True, True)
    # miss with stream 0 over its entitlement: only stream 0 demoted
    t_before = bank.thresholds
    assert bank.note_tick(True, [400, 100, 200]) == (True, False, False)
    after = bank.thresholds
    assert after[0] > t_before[0]
    assert after[1] == t_before[1] and after[2] == t_before[2]
    # live-subset form: costs map onto the named streams only
    assert bank.note_tick(True, [100, 500], streams=(1, 2)) == \
        (False, False, True)


def test_per_stream_config_split():
    cfg = SwitchingConfig(c54_per_sec_budget=1000, frame_high=100,
                          frame_low=0, fps=10)
    half = per_stream_config(cfg, 0.5)
    assert (half.c54_per_sec_budget, half.frame_high) == (500, 50)
    assert half.frame_low == 0                        # 0 stays 0
    tiny = per_stream_config(cfg, 1e-6)
    assert tiny.c54_per_sec_budget == 1               # floored, still adapts
    assert per_stream_config(cfg, 1.0) is cfg
    with pytest.raises(ValueError):
        per_stream_config(cfg, 0.0)
    bank = StreamSwitcherBank(cfg, streams=2, shares=(1.0, 1.0))
    assert bank.tick_quotas() == (50, 50)             # budget/share/fps


# -- async composition --------------------------------------------------------

def test_inflight_ticks_match_synchronous(params, tenant_streams):
    plan_sync = ExecutionPlan(streams=4, dispatch="fused")
    plan_async = ExecutionPlan(streams=4, dispatch="fused", inflight=3)
    a = SREngine(params, CFG, plan=plan_sync, switching=_stable_switching())
    b = SREngine(params, CFG, plan=plan_async, switching=_stable_switching())
    ra = list(a.serve_streams(tenant_streams))
    rb = list(b.serve_streams(tenant_streams))
    assert [r.stream_id for r in ra] == [r.stream_id for r in rb]
    for x, y in zip(ra, rb):
        assert bool(jnp.all(x.image == y.image))
        assert x.counts == y.counts
