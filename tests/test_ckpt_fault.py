"""Checkpointing + fault-tolerant supervision + elastic restore."""
import os

import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (InjectedFailure, StragglerMonitor,
                                           SupervisorConfig, TrainSupervisor)


def _state(x=0.0):
    return {"w": jnp.asarray([x, x + 1.0]), "step": jnp.asarray(0, jnp.int32),
            "nested": {"m": jnp.ones((2, 3)) * x}}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    s = _state(3.0)
    cm.save(10, s, meta={"foo": "bar"})
    restored, meta = cm.restore(_state())
    assert meta["step"] == 10 and meta["foo"] == "bar"
    np.testing.assert_allclose(np.asarray(restored["w"]), [3.0, 4.0])
    np.testing.assert_allclose(np.asarray(restored["nested"]["m"]), 3.0)


def test_keep_k_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        cm.save(step, _state(step))
    assert cm.all_steps() == [3, 4]


def test_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(5, _state(5.0), blocking=False)
    cm.wait()
    assert cm.latest_step() == 5


def test_atomicity_no_partial_dirs(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, _state(1.0))
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)


def test_supervisor_recovers_and_replays_deterministically(tmp_path):
    """Crash at step 47 -> restore from 40 -> final state bit-identical to a
    crash-free run (deterministic replay)."""

    def step_fn(state, batch):
        return {"w": state["w"] + batch}, {}

    def make_batch(step):
        return jnp.asarray(float(step))

    def run(with_failure):
        cm = CheckpointManager(str(tmp_path / ("a" if with_failure else "b")), keep=3)
        sup = TrainSupervisor(step_fn, make_batch, cm,
                              SupervisorConfig(ckpt_every=10, async_ckpt=False))
        fired = {"done": False}

        def hook(step):
            if with_failure and step == 47 and not fired["done"]:
                fired["done"] = True
                raise InjectedFailure("simulated node loss")

        return sup.run({"w": jnp.zeros(())}, 0, 60, failure_hook=hook), sup

    s_fail, sup = run(True)
    s_ok, _ = run(False)
    assert sup.restarts == 1
    np.testing.assert_allclose(np.asarray(s_fail["w"]), np.asarray(s_ok["w"]))


def test_supervisor_elastic_reshard_hook(tmp_path):
    calls = []

    def step_fn(state, batch):
        return state, {}

    cm = CheckpointManager(str(tmp_path), keep=2)
    sup = TrainSupervisor(step_fn, lambda s: None, cm,
                          SupervisorConfig(ckpt_every=5, async_ckpt=False))
    fired = {"done": False}

    def hook(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise InjectedFailure("host lost")

    sup.run({"w": jnp.zeros(())}, 0, 10, failure_hook=hook,
            reshard=lambda s: (calls.append(1), s)[1])
    assert calls == [1]                       # reshard invoked on recovery


def test_straggler_monitor():
    m = StragglerMonitor(4, k=1.5)
    for shard, dt in [(0, 1.0), (1, 1.0), (2, 1.0), (3, 5.0)]:
        for _ in range(3):
            m.record(shard, dt)
    assert m.stragglers().tolist() == [3]
