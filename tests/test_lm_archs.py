"""Per-architecture smoke tests (assignment f): reduced config of each of the
10 archs runs one forward/train step on CPU, asserting shapes + no NaNs;
plus decode<->prefill consistency on representatives of each family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import param_count_estimate
from repro.configs.registry import ARCH_NAMES, get_config
from repro.models.lm import encdec as E
from repro.models.lm import transformer as T

KEY = jax.random.PRNGKey(0)
B, S, ML = 2, 16, 24


def _batch(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extras = {}
    if cfg.is_encoder_decoder:
        extras["src"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    if cfg.frontend == "vision":
        extras["pe"] = jax.random.normal(KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return toks, extras


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_loss(name):
    cfg = get_config(name, smoke=True)
    toks, extras = _batch(cfg)
    if cfg.is_encoder_decoder:
        p = E.init_encdec(KEY, cfg)
        loss = E.encdec_loss(p, cfg, extras["src"], toks, toks)
    else:
        p = T.init_lm(KEY, cfg)
        loss = T.lm_loss(p, cfg, toks, toks, prefix_embeds=extras.get("pe"))
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_train_step_reduces_loss(name):
    """One real optimizer step must run and produce finite, changed params."""
    from repro.launch import steps as ST
    from repro.train import optimizer as O
    cfg = get_config(name, smoke=True)
    opt = O.chain_clip(O.adam(1e-2), 1.0)    # no warmup: bf16-visible updates
    toks, extras = _batch(cfg)
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = extras["src"].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["embeds"] = extras["pe"].astype(jnp.bfloat16)
    p = (E.init_encdec if cfg.is_encoder_decoder else T.init_lm)(KEY, cfg)
    state = {"params": p, "opt": opt.init(p)}
    step = jax.jit(ST.make_train_step(cfg, opt, remat=False))
    new_state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    before = jax.tree_util.tree_leaves(p)[0]
    after = jax.tree_util.tree_leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before, np.float32), np.asarray(after, np.float32))


@pytest.mark.parametrize("name", ["granite-8b", "deepseek-v3-671b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "seamless-m4t-medium", "qwen2-0.5b"])
def test_decode_matches_prefill_next_token(name):
    """Prefill S tokens, decode token S; compare against prefilling S+1 —
    the KV-cache path must agree with the full forward (per family)."""
    cfg = get_config(name, smoke=True)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        src = jax.random.normal(KEY, (B, 8, cfg.d_model))
        p = E.init_encdec(KEY, cfg)
        _, caches = E.encdec_prefill(p, cfg, src, toks[:, :S], ML)
        logits_dec, _ = E.encdec_decode_step(p, cfg, toks[:, S:S + 1], caches,
                                             jnp.asarray(S))
        logits_ref, _ = E.encdec_prefill(p, cfg, src, toks, ML)
    else:
        p = T.init_lm(KEY, cfg)
        _, caches = T.lm_prefill(p, cfg, toks[:, :S], ML)
        logits_dec, _ = T.lm_decode_step(p, cfg, toks[:, S:S + 1], caches,
                                         jnp.asarray(S))
        logits_ref, _ = T.lm_prefill(p, cfg, toks, ML)
    # bf16 params + different contraction order (e.g. MLA absorbed decode)
    # => small absolute drift; ranking must agree up to near-ties.
    ld, lr = np.asarray(logits_dec), np.asarray(logits_ref)
    np.testing.assert_allclose(ld, lr, rtol=8e-2, atol=8e-2)
    ref_max = lr.max(-1)
    chosen = np.take_along_axis(lr, ld.argmax(-1)[..., None], -1)[..., 0]
    assert (ref_max - chosen <= 0.1).all(), "decode picked a non-near-tie token"


def test_param_estimates_match_full_configs():
    """Closed-form estimates used in §Roofline MODEL_FLOPS hit the advertised
    model sizes (within naming tolerance)."""
    expect = {"grok-1-314b": (314e9, 0.15), "deepseek-v3-671b": (671e9, 0.15),
              "granite-8b": (8e9, 0.15), "minitron-8b": (8e9, 0.20),
              "granite-3-2b": (2.5e9, 0.25), "qwen2-0.5b": (0.5e9, 0.25),
              "falcon-mamba-7b": (7e9, 0.25), "zamba2-1.2b": (1.2e9, 0.35)}
    for name, (target, tol) in expect.items():
        n = param_count_estimate(get_config(name))
        assert abs(n - target) / target < tol, f"{name}: {n/1e9:.2f}B vs {target/1e9}B"


def test_smoke_param_counts_small():
    for name in ARCH_NAMES:
        cfg = get_config(name, smoke=True)
        n = param_count_estimate(cfg)
        assert n < 5e6, f"{name} smoke config too big: {n}"
