"""Group-fusion megakernel conformance (`kernels/megakernel.py` +
`ExecutionPlan.fusion`).

Contract under test (docs/api.md "Group fusion"):

  * one Pallas launch per subnet runs the whole layer group (BSConv ->
    n_sfb x SFB -> DSConv, residuals included) with features resident in
    VMEM scratch — fp32 allclose to BOTH the per-op kernel stack and the
    pure-jnp reference, for every width and ragged batch size;
  * the quantized megakernel is BIT-EXACT vs the integer-domain reference
    (same `_q*_math` lattice, codes never leave VMEM between groups);
  * `fusion="group"` threads through the engine unchanged: same routing
    (golden pins), same images (fp32 allclose / quant bit-exact vs
    `fusion="layer"`) across backends, shard counts and tenant streams;
  * empty routing buckets and padded batches are handled at every entry
    (the PR's bugfix satellites: no div-by-zero grids, no pad-row leakage
    through the integer requantize chain);
  * the compiled-executable caches are bounded (`core/caching.BoundedCache`),
    sized from ``plan.stats_window``, and surfaced via
    ``FrameResult.summary()`` / ``SREngine.summary()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecutionPlan, SREngine
from repro.core.caching import BoundedCache, bounded_cache
from repro.data.synthetic import degrade, random_image
from repro.kernels.dispatch import pad_batch, resolve_block
from repro.kernels.megakernel import (VMEM_BYTES, autotune_block_patches,
                                      autotune_report,
                                      essr_forward_megakernel,
                                      essr_forward_qmegakernel)
from repro.kernels.ops import essr_forward_kernels
from repro.kernels.qconv import essr_forward_qkernels, essr_forward_qref
from repro.models.essr import ESSRConfig, essr_forward, init_essr
from repro.quant.pams import build_quant_pack

CFG = ESSRConfig(scale=2)
TOY = ESSRConfig(scale=2, n_sfb=2, channels=8)

#: Same fixed mixed-content frame + routing pins as
#: tests/test_fused_dispatch.py / test_quant_conformance.py.
GOLDEN_COUNTS = (10, 2, 13)


def _golden_frame(hw: int = 128, seed: int = 1234):
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw), jnp.linspace(0, 1, hw),
                          indexing="ij")
    smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
    tex = degrade(jnp.asarray(random_image(seed, 2 * hw, 2 * hw)), 2)
    return jnp.where((yy < 0.5)[..., None], smooth, tex)


def _toy(n: int, seed: int = 0):
    params = init_essr(jax.random.PRNGKey(0), TOY)
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n, 32, 32, 3))
    return params, x


# ---------------------------------------------------------------------------
# kernel level: megakernel vs per-op stack vs jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize("n", [1, 3, 7])
def test_megakernel_matches_reference(width, n):
    params, x = _toy(n)
    got = essr_forward_megakernel(params, x, TOY, width=width, interpret=True)
    want = essr_forward(params, x, TOY, width=width)
    assert got.shape == (n, 64, 64, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [1, 5, 9])
def test_megakernel_matches_perop_stack(n):
    """Group fusion rearranges WHERE features live (VMEM scratch vs HBM
    round-trips), never the math: same results as the layer-fused stack."""
    params, x = _toy(n, seed=3)
    got = essr_forward_megakernel(params, x, TOY, interpret=True)
    want = essr_forward_kernels(params, x, TOY, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["int8", "fxp10"])
@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize("n", [1, 3, 7])
def test_qmegakernel_bitexact_vs_integer_reference(mode, width, n):
    """The quantized megakernel shares the `_q*_math` helpers with the
    reference chain, so its integer arithmetic must be bit-exact — any
    drift means the fused chain left the PAMS lattice."""
    params, x = _toy(n, seed=1)
    pack = build_quant_pack(params, TOY, mode, x)
    got = essr_forward_qmegakernel(params, x, TOY, width=width, pack=pack,
                                   interpret=True)
    want = essr_forward_qref(params, x, TOY, width, pack=pack)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["int8", "fxp10"])
def test_qmegakernel_bitexact_vs_perop_chain(mode):
    params, x = _toy(6, seed=2)
    pack = build_quant_pack(params, TOY, mode, x)
    got = essr_forward_qmegakernel(params, x, TOY, pack=pack, interpret=True)
    want = essr_forward_qkernels(params, x, TOY, pack=pack, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_megakernel_grad_and_jvp():
    """`jax.custom_jvp` keeps the fp32 megakernel trainable in BOTH autodiff
    modes: reverse (grad) and forward (jvp) defer to the pure-JAX twin."""
    params, x = _toy(2)

    def loss(p, v):
        return jnp.sum(essr_forward_megakernel(p, v, TOY, interpret=True) ** 2)

    def loss_ref(p, v):
        return jnp.sum(essr_forward(p, v, TOY) ** 2)

    g = jax.grad(loss)(params, x)
    g_ref = jax.grad(loss_ref)(params, x)
    for got, want in zip(jax.tree_util.tree_leaves(g),
                         jax.tree_util.tree_leaves(g_ref)):
        scale = max(float(jnp.max(jnp.abs(want))), 1e-6)
        np.testing.assert_allclose(np.asarray(got) / scale,
                                   np.asarray(want) / scale, atol=1e-3)
    # the reference model is custom_vjp (reverse-only), so the forward-mode
    # oracle is its reverse-mode directional derivative <grad, dx>
    dx = jnp.ones_like(x) * 0.1
    _, t = jax.jvp(lambda v: loss(params, v), (x,), (dx,))
    t_ref = jnp.sum(jax.grad(loss_ref, argnums=1)(params, x) * dx)
    np.testing.assert_allclose(float(t), float(t_ref), rtol=1e-3)


# ---------------------------------------------------------------------------
# bugfix satellites: empty buckets, padded batches, resolve_block
# ---------------------------------------------------------------------------

def test_empty_bucket_every_fused_entry():
    """An emptied routing bucket (N=0) must return an empty output, not
    divide by zero sizing the grid (the seed's `min(block, 0)` bug)."""
    params, x = _toy(4)
    empty = x[:0]
    pack = build_quant_pack(params, TOY, "int8", x)
    for out in (
        essr_forward_kernels(params, empty, TOY, interpret=True),
        essr_forward_megakernel(params, empty, TOY, interpret=True),
        essr_forward_qkernels(params, empty, TOY, pack=pack, interpret=True),
        essr_forward_qmegakernel(params, empty, TOY, pack=pack,
                                 interpret=True),
    ):
        assert out.shape == (0, 64, 64, 3)


@pytest.mark.parametrize("mode", ["int8", "fxp10"])
@pytest.mark.parametrize("n", [3, 5, 7])
def test_padded_batch_no_pad_row_leakage(mode, n):
    """Prime batch sizes force zero-row padding inside the integer chain;
    those pad rows must not flow through accumulate+requantize into the
    real rows (each launch must equal the unpadded reference bit-for-bit,
    AND equal itself computed one sample at a time)."""
    params, x = _toy(n, seed=4)
    pack = build_quant_pack(params, TOY, mode, x)
    batched = essr_forward_qkernels(params, x, TOY, pack=pack, interpret=True)
    ref = essr_forward_qref(params, x, TOY, TOY.channels, pack=pack)
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(ref))
    solo = jnp.concatenate([
        essr_forward_qkernels(params, x[i:i + 1], TOY, pack=pack,
                              interpret=True) for i in range(n)])
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(solo))


def test_resolve_block_and_pad_batch():
    assert resolve_block(0, 8) == 0                  # empty bucket: no grid
    assert resolve_block(3, 8) == 3                  # never exceeds n
    assert resolve_block(9, 4) == 3                  # minimal-pad block
    assert resolve_block(16, 4) == 4                 # exact fit unchanged
    with pytest.raises(ValueError):
        pad_batch(jnp.zeros((4, 2, 2, 3)), 0)        # degenerate block
    padded, n = pad_batch(jnp.zeros((5, 2, 2, 3)), 4)
    assert padded.shape[0] == 8 and n == 5


# ---------------------------------------------------------------------------
# roofline-driven block autotuner
# ---------------------------------------------------------------------------

def test_autotune_block_bounds():
    rep = autotune_report(54, 32, 4)
    bp = rep["block_patches"]
    assert bp == autotune_block_patches(54, 32, 4)
    # VMEM ceiling: weights + double-buffered feature block fit the budget
    assert rep["weight_bytes"] + 2 * bp * rep["per_patch_bytes"] \
        <= VMEM_BYTES or bp == rep["mxu_row_floor"]
    # MXU floor: the flattened (block*p*p, C) operand keeps the rows full
    assert bp * 32 * 32 >= 256
    assert rep["bound"] in ("memory", "compute")
    # narrower subnets fit more patches per block at the same budget
    assert autotune_block_patches(27, 32, 4) >= autotune_block_patches(54, 32, 4)


# ---------------------------------------------------------------------------
# engine level: fusion="group" vs fusion="layer" across the serving matrix
# ---------------------------------------------------------------------------

def _pair(backend, quant, **plan_kw):
    mk = lambda fusion: SREngine.from_config(
        CFG, seed=1, backend=backend,
        plan=ExecutionPlan(quant=quant, fusion=fusion, **plan_kw))
    return mk("layer"), mk("group")


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("quant", [None, "fxp10", "int8"])
def test_engine_group_matches_layer(backend, quant):
    frame = _golden_frame()
    layer, group = _pair(backend, quant)
    rl, rg = layer.upscale(frame), group.upscale(frame)
    np.testing.assert_array_equal(np.asarray(rl.ids), np.asarray(rg.ids))
    if quant is None:
        np.testing.assert_allclose(np.asarray(rl.image), np.asarray(rg.image),
                                   rtol=1e-5, atol=1e-5)
    else:
        # integer serving: both fusion modes walk the same PAMS lattice
        np.testing.assert_array_equal(np.asarray(rl.image),
                                      np.asarray(rg.image))


@pytest.mark.parametrize("shards", [1, 4])
def test_engine_group_matches_layer_sharded(shards):
    """shards > device_count exercises the documented transparent-degrade
    path; with forced host devices it exercises the real patch mesh —
    group fusion must match layer fusion either way."""
    frame = _golden_frame()
    layer, group = _pair("pallas", None, shards=shards)
    rl, rg = layer.serve(frame), group.serve(frame)
    assert rl.counts == rg.counts
    np.testing.assert_allclose(np.asarray(rl.image), np.asarray(rg.image),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("streams", [1, 4])
def test_engine_group_matches_layer_streams(streams):
    frame = _golden_frame()
    frames = [[jnp.roll(frame, 11 * (s + 1), axis=1)] for s in range(streams)]
    outs = {}
    for fusion in ("layer", "group"):
        eng = SREngine.from_config(
            CFG, seed=1, backend="pallas",
            plan=ExecutionPlan(dispatch="fused", streams=streams,
                               quant="int8", fusion=fusion))
        rs = list(eng.serve_streams([list(f) for f in frames]))
        # a single tenant serves on the plain streaming path (stream_id None)
        sids = [0 if r.stream_id is None else r.stream_id for r in rs]
        assert sorted(sids) == list(range(streams))
        outs[fusion] = dict(zip(sids, (np.asarray(r.image) for r in rs)))
    for sid in range(streams):
        np.testing.assert_array_equal(outs["layer"][sid], outs["group"][sid])


def test_golden_routing_pinned_under_group_fusion():
    """Fusion moves execution INSIDE the subnet forward; the edge unit and
    Algorithm-1 thresholds never see it — the golden pins must not move."""
    frame = _golden_frame()
    eng = SREngine.from_config(CFG, seed=1, backend="pallas",
                               plan=ExecutionPlan(fusion="group"))
    r = eng.upscale(frame)
    assert r.counts == GOLDEN_COUNTS, (
        f"group fusion moved routing: {r.counts} != {GOLDEN_COUNTS}")


def test_plan_rejects_unknown_fusion():
    with pytest.raises(ValueError):
        ExecutionPlan(fusion="super")


# ---------------------------------------------------------------------------
# bounded compiled-executable caches
# ---------------------------------------------------------------------------

def test_bounded_cache_lru_semantics():
    calls = []

    @bounded_cache(maxsize=2)
    def f(x):
        calls.append(x)
        return x * 10

    assert f(1) == 10 and f(2) == 20 and f(1) == 10
    assert calls == [1, 2]                       # second f(1) was a hit
    f(3)                                         # evicts 2 (LRU; 1 was touched)
    f(2)
    assert calls == [1, 2, 3, 2]
    info = f.cache_info()
    assert (info.hits, info.maxsize, info.currsize) == (1, 2, 2)
    occ = f.occupancy()
    assert occ["evictions"] == 2 and occ["size"] == 2
    f.cache_clear()
    assert f.occupancy()["size"] == 0


def test_bounded_cache_resize_evicts():
    c = BoundedCache(lambda x: x, maxsize=4)
    for i in range(4):
        c(i)
    c.resize(2)
    occ = c.occupancy()
    assert occ["size"] == 2 and occ["maxsize"] == 2 and occ["evictions"] == 2
    assert c(3) == 3 and c.occupancy()["hits"] == 1    # newest survived
    with pytest.raises(ValueError):
        c.resize(0)
    with pytest.raises(ValueError):
        BoundedCache(lambda: None, maxsize=0)


def test_engine_sizes_caches_from_stats_window_and_surfaces_occupancy():
    from repro.core.pipeline import (compiled_cache_occupancy,
                                     configure_compiled_caches)
    frame = _golden_frame()
    try:
        eng = SREngine.from_config(CFG, seed=1,
                                   plan=ExecutionPlan(stats_window=640))
        occ = compiled_cache_occupancy()
        # max(16, min(512, 640 // 32)) == 20
        assert all(v["maxsize"] == 20 for v in occ.values())
        r = eng.upscale(frame)
        s = r.summary()
        assert {"fused_frame_fn", "fused_stream_frame_fn",
                "get_geometry"} <= set(s["compiled_caches"])
        assert s["compiled_caches"]["get_geometry"]["size"] >= 1
        assert s["mode"] == "edge_select" and s["n_patches"] == r.n_patches
        eng.serve(frame)
        assert "compiled_caches" in eng.summary()
    finally:
        configure_compiled_caches(128)           # restore the default bound
