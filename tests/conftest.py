import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device
# (the 512-device fake topology belongs to launch/dryrun.py ONLY).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # container has no hypothesis; install the deterministic mini-stub so the
    # property tests still collect and run
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback  # noqa: F401
