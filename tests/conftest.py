import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device
# (the 512-device fake topology belongs to launch/dryrun.py ONLY).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
