"""ESSR model: exact paper identities + forward behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.essr import (ESSRConfig, ESSR_X2, ESSR_X4, essr_forward,
                               essr_macs, essr_macs_per_lr_pixel,
                               essr_param_count, init_essr, slice_width)
from repro.models.layers import count_params


def test_param_counts_match_paper_table2():
    # Table II: 4 SFB -> 43.9K, 5 -> 53.9K, 6 -> 63.9K, 5 w/o bias -> 53.6K
    assert essr_param_count(ESSRConfig(n_sfb=4, scale=4)) == 43_896
    assert essr_param_count(ESSRConfig(n_sfb=5, scale=4)) == 53_886
    assert essr_param_count(ESSRConfig(n_sfb=6, scale=4)) == 63_876
    # Table II's "5-w/o Bias = 53.6K" drops exactly the fuse-1x1 + final-pw
    # biases (5*54 + 48 = 318 params): 53886-318 = 53568 = 53.6K. Our
    # bias=False removes ALL biases (52,326) — both identities checked:
    assert 53_886 - (5 * 54 + 48) == 53_568
    assert essr_param_count(ESSRConfig(n_sfb=5, scale=4, bias=False)) == 52_326


def test_param_count_x2_matches_paper_51k():
    assert essr_param_count(ESSR_X2) == 51_906          # Table V "51K"


def test_init_matches_formula():
    for cfg in (ESSR_X2, ESSR_X4):
        p = init_essr(jax.random.PRNGKey(0), cfg)
        assert count_params(p) == essr_param_count(cfg)


def test_macs_match_paper_tables():
    # Table V/VI: MACs at 1920x1080 GT: x2 -> 26G, x4 -> 7G
    assert abs(essr_macs(ESSR_X2, (540, 960)) / 1e9 - 26.1) < 0.2
    assert abs(essr_macs(ESSR_X4, (270, 480)) / 1e9 - 6.78) < 0.1


def test_c27_is_29_percent_of_c54_macs():
    # Sec. IV-C: "MACs of the C27 model amount to only 29.1% of ... C54"
    ratio = essr_macs_per_lr_pixel(ESSR_X4, 27) / essr_macs_per_lr_pixel(ESSR_X4, 54)
    assert abs(ratio - 0.291) < 0.02


def test_forward_shapes_and_finite():
    p = init_essr(jax.random.PRNGKey(0), ESSR_X4)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 12, 12, 3))
    for w in (0, 27, 54):
        y = essr_forward(p, x, ESSR_X4, width=w)
        assert y.shape == (2, 48, 48, 3)
        assert bool(jnp.isfinite(y).all())


def test_width_slicing_consistency():
    """C27 forward == forward of explicitly sliced params (weight sharing)."""
    p = init_essr(jax.random.PRNGKey(0), ESSR_X4)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 8, 8, 3))
    via_width = essr_forward(p, x, ESSR_X4, width=27)
    sliced = slice_width(p, 27)
    via_slice = essr_forward(sliced, x, ESSRConfig(channels=27, scale=4))
    np.testing.assert_allclose(np.asarray(via_width), np.asarray(via_slice),
                               rtol=1e-6, atol=1e-6)


def test_supernet_grads_only_touch_selected_slice():
    """ARM training rule: C27 loss grads vanish outside the first-27 slice."""
    p = init_essr(jax.random.PRNGKey(0), ESSR_X4)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 8, 8, 3))
    hr = jax.random.uniform(jax.random.PRNGKey(2), (1, 32, 32, 3))

    def loss(params):
        return jnp.mean(jnp.abs(essr_forward(params, x, ESSR_X4, width=27) - hr))

    g = jax.grad(loss)(p)
    # second-half output channels of the first conv never touched by C27
    assert float(jnp.abs(g["first"]["pw"][..., 27:]).max()) == 0.0
    assert float(jnp.abs(g["sfbs"][0]["fuse"][:, :, 27:, :]).max()) == 0.0
    assert float(jnp.abs(g["sfbs"][0]["fuse"][:, :, :27, 27:]).max()) == 0.0
    # sliced region does receive gradient
    assert float(jnp.abs(g["first"]["pw"][..., :27]).max()) > 0.0


def test_bilinear_subnet_is_pure_interpolation():
    p = init_essr(jax.random.PRNGKey(0), ESSR_X4)
    x = jnp.ones((1, 8, 8, 3)) * 0.5
    y = essr_forward(p, x, ESSR_X4, width=0)
    np.testing.assert_allclose(np.asarray(y), 0.5, rtol=1e-6)
