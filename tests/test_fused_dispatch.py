"""Fused single-dispatch frame graph (`ExecutionPlan.dispatch = "fused"`).

Contract under test (docs/api.md "Dispatch modes & async streaming"):

  * with adequate capacity, fused in-graph routing is IDENTICAL to host
    dispatch — same ids, same counts (golden mixed frame pins), allclose
    images across backends, quant modes and shard counts;
  * capacity overflow spills deterministically (raster order, priciest
    subnet first, cascading toward the dense bilinear floor);
  * the async double-buffered stream returns the same results as the
    synchronous fused stream, in frame order;
  * warmup()/FrameResult.compiled bookkeeping and the bounded stats window.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ExecutionPlan, FrameResult, SREngine
from repro.core import subnet_policy as sp
from repro.core.adaptive import SwitchingConfig
from repro.core.patching import get_geometry
from repro.core.pipeline import (capacity_route, fused_frame_forward,
                                 snap_capacity)
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig

CFG = ESSRConfig(scale=2)

#: Same fixed mixed-content frame + routing pins as
#: tests/test_quant_conformance.py — all three buckets populated.
GOLDEN_COUNTS = (10, 2, 13)


def _golden_frame(hw: int = 128, seed: int = 1234):
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw), jnp.linspace(0, 1, hw),
                          indexing="ij")
    smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
    tex = degrade(jnp.asarray(random_image(seed, 2 * hw, 2 * hw)), 2)
    return jnp.where((yy < 0.5)[..., None], smooth, tex)


def _stable_switching() -> SwitchingConfig:
    """Frozen thresholds: stream tests compare dispatch paths, and moving
    thresholds would change routing between the compared runs."""
    return SwitchingConfig(frame_high=10 ** 9, frame_low=0)


# ---------------------------------------------------------------------------
# routing equality + image allclose vs host dispatch
# ---------------------------------------------------------------------------

def test_fused_routing_matches_host_on_golden_frame():
    frame = _golden_frame()
    host = SREngine.from_config(CFG, seed=1)
    fused = SREngine.from_config(CFG, seed=1,
                                 plan=ExecutionPlan(dispatch="fused"))
    rh, rf = host.upscale(frame), fused.upscale(frame)
    assert rh.dispatch == "host" and rf.dispatch == "fused"
    assert rh.counts == GOLDEN_COUNTS and rf.counts == GOLDEN_COUNTS
    np.testing.assert_array_equal(np.asarray(rf.ids), np.asarray(rh.ids))
    np.testing.assert_allclose(np.asarray(rf.scores), np.asarray(rh.scores),
                               rtol=1e-5, atol=1e-5)
    assert rf.spill_counts == (0, 0, 0)
    np.testing.assert_allclose(np.asarray(rf.image), np.asarray(rh.image),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("quant", [None, "int8"])
def test_fused_allclose_across_backends_and_quant(backend, quant):
    frame = _golden_frame()
    plan = ExecutionPlan(quant=quant)
    host = SREngine.from_config(CFG, seed=1, backend=backend, plan=plan)
    fused = SREngine.from_config(CFG, seed=1, backend=backend,
                                 plan=plan.replace(dispatch="fused"))
    rh, rf = host.upscale(frame), fused.upscale(frame)
    assert rf.backend == rh.backend            # honest labels either way
    np.testing.assert_array_equal(np.asarray(rf.ids), np.asarray(rh.ids))
    np.testing.assert_allclose(np.asarray(rf.image), np.asarray(rh.image),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shards", [1, 4])
def test_fused_allclose_under_sharding(shards):
    if shards > jax.device_count():
        pytest.skip(f"{jax.device_count()} device(s) visible; run under "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=4")
    frame = _golden_frame()
    single = SREngine.from_config(CFG, seed=1)
    fused = SREngine.from_config(
        CFG, seed=1, plan=ExecutionPlan(dispatch="fused", shards=shards))
    r1, rf = single.upscale(frame), fused.upscale(frame)
    assert rf.shards == shards
    np.testing.assert_array_equal(np.asarray(rf.ids), np.asarray(r1.ids))
    np.testing.assert_allclose(np.asarray(rf.image), np.asarray(r1.image),
                               rtol=1e-5, atol=1e-5)


def test_fused_fp32_bit_exact_vs_host():
    """Same weights, same routed patches, same per-subnet executables: the
    fused graph is not merely allclose — on the ref backend it reproduces
    host dispatch exactly (both run `_forward_width_jit` inlined)."""
    frame = _golden_frame()
    host = SREngine.from_config(CFG, seed=1)
    fused = SREngine.from_config(CFG, seed=1,
                                 plan=ExecutionPlan(dispatch="fused"))
    np.testing.assert_array_equal(np.asarray(fused.upscale(frame).image),
                                  np.asarray(host.upscale(frame).image))


# ---------------------------------------------------------------------------
# capacity / spill semantics
# ---------------------------------------------------------------------------

def test_snap_capacity():
    assert snap_capacity(0) == 0
    assert snap_capacity(5) == 8
    assert snap_capacity(9) == 16
    assert snap_capacity(9, n_total=12) == 12      # clamps to the frame
    assert snap_capacity(3, buckets=(4, 32)) == 4


def test_capacity_route_cascade_deterministic():
    ids = jnp.asarray(np.array([2, 2, 1, 2, 0, 2, 1, 2], np.int32))
    eff, spills = capacity_route(ids, (0, 3, 2))
    # C54 keeps its first 2 in raster order; 3 overflow -> C27 candidates
    # are [native 1s + spilled 2s] in raster order, capacity 3 keeps the
    # first 3, the rest land on the bilinear floor
    np.testing.assert_array_equal(
        np.asarray(eff), [2, 2, 1, 1, 0, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(spills), [0, 2, 3])


def test_fused_spill_pinned_capacity_and_determinism():
    frame = _golden_frame()
    host = SREngine.from_config(CFG, seed=1)
    rh = host.upscale(frame)
    pin = SREngine.from_config(
        CFG, seed=1,
        plan=ExecutionPlan(dispatch="fused", capacity=(0, 8, 4)))
    r1, r2 = pin.upscale(frame), pin.upscale(frame)
    # C54 wants 13, keeps 4 (raster order), 9 spill into C27; C27 holds
    # its native 2 + 6 spilled, 3 overflow to bilinear
    assert r1.spill_counts == (0, 3, 9)
    assert r1.counts == (13, 8, 4)
    assert sum(r1.counts) == sum(rh.counts)
    # deterministic: the same frame spills identically every time, and the
    # served C54 patches are exactly the first 4 of the host-routed C54s
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.image), np.asarray(r2.image))
    host_c54 = np.flatnonzero(np.asarray(rh.ids) == sp.C54)
    fused_c54 = np.flatnonzero(np.asarray(r1.ids) == sp.C54)
    np.testing.assert_array_equal(fused_c54, host_c54[:4])


def test_fused_capacity_grows_after_spill():
    """Auto capacity: a frame that routes past the probed profile spills
    once (honest FrameResult), and the engine regrows the profile so the
    next identical frame routes without demotion."""
    smooth = jnp.stack(jnp.meshgrid(jnp.linspace(0, 1, 128),
                                    jnp.linspace(0, 1, 128),
                                    indexing="ij")[:1] * 3, axis=-1)
    busy = _golden_frame()
    eng = SREngine.from_config(CFG, seed=1,
                               plan=ExecutionPlan(dispatch="fused"))
    r_smooth = eng.upscale(smooth)              # probe: everything bilinear
    assert r_smooth.counts[sp.C54] == 0
    r_busy = eng.upscale(busy)                  # exceeds the probed profile
    assert any(r_busy.spill_counts)
    r_again = eng.upscale(busy)                 # profile regrew: no spill
    assert r_again.spill_counts == (0, 0, 0)
    assert r_again.counts == GOLDEN_COUNTS


def test_plan_validation():
    with pytest.raises(ValueError):
        ExecutionPlan(dispatch="gpu")
    with pytest.raises(ValueError):
        ExecutionPlan(inflight=0)
    with pytest.raises(ValueError):
        ExecutionPlan(stats_window=0)
    with pytest.raises(ValueError):
        ExecutionPlan(capacity=(0, -1, 4))
    p = ExecutionPlan(dispatch="fused", capacity=[0, 8, 4], inflight=2)
    assert p.capacity == (0, 8, 4)              # normalized to a tuple
    with pytest.raises(ValueError):             # must match the subnet trio
        SREngine.from_config(CFG, plan=ExecutionPlan(
            dispatch="fused", capacity=(0, 8))).upscale(_golden_frame())


def test_fused_falls_back_to_host_for_other_modes():
    frame = _golden_frame()
    eng = SREngine.from_config(CFG, seed=1,
                               plan=ExecutionPlan(dispatch="fused"))
    r = eng.upscale(frame, mode="all_patches", width=CFG.channels)
    assert r.dispatch == "host" and r.spill_counts is None
    ids = np.zeros(r.n_patches, np.int64)
    r2 = eng.upscale(frame, ids_override=ids)
    assert r2.dispatch == "host"
    r3 = eng.reference(frame)
    assert r3.dispatch == "host"


# ---------------------------------------------------------------------------
# streaming: sync == async, ordering, control
# ---------------------------------------------------------------------------

def test_async_stream_matches_sync_stream():
    """Double-buffered fused streaming returns exactly the synchronous
    results, in frame order (capacity pinned + thresholds frozen, so the
    one-frame control delay has nothing to act on — the documented setting
    where async is a pure latency-hiding change)."""
    frames = [_golden_frame(seed=1234 + i) for i in range(4)]
    mk = lambda inflight: SREngine.from_config(
        CFG, seed=1, switching=_stable_switching(),
        plan=ExecutionPlan(dispatch="fused", capacity=(0, 16, 16),
                           inflight=inflight))
    sync_r = list(mk(1).stream(frames))
    async_r = list(mk(3).stream(frames))
    assert len(sync_r) == len(async_r) == 4
    for a, b in zip(sync_r, async_r):
        assert a.counts == b.counts and a.spill_counts == b.spill_counts
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.image),
                                      np.asarray(b.image))


def test_async_control_delay_is_one_frame():
    """With adaptation ON, the async switcher reads counts one frame late:
    after a C54-heavy frame, sync raises thresholds before serving the next
    frame, async only after it — the documented inflight-1 control delay."""
    frames = [_golden_frame(seed=7), _golden_frame(seed=8),
              _golden_frame(seed=9)]
    trig = SwitchingConfig(frame_high=5, frame_low=0)   # golden C54=13 > 5
    mk = lambda inflight: SREngine.from_config(
        CFG, seed=1, switching=trig,
        plan=ExecutionPlan(dispatch="fused", capacity=(0, 32, 32),
                           inflight=inflight))
    sync_r = list(mk(1).stream(frames))
    async_r = list(mk(2).stream(frames))
    # frame 0 routes identically (same initial thresholds), and its C54
    # count trips the trim: sync raises thresholds BEFORE serving frame 1
    assert sync_r[0].counts == async_r[0].counts
    t1, t2 = sp.DEFAULT_T1, sp.DEFAULT_T2
    assert sync_r[0].thresholds == (t1 + trig.t1_step, t2 + trig.t2_step)
    # async frame 1 was launched before frame 0 materialized: it still
    # routed at the INITIAL thresholds — exactly what a plain upscale of
    # that frame (plan thresholds == initial) produces
    ref = SREngine.from_config(
        CFG, seed=1, plan=ExecutionPlan(dispatch="fused",
                                        capacity=(0, 32, 32)))
    assert async_r[1].counts == ref.upscale(frames[1]).counts


def test_fused_stream_records_and_summary():
    frames = [_golden_frame()] * 3
    eng = SREngine.from_config(
        CFG, seed=1, switching=_stable_switching(),
        plan=ExecutionPlan(dispatch="fused", inflight=2, stats_window=2))
    out = list(eng.stream(frames))
    assert all(isinstance(r, FrameResult) for r in out)
    assert len(eng.stats) == 2                  # bounded window
    s = eng.summary()
    assert s["frames"] == 2 and s["stats_window"] == 2
    assert s["spilled_patches"] == [0, 0, 0]
    # compact records hold no images/ids/scores
    assert all(r.image is None and r.ids is None for r in eng.stats)


def test_stream_enforces_c54_budget_even_when_seeded_by_upscale():
    """The in-graph C54 ceiling must hold no matter which path seeded the
    capacity profile: the cache stays unclamped, the stream clamps per
    call — and a stream-clamped serve must not force spills on later
    single-frame upscale() calls (review regression)."""
    budget = SwitchingConfig(c54_per_sec_budget=4 * 30, fps=30,
                             frame_high=10 ** 9, frame_low=0)   # 4 C54/frame
    eng = SREngine.from_config(CFG, seed=1, switching=budget,
                               plan=ExecutionPlan(dispatch="fused"))
    r_up = eng.upscale(_golden_frame())     # seeds the unclamped profile
    assert r_up.counts == GOLDEN_COUNTS and r_up.spill_counts == (0, 0, 0)
    r_st = eng.serve(_golden_frame())       # streamed: ceiling 4 C54/frame
    assert r_st.counts[sp.C54] <= 4
    assert r_st.spill_counts[sp.C54] == GOLDEN_COUNTS[sp.C54] - 4
    r_up2 = eng.upscale(_golden_frame())    # full profile again, no spill
    assert r_up2.counts == GOLDEN_COUNTS and r_up2.spill_counts == (0, 0, 0)
    # a PINNED profile is the operator override: served verbatim even when
    # streaming — its C54 entry replaces the budget-derived ceiling
    # (documented on ExecutionPlan.capacity)
    pin = SREngine.from_config(
        CFG, seed=1, switching=budget,
        plan=ExecutionPlan(dispatch="fused", capacity=(0, 16, 16)))
    r_pin = pin.serve(_golden_frame())
    assert r_pin.counts == GOLDEN_COUNTS and r_pin.spill_counts == (0, 0, 0)


def test_stats_window_rotation():
    """engine.stats is a bounded deque: after rotation summary() covers the
    newest stats_window frames, and the retired shim's monotone mirror
    counter is gone from the engine surface."""
    from repro.models.essr import init_essr
    params = init_essr(jax.random.PRNGKey(0), CFG)
    engine = SREngine(params, CFG, plan=ExecutionPlan(stats_window=2),
                      switching=_stable_switching())
    frame = _golden_frame()
    for _ in range(4):
        engine.serve(frame)
    assert len(engine.stats) == 2                 # deque rotated
    assert engine.summary()["frames"] == 2
    assert not hasattr(engine, "stats_total")     # mirror plumbing deleted


# ---------------------------------------------------------------------------
# warmup / compiled bookkeeping
# ---------------------------------------------------------------------------

def test_warmup_and_compiled_flag():
    eng = SREngine.from_config(CFG, seed=1,
                               plan=ExecutionPlan(dispatch="fused"))
    w = eng.warmup((128, 128))
    assert w.compiled is False and w.dispatch == "fused"
    assert all(c > 0 for c in w.counts)        # synthetic frame hits all 3
    assert len(eng.stats) == 0                 # warmup never pollutes stats
    w2 = eng.warmup((128, 128))
    assert w2.compiled is True                 # same shape+profile: warm


def test_summary_excludes_warmup_frames():
    frames = [_golden_frame()] * 3
    eng = SREngine.from_config(CFG, seed=1, switching=_stable_switching(),
                               plan=ExecutionPlan(dispatch="fused",
                                                  capacity=(0, 16, 16)))
    out = list(eng.stream(frames))
    assert out[0].compiled is False and out[1].compiled is True
    s = eng.summary()
    assert s["warmup_frames_excluded"] == 1
    steady = [r.latency_s for r in out[1:]]
    assert abs(s["mean_latency_s"] - float(np.mean(steady))) < 1e-9


def test_direct_fused_frame_forward():
    """The low-level entry: one call, six device arrays, equal to the
    host reference pipeline."""
    from repro.core.pipeline import edge_selective_sr
    from repro.models.essr import init_essr
    frame = _golden_frame()
    params = init_essr(jax.random.PRNGKey(1), CFG)
    ref = edge_selective_sr(params, frame, CFG)
    g = get_geometry(128, 128, 32, 2, CFG.scale)
    caps = tuple(snap_capacity(c, n_total=g.n) for c in ref.counts)
    img, ids, scores, counts, spills, health = fused_frame_forward(
        params, frame, CFG, geometry=g, caps=caps)
    np.testing.assert_array_equal(np.asarray(ids), ref.ids)
    np.testing.assert_array_equal(np.asarray(counts), list(ref.counts))
    assert not np.asarray(spills).any()
    assert not np.asarray(health).any()        # golden frame is clean
    np.testing.assert_allclose(np.asarray(img), np.asarray(ref.image),
                               rtol=1e-5, atol=1e-5)
