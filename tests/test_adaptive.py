"""Resource-adaptive model switching — Algorithm 1 (Sec. IV-A)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import subnet_policy as sp
from repro.core.adaptive import (AdaptiveSwitcher, ShardSwitcherBank,
                                 SwitchingConfig, per_shard_config)
from repro.core.patching import shard_slices


def _mk(budget=10_000, high=1000, low=700, fps=30):
    return AdaptiveSwitcher(SwitchingConfig(
        c54_per_sec_budget=budget, frame_high=high, frame_low=low, fps=fps))


def test_budget_ceiling_demotes_to_c27():
    """'Rest of the patches run with C27' when the per-second C54 budget hits."""
    sw = _mk(budget=5)
    ids = sw.assign(np.full(20, 255.0))          # all want C54
    assert (ids == sp.C54).sum() == 5
    assert (ids == sp.C27).sum() == 15
    assert (ids == sp.BILINEAR).sum() == 0       # quality floor is C27, not bilinear


def test_thresholds_rise_when_frame_overloaded():
    sw = _mk(budget=10 ** 9, high=10, low=2)
    t1, t2 = sw.thresholds
    sw.assign(np.full(50, 255.0))                # 50 C54 > high=10
    assert sw.thresholds == (t1 + 1, t2 + 5)     # Algorithm 1: +1 / +5


def test_thresholds_fall_when_frame_underloaded():
    sw = _mk(budget=10 ** 9, high=100, low=50)
    t1, t2 = sw.thresholds
    sw.assign(np.full(10, 255.0))                # 10 C54 < low=50
    assert sw.thresholds == (t1 - 1, t2 - 5)


def test_budget_resets_each_second():
    sw = _mk(budget=5, fps=2)
    sw.assign(np.full(10, 255.0))
    sw.assign(np.full(10, 255.0))                # second rolls over after 2 frames
    ids = sw.assign(np.full(10, 255.0))
    assert (ids == sp.C54).sum() == 5            # fresh budget


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 255), min_size=1, max_size=200), st.integers(1, 50))
def test_controller_invariants(scores, frames):
    """Thresholds stay bounded + ordered; C54/sec never exceeds budget."""
    sw = _mk(budget=20, high=5, low=1, fps=4)
    scores = np.array(scores, np.float32)
    c54_in_second = 0
    for f in range(min(frames, 20)):
        if f % 4 == 0:
            c54_in_second = 0
        ids = sw.assign(scores)
        c54_in_second += (ids == sp.C54).sum()
        assert c54_in_second <= 20
        t1, t2 = sw.thresholds
        assert 0 <= t1 < t2 <= 256


def test_straggler_demotion_raises_thresholds():
    sw = _mk()
    t1, t2 = sw.thresholds
    sw.demote_for_straggler(severity=2.0)
    assert sw.thresholds == (t1 + 2, t2 + 10)


# -- sharded streaming: ShardSwitcherBank ------------------------------------

def test_per_shard_config_splits_budgets():
    cfg = SwitchingConfig(c54_per_sec_budget=100, frame_high=40, frame_low=20)
    split = per_shard_config(cfg, 4)
    assert (split.c54_per_sec_budget, split.frame_high, split.frame_low) == \
        (25, 10, 5)
    assert (split.t1, split.t2) == (cfg.t1, cfg.t2)   # per-controller, unsplit
    assert per_shard_config(cfg, 1) is cfg
    tiny = per_shard_config(SwitchingConfig(c54_per_sec_budget=2,
                                            frame_high=2, frame_low=1), 8)
    assert tiny.c54_per_sec_budget >= 1 and tiny.frame_low >= 1
    # frame_low=0 means "never decay thresholds": splitting must not
    # re-enable decay by flooring it to 1
    frozen = per_shard_config(SwitchingConfig(frame_low=0), 4)
    assert frozen.frame_low == 0
    with pytest.raises(ValueError):
        per_shard_config(cfg, 0)


def _bank(shards=2, **kw):
    base = dict(c54_per_sec_budget=10 ** 9, frame_high=10 ** 6, frame_low=0)
    base.update(kw)
    return ShardSwitcherBank(SwitchingConfig(**base), shards=shards)


def test_bank_assigns_per_shard_thresholds():
    """Each shard routes its raster strip under its OWN live thresholds."""
    bank = _bank(shards=2)
    bank.switchers[1].t1, bank.switchers[1].t2 = 100.0, 200.0
    scores = np.array([50.0, 50.0, 50.0, 50.0])     # C54 at default (8, 40)
    ids = bank.assign(scores, shard_slices(4, 2))
    assert ids.tolist() == [sp.C54, sp.C54, sp.BILINEAR, sp.BILINEAR]


def test_straggler_shard_demotes_and_c54_drops():
    """Satellite criterion: a shard that misses its deadline slice raises
    (t1, t2), and its next-frame C54 count drops; its balanced peer keeps
    routing at the old thresholds."""
    bank = _bank(shards=2)
    slices = shard_slices(8, 2)
    # shard 0's scores sit just above t2=40: one +5 demotion step moves them
    # below; shard 1 stays cheap (all bilinear)
    scores = np.array([41.0, 42.0, 43.0, 44.0, 1.0, 1.0, 1.0, 1.0])
    ids = bank.assign(scores, slices)
    assert sp.subnet_counts(ids[slices[0]])[2] == 4          # all C54
    t_before = bank.thresholds
    costs = [4 * 1000.0, 0.0]                                # shard 0 heavy
    demoted = bank.note_frame(True, costs)
    assert demoted == (True, False)
    t_after = bank.thresholds
    assert t_after[0][0] > t_before[0][0] and t_after[0][1] > t_before[0][1]
    assert t_after[1] == t_before[1]                         # peer untouched
    ids2 = bank.assign(scores, slices)
    assert sp.subnet_counts(ids2[slices[0]])[2] < 4          # C54 share fell


def test_uniform_overload_demotes_all_shards():
    bank = _bank(shards=3)
    before = bank.thresholds
    assert bank.note_frame(True, [5.0, 5.0, 5.0]) == (True, True, True)
    assert all(a[1] > b[1] for a, b in zip(bank.thresholds, before))
    # a met deadline never demotes
    assert bank.note_frame(False, [9.0, 0.0, 0.0]) == (False, False, False)


def test_sustained_misses_respect_bounds():
    """Thresholds stay inside t1_bounds/t2_bounds (and ordered) no matter how
    long a shard keeps missing."""
    cfg = SwitchingConfig(c54_per_sec_budget=10 ** 9, frame_high=10 ** 6,
                          frame_low=0, t1_bounds=(0.0, 100.0),
                          t2_bounds=(1.0, 150.0))
    bank = ShardSwitcherBank(cfg, shards=2)
    scores = np.full(8, 255.0)
    for _ in range(200):
        bank.assign(scores, shard_slices(8, 2))
        bank.note_frame(True, [7.0, 1.0])
    for (t1, t2) in bank.thresholds:
        assert 0.0 <= t1 <= 100.0 and t1 < t2 <= 151.0   # clamp keeps order
    # the heavy shard is pinned at (or within one step of) the ceiling
    assert bank.thresholds[0][0] == 100.0


def test_bank_validates_shapes():
    bank = _bank(shards=2)
    with pytest.raises(ValueError):
        bank.assign(np.zeros(4), shard_slices(4, 3))
    with pytest.raises(ValueError):
        bank.note_frame(True, [1.0])
    with pytest.raises(ValueError):
        ShardSwitcherBank(shards=0)
