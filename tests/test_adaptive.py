"""Resource-adaptive model switching — Algorithm 1 (Sec. IV-A)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import subnet_policy as sp
from repro.core.adaptive import AdaptiveSwitcher, SwitchingConfig


def _mk(budget=10_000, high=1000, low=700, fps=30):
    return AdaptiveSwitcher(SwitchingConfig(
        c54_per_sec_budget=budget, frame_high=high, frame_low=low, fps=fps))


def test_budget_ceiling_demotes_to_c27():
    """'Rest of the patches run with C27' when the per-second C54 budget hits."""
    sw = _mk(budget=5)
    ids = sw.assign(np.full(20, 255.0))          # all want C54
    assert (ids == sp.C54).sum() == 5
    assert (ids == sp.C27).sum() == 15
    assert (ids == sp.BILINEAR).sum() == 0       # quality floor is C27, not bilinear


def test_thresholds_rise_when_frame_overloaded():
    sw = _mk(budget=10 ** 9, high=10, low=2)
    t1, t2 = sw.thresholds
    sw.assign(np.full(50, 255.0))                # 50 C54 > high=10
    assert sw.thresholds == (t1 + 1, t2 + 5)     # Algorithm 1: +1 / +5


def test_thresholds_fall_when_frame_underloaded():
    sw = _mk(budget=10 ** 9, high=100, low=50)
    t1, t2 = sw.thresholds
    sw.assign(np.full(10, 255.0))                # 10 C54 < low=50
    assert sw.thresholds == (t1 - 1, t2 - 5)


def test_budget_resets_each_second():
    sw = _mk(budget=5, fps=2)
    sw.assign(np.full(10, 255.0))
    sw.assign(np.full(10, 255.0))                # second rolls over after 2 frames
    ids = sw.assign(np.full(10, 255.0))
    assert (ids == sp.C54).sum() == 5            # fresh budget


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 255), min_size=1, max_size=200), st.integers(1, 50))
def test_controller_invariants(scores, frames):
    """Thresholds stay bounded + ordered; C54/sec never exceeds budget."""
    sw = _mk(budget=20, high=5, low=1, fps=4)
    scores = np.array(scores, np.float32)
    c54_in_second = 0
    for f in range(min(frames, 20)):
        if f % 4 == 0:
            c54_in_second = 0
        ids = sw.assign(scores)
        c54_in_second += (ids == sp.C54).sum()
        assert c54_in_second <= 20
        t1, t2 = sw.thresholds
        assert 0 <= t1 < t2 <= 256


def test_straggler_demotion_raises_thresholds():
    sw = _mk()
    t1, t2 = sw.thresholds
    sw.demote_for_straggler(severity=2.0)
    assert sw.thresholds == (t1 + 2, t2 + 10)
