"""Minimal deterministic stand-in for `hypothesis`, installed by conftest.py
ONLY when the real package is absent (the container does not ship it).

Covers exactly the API surface the test suite uses:

    from hypothesis import given, settings, strategies as st
    st.integers / st.floats / st.lists / st.sampled_from

`given` reruns the test body over samples from a fixed-seed RNG — weaker than
real hypothesis (no shrinking, no edge-case bias) but it keeps the property
tests executable and deterministic instead of failing at collection.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [elements.draw(r) for _ in range(r.randint(min_size, max_size))])


def given(*strategies_args):
    def decorate(fn):
        def runner():
            n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0xE55E)          # fixed seed: deterministic CI
            for _ in range(n):
                fn(*[s.draw(rng) for s in strategies_args])
        # plain zero-arg function on purpose: pytest must NOT see the original
        # parameters (it would treat them as fixtures)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        # honour @settings applied in either decorator order
        runner._max_examples = getattr(fn, "_max_examples",
                                       _DEFAULT_MAX_EXAMPLES)
        return runner
    return decorate


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate


def _install():
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.sampled_from = sampled_from
    hyp.strategies = st
    hyp.given = given
    hyp.settings = settings
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install()
