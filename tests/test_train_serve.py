"""Training substrate + serving runtime integration tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SREngine
from repro.core.adaptive import SwitchingConfig
from repro.data.synthetic import degrade, patch_batches, random_image
from repro.models.essr import ESSRConfig, init_essr
from repro.train import optimizer as O
from repro.train import losses as Ls
from repro.train.trainer import make_grad_accum_step, train_essr_supernet


def test_supernet_training_reduces_loss():
    cfg = ESSRConfig(scale=2)
    params = init_essr(jax.random.PRNGKey(0), cfg)
    data = patch_batches(0, batch=4, lr_patch=12, scale=2, pool=2, pool_hw=48)
    _, _, hist = train_essr_supernet(params, cfg, data, steps=25,
                                     opt=O.lamb(2e-3), log_every=0)
    assert np.mean(hist[-5:]) < 0.6 * hist[0]


def test_optimizers_step_sanity():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    for opt in (O.sgd(0.1, momentum=0.9), O.adam(0.1), O.adamw(0.1),
                O.lamb(0.1), O.adafactor(0.1),
                O.adam(0.1, moment_dtype=jnp.bfloat16)):
        st = opt.init(params)
        upd, st = opt.update(grads, st, params)
        new = O.apply_updates(params, upd)
        assert float(new["w"][0, 0]) < 1.0          # moved against the gradient
        upd, st = opt.update(grads, st, params)     # second step works


def test_cosine_and_multistep_schedules():
    s = O.cosine_decay(1.0, 100, warmup=10)
    assert float(s(0)) < 0.11
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 0.01
    m = O.multistep(1.0, [10, 20], 0.5)
    assert float(m(5)) == 1.0 and float(m(15)) == 0.5 and float(m(25)) == 0.25


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    assert abs(float(O.global_norm(clipped)) - 1.0) < 1e-5


def test_grad_accum_matches_full_batch():
    w0 = {"w": jnp.ones((4,))}

    def loss(p, x, y):
        return jnp.mean((x @ p["w"] - y) ** 2)

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    y = jax.random.normal(jax.random.PRNGKey(1), (8,))
    opt = O.sgd(0.1)
    # full batch
    g_full = jax.grad(loss)(w0, x, y)
    upd, _ = opt.update(g_full, opt.init(w0), w0)
    ref = O.apply_updates(w0, upd)
    # 4 microbatches
    step = make_grad_accum_step(loss, opt, 4)
    micro = (x.reshape(4, 2, 4), y.reshape(4, 2))
    got, _, _ = step(w0, opt.init(w0), micro)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                               rtol=1e-5, atol=1e-6)


def test_losses_finite_and_sane():
    a = jax.random.uniform(jax.random.PRNGKey(0), (1, 32, 32, 3))
    b = jnp.clip(a + 0.05 * jax.random.normal(jax.random.PRNGKey(1), a.shape), 0, 1)
    assert float(Ls.psnr(a, a)) > 100
    assert float(Ls.psnr_y(a, b)) > 15
    assert 0.3 < float(Ls.ssim(a, b)) <= 1.0
    assert float(Ls.ssim(a, a)) > 0.99
    assert np.isfinite(float(Ls.artifact_loss(a, b)))
    feat = Ls.init_feature_net(jax.random.PRNGKey(7))
    assert np.isfinite(float(Ls.perceptual_loss(feat, a, b)))
    assert float(Ls.perceptual_loss(feat, a, a)) < 1e-6


def test_gan_steps_run():
    from repro.train.gan import init_discriminator, make_gan_steps
    cfg = ESSRConfig(scale=2)
    params = init_essr(jax.random.PRNGKey(0), cfg)
    d_params = init_discriminator(jax.random.PRNGKey(1))
    feat = Ls.init_feature_net(jax.random.PRNGKey(7))
    g_opt, d_opt = O.adam(1e-4), O.adam(1e-4)
    g_step, d_step = make_gan_steps(cfg, g_opt, d_opt, feat)
    lr = jax.random.uniform(jax.random.PRNGKey(2), (2, 12, 12, 3))
    hr = jax.random.uniform(jax.random.PRNGKey(3), (2, 24, 24, 3))
    p, gs, sr, gl = g_step(params, g_opt.init(params), d_params, lr, hr, width=54)
    dp, ds, dl = d_step(d_params, d_opt.init(d_params), sr, hr)
    assert np.isfinite(float(gl)) and np.isfinite(float(dl))


def test_stream_end_to_end():
    cfg = ESSRConfig(scale=2)
    params = init_essr(jax.random.PRNGKey(0), cfg)
    engine = SREngine(params, cfg,
                      switching=SwitchingConfig(c54_per_sec_budget=3,
                                                frame_high=2, frame_low=1,
                                                fps=2))
    frames = (degrade(jnp.asarray(random_image(i, 128, 128)), 2)
              for i in range(3))
    for r in engine.stream(frames):
        assert r.image.shape == (128, 128, 3)
    s = engine.summary()
    assert s["frames"] == 3
    assert abs(sum(s["subnet_share"].values()) - 1.0) < 1e-3


def test_synthetic_data_properties():
    img = random_image(0, 96, 96)
    assert img.shape == (96, 96, 3) and img.min() >= 0 and img.max() <= 1
    from repro.core.edge_score import edge_score
    from repro.core.patching import extract_patches
    patches, _ = extract_patches(jnp.asarray(img), 32, 2)
    scores = np.asarray(edge_score(patches))
    assert scores.std() > 1.0          # content classes actually differ
