"""Extra property tests: optimizer invariants + checkpoint idempotence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.ckpt.checkpoint import CheckpointManager
from repro.train import optimizer as O


@settings(max_examples=15, deadline=None)
@given(st.floats(0.5, 100.0), st.integers(0, 2 ** 31 - 1))
def test_lamb_update_invariant_to_gradient_scale(scale, seed):
    """LAMB's trust ratio makes the update direction+magnitude invariant to a
    uniform gradient rescale (after the adam normalizer) — the property that
    lets the paper train at batch 256 / lr 3e-3."""
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (8, 8)) + 2.0}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 8))}
    opt = O.lamb(0.1)

    def one_update(g):
        st_ = opt.init(params)
        upd, _ = opt.update(g, st_, params)
        return np.asarray(upd["w"])

    u1 = one_update(grads)
    u2 = one_update(jax.tree_util.tree_map(lambda g: g * scale, grads))
    np.testing.assert_allclose(u1, u2, rtol=2e-3, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_adam_step_bounded_by_lr(seed):
    """|adam update| <= ~lr per element (bias-corrected, eps-regularized)."""
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (16,))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (16,)) * 100}
    opt = O.adam(1e-2)
    upd, _ = opt.update(grads, opt.init(params), params)
    assert float(jnp.abs(upd["w"]).max()) <= 1e-2 * 1.01


def test_checkpoint_save_is_idempotent(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    s = {"w": jnp.arange(6.0).reshape(2, 3)}
    cm.save(3, s)
    cm.save(3, s)                              # overwrite same step
    restored, meta = cm.restore(s)
    assert meta["step"] == 3
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(s["w"]))


def test_checkpoint_restore_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5)
    for step in (1, 2, 3):
        cm.save(step, {"w": jnp.full((2,), float(step))})
    restored, meta = cm.restore({"w": jnp.zeros((2,))}, step=2)
    assert meta["step"] == 2
    np.testing.assert_allclose(np.asarray(restored["w"]), 2.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4))
def test_pipeline_bubble_formula(n_micro, n_stages):
    from repro.distributed.pipeline import bubble_fraction
    b = bubble_fraction(n_micro, n_stages)
    assert 0.0 <= b < 1.0
    assert b == (n_stages - 1) / (n_micro + n_stages - 1)
