"""Edge score + subnet decision (paper Sec. II) — unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import subnet_policy as sp
from repro.core.edge_score import edge_score, edge_score_luma
from repro.models.essr import ESSR_X4


def test_edge_score_flat_patch_is_zero():
    flat = jnp.ones((2, 16, 16, 3)) * 0.3
    np.testing.assert_allclose(np.asarray(edge_score(flat)), 0.0, atol=1e-3)


def test_edge_score_detects_edges():
    patch = np.zeros((1, 16, 16, 3), np.float32)
    patch[:, :, 8:] = 1.0                     # vertical step edge
    s_edge = float(edge_score(jnp.asarray(patch))[0])
    s_flat = float(edge_score(jnp.zeros((1, 16, 16, 3)))[0])
    assert s_edge > s_flat + 5.0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_edge_score_invariant_to_luma_offset(seed):
    """Laplacian of a constant is 0 => adding a constant can't change score."""
    key = jax.random.PRNGKey(seed)
    luma = jax.random.uniform(key, (1, 12, 12)) * 100.0
    s1 = float(edge_score_luma(luma)[0])
    s2 = float(edge_score_luma(luma + 50.0)[0])
    assert abs(s1 - s2) < 1e-2


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_scores_in_range(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (4, 16, 16, 3))
    s = np.asarray(edge_score(x))
    assert (s >= 0).all() and (s <= 255).all()


def test_decision_boundaries():
    scores = jnp.asarray([0.0, 7.9, 8.0, 39.9, 40.0, 200.0])
    ids = np.asarray(sp.decide(scores, 8, 40))
    assert ids.tolist() == [0, 0, 1, 1, 2, 2]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0, 255), min_size=4, max_size=64),
       st.floats(1, 100), st.floats(1, 100))
def test_raising_thresholds_never_raises_macs(scores, t1, dt):
    """Monotonicity: higher thresholds => never more MACs."""
    t2 = t1 + dt
    m = sp.SubnetMacs.make(ESSR_X4)
    arr = jnp.asarray(np.array(scores, np.float32))
    base = m.total(sp.subnet_counts(sp.decide(arr, t1, t2)))
    up = m.total(sp.subnet_counts(sp.decide(arr, t1 + 5, t2 + 5)))
    assert up <= base


def test_threshold_search_hits_target():
    rng = np.random.default_rng(0)
    scores = rng.uniform(0, 120, size=500)
    t1, t2 = sp.thresholds_for_target_saving(scores, 0.5, ESSR_X4)
    got = sp.mac_saving(scores, t1, t2, ESSR_X4)["saving_vs_c54"]
    assert abs(got - 0.5) < 0.08


def test_mac_saving_all_c54_is_zero():
    scores = np.full(10, 255.0)
    assert sp.mac_saving(scores, 8, 40, ESSR_X4)["saving_vs_c54"] == 0.0
