"""Quantized-serving conformance harness (paper Sec. IV-H; ISSUE 4).

Three contracts, in increasing altitude:

  1. **Integer consistency** — the codes the Pallas qconv kernels produce
     are bit-exact vs `quant.pams.int_codes` (the quantizer) and vs the
     pure-jnp integer-domain reference `essr_forward_qref` (the whole
     chain). Both sides run jit'd: XLA's fp contraction must be decided
     identically or a 1-ulp excess-precision difference can flip a code on
     a .5 rounding boundary.
  2. **Fake-quant vs integer-domain** — per fused group, the dequantized
     kernel output is allclose to the fake-quant emulation of the same
     layers within a few quantization steps (fp summation order differs,
     lattices do not).
  3. **Accuracy budget** — on the synthetic frame suite a quantized engine
     stays within the paper's 0.6 dB of the fp32 engine, for the ref and
     pallas backends, sharded and unsharded.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan, SREngine
from repro.data.synthetic import degrade, random_image
from repro.kernels.qconv import (act_qconsts, essr_forward_qkernels,
                                 essr_forward_qref, prepare_qparams,
                                 qbsconv_fused, quantize_fused)
from repro.models.essr import ESSRConfig, init_essr
from repro.quant.pams import (build_quant_pack, code_dtype, effective_alpha,
                              int_codes, quantized_essr_forward)
from repro.train.losses import psnr_y

MULTI = jax.device_count() >= 2
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

CFG = ESSRConfig(scale=2, channels=8, n_sfb=2)


def _params_and_batch(n=5, hw=12, seed=0):
    params = init_essr(jax.random.PRNGKey(seed), CFG)
    x = jax.random.uniform(jax.random.PRNGKey(seed + 1), (n, hw, hw, 3))
    return params, x


# ---------------------------------------------------------------------------
# 1. integer consistency: kernel codes == int_codes / qref, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "fxp10"])
def test_quantize_fused_bitexact_vs_int_codes(mode):
    """The Pallas quantizer must land on exactly the `int_codes` lattice."""
    params, x = _params_and_batch()
    pack = build_quant_pack(params, CFG, mode, x)
    raw = pack.act_scales(CFG.channels)["in"]
    a, s = act_qconsts(raw, pack.qmax)
    got = quantize_fused(x, a=a, s=s, bits=pack.bits, interpret=True)
    want = int_codes(x, effective_alpha(jnp.float32(raw)), pack.qmax)
    assert got.dtype == code_dtype(pack.bits)
    np.testing.assert_array_equal(np.asarray(got, np.int32), np.asarray(want))


@pytest.mark.parametrize("mode", ["int8", "fxp10"])
@pytest.mark.parametrize("width", [4, 8])
def test_qkernel_chain_bitexact_vs_integer_reference(mode, width):
    """Whole kernel chain vs the jnp integer-domain spec: bit-exact, for
    both subnet widths and both lattice dtypes (int8 / int32)."""
    params, x = _params_and_batch()
    pack = build_quant_pack(params, CFG, mode, x)
    ref = essr_forward_qref(params, x, CFG, width, pack=pack)
    ker = essr_forward_qkernels(params, x, CFG, width, pack=pack,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_qkernels_serve_per_tensor_weight_quant():
    """per_channel_weights=False (per-tensor weight alphas) must serve on
    the integer path too: the 0-d weight step broadcasts to the channel
    shape instead of crashing the scale folding."""
    params, x = _params_and_batch()
    pack = build_quant_pack(params, CFG, "int8", x,
                            per_channel_weights=False)
    ref = essr_forward_qref(params, x, CFG, 8, pack=pack)
    ker = essr_forward_qkernels(params, x, CFG, 8, pack=pack, interpret=True)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_qkernel_bitexact_survives_odd_batches():
    """Prime batch sizes exercise the pad/re-slice path of every kernel."""
    params, _ = _params_and_batch()
    x = jax.random.uniform(jax.random.PRNGKey(7), (7, 12, 12, 3))
    pack = build_quant_pack(params, CFG, "int8", x)
    ref = essr_forward_qref(params, x, CFG, 8, pack=pack)
    ker = essr_forward_qkernels(params, x, CFG, 8, pack=pack, interpret=True)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


# ---------------------------------------------------------------------------
# 2. fake-quant vs integer-domain, per fused group and whole model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "fxp10"])
def test_qbsconv_group_allclose_vs_fakequant(mode):
    """One BSConv group: integer path vs the fake-quant emulation of the
    same layers, within a few output-lattice steps."""
    from repro.models import layers as L
    from repro.quant.pams import quantize, quantize_weight_tree
    params, x = _params_and_batch()
    pack = build_quant_pack(params, CFG, mode, x)
    q, c = prepare_qparams(params, CFG, CFG.channels, pack)
    raw = pack.act_scales(CFG.channels)

    # integer path: quantize input -> qbsconv -> dequant at the output site
    a, s = act_qconsts(raw["in"], pack.qmax)
    xq = quantize_fused(x, a=a, s=s, bits=pack.bits, interpret=True)
    got = qbsconv_fused(xq, q["first"]["pwq"], q["first"]["pw_scale"],
                        q["first"]["pwb"], q["first"]["dw_fq"],
                        q["first"]["dwb"], relu=False, a_out=c["a_first"],
                        s_out=c["s_first"], interpret=True)
    got = np.asarray(got, np.float32) * c["s_first"]

    # fake-quant path: same sites, fp arithmetic throughout
    fq_params = quantize_weight_tree(params, pack.qcfg)
    f = quantize(x, effective_alpha(jnp.float32(raw["in"])), pack.qmax)
    f = quantize(L.bsconv(fq_params["first"], f),
                 effective_alpha(jnp.float32(raw["first"])), pack.qmax)
    np.testing.assert_allclose(got, np.asarray(f), atol=3 * c["s_first"])


@pytest.mark.parametrize("mode", ["int8", "fxp10"])
@pytest.mark.parametrize("width", [4, 8])
def test_whole_model_allclose_vs_fakequant(mode, width):
    """Integer-domain forward vs `quantized_essr_forward` end to end: the
    two serving backends of one quant mode must agree to within the
    accumulated lattice noise (a handful of recon-site steps at the output,
    scaled through pixel shuffle)."""
    params, x = _params_and_batch()
    pack = build_quant_pack(params, CFG, mode, x)
    scales = {k: jnp.asarray(v, jnp.float32)
              for k, v in pack.act_scales(width).items()}
    fq = quantized_essr_forward(params, scales, x, CFG, pack.qcfg,
                                width=width)
    integer = essr_forward_qref(params, x, CFG, width, pack=pack)
    _, s_recon = act_qconsts(pack.act_scales(width)["recon"], pack.qmax)
    np.testing.assert_allclose(np.asarray(integer), np.asarray(fq),
                               atol=8 * s_recon)


# ---------------------------------------------------------------------------
# 3. engine-level conformance: labels, routing, PSNR budget, sharding
# ---------------------------------------------------------------------------

def _frames(n=2, hw=96, scale=2):
    hrs = [jnp.asarray(random_image(300 + i, hw, hw)) for i in range(n)]
    return [(hr, degrade(hr, scale)) for hr in hrs]


def test_engine_backend_labels_and_plan_guard():
    cfg = ESSRConfig(scale=2)
    frame = _frames(1)[0][1]
    eng = SREngine.from_config(cfg, seed=1, plan=ExecutionPlan(quant="int8"))
    r = eng.upscale(frame)
    assert r.backend == "ref-int8"
    pal = SREngine.from_config(cfg, seed=1, plan=ExecutionPlan(quant="int8"),
                               backend="pallas")
    assert pal.upscale(frame).backend == "pallas-interpret-int8"
    # whole-frame reference stays fp32 (and says so)
    assert eng.reference(frame).backend == "ref"
    # quant is engine state: a per-call plan cannot change it
    with pytest.raises(ValueError, match="engine-level"):
        eng.upscale(frame, plan=eng.plan.replace(quant="fxp10"))
    with pytest.raises(ValueError, match="quant"):
        ExecutionPlan(quant="fp4")


def test_engine_pallas_int8_bitexact_vs_integer_reference():
    """Acceptance: the engine's pallas-int8 frame equals running every
    routed bucket through the jnp integer-domain reference by hand."""
    cfg = ESSRConfig(scale=2)
    frame = _frames(1)[0][1]
    plan = ExecutionPlan(quant="int8")
    eng = SREngine.from_config(cfg, seed=1, plan=plan, backend="pallas")
    got = eng.upscale(frame)

    ref_eng = SREngine.from_config(cfg, seed=1, plan=plan, backend="ref")
    geom = plan.geometry(frame.shape[0], frame.shape[1], cfg.scale)
    patches = geom.extract(frame)
    out = np.zeros((patches.shape[0], plan.patch * cfg.scale,
                    plan.patch * cfg.scale, 3), np.float32)
    widths = cfg.subnet_widths()
    from repro.models.layers import bilinear_resize
    for k, w in enumerate(widths):
        idx = np.flatnonzero(got.ids == k)
        if idx.size == 0:
            continue
        batch = jnp.take(patches, jnp.asarray(idx), axis=0)
        if w == 0:
            out[idx] = np.asarray(bilinear_resize(batch, cfg.scale))
        else:
            out[idx] = np.asarray(essr_forward_qref(
                ref_eng.params, batch, cfg, w, pack=eng.qpack))
    want = geom.fuse_average(jnp.asarray(out))
    np.testing.assert_array_equal(np.asarray(got.image), np.asarray(want))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("mode", ["fxp10", "int8"])
def test_psnr_budget_vs_fp32(backend, mode):
    """Paper bound: whole-model quantization costs < 0.6 dB. Measured on
    the synthetic suite against the SAME engine serving fp32 (weights are
    bench-scale random init, so the *difference* is what the lattice costs;
    FXP10's two extra bits must not lose to int8)."""
    cfg = ESSRConfig(scale=2)
    frames = _frames(2)
    fp = SREngine.from_config(cfg, seed=1)
    q = SREngine.from_config(cfg, seed=1, plan=ExecutionPlan(quant=mode),
                             backend=backend)
    drops = []
    for hr, lr in frames:
        p_fp = float(psnr_y(fp.upscale(lr).image, hr))
        p_q = float(psnr_y(q.upscale(lr).image, hr))
        drops.append(p_fp - p_q)
    assert max(drops) < 0.6, f"quant PSNR drop {drops} exceeds 0.6 dB budget"


@needs_devices
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_sharded_quant_matches_unsharded(backend):
    """Acceptance: sharded and unsharded quantized serving agree (the
    shard_map split only re-partitions the patch batch; every patch still
    runs the identical lattice math)."""
    cfg = ESSRConfig(scale=2)
    frame = _frames(1)[0][1]
    single = SREngine.from_config(cfg, seed=1, backend=backend,
                                  plan=ExecutionPlan(quant="int8"))
    shardN = SREngine.from_config(
        cfg, seed=1, backend=backend,
        plan=ExecutionPlan(quant="int8",
                           shards=min(4, jax.device_count())))
    r1 = single.upscale(frame)
    rn = shardN.upscale(frame)
    assert rn.backend.endswith("-int8")
    np.testing.assert_allclose(np.asarray(r1.image), np.asarray(rn.image),
                               atol=1e-6)
    # streaming path too (per-shard controllers + quant lattice compose)
    res = shardN.serve(frame)
    assert len(res.shard_counts) == shardN.plan.shards


# ---------------------------------------------------------------------------
# golden routing: quantized serving must not move the router
# ---------------------------------------------------------------------------

#: Pinned (bilinear, C27, C54) patch counts for the fixed mixed-content
#: frame below (smooth gradient top half, textured synthetic bottom half:
#: all three routing buckets populated) under the default thresholds. If
#: edge scoring or routing ever starts seeing quantized inputs, these shift
#: and this test says so BEFORE a silent quality/throughput regression
#: ships.
GOLDEN_COUNTS = (10, 2, 13)


def _golden_frame(hw: int = 128, seed: int = 1234):
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw), jnp.linspace(0, 1, hw),
                          indexing="ij")
    smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
    tex = degrade(jnp.asarray(random_image(seed, 2 * hw, 2 * hw)), 2)
    return jnp.where((yy < 0.5)[..., None], smooth, tex)


def test_golden_routing_pinned_and_quant_invariant():
    cfg = ESSRConfig(scale=2)
    lr = _golden_frame()
    fp = SREngine.from_config(cfg, seed=1)
    r_fp = fp.upscale(lr)
    assert r_fp.counts == GOLDEN_COUNTS, (
        f"edge-score routing moved: {r_fp.counts} != pinned {GOLDEN_COUNTS}")
    for mode in ("fxp10", "int8"):
        r_q = SREngine.from_config(cfg, seed=1,
                                   plan=ExecutionPlan(quant=mode)).upscale(lr)
        assert r_q.counts == GOLDEN_COUNTS
        np.testing.assert_array_equal(r_q.ids, r_fp.ids)
