"""SREngine facade, ExecutionPlan, bucket padding, and deprecation shims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecutionPlan, FrameResult, SREngine
from repro.core import subnet_policy as sp
from repro.core.adaptive import AdaptiveSwitcher, SwitchingConfig
from repro.core.pipeline import (DEFAULT_BUCKETS, _bucket, edge_selective_sr,
                                 sr_all_patches)
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSRConfig, init_essr


CFG = ESSRConfig(scale=2)


@pytest.fixture(scope="module")
def engine():
    return SREngine.from_config(CFG, plan=ExecutionPlan(t1=8, t2=40))


@pytest.fixture(scope="module")
def lr_frame():
    hr = jnp.asarray(random_image(3, 128, 128))
    return degrade(hr, 2)          # 64x64 LR -> 9 patches at patch=32/overlap=2


# -- ExecutionPlan -----------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError):
        ExecutionPlan(subnet_policy="nope")
    with pytest.raises(ValueError):
        ExecutionPlan(patch=16, overlap=16)
    with pytest.raises(ValueError):
        ExecutionPlan(t1=40, t2=8)
    with pytest.raises(ValueError):
        ExecutionPlan(buckets=())
    with pytest.raises(ValueError):
        ExecutionPlan(buckets=(128, 8))


def test_plan_validation_error_format():
    """Every rule — per-field and cross-field — raises the one error shape:
    field name, got-value, allowed set."""
    cases = [
        (dict(subnet_policy="nope"), "ExecutionPlan.subnet_policy='nope'"),
        (dict(patch=16, overlap=16), "ExecutionPlan.overlap=16"),
        (dict(t1=40, t2=8), "ExecutionPlan.t2=8"),
        (dict(buckets=()), "ExecutionPlan.buckets=()"),
        (dict(inflight=2), "ExecutionPlan.inflight=2"),
        (dict(streams=2), "ExecutionPlan.streams=2"),
        (dict(streams=0, dispatch="fused"), "ExecutionPlan.streams=0"),
        (dict(streams=2, dispatch="fused", subnet_policy="all_c54"),
         "ExecutionPlan.streams=2"),
        (dict(streams=2, dispatch="fused", stream_shares=(1.0,)),
         "ExecutionPlan.stream_shares=(1.0,)"),
        (dict(stream_shares=(0.0,)), "ExecutionPlan.stream_shares=(0.0,)"),
    ]
    for kwargs, prefix in cases:
        with pytest.raises(ValueError, match=r"allowed ") as ei:
            ExecutionPlan(**kwargs)
        assert str(ei.value).startswith(prefix), (kwargs, str(ei.value))


def test_plan_capacity_coercion_chains_cause():
    """The capacity coercion failure is chained (`raise ... from e`) so the
    non-int-iterable root cause survives — the former bare re-raise hid it."""
    with pytest.raises(ValueError) as ei:
        ExecutionPlan(capacity=("a", "b", "c"))   # iterable, non-int entries
    assert "ExecutionPlan.capacity=" in str(ei.value)
    assert isinstance(ei.value.__cause__, ValueError)
    with pytest.raises(ValueError) as ei:
        ExecutionPlan(capacity=object())          # not iterable at all
    assert isinstance(ei.value.__cause__, TypeError)
    with pytest.raises(ValueError):
        ExecutionPlan(capacity=(0, -1, 4))        # int but out of bounds


def test_plan_streams_normalizes_shares():
    p = ExecutionPlan(streams=2, dispatch="fused", stream_shares=[3, 1])
    assert p.stream_shares == (3.0, 1.0)          # tuple-coerced, hashable
    assert ExecutionPlan(streams=4, dispatch="fused").stream_shares is None


def test_plan_replace_and_decide():
    p = ExecutionPlan(t1=8, t2=40)
    assert p.replace(t1=0, t2=0).thresholds == (0, 0)
    scores = np.array([0.0, 10.0, 100.0])
    assert p.decide(scores).tolist() == [sp.BILINEAR, sp.C27, sp.C54]
    assert p.replace(t1=200, t2=201).decide(scores).tolist() == [0, 0, 0]
    forced = p.replace(subnet_policy="all_c27").decide(scores)
    assert forced.tolist() == [sp.C27] * 3


# -- bucket padding path -----------------------------------------------------

def test_bucket_schedule():
    assert _bucket(1) == 8 and _bucket(8) == 8 and _bucket(9) == 16
    assert _bucket(5000, DEFAULT_BUCKETS) == 8192          # ceil to multiple
    assert _bucket(3, (4, 16)) == 4 and _bucket(5, (4, 16)) == 16


def test_bucket_padding_writes_only_real_indices(engine, lr_frame):
    """Padding a subnet batch duplicates patch 0; those duplicate outputs must
    never land in other patches' slots of the fused frame."""
    n = 9
    ids = np.zeros(n, dtype=np.int64)
    ids[0] = sp.C54            # batch of 1 -> padded to bucket 8 with patch 0
    mixed = engine.upscale(lr_frame, ids_override=ids)
    all_bilinear = engine.upscale(lr_frame,
                                  ids_override=np.zeros(n, dtype=np.int64))
    assert mixed.counts == (8, 0, 1)
    # HR region covered only by patches 1.. (LR y,x >= 34) must be identical
    np.testing.assert_allclose(np.asarray(mixed.image[68:, 68:]),
                               np.asarray(all_bilinear.image[68:, 68:]),
                               atol=1e-6)
    # patch 0's exclusive region (LR y,x < 30) must reflect the C54 forward
    assert float(jnp.abs(mixed.image[:60, :60]
                         - all_bilinear.image[:60, :60]).max()) > 1e-4


def test_pipeline_matches_seed_loop_reference(engine, lr_frame):
    """The device-resident gather/scatter pipeline is allclose-identical to
    the seed per-patch loop pipeline, routing included."""
    new = edge_selective_sr(engine.params, lr_frame, engine.cfg)
    old = edge_selective_sr(engine.params, lr_frame, engine.cfg,
                            use_loop_reference=True)
    assert new.ids.tolist() == old.ids.tolist()
    np.testing.assert_allclose(np.asarray(new.image), np.asarray(old.image),
                               atol=1e-5)


# -- upscale modes + ids_override round-trip ---------------------------------

def test_ids_override_roundtrip(engine, lr_frame):
    ids = np.array([0, 1, 2, 0, 1, 2, 0, 1, 2], dtype=np.int64)
    res = engine.upscale(lr_frame, ids_override=ids)
    assert res.ids.tolist() == ids.tolist()
    assert res.counts == sp.subnet_counts(ids)
    ref = edge_selective_sr(engine.params, lr_frame, engine.cfg,
                            ids_override=ids)
    np.testing.assert_allclose(np.asarray(res.image), np.asarray(ref.image),
                               atol=1e-6)
    assert res.mac_saving == ref.mac_saving


def test_modes_and_result_shape(engine, lr_frame):
    r = engine.upscale(lr_frame)
    assert isinstance(r, FrameResult)
    assert r.image.shape == (128, 128, 3) and r.mode == "edge_select"
    assert r.n_patches == 9 and r.scores is not None and r.latency_s > 0
    w = engine.reference(lr_frame)
    assert w.image.shape == (128, 128, 3) and w.mode == "whole"
    assert w.backend == "ref"        # sr_whole always runs the pure-JAX path
    a = engine.upscale(lr_frame, mode="all_patches", width=CFG.channels)
    assert a.counts == (0, 0, 9)
    with pytest.raises(ValueError):
        engine.upscale(lr_frame, mode="nope")
    with pytest.raises(ValueError):
        engine.upscale(lr_frame, mode="all_patches", width=13)
    with pytest.raises(ValueError):
        engine.reference(lr_frame, width=13)
    with pytest.raises(ValueError):
        engine.upscale(lr_frame, width=27)       # width needs all_patches/whole
    with pytest.raises(ValueError):
        engine.upscale(lr_frame, mode="whole",
                       ids_override=np.zeros(9, dtype=np.int64))
    forced = engine.upscale(lr_frame,
                            plan=engine.plan.replace(subnet_policy="all_c27"))
    assert forced.counts == (0, 9, 0) and forced.scores is None
    assert forced.mode == "all_patches"   # labeled as what actually ran


def test_sr_all_patches_width_validation(engine, lr_frame):
    with pytest.raises(ValueError):
        sr_all_patches(engine.params, lr_frame, CFG, width=13)
    img = sr_all_patches(engine.params, lr_frame, CFG, width=CFG.channels // 2)
    assert img.shape == (128, 128, 3)


def test_backend_selected_once(lr_frame):
    with pytest.raises(ValueError):
        SREngine.from_config(CFG, backend="typo")
    ref = SREngine.from_config(CFG, seed=1)
    pal = SREngine.from_config(CFG, seed=1, backend="pallas")
    r, p = ref.upscale(lr_frame), pal.upscale(lr_frame)
    # honest labeling: on a CPU host the auto interpret policy falls back to
    # the Pallas interpreter, and the result says so
    assert (r.backend, p.backend) == ("ref", "pallas-interpret")
    np.testing.assert_allclose(np.asarray(r.image), np.asarray(p.image),
                               atol=1e-5)
    # forcing interpret=True pins the same label; ref never relabels
    forced = SREngine.from_config(
        CFG, seed=1, backend="pallas",
        plan=ExecutionPlan(interpret=True))
    assert forced.backend_label == "pallas-interpret"
    assert forced.upscale(lr_frame).backend == "pallas-interpret"
    assert ref.backend_label == "ref"


# -- streaming ---------------------------------------------------------------

def test_stream_and_summary(lr_frame):
    eng = SREngine.from_config(
        CFG, switching=SwitchingConfig(c54_per_sec_budget=3, frame_high=2,
                                       frame_low=1, fps=2))
    out = list(eng.stream([lr_frame] * 3))
    assert len(out) == 3 and all(isinstance(r, FrameResult) for r in out)
    s = eng.summary()
    assert s["frames"] == 3 and s["backend"] == "ref"
    assert abs(sum(s["subnet_share"].values()) - 1.0) < 1e-3
    forced = SREngine.from_config(
        CFG, plan=ExecutionPlan(subnet_policy="all_c27"))
    with pytest.raises(ValueError):      # streaming is adaptive-only
        forced.serve(lr_frame)


def test_from_checkpoint_falls_back_to_init(tmp_path):
    eng = SREngine.from_checkpoint(cfg=CFG, bench_cache=str(tmp_path))
    assert eng.upscale(jnp.zeros((40, 40, 3))).image.shape == (80, 80, 3)


def test_from_checkpoint_missing_ema_warns(tmp_path):
    """prefer='ema' against a checkpoint written without an 'ema' tree must
    warn and serve 'params' — not crash, not silently mis-restore."""
    from repro.ckpt.checkpoint import CheckpointManager
    params = init_essr(jax.random.PRNGKey(7), CFG)
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, {"params": params}, blocking=True)
    with pytest.warns(UserWarning, match="no 'ema' tree"):
        eng = SREngine.from_checkpoint(str(tmp_path), cfg=CFG, prefer="ema")
    np.testing.assert_array_equal(
        np.asarray(eng.params["first"]["pw"]),
        np.asarray(params["first"]["pw"]))
    # a checkpoint WITH an ema tree restores it without warning
    ema = jax.tree_util.tree_map(lambda a: a * 0.5, params)
    cm2 = CheckpointManager(str(tmp_path / "full"))
    cm2.save(9, {"params": params, "ema": ema}, blocking=True)
    eng2 = SREngine.from_checkpoint(str(tmp_path / "full"), cfg=CFG,
                                    prefer="ema")
    np.testing.assert_array_equal(
        np.asarray(eng2.params["first"]["pw"]),
        np.asarray(ema["first"]["pw"]))
    # an ema-only checkpoint with prefer='params' serves the ema tree
    cm3 = CheckpointManager(str(tmp_path / "emaonly"))
    cm3.save(2, {"ema": ema}, blocking=True)
    with pytest.warns(UserWarning, match="no 'params' tree"):
        eng3 = SREngine.from_checkpoint(str(tmp_path / "emaonly"), cfg=CFG,
                                        prefer="params")
    np.testing.assert_array_equal(
        np.asarray(eng3.params["first"]["pw"]),
        np.asarray(ema["first"]["pw"]))


def test_upscale_sub_patch_frame(engine):
    """Frames smaller than the patch reflect-pad through the pipeline (the
    seed crashed in lax.dynamic_slice)."""
    r = engine.upscale(jnp.zeros((20, 24, 3)))
    assert r.image.shape == (40, 48, 3) and r.n_patches == 1


def test_plan_interpret_and_geometry():
    with pytest.raises(ValueError):
        ExecutionPlan(interpret="yes")
    p = ExecutionPlan()
    assert p.interpret is None and p.replace(interpret=True).interpret is True
    g = p.geometry(64, 64, 2)
    assert g is p.geometry(64, 64, 2)      # cached: zero per-frame setup
    assert g.n == 9 and g.scale == 2


# -- deprecation shims -------------------------------------------------------

def test_frame_server_alias_raises_with_migration_path():
    """The retired shim fails loudly and names the replacements — stale call
    sites must not silently fork serving behavior."""
    from repro.runtime.serving import FrameServer
    params = init_essr(jax.random.PRNGKey(0), CFG)
    with pytest.raises(RuntimeError, match=r"serve_streams"):
        FrameServer(params, CFG)
    with pytest.raises(RuntimeError, match=r"SREngine"):
        FrameServer()


def test_switching_config_not_shared():
    a, b = AdaptiveSwitcher(), AdaptiveSwitcher()
    assert a.cfg is not b.cfg
    params = init_essr(jax.random.PRNGKey(0), CFG)
    e1, e2 = SREngine(params, CFG), SREngine(params, CFG)
    assert e1.switcher.cfg is not e2.switcher.cfg
