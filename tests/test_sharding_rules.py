"""Sharding rules: every spec must evenly divide its dim on the production
meshes (validated abstractly — no devices needed)."""
import math

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ALL_SHAPES, shape_applicable
from repro.configs.registry import ARCH_NAMES, get_config
from repro.distributed import sharding as SH
from repro.launch import steps as ST


class _FakeMesh:
    """Quacks like a Mesh for spec GENERATION (shape + axis_names only)."""

    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)


def _mesh_info(multi):
    shape = {"pod": 2, "data": 16, "model": 16} if multi else {"data": 16, "model": 16}
    return SH.MeshInfo(_FakeMesh(shape), tuple(a for a in shape if a != "model"),
                       "model")


def _axis_size(mi, entry):
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return math.prod(mi.mesh.shape[n] for n in names)


def _check_tree(specs, leaves, mi, where):
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(leaves)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_size(mi, entry)
            assert dim % size == 0, (
                f"{where}: dim {dim} not divisible by {entry}({size}) "
                f"for leaf {leaf.shape}, spec {spec}")


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divisible(arch, multi):
    cfg = get_config(arch)
    mi = _mesh_info(multi)
    params = ST.abstract_params(cfg)
    specs = SH.param_specs(params, cfg, mi)
    _check_tree(specs, params, mi, f"{arch} params")


@pytest.mark.parametrize("arch", ["grok-1-314b", "deepseek-v3-671b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "seamless-m4t-medium"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    if not shape_applicable(cfg, shape)[0]:
        pytest.skip("skip cell")
    mi = _mesh_info(False)
    caches = ST.abstract_caches(cfg, shape)
    specs = SH.cache_specs(caches, cfg, mi, shape.global_batch)
    _check_tree(specs, caches, mi, f"{arch} caches {shape_name}")


def test_vocab_padding_always_shards():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 512 == 0
        assert cfg.vocab_padded >= cfg.vocab_size
        assert cfg.vocab_padded % 16 == 0
