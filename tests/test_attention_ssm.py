"""Numerics of the attention + SSM substrates against naive references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import LMConfig
from repro.models.lm.attention import (apply_rope, blockwise_attention,
                                       decode_attention, rope_freqs)
from repro.models.lm import ssm as S

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, causal=True):
    b, sq, h, d = q.shape
    g = k.shape[2]
    rep = h // g
    qh = q.reshape(b, sq, g, rep, d).astype(jnp.float32)
    s = jnp.einsum("bqgrd,bkgd->bqgrk", qh, k.astype(jnp.float32)) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqgrk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d)


@pytest.mark.parametrize("sq,chunk", [(16, 4), (16, 16), (13, 5)])
@pytest.mark.parametrize("h,g", [(4, 4), (8, 2)])
def test_blockwise_matches_naive(sq, chunk, h, g):
    d = 8
    q = jax.random.normal(KEY, (2, sq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, sq, g, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, sq, g, d))
    a = blockwise_attention(q, k, v, causal=True, chunk=chunk)
    b = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_blockwise_noncausal():
    q = jax.random.normal(KEY, (1, 8, 2, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 2, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 2, 4))
    a = blockwise_attention(q, k, v, causal=False, chunk=5)
    b = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_decode_attention_masks_beyond_length():
    q = jax.random.normal(KEY, (1, 1, 2, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 2, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 10, 2, 4))
    out5 = decode_attention(q, k, v, jnp.asarray(5))
    k2 = k.at[:, 5:].set(999.0)         # garbage beyond fill must not matter
    v2 = v.at[:, 5:].set(999.0)
    out5b = decode_attention(q, k2, v2, jnp.asarray(5))
    np.testing.assert_allclose(np.asarray(out5), np.asarray(out5b), rtol=1e-5)


def test_rope_is_rotation():
    cos, sin = rope_freqs(8, 1e4, jnp.arange(6))
    x = jax.random.normal(KEY, (1, 6, 2, 8))
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


# ---------------------------------------------------------------------------
# SSM: chunked scan == naive recurrence; decode == forward
# ---------------------------------------------------------------------------

_SSM_CFG = LMConfig(name="t", family="ssm", n_layers=1, d_model=16, n_heads=0,
                    n_kv_heads=0, d_ff=0, vocab_size=64, ssm_state=4,
                    ssm_conv=3, ssm_chunk=5)


def test_mamba1_chunked_equals_stepwise_decode():
    """Running the full sequence == feeding tokens one-by-one through the
    decode recurrence (exactness of the chunked scan + state handoff)."""
    p = S.init_mamba1(KEY, _SSM_CFG, jnp.float32)
    u = jax.random.normal(KEY, (2, 11, 16)) * 0.3
    full, state = S.mamba1_forward(p, u, _SSM_CFG, return_state=True)
    cache = S.mamba1_init_cache(_SSM_CFG, 2)
    outs = []
    for t in range(11):
        y, cache = S.mamba1_decode(p, u[:, t:t + 1], _SSM_CFG, cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state["h"]), np.asarray(cache["h"]),
                               rtol=2e-3, atol=2e-3)


_M2_CFG = dataclasses.replace(_SSM_CFG, family="hybrid", ssm_head_dim=8,
                              n_heads=2, n_kv_heads=2, d_ff=32, ssm_state=4)


def test_mamba2_chunked_equals_stepwise_decode():
    p = S.init_mamba2(KEY, _M2_CFG, jnp.float32)
    u = jax.random.normal(KEY, (2, 11, 16)) * 0.3
    full, state = S.mamba2_forward(p, u, _M2_CFG, return_state=True)
    cache = S.mamba2_init_cache(_M2_CFG, 2)
    outs = []
    for t in range(11):
        y, cache = S.mamba2_decode(p, u[:, t:t + 1], _M2_CFG, cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state["h"]), np.asarray(cache["h"]),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20))
def test_mamba1_state_exact_for_any_seq_len_vs_chunk(s_len):
    """Padding correction: the returned state must be exact even when
    seq_len % chunk != 0 (dt=0 identity updates on the pad)."""
    p = S.init_mamba1(KEY, _SSM_CFG, jnp.float32)
    u = jax.random.normal(KEY, (1, s_len, 16)) * 0.3
    _, st1 = S.mamba1_forward(p, u, _SSM_CFG, return_state=True)
    big = dataclasses.replace(_SSM_CFG, ssm_chunk=64)   # single big chunk
    _, st2 = S.mamba1_forward(p, u, big, return_state=True)
    np.testing.assert_allclose(np.asarray(st1["h"]), np.asarray(st2["h"]),
                               rtol=1e-4, atol=1e-5)
