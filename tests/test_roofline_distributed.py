"""Roofline HLO parsers (trip-count-aware) + multi-device behaviours.

Multi-device cases (shard_map flash-decoding, compressed psum, pipeline,
a miniature dry-run, elastic restore) run in SUBPROCESSES because
XLA_FLAGS device-count faking must precede jax import — the main test
process stays at 1 device by design.
"""
import json
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


# ---------------------------------------------------------------------------
# parser units (no devices needed)
# ---------------------------------------------------------------------------

def test_collective_parser_trip_counts():
    hlo = """\
HloModule m

%body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %ar = f32[4,8]{1,0} all-reduce(%x), replica_groups={}
}

%cond (p: (s32[], f32[4,8])) -> pred[] {
  %c = pred[] compare(%a, %b)
}

ENTRY %main (a: f32[4,8]) -> f32[4,8] {
  %w = (s32[], f32[4,8]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[8,8]{1,0} all-gather(f32[4,8]{1,0} %y), dimensions={0}
}
"""
    from repro.launch.roofline import parse_collective_bytes
    got = parse_collective_bytes(hlo)
    assert got["all-reduce"] == 4 * 8 * 4 * 7          # result type x trips
    assert got["all-gather"] == 4 * 8 * 4              # operand type x 1


def test_dot_flops_parser():
    hlo = """\
HloModule m

%body (p: (s32[])) -> (s32[]) {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  %d = f32[8,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond (p: (s32[])) -> pred[] {
  %c = pred[] compare(%x, %y)
}

ENTRY %main (a: f32[8,16]) -> f32[8,4] {
  %w = (s32[]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
}
"""
    from repro.launch.roofline import parse_dot_flops
    assert parse_dot_flops(hlo) == 2 * 8 * 4 * 16 * 3


def test_roofline_terms_math():
    from repro.launch.roofline import roofline, PEAK_FLOPS, HBM_BW, ICI_BW
    t = roofline(PEAK_FLOPS, HBM_BW, ICI_BW * 2, 4, PEAK_FLOPS * 4)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 2.0) < 1e-9
    assert t.dominant == "collective"
    assert abs(t.useful_flops_ratio - 1.0) < 1e-9


def test_analytic_flops_cross_check_unrolled():
    """The analytic cost model must agree with XLA's own cost analysis on an
    UNROLLED compile (no scan undercount) of a reduced dense config."""
    out = _run("""
        import jax, jax.numpy as jnp, json
        from repro.configs.registry import get_config
        from repro.models.lm import transformer as T
        cfg = get_config('granite-3-2b', smoke=True)
        p = jax.eval_shape(lambda k: T.init_lm(k, cfg), jax.random.PRNGKey(0))
        B, S = 2, 64
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

        def fwd_unrolled(params, tokens):
            x = jnp.take(params['embed'], tokens, axis=0)
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i], params['layers'])
                x, _ = T.block_forward(lp, x, cfg)
            from repro.models.lm.attention import rmsnorm
            x = rmsnorm(x, params['final_norm'], cfg.norm_eps)
            return (x @ params['lm_head']).astype(jnp.float32)

        c = jax.jit(fwd_unrolled).lower(p, toks).compile()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per partition
            ca = ca[0]
        flops_xla = ca['flops']
        from repro.launch.costmodel import cell_cost
        from repro.configs.base import ShapeSpec
        cc = cell_cost(cfg, ShapeSpec('t', S, B, 'prefill'), 1)
        print(json.dumps({'xla': flops_xla, 'analytic': cc.flops_global}))
    """, devices=1)
    d = json.loads(out.strip().splitlines()[-1])
    ratio = d["analytic"] / d["xla"]
    # blockwise attention recompute + bf16 dot counting give slack; the model
    # must be the right order of magnitude and not undercount by layers.
    assert 0.5 < ratio < 2.0, d


# ---------------------------------------------------------------------------
# multi-device subprocess tests
# ---------------------------------------------------------------------------

def test_flash_decode_matches_reference():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.collectives import flash_decode_attention
        from repro.models.lm.attention import decode_attention
        mesh = make_test_mesh((8,), ('model',))
        B, S, G, H, D = 2, 32, 2, 4, 8
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (B, 1, H, D))
        kc = jax.random.normal(k2, (B, S, G, D))
        vc = jax.random.normal(k3, (B, S, G, D))
        length = jnp.asarray(20)
        ref = decode_attention(q, kc, vc, length)
        got = flash_decode_attention(mesh, 'model', q, kc, vc, length)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)
        print('OK')
    """)
    assert "OK" in out


def test_compressed_psum_reduces_with_error_feedback():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.collectives import compressed_psum, init_error_state
        mesh = make_test_mesh((4,), ('data',))
        g = {'w': jnp.linspace(-1, 1, 64).reshape(8, 8)}
        err = init_error_state(g)
        red, err = compressed_psum(mesh, 'data', g, err)
        np.testing.assert_allclose(np.asarray(red['w']), np.asarray(g['w']),
                                   rtol=0.02, atol=0.02)   # int8 quant noise
        # error feedback: accumulated residual is bounded by one quant step
        assert float(jnp.abs(err['w']).max()) <= float(jnp.abs(g['w']).max()) / 127 + 1e-6
        print('OK')
    """)
    assert "OK" in out


def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.pipeline import pipelined_forward, bubble_fraction
        mesh = make_test_mesh((4,), ('pod',))
        L, MB, B, S, D = 8, 6, 2, 4, 16
        keys = jax.random.split(jax.random.PRNGKey(0), L)
        Ws = jax.vmap(lambda k: 0.3 * jax.random.normal(k, (D, D)))(keys)

        def stage_fn(stage_params, x):   # stage_params: (L/stages, D, D)
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, stage_params)
            return x

        x = jax.random.normal(jax.random.PRNGKey(1), (MB, B, S, D))
        got = pipelined_forward(mesh, stage_fn, {'w': Ws}['w'], x, 4)
        # sequential reference
        def seq(x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            y, _ = jax.lax.scan(body, x, Ws)
            return y
        ref = jax.vmap(seq)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)
        assert abs(bubble_fraction(6, 4) - 3/9) < 1e-9
        print('OK')
    """)
    assert "OK" in out


def test_elastic_restore_to_smaller_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.launch.mesh import make_test_mesh
        mesh8 = make_test_mesh((8,), ('data',))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P('data', None)))
        d = tempfile.mkdtemp()
        cm = CheckpointManager(d)
        cm.save(1, {'x': x})
        # 'lose half the fleet': restore onto a 4-way mesh
        mesh4 = make_test_mesh((4,), ('data',))
        sh = {'x': NamedSharding(mesh4, P('data', None))}
        restored, _ = cm.restore({'x': x}, shardings=sh)
        assert restored['x'].sharding.num_devices == 4
        np.testing.assert_allclose(np.asarray(restored['x']), np.asarray(x))
        print('OK')
    """)
    assert "OK" in out


def test_shardmap_moe_matches_einsum_reference():
    """Both shard_map MoE modes (expert-TP with psum-after-combine, EP with
    all_to_all) must equal the single-device einsum MoE bit-for-bit-ish when
    capacity is generous (§Perf G2/G4/D1 changes are comm-only)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import LMConfig
        from repro.models.lm import ffn as F
        from repro.distributed.moe import moe_forward_shardmap
        from repro.launch.mesh import make_test_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = make_test_mesh((2, 4), ('data', 'model'))
        for mode, E in [('expert_tp', 4), ('ep_alltoall', 8)]:
            cfg = LMConfig(name='t', family='moe', n_layers=1, d_model=16,
                           n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                           n_experts=E, n_experts_per_tok=2, moe_d_ff=32,
                           moe_mode=mode, capacity_factor=8.0)
            p = F.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
            ref, _ = F.moe_forward(p, x, cfg)
            if mode == 'ep_alltoall':
                wi = NamedSharding(mesh, P('model', 'data', None))
                wo = NamedSharding(mesh, P('model', None, 'data'))
            else:
                wi = NamedSharding(mesh, P(None, 'data', 'model'))
                wo = NamedSharding(mesh, P(None, 'model', 'data'))
            ps = {'router': p['router'],
                  'w_in': jax.device_put(p['w_in'], wi),
                  'w_gate': jax.device_put(p['w_gate'], wi),
                  'w_out': jax.device_put(p['w_out'], wo)}
            xs = jax.device_put(x, NamedSharding(mesh, P('data', 'model', None)))
            got, _ = moe_forward_shardmap(ps, xs, cfg, mesh, 'data', 'model')
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-5, atol=1e-6)
        print('OK')
    """)
    assert "OK" in out


def test_mini_dryrun_on_test_mesh():
    """The full lower_cell path (train + decode) on a 4-device test mesh with
    a reduced config — the same machinery the 512-chip dry-run uses."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import ShapeSpec
        from repro.configs.registry import get_config
        from repro.distributed import sharding as SH
        from repro.launch import steps as ST
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((2, 2), ('data', 'model'))
        mi = SH.mesh_info(mesh)
        cfg = get_config('granite-8b', smoke=True)
        for spec in (ShapeSpec('t', 64, 4, 'train'), ShapeSpec('d', 64, 4, 'decode'),
                     ShapeSpec('p', 64, 4, 'prefill')):
            cell = ST.lower_cell(cfg, spec, mi, remat=True)
            compiled = cell.lowered.compile()
            assert compiled.memory_analysis() is not None
        print('OK')
    """, devices=4)
    assert "OK" in out
