"""MoE routing + the ESSR-style dynamic-width FFN."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.lm import ffn as F

KEY = jax.random.PRNGKey(0)

MOE_CFG = LMConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                   n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4,
                   n_experts_per_tok=2, moe_d_ff=32, capacity_factor=2.0)


def test_moe_forward_shapes_and_finite():
    p = F.init_moe(KEY, MOE_CFG, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))
    y, aux = F.moe_forward(p, x, MOE_CFG)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(float(aux))


def test_moe_with_shared_expert():
    cfg = dataclasses.replace(MOE_CFG, n_shared_experts=1)
    p = F.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))
    y, _ = F.moe_forward(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_grads_flow_to_experts_and_router():
    p = F.init_moe(KEY, MOE_CFG, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))

    def loss(p):
        y, aux = F.moe_forward(p, x, MOE_CFG)
        return jnp.mean(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_in"]).max()) > 0


def test_moe_capacity_drops_tokens_when_tight():
    """cf=0.1 forces drops; output must stay finite and dropped tokens get
    only the shared-expert/zero contribution (never NaN)."""
    cfg = dataclasses.replace(MOE_CFG, capacity_factor=0.1)
    p = F.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, 16))
    y, _ = F.moe_forward(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # with tiny capacity some outputs are exactly zero rows
    zero_rows = (np.abs(np.asarray(y)).sum(-1) == 0).sum()
    assert zero_rows > 0


def test_moe_capacity_sublane_aligned():
    assert F.moe_capacity(4096, MOE_CFG) % 8 == 0


# ---------------------------------------------------------------------------
# dynamic width (the paper's technique for LMs)
# ---------------------------------------------------------------------------

def test_dynamic_width_full_capacity_equals_mlp():
    p = F.init_mlp(KEY, 16, 32, "silu", jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))
    full = F.dynamic_width_ffn(p, x, "silu", capacity_frac=1.0)
    ref = F.mlp(p, x, "silu")
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_dynamic_width_half_uses_shared_slice():
    """Tokens routed to the narrow path must equal an explicit half-width MLP
    built from the SAME weights (the C27 c C54 sharing rule)."""
    p = F.init_mlp(KEY, 16, 32, "silu", jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 16))
    out = F.dynamic_width_ffn(p, x, "silu", capacity_frac=0.25)
    half = {"w_in": p["w_in"][:, :16], "w_gate": p["w_gate"][:, :16],
            "w_out": p["w_out"][:16]}
    ref_half = F.mlp(half, x, "silu")
    scores = F.token_edge_score(x.reshape(-1, 16))
    order = np.argsort(-np.asarray(scores))
    narrow_tokens = order[2:]                     # capacity = 2 of 8
    got = np.asarray(out).reshape(-1, 16)[narrow_tokens]
    want = np.asarray(ref_half).reshape(-1, 16)[narrow_tokens]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_token_edge_score_orders_by_magnitude():
    x = jnp.stack([jnp.ones(8) * 0.1, jnp.ones(8) * 5.0])
    s = np.asarray(F.token_edge_score(x))
    assert s[1] > s[0]
