"""Slim-overlap patching + overlap-average fusion (Sec. IV-I)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.patching import (extract_patches, fuse_patches_average,
                                 grid_starts, overlap_mac_overhead)


@settings(max_examples=30, deadline=None)
@given(st.integers(33, 200), st.integers(8, 48), st.integers(0, 6))
def test_grid_covers_every_pixel(size, patch, overlap):
    if overlap >= patch or patch > size:
        return
    starts = grid_starts(size, patch, overlap)
    covered = np.zeros(size, bool)
    for s in starts:
        covered[s:s + patch] = True
        assert s + patch <= size
    assert covered.all()


def test_extract_fuse_identity():
    """overlap+average of the identity model reconstructs the frame exactly."""
    img = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (64, 64, 3)).astype(np.float32))
    patches, pos = extract_patches(img, patch=32, overlap=2)
    out = fuse_patches_average(patches, pos, 1, (64, 64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(img), atol=1e-6)


def test_fuse_averages_disagreeing_patches():
    img = jnp.zeros((34, 32, 1))                 # tiles only along y: 2 patches
    patches, pos = extract_patches(img, patch=32, overlap=30)
    assert patches.shape[0] == 2
    patches = patches.at[0].set(0.0).at[1].set(1.0)
    out = fuse_patches_average(patches, pos, 1, (34, 32))
    # overlapping band (rows 2..31) must average to 0.5
    assert abs(float(out[17, 10, 0]) - 0.5) < 1e-6
    assert abs(float(out[0, 10, 0]) - 0.0) < 1e-6      # only patch 0
    assert abs(float(out[33, 10, 0]) - 1.0) < 1e-6     # only patch 1


def test_paper_mac_overhead_114_percent():
    # Table IV: 8-px HR overlap (2-px LR at x4) -> 114% MACs
    assert abs(overlap_mac_overhead(32, 2) - 1.138) < 0.01


def test_positions_scale_to_hr():
    img = jnp.zeros((62, 62, 3))
    patches, pos = extract_patches(img, patch=32, overlap=2)
    assert patches.shape[0] == len(pos) == 4
    assert pos[-1].tolist() == [30, 30]
