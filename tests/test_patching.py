"""Slim-overlap patching + overlap-average fusion (Sec. IV-I)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.patching import (extract_patches, extract_patches_loop,
                                 fuse_patches_average,
                                 fuse_patches_average_loop, get_geometry,
                                 grid_starts, overlap_mac_overhead)


@settings(max_examples=30, deadline=None)
@given(st.integers(33, 200), st.integers(8, 48), st.integers(0, 6))
def test_grid_covers_every_pixel(size, patch, overlap):
    if overlap >= patch or patch > size:
        return
    starts = grid_starts(size, patch, overlap)
    covered = np.zeros(size, bool)
    for s in starts:
        covered[s:s + patch] = True
        assert s + patch <= size
    assert covered.all()


def test_extract_fuse_identity():
    """overlap+average of the identity model reconstructs the frame exactly."""
    img = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (64, 64, 3)).astype(np.float32))
    patches, pos = extract_patches(img, patch=32, overlap=2)
    out = fuse_patches_average(patches, pos, 1, (64, 64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(img), atol=1e-6)


def test_fuse_averages_disagreeing_patches():
    img = jnp.zeros((34, 32, 1))                 # tiles only along y: 2 patches
    patches, pos = extract_patches(img, patch=32, overlap=30)
    assert patches.shape[0] == 2
    patches = patches.at[0].set(0.0).at[1].set(1.0)
    out = fuse_patches_average(patches, pos, 1, (34, 32))
    # overlapping band (rows 2..31) must average to 0.5
    assert abs(float(out[17, 10, 0]) - 0.5) < 1e-6
    assert abs(float(out[0, 10, 0]) - 0.0) < 1e-6      # only patch 0
    assert abs(float(out[33, 10, 0]) - 1.0) < 1e-6     # only patch 1


# -- vectorized paths vs the seed loop oracles -------------------------------

SWEEP = [  # (h, w, patch, overlap, scale) incl. odd frame sizes
    (64, 64, 32, 2, 4), (62, 62, 32, 2, 2), (47, 53, 16, 3, 2),
    (34, 32, 32, 30, 1), (33, 95, 32, 2, 4), (40, 40, 8, 0, 2),
]


@pytest.mark.parametrize("h,w,patch,overlap,scale", SWEEP)
def test_vectorized_extract_matches_loop(h, w, patch, overlap, scale):
    img = jnp.asarray(np.random.default_rng(1).uniform(
        0, 1, (h, w, 3)).astype(np.float32))
    pv, posv = extract_patches(img, patch, overlap)
    pl, posl = extract_patches_loop(img, patch, overlap)
    assert np.array_equal(posv, posl)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(pl))


@pytest.mark.parametrize("h,w,patch,overlap,scale", SWEEP)
def test_vectorized_fuse_matches_loop(h, w, patch, overlap, scale):
    g = get_geometry(h, w, patch, overlap, scale)
    ps = patch * scale
    sr = jnp.asarray(np.random.default_rng(2).uniform(
        0, 1, (g.n, ps, ps, 3)).astype(np.float32))
    ref = fuse_patches_average_loop(sr, g.pos, scale, (h * scale, w * scale))
    np.testing.assert_allclose(np.asarray(g.fuse_average(sr)),
                               np.asarray(ref), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(fuse_patches_average(sr, g.pos, scale,
                                        (h * scale, w * scale))),
        np.asarray(ref), atol=1e-5)


def test_fuse_average_arbitrary_positions():
    """Non-cartesian position lists take the flat-scatter fallback."""
    pos = np.array([(0, 0), (2, 5)], dtype=np.int64)   # not a product grid
    sr = jnp.ones((2, 8, 8, 1))
    out = fuse_patches_average(sr, pos, 1, (10, 13))
    ref = fuse_patches_average_loop(sr, pos, 1, (10, 13))
    covered = ~np.isnan(np.asarray(ref))
    np.testing.assert_allclose(np.asarray(out)[covered],
                               np.asarray(ref)[covered], atol=1e-6)


def test_small_frame_reflect_pad():
    """Frames smaller than the patch are reflect-padded, then cropped back
    (the seed crashed in lax.dynamic_slice)."""
    img = jnp.asarray(np.random.default_rng(3).uniform(
        0, 1, (20, 24, 3)).astype(np.float32))
    patches, pos = extract_patches(img, patch=32, overlap=2)
    assert patches.shape == (1, 32, 32, 3) and pos.tolist() == [[0, 0]]
    # identity model round-trip still reconstructs the original exactly
    out = fuse_patches_average(patches, pos, 1, (20, 24))
    np.testing.assert_allclose(np.asarray(out), np.asarray(img), atol=1e-6)
    g = get_geometry(20, 24, 32, 2, 2)
    fused = g.fuse_average(jnp.repeat(jnp.repeat(g.extract(img), 2, 1), 2, 2))
    assert fused.shape == (40, 48, 3)


def test_geometry_cache_hits():
    a = get_geometry(64, 64, 32, 2, 4)
    assert get_geometry(64, 64, 32, 2, 4) is a     # LRU: zero per-frame setup
    assert get_geometry(64, 64, 32, 2, 2) is not a


def test_paper_mac_overhead_114_percent():
    # Table IV: 8-px HR overlap (2-px LR at x4) -> 114% MACs
    assert abs(overlap_mac_overhead(32, 2) - 1.138) < 0.01


def test_positions_scale_to_hr():
    img = jnp.zeros((62, 62, 3))
    patches, pos = extract_patches(img, patch=32, overlap=2)
    assert patches.shape[0] == len(pos) == 4
    assert pos[-1].tolist() == [30, 30]
