"""The range certifier must be sound (concrete runs always land inside the
predicted intervals), must prove the shipped integer datapath overflow-free,
must fail closed on anything it cannot bound — and the cost model + metrics
gate must price and protect the same entry points."""
import functools
import importlib.util
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.cost_model import price_jaxpr
from repro.analysis.range_infer import (
    TOP,
    bits_needed,
    check_quant_scales,
    hull,
    infer_ranges,
)
from repro.analysis.report import Report, gate_metrics

REPO_ROOT = __file__.rsplit("/tests/", 1)[0]


@functools.lru_cache(maxsize=1)
def _setup():
    from repro.analysis.jaxpr_audit import _audit_setup
    return _audit_setup()


def codes(violations):
    return {v.code for v in violations}


# ---------------------------------------------------------------------------
# interval domain basics
# ---------------------------------------------------------------------------

def test_bits_needed():
    assert bits_needed(0, 127) == 8
    assert bits_needed(-128, 127) == 8
    assert bits_needed(0, 128) == 9
    assert bits_needed(-32768, 32767) == 16
    assert bits_needed(0, 0) == 1
    assert bits_needed(float("-inf"), 0) is None


def test_interval_arithmetic_is_exact_on_simple_chain():
    def f(x):
        y = x * 2.0 - 1.0                       # [-1, 1]
        return jnp.abs(y) + jnp.minimum(y, 0.0)  # [0,1] + [-1,0]

    res = infer_ranges(f, (jnp.zeros((4,), jnp.float32),), {0: (0.0, 1.0)})
    iv = hull(res.outputs)
    assert (iv.lo, iv.hi) == (-1.0, 1.0)
    assert res.violations == []


def test_concrete_arguments_fold_exactly():
    # the correlated alpha/step chain a pure interval domain cannot bound:
    # with concrete weights it folds to the exact code values
    w = jnp.asarray([0.5, -1.5, 3.0], jnp.float32)

    def f(w, x):
        alpha = jnp.max(jnp.abs(w))
        step = alpha / 127.0
        wq = jnp.round(jnp.clip(w, -alpha, alpha) / step)
        return x * jnp.max(jnp.abs(wq))

    res = infer_ranges(f, (w, jnp.zeros((4,), jnp.float32)),
                       {1: (0.0, 1.0)})
    iv = hull(res.outputs)
    assert (iv.lo, iv.hi) == (0.0, 127.0)


# ---------------------------------------------------------------------------
# ESSR301 — overflow proof failures
# ---------------------------------------------------------------------------

def test_essr301_huge_alpha_overflows_int8():
    # a huge alpha with a unit step pushes codes far past the int8 lattice
    def f(x):
        codes_ = jnp.round(jnp.clip(x, -1e6, 1e6) / 1.0)
        return codes_.astype(jnp.int8)

    res = infer_ranges(f, (jnp.zeros((8,), jnp.float32),), {0: (0.0, 1e6)},
                       entry="fixture.huge_alpha")
    assert "ESSR301" in codes(res.violations)


def test_essr301_int16_accumulator_budget_on_qref():
    from repro.kernels.qconv import essr_forward_qref
    s = _setup()
    fn = lambda p, x: essr_forward_qref(p, x, s.cfg, width=8, pack=s.pack)
    args = (s.params, s.patches)
    # the int8 chain needs ~18 accumulator bits: a what-if 16-bit budget is
    # a proof failure...
    res16 = infer_ranges(fn, args, {1: (0.0, 1.0)},
                         entry="fixture.qref16", acc_bits=16)
    assert "ESSR301" in codes(res16.violations)
    # ...while the real int32 accumulators certify clean
    res32 = infer_ranges(fn, args, {1: (0.0, 1.0)},
                         entry="fixture.qref32", acc_bits=32)
    assert res32.violations == []


def test_essr302_bit_budget_gate():
    from repro.kernels.qconv import essr_forward_qref
    s = _setup()
    fn = lambda p, x: essr_forward_qref(p, x, s.cfg, width=8, pack=s.pack)
    res = infer_ranges(fn, (s.params, s.patches), {1: (0.0, 1.0)},
                       entry="fixture.budget", bit_budget=12)
    assert "ESSR302" in codes(res.violations)
    gs = res.groups()
    assert gs and max(g["acc_bits"] for g in gs.values()) > 12
    assert all(g["headroom_vs_paper"] == 24 - g["acc_bits"]
               for g in gs.values())


# ---------------------------------------------------------------------------
# ESSR303 — degenerate quant scales
# ---------------------------------------------------------------------------

class _FakePack:
    qmax = 127
    scales = ((8, (("first", 1e-15), ("sfb0_b1", 0.5))),)


def test_essr303_degenerate_scale_flagged():
    vs = check_quant_scales(_FakePack(), "test")
    assert [v.code for v in vs] == ["ESSR303"]
    assert "first" in vs[0].site and "test" in vs[0].site


def test_essr303_shipped_packs_clean():
    s = _setup()
    assert check_quant_scales(s.pack, "int8") == []
    assert check_quant_scales(s.pack_fxp10, "fxp10") == []


# ---------------------------------------------------------------------------
# ESSR304 — fail closed, never guess
# ---------------------------------------------------------------------------

def test_essr304_unknown_primitive_fails_closed():
    def f(x):
        return jax.lax.population_count(x)

    res = infer_ranges(f, (jnp.zeros((4,), jnp.int32),), {0: (0.0, 8.0)},
                       entry="fixture.popcount")
    assert "ESSR304" in codes(res.violations)
    assert hull(res.outputs) == TOP          # unbounded, not guessed


# ---------------------------------------------------------------------------
# satellite: the quantization step floor is ONE constant (pams.EPS)
# ---------------------------------------------------------------------------

def test_step_floor_unified_at_degenerate_alpha():
    from repro.kernels.qconv import act_qconsts
    from repro.quant import pams

    for alpha in (0.0, 1e-30, -1e-9, 0.3, 7.5):
        a, s = act_qconsts(alpha, 127)
        a_ref = float(pams.effective_alpha(jnp.asarray(alpha, jnp.float32)))
        s_ref = float(pams.step_size(jnp.asarray(a_ref, jnp.float32), 127))
        assert a == a_ref
        assert s == s_ref, f"floor mismatch at alpha={alpha}"
        assert s >= pams.EPS

    # and the code lattices agree bit-for-bit at the degenerate point
    x = jnp.linspace(-1.0, 1.0, 16, dtype=jnp.float32)
    a, s = act_qconsts(0.0, 127)
    ref = pams.int_codes(x, pams.effective_alpha(jnp.float32(0.0)), 127)
    kern = jnp.round(jnp.clip(x, -a, a) / s).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(kern))


# ---------------------------------------------------------------------------
# soundness: concrete integer activations stay inside predicted intervals
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.25, 4.0))
def test_qref_codes_inside_predicted_intervals(seed, wscale):
    from repro.kernels.qconv import essr_forward_qref
    from repro.models.essr import init_essr
    from repro.quant.pams import build_quant_pack

    s = _setup()
    params = jax.tree_util.tree_map(
        lambda w: w * wscale, init_essr(jax.random.PRNGKey(seed), s.cfg))
    pack = build_quant_pack(params, s.cfg, "int8", s.patches)
    fn = lambda p, x: essr_forward_qref(p, x, s.cfg, width=8, pack=pack,
                                        return_codes=True)
    res = infer_ranges(fn, (params, s.patches), {1: (0.0, 1.0)},
                       entry="prop.qref")
    assert res.violations == []
    img_iv, code_ivs = res.outputs

    x = jax.random.uniform(jax.random.PRNGKey(seed ^ 0x9E37),
                           s.patches.shape, jnp.float32)
    img, concrete = fn(params, x)
    for site, c in concrete.items():
        iv = hull(code_ivs[site])
        lo, hi = float(jnp.min(c)), float(jnp.max(c))
        assert iv.lo - 1e-5 <= lo and hi <= iv.hi + 1e-5, (
            f"{site}: concrete [{lo}, {hi}] escapes predicted "
            f"[{iv.lo}, {iv.hi}]")
    # the fp image tail: predicted bounds are real-arithmetic, so the f32
    # evaluation may exceed them by rounding ulps — relative slack
    iv = hull(img_iv)
    slack = 1e-4 + 1e-5 * max(abs(iv.lo), abs(iv.hi))
    assert iv.lo - slack <= float(jnp.min(img))
    assert float(jnp.max(img)) <= iv.hi + slack


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_prices_known_matmul():
    closed = jax.make_jaxpr(lambda a, b: a @ b)(
        jnp.zeros((4, 8), jnp.float32), jnp.zeros((8, 16), jnp.float32))
    cost = price_jaxpr(closed)
    assert cost.macs == 4 * 8 * 16
    assert cost.int_macs == 0
    assert cost.io_bytes == (4 * 8 + 8 * 16 + 4 * 16) * 4
    assert cost.hbm_bytes == cost.io_bytes


def test_cost_model_counts_integer_macs():
    closed = jax.make_jaxpr(
        lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32))(
        jnp.zeros((4, 8), jnp.int8), jnp.zeros((8, 16), jnp.int8))
    cost = price_jaxpr(closed)
    assert cost.macs == cost.int_macs == 4 * 8 * 16


# ---------------------------------------------------------------------------
# metrics gate
# ---------------------------------------------------------------------------

def _mk(macs=100.0, hbm=1000.0, bits=18, entry="e", group="g"):
    return Report([], metrics={
        "static_costs": {"entries": {entry: {"macs": macs,
                                             "hbm_bytes": hbm}}},
        "bitwidth": {"paper_acc_bits": 24,
                     "entries": {entry: {"groups": {group: {
                         "acc_bits": bits}}}}},
    })


def test_gate_metrics_semantics():
    base = _mk()
    assert gate_metrics(_mk(), base) == []                     # identical
    assert gate_metrics(_mk(macs=105.0), base) == []           # inside band
    fails = gate_metrics(_mk(macs=120.0), base, traffic_tol=0.10)
    assert len(fails) == 1 and "macs" in fails[0]              # traffic grew
    assert gate_metrics(_mk(macs=50.0, hbm=400.0), base) == []  # shrink ok
    fails = gate_metrics(_mk(bits=19), base)
    assert len(fails) == 1 and "bit-width grew" in fails[0]    # headroom
    assert gate_metrics(_mk(bits=17), base) == []              # tighter ok
    fails = gate_metrics(_mk(entry="other"), base)
    assert len(fails) == 2                                     # coverage loss
    assert gate_metrics(base, _mk(entry="other"))  # symmetric loss flagged
    assert gate_metrics(_mk(), Report([])) == []               # no baseline


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _lint_cli():
    spec = importlib.util.spec_from_file_location(
        "essr_lint", os.path.join(REPO_ROOT, "scripts", "essr_lint.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_cli_list_rules(capsys):
    assert _lint_cli().main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    from repro.analysis.report import RULE_REGISTRY
    for code in RULE_REGISTRY:
        assert code in out


def test_cli_select_rejects_unknown_code():
    with pytest.raises(SystemExit):
        _lint_cli().main(["--ast", "--select", "ESSR999"])


def test_cli_ignore_filters_pass():
    assert _lint_cli().main(["--ast", "--ignore", "ESSR201,ESSR202",
                             "--no-baseline"]) == 0


# ---------------------------------------------------------------------------
# the committed baseline certifies the shipped tree
# ---------------------------------------------------------------------------

def test_committed_baseline_certifies_bitwidths_and_costs():
    with open(os.path.join(REPO_ROOT, "ANALYSIS_baseline.json")) as f:
        base = json.load(f)
    from repro.analysis.report import RULES
    assert base["rules"] == {c: RULES[c] for c in sorted(RULES)}

    bw = base["metrics"]["bitwidth"]
    assert bw["paper_acc_bits"] == 24
    fxp = bw["entries"]["kernels.qconv.essr_forward_qkernels[fxp10]"]
    assert fxp["groups"], "fxp10 chain must be certified per fused group"
    for entry, row in bw["entries"].items():
        for group, info in row["groups"].items():
            assert info["acc_bits"] <= 32, (entry, group)
            assert info["headroom_vs_paper"] == 24 - info["acc_bits"]

    cost = base["metrics"]["static_costs"]["entries"]
    fused = cost["core.pipeline.fused_frame_fn[pallas-int8]"]
    assert fused["int_macs"] > 0 and fused["hbm_bytes"] > 0
    assert fused["pallas_traffic"], "per-kernel traffic must be recorded"


def test_shipped_entry_points_certify_clean():
    from repro.analysis.range_infer import run_range_audit
    violations, metrics = run_range_audit()
    assert violations == []
    # the int-domain reference chains fit the paper's 24-bit accumulator
    ref8 = metrics["entries"]["kernels.qconv.essr_forward_qref[int8]"]
    assert ref8["groups"]["top"]["acc_bits"] <= 24
