"""Serving resilience (`repro.runtime.guard` + the `plan.on_poison` /
`plan.faults` / `plan.max_retries` / `plan.quarantine_ticks` /
`plan.watchdog_s` knobs).

Contract under test (docs/api.md "Resilience & fault injection"):

  * poison-frame matrix: NaN / Inf / out-of-range / wrong-dtype inputs
    across backends x quant modes x fusion levels follow the documented
    ``on_poison`` policy — "raise" raises `PoisonFrameError`, "sanitize"
    and "bilinear" always serve a finite frame, "off" disables verdicts;
  * the health verdict is computed in-graph (fused dispatch stays a single
    device program; no host sync added — tier-1 ESSR1xx audits hold);
  * injected faults are deterministic: identical seeded `FaultPlan` runs
    produce identical degradation ledgers and identical outputs;
  * the degradation ladder steps fusion -> backend -> quant in documented
    order, retries are bounded, and serving labels stay honest;
  * per-tenant isolation: a poisoned or crashing stream never perturbs
    healthy tenants (bit-equal vs a no-fault run with pinned capacity);
    quarantined streams re-admit after ``plan.quarantine_ticks``;
  * corrupted QuantPack caches and truncated checkpoint manifests warn and
    fall back instead of crashing engine construction.
"""
import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ExecutionPlan, SREngine
from repro.core.adaptive import SwitchingConfig
from repro.models.essr import ESSRConfig, init_essr
from repro.runtime.guard import (FaultInjector, FaultPlan,
                                 PoisonFrameError, build_ladder)

CFG = ESSRConfig(scale=2)
HW = 64                                      # 64x64 LR -> 9 patches


def _stable_switching():
    return SwitchingConfig(frame_high=10 ** 9, frame_low=0)


def _clean_frame(seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((HW, HW, 3), np.float32))


def _poison(frame, kind: str):
    """Hand-poisoned frame (independent of the injector, so the matrix
    exercises the verdict, not the harness)."""
    f = np.array(frame)
    if kind == "dtype":
        return (f * 255).astype(np.uint8)
    bad = {"nan": np.nan, "inf": np.inf, "range": 3.0e6}[kind]
    f[4:12, 4:12, :] = bad
    return jnp.asarray(f)


_ENGINES = {}


def _engine(backend="ref", quant=None, fusion="layer", on_poison="raise",
            **plan_kw):
    """Engines are cached per configuration: construction (PTQ calibration
    for quant modes) dominates the matrix's runtime otherwise."""
    key = (backend, quant, fusion, on_poison, tuple(sorted(plan_kw.items())))
    if key not in _ENGINES:
        plan = ExecutionPlan(dispatch="fused", quant=quant, fusion=fusion,
                             on_poison=on_poison, **plan_kw)
        _ENGINES[key] = SREngine.from_config(
            CFG, seed=1, backend=backend, plan=plan,
            switching=_stable_switching())
    return _ENGINES[key]


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------

def test_faultplan_validation():
    with pytest.raises(ValueError, match="FaultPlan.poison_rate"):
        FaultPlan(poison_rate=1.5)
    with pytest.raises(ValueError, match="FaultPlan.poison_kinds"):
        FaultPlan(poison_kinds=("gamma-ray",))
    with pytest.raises(ValueError, match="FaultPlan.delay_s"):
        FaultPlan(delay_rate=0.5, delay_s=-1.0)
    fp = FaultPlan(seed=3, poison_rate=0.5, poison_kinds=["nan", "inf"],
                   target_streams=[1])
    assert fp.poison_kinds == ("nan", "inf")       # normalized to tuples
    assert fp.target_streams == (1,)


def test_plan_resilience_knob_validation():
    with pytest.raises(ValueError, match="ExecutionPlan.on_poison"):
        ExecutionPlan(on_poison="panic")
    with pytest.raises(ValueError, match="ExecutionPlan.faults"):
        ExecutionPlan(faults="chaos")
    with pytest.raises(ValueError, match="ExecutionPlan.max_retries"):
        ExecutionPlan(max_retries=-1)
    with pytest.raises(ValueError, match="ExecutionPlan.watchdog_s"):
        ExecutionPlan(watchdog_s=0.0)
    with pytest.raises(ValueError, match="watchdog"):
        ExecutionPlan(dispatch="host", watchdog_s=1.0)   # cross rule


# ---------------------------------------------------------------------------
# poison-frame matrix
# ---------------------------------------------------------------------------

#: (backend, quant, fusion) serving points the matrix sweeps. The first is
#: the cheap reference point swept against every kind x policy; the others
#: confirm the verdict rides inside the quantized / grouped / pallas
#: executables too.
MATRIX_POINTS = [("ref", None, "layer"),
                 ("pallas", "int8", "group"),
                 ("ref", "fxp10", "layer")]


@pytest.mark.parametrize("kind", ["nan", "inf", "range", "dtype"])
@pytest.mark.parametrize("backend,quant,fusion", MATRIX_POINTS)
def test_poison_raise_policy(backend, quant, fusion, kind):
    eng = _engine(backend, quant, fusion, "raise")
    with pytest.raises(PoisonFrameError):
        eng.upscale(_poison(_clean_frame(), kind))
    # the engine is not wedged: the next clean frame serves normally
    r = eng.upscale(_clean_frame())
    assert r.health == (0, 0, 0)
    assert np.isfinite(np.asarray(r.image)).all()


@pytest.mark.parametrize("kind", ["nan", "inf", "range", "dtype"])
@pytest.mark.parametrize("policy", ["sanitize", "bilinear"])
@pytest.mark.parametrize("backend,quant,fusion", MATRIX_POINTS)
def test_poison_recovery_policies(backend, quant, fusion, policy, kind):
    eng = _engine(backend, quant, fusion, policy)
    r = eng.upscale(_poison(_clean_frame(), kind))
    img = np.asarray(r.image)
    assert np.isfinite(img).all(), f"{policy} must serve a finite frame"
    assert r.health is not None
    if kind == "dtype":
        # integer input is normalized on ingest; the normalized frame is
        # clean, so the verdict is all-zero but the frame still serves
        assert r.health == (0, 0, 0)
    else:
        assert any(r.health), f"verdict missed the {kind} poisoning"
        if policy == "bilinear":
            assert not np.asarray(r.ids).any(), \
                "bilinear policy must demote every patch to the dense floor"


def test_poison_off_disables_verdicts():
    eng = _engine("ref", None, "layer", "off")
    r = eng.upscale(_poison(_clean_frame(), "range"))
    assert r.health is None and r.degraded == ()


def test_sanitize_bit_equal_on_clean_frames():
    """The sanitize path is a no-op on healthy input: verdict-on serving
    must not perturb clean frames (the guarded-vs-unguarded bench band
    rests on this)."""
    frame = _clean_frame(5)
    a = _engine("ref", None, "layer", "off").upscale(frame)
    b = _engine("ref", None, "layer", "sanitize").upscale(frame)
    assert (np.asarray(a.image).tobytes() == np.asarray(b.image).tobytes())
    assert b.health == (0, 0, 0)


# ---------------------------------------------------------------------------
# fault-injection determinism
# ---------------------------------------------------------------------------

def test_injector_deterministic_across_instances():
    fp = FaultPlan(seed=11, poison_rate=0.5, poison_kinds=("nan", "range"))
    a, b = FaultInjector(fp), FaultInjector(fp)
    frame = np.array(_clean_frame(2))
    for idx in range(8):
        fa = np.asarray(a.poison_frame(frame, 0, idx))
        fb = np.asarray(b.poison_frame(frame, 0, idx))
        assert fa.tobytes() == fb.tobytes()
    # a different seed moves the corruption
    c = FaultInjector(FaultPlan(seed=12, poison_rate=0.5,
                                poison_kinds=("nan", "range")))
    assert any(
        np.asarray(c.poison_frame(frame, 0, i)).tobytes()
        != np.asarray(a.poison_frame(frame, 0, i)).tobytes()
        for i in range(8))


def test_degradation_ladder_order():
    steps = [v.step for v in build_ladder("pallas", False, True, "group")]
    # the first rung is the as-planned serving point (empty step label)
    assert steps == ["", "fusion:group->layer",
                     "backend:pallas->interpret", "backend:->ref",
                     "quant:->fp32"]
    # the floor plan has no rungs below it
    assert [v.step for v in build_ladder("ref", False, False, "layer")] \
        == [""]
    # interpret-resolved pallas skips the interpret rung
    assert "backend:pallas->interpret" not in [
        v.step for v in build_ladder("pallas", True, False, "layer")]


def test_injected_backend_failures_degrade_deterministically():
    fp = FaultPlan(seed=4, backend_failure_rate=1.0)

    def run():
        eng = SREngine.from_config(
            CFG, seed=1, backend="pallas",
            plan=ExecutionPlan(dispatch="fused", quant="int8",
                               fusion="group", faults=fp),
            switching=_stable_switching())
        outs = [eng.upscale(_clean_frame(i)) for i in range(4)]
        return eng, outs

    eng1, outs1 = run()
    s1 = eng1.summary()["degradations"]
    assert s1["by_kind"].get("degrade", 0) >= 1
    # every frame served despite the failures, each labeled for what ran
    assert all(np.isfinite(np.asarray(o.image)).all() for o in outs1)
    assert outs1[0].degraded != ()
    eng2, outs2 = run()
    s2 = eng2.summary()["degradations"]
    assert s1["by_kind"] == s2["by_kind"]
    assert s1["by_step"] == s2["by_step"]
    assert [o.degraded for o in outs1] == [o.degraded for o in outs2]
    assert [o.backend for o in outs1] == [o.backend for o in outs2]


def test_watchdog_records_ladder_step():
    # a pallas/group plan has rungs for the watchdog to step down; an
    # impossible 1ns budget fires it on every frame
    eng = SREngine.from_config(
        CFG, seed=1, backend="pallas",
        plan=ExecutionPlan(dispatch="fused", fusion="group",
                           watchdog_s=1e-9),
        switching=_stable_switching())
    outs = list(eng.stream([_clean_frame(i) for i in range(3)]))
    assert len(outs) == 3
    assert any(o.degraded for o in outs)
    assert eng.summary()["degradations"]["by_kind"].get("watchdog", 0) >= 1
    # at the floor the watchdog keeps recording but has nothing to step
    eng2 = SREngine.from_config(
        CFG, seed=1, plan=ExecutionPlan(dispatch="fused", watchdog_s=1e-9),
        switching=_stable_switching())
    list(eng2.stream([_clean_frame(i) for i in range(2)]))
    assert eng2.summary()["degradations"]["by_kind"].get("watchdog", 0) >= 1


# ---------------------------------------------------------------------------
# per-tenant isolation (StreamMultiplexer)
# ---------------------------------------------------------------------------

def _mux_engine(params, faults, on_poison="raise", qt=1):
    plan = ExecutionPlan(dispatch="fused", streams=3, capacity=(0, 9, 9),
                         on_poison=on_poison, faults=faults,
                         quarantine_ticks=qt)
    return SREngine(params, CFG, plan=plan, switching=_stable_switching())


@pytest.fixture(scope="module")
def params():
    return init_essr(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def tenant_frames():
    return [[_clean_frame(100 * s + i) for i in range(4)] for s in range(3)]


def test_mux_poison_isolation_bit_equal(params, tenant_frames):
    """One tenant's poisoned frames must not perturb the others by a single
    bit (pinned capacity keeps the shared pool fault-independent), and the
    quarantine cycle must be deterministic across identical runs."""
    fp = FaultPlan(seed=7, poison_rate=1.0, poison_kinds=("nan",),
                   target_streams=(1,))
    base = list(_mux_engine(params, None).serve_streams(tenant_frames))
    eng1 = _mux_engine(params, fp)
    outs1 = list(eng1.serve_streams(tenant_frames))
    assert all(o.stream_id != 1 for o in outs1), \
        "poisoned tenant's results must be suppressed under raise"
    by_base, by_fault = {}, {}
    for o in base:
        by_base.setdefault(o.stream_id, []).append(np.asarray(o.image))
    for o in outs1:
        by_fault.setdefault(o.stream_id, []).append(np.asarray(o.image))
    for sid in (0, 2):
        assert len(by_fault[sid]) == len(by_base[sid]) == 4
        for a, b in zip(by_base[sid], by_fault[sid]):
            assert a.tobytes() == b.tobytes(), f"tenant {sid} perturbed"
    kinds = eng1.summary()["degradations"]["by_kind"]
    assert kinds.get("quarantine", 0) >= 1 and kinds.get("readmit", 0) >= 1
    eng2 = _mux_engine(params, fp)
    outs2 = list(eng2.serve_streams(tenant_frames))
    assert [o.stream_id for o in outs1] == [o.stream_id for o in outs2]
    assert kinds == eng2.summary()["degradations"]["by_kind"]


def test_mux_quarantine_zero_retires_permanently(params, tenant_frames):
    fp = FaultPlan(seed=7, poison_rate=1.0, poison_kinds=("inf",),
                   target_streams=(1,))
    eng = _mux_engine(params, fp, qt=0)
    outs = list(eng.serve_streams(tenant_frames))
    assert all(o.stream_id != 1 for o in outs)
    kinds = eng.summary()["degradations"]["by_kind"]
    # retired on the FIRST poison verdict: no re-admission, one poison event
    assert kinds.get("retire", 0) == 1 and kinds.get("poison", 0) == 1
    assert "readmit" not in kinds


def test_mux_sanitize_serves_every_tenant(params, tenant_frames):
    fp = FaultPlan(seed=7, poison_rate=1.0, poison_kinds=("nan",),
                   target_streams=(1,))
    eng = _mux_engine(params, fp, on_poison="sanitize")
    outs = list(eng.serve_streams(tenant_frames))
    assert sorted({o.stream_id for o in outs}) == [0, 1, 2]
    for o in outs:
        assert np.isfinite(np.asarray(o.image)).all()
        if o.stream_id == 1:
            assert o.health is not None and o.health[0] > 0


def test_mux_iterator_crash_retires_only_that_stream(params, tenant_frames):
    class Boom:
        def __init__(self, frames):
            self.frames = frames

        def __iter__(self):
            yield self.frames[0]
            raise RuntimeError("tenant iterator died")

    eng = _mux_engine(params, None)
    streams = [tenant_frames[0], Boom(tenant_frames[1]), tenant_frames[2]]
    outs = list(eng.serve_streams(streams))
    ids = [o.stream_id for o in outs]
    assert ids.count(1) == 1, "stream 1 serves its one good frame"
    assert ids.count(0) == 4 and ids.count(2) == 4, \
        "healthy tenants serve every frame"
    kinds = eng.summary()["degradations"]["by_kind"]
    assert kinds.get("retire", 0) == 1


def test_solo_stream_iterator_exception_recorded():
    def frames():
        yield _clean_frame(0)
        yield _clean_frame(1)
        raise ValueError("camera unplugged")

    eng = _engine("ref", None, "layer", "raise")
    n_before = len(eng.guard.events)
    outs = list(eng.stream(frames()))
    assert len(outs) == 2
    retires = [e for e in eng.guard.events[n_before:]
               if e["kind"] == "retire"]
    assert len(retires) == 1 and "camera unplugged" in retires[0]["reason"]


# ---------------------------------------------------------------------------
# persisted-state integrity (QuantPack cache, checkpoint manifest)
# ---------------------------------------------------------------------------

def test_quant_pack_corruption_warns_and_recalibrates(tmp_path):
    from repro.quant.pams import (build_quant_pack, load_quant_pack,
                                  params_fingerprint, save_quant_pack)
    p = init_essr(jax.random.PRNGKey(0), CFG)
    x = jnp.stack([_clean_frame(i)[:32, :32] for i in range(2)])
    pack = build_quant_pack(p, CFG, "int8", x)
    fp = params_fingerprint(p)
    path = str(tmp_path / "alphas.json")
    save_quant_pack(path, pack, fp)
    assert load_quant_pack(path, fp) == pack       # round trip intact
    # truncation fails the integrity checksum -> warn + recalibrate
    with open(path) as f:
        body = f.read()
    with open(path, "w") as f:
        f.write(body[: len(body) // 2])
    with pytest.warns(UserWarning, match="corrupted"):
        assert load_quant_pack(path, fp) is None
    # injector-corrupted payload (not even JSON) -> same fallback
    save_quant_pack(path, pack, fp)
    FaultInjector.corrupt_file(path)
    with pytest.warns(UserWarning, match="corrupted"):
        assert load_quant_pack(path, fp) is None
    # a bit-flip inside otherwise-valid JSON is caught by the checksum
    save_quant_pack(path, pack, fp)
    with open(path) as f:
        tampered = f.read().replace('"bits": 8', '"bits": 7')
    with open(path, "w") as f:
        f.write(tampered)
    with pytest.warns(UserWarning, match="corrupted"):
        assert load_quant_pack(path, fp) is None
    # quiet recalibration cases stay quiet: missing file, stale fingerprint,
    # and a legacy pack written before checksums were recorded
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert load_quant_pack(str(tmp_path / "missing.json"), fp) is None
        save_quant_pack(path, pack, fp)
        assert load_quant_pack(path, "0" * 16) is None
        with open(path) as f:
            legacy = json.load(f)
        del legacy["checksum"]
        with open(path, "w") as f:
            json.dump(legacy, f)
        assert load_quant_pack(path, fp) is None


def test_truncated_checkpoint_manifest_warns_and_serves(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    p = init_essr(jax.random.PRNGKey(7), CFG)
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, {"params": p, "ema": p}, blocking=True)
    manifest = tmp_path / "step_5" / "manifest.bin"
    blob = manifest.read_bytes()
    manifest.write_bytes(blob[: len(blob) // 2])
    with pytest.warns(UserWarning):
        eng = SREngine.from_checkpoint(str(tmp_path), cfg=CFG,
                                       bench_cache=None)
    # construction survived; the engine serves (fresh init fallback)
    r = eng.upscale(_clean_frame())
    assert np.isfinite(np.asarray(r.image)).all()
