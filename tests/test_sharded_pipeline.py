"""Sharded patch-stream execution: shard/unshard equivalence + degradation.

The data-parallel path (ExecutionPlan.shards > 1 -> shard_map over the 1-D
patch mesh) must be numerically indistinguishable from the single-device
path for every geometry, including frames whose patch count does not divide
the shard count. Multi-device cases run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI leg); on a
single-device host they exercise the transparent degrade path instead.
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan, SREngine
from repro.core.patching import shard_slices
from repro.core.pipeline import edge_selective_sr, sharded_forward
from repro.data.synthetic import degrade, random_image
from repro.launch.mesh import make_patch_mesh
from repro.models.essr import ESSRConfig, init_essr

MULTI = jax.device_count() >= 2
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


# -- shard_slices ------------------------------------------------------------

def test_shard_slices_cover_and_balance():
    for n, shards in [(10, 4), (9, 2), (8, 8), (3, 5), (0, 2), (7, 1)]:
        sl = shard_slices(n, shards)
        assert len(sl) == shards
        idx = np.concatenate([np.arange(n)[s] for s in sl])
        assert idx.tolist() == list(range(n))          # exact cover, in order
        sizes = [len(np.arange(n)[s]) for s in sl]
        assert max(sizes) - min(sizes) <= 1            # balanced
    with pytest.raises(ValueError):
        shard_slices(4, 0)


# -- mesh helpers ------------------------------------------------------------

def test_make_patch_mesh_validates():
    m = make_patch_mesh(1)
    assert m.axis_names == ("shard",) and m.size == 1
    with pytest.raises(ValueError):
        make_patch_mesh(0)
    with pytest.raises(ValueError):
        make_patch_mesh(jax.device_count() + 1)


def test_patch_batch_spec_requires_1d_mesh():
    from repro.distributed.sharding import patch_batch_spec
    assert patch_batch_spec(make_patch_mesh(1)) == \
        jax.sharding.PartitionSpec("shard")
    if jax.device_count() >= 4:
        bad = jax.make_mesh((2, 2), ("a", "b"))
        with pytest.raises(ValueError):
            patch_batch_spec(bad)


# -- equivalence: sharded vs single-device -----------------------------------

CFG = ESSRConfig(scale=2)


def _frame(seed, h, w, scale=2):
    return degrade(jnp.asarray(random_image(seed, h * scale, w * scale)),
                   scale)


@needs_devices
def test_sharded_forward_matches_single_device():
    """Raw per-subnet batch forward, padded non-divisible batch included."""
    params = init_essr(jax.random.PRNGKey(0), CFG)
    mesh = make_patch_mesh(min(4, jax.device_count()))
    for n in (4, 7):                    # 7 does not divide the mesh size
        patches = jax.random.uniform(jax.random.PRNGKey(n), (n, 32, 32, 3))
        for width in (0, 27, 54):
            got = sharded_forward(params, patches, CFG, width, mesh=mesh)
            from repro.core.pipeline import resolve_backend
            want = resolve_backend("ref")(params, patches, CFG, width)
            assert got.shape == (n, 64, 64, 3)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5)


@needs_devices
@pytest.mark.parametrize("patch,overlap,scale,hw", [
    (32, 2, 2, (64, 64)),     # 9 patches: does not divide 2 or 4 shards
    (32, 2, 2, (64, 96)),     # 12 patches
    (16, 4, 2, (48, 40)),     # non-default patch/overlap
    (32, 2, 4, (64, 64)),     # paper scale
])
def test_sharded_pipeline_allclose(patch, overlap, scale, hw):
    """Full edge-selective pipeline through the mesh == single device,
    threshold routing included, across patch/overlap/scale sweeps."""
    cfg = ESSRConfig(scale=scale)
    params = init_essr(jax.random.PRNGKey(1), cfg)
    # half smooth gradient / half noise: exercises all three routing classes
    # (random_image's stroke generator rejects sub-32px edge tiles)
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw[0]), jnp.linspace(0, 1, hw[1]),
                          indexing="ij")
    smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
    noise = jax.random.uniform(jax.random.PRNGKey(2), (hw[0], hw[1], 3))
    frame = jnp.where((yy < 0.5)[..., None], smooth, noise)
    mesh = make_patch_mesh(min(4, jax.device_count()))
    kw = dict(patch=patch, overlap=overlap)
    want = edge_selective_sr(params, frame, cfg, **kw)
    got = edge_selective_sr(params, frame, cfg, mesh=mesh, **kw)
    assert got.ids.tolist() == want.ids.tolist()
    np.testing.assert_allclose(np.asarray(got.image), np.asarray(want.image),
                               atol=1e-5)


@needs_devices
def test_engine_shards_allclose_and_surfaces_fields():
    """Acceptance criterion: ExecutionPlan(shards=4) frames are allclose to
    the single-device path; streamed FrameResults carry per-shard fields."""
    single = SREngine.from_config(CFG, seed=3)
    shard4 = SREngine.from_config(CFG, seed=3, plan=ExecutionPlan(shards=4))
    frame = _frame(9, 64, 64)
    r1, r4 = single.upscale(frame), shard4.upscale(frame)
    assert r4.shards == 4
    np.testing.assert_allclose(np.asarray(r1.image), np.asarray(r4.image),
                               atol=1e-5)
    res = shard4.serve(frame)
    assert len(res.shard_counts) == 4
    assert len(res.shard_thresholds) == 4
    assert res.shard_deadline_missed == (False,) * 4     # no deadline set
    assert sum(sum(c) for c in res.shard_counts) == res.n_patches
    s = shard4.summary()
    assert s["shards"] == 4 and s["shard_deadline_misses"] == [0, 0, 0, 0]


def test_engine_degrades_transparently_on_few_devices():
    """shards > device_count keeps per-shard routing control but dispatches
    on the devices that exist — numerics identical, a warning tells the
    operator."""
    want_warn = jax.device_count() < 8
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = SREngine.from_config(CFG, seed=5, plan=ExecutionPlan(shards=8))
    assert any("device" in str(x.message) for x in w) == want_warn
    frame = _frame(11, 64, 64)
    r = eng.upscale(frame)
    ref = SREngine.from_config(CFG, seed=5).upscale(frame)
    np.testing.assert_allclose(np.asarray(r.image), np.asarray(ref.image),
                               atol=1e-5)
    res = eng.serve(frame)                 # 9 patches over 8 logical shards
    assert len(res.shard_counts) == 8      # routing stays 8-way sharded
    assert res.n_patches == 9


def test_straggler_demotion_drops_next_frame_c54_through_engine():
    """Engine-level satellite criterion: with an impossible deadline the
    overloaded shard is demoted and its next-frame C54 count drops."""
    from repro.core.adaptive import SwitchingConfig
    # top strip = noise (C54 demand), bottom strip = flat (cheap): shard 0
    # owns the heavy raster rows
    noise = jax.random.uniform(jax.random.PRNGKey(0), (32, 64, 3))
    flat = jnp.full((32, 64, 3), 0.5)
    frame = jnp.concatenate([noise, flat], axis=0)
    eng = SREngine.from_config(
        CFG, seed=0, plan=ExecutionPlan(shards=3), deadline_s=1e-9,
        switching=SwitchingConfig(c54_per_sec_budget=10 ** 9,
                                  frame_high=10 ** 6, frame_low=0))
    first = eng.serve(frame)
    assert first.deadline_missed
    assert any(first.shard_deadline_missed)
    heavy = int(np.argmax([c[2] for c in first.shard_counts]))
    assert first.shard_deadline_missed[heavy]
    t_first = first.shard_thresholds[heavy]
    second = eng.serve(frame)
    assert second.shard_thresholds[heavy] > t_first     # keeps rising
    # demotion holds or shrinks the straggler's C54 share, never grows it
    assert second.shard_counts[heavy][2] <= first.shard_counts[heavy][2]
    # run until the demotions bite: C54 must eventually drop strictly
    for _ in range(30):
        cur = eng.serve(frame)
        if cur.shard_counts[heavy][2] < first.shard_counts[heavy][2]:
            break
    else:
        pytest.fail("straggler demotion never reduced the shard's C54 count")
