"""PAMS quantization (Sec. IV-H): FXP10 + int8 modes."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.essr import ESSR_X4, ESSRConfig, essr_forward, init_essr
from repro.quant.pams import (QuantConfig, calibrate_act_scales, int_codes,
                              quantize, quantized_essr_forward,
                              quantize_weight_tree)

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 10]))
def test_quant_error_bounded_by_half_step(seed, bits):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,)) * 2.0
    alpha = jnp.asarray(2.5)
    qmax = 2 ** (bits - 1) - 1
    q = quantize(x, alpha, qmax)
    step = float(alpha) / qmax
    inside = np.abs(np.asarray(x)) <= float(alpha)
    err = np.abs(np.asarray(q - x))[inside]
    assert (err <= step / 2 + 1e-6).all()


def test_int_codes_in_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
    for bits in (8, 10):
        qmax = 2 ** (bits - 1) - 1
        codes = np.asarray(int_codes(x, jnp.asarray(1.5), qmax))
        assert codes.min() >= -qmax and codes.max() <= qmax


def test_ste_gradient_passthrough():
    f = lambda x: jnp.sum(quantize(x, jnp.asarray(1.0), 511))
    g = jax.grad(f)(jnp.asarray([0.3, -0.2, 0.9]))
    np.testing.assert_allclose(np.asarray(g), 1.0)      # identity inside clip


def test_quantized_forward_close_to_fp_fxp10():
    """Paper: whole-model FXP10 costs only ~0.03 dB. An untrained net has
    exploding activations (He init x 17 layers), so we measure SNR relative
    to the fp output rather than absolute PSNR."""
    cfg = ESSRConfig(scale=2)
    p = init_essr(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 12, 12, 3))
    # max-calibration (percentile 100): isolates pure rounding error
    qc10 = QuantConfig(bits=10, act_percentile=100.0)
    qc8 = QuantConfig(bits=8, act_percentile=100.0)
    scales = calibrate_act_scales(p, cfg, x, qc10)
    fp = np.asarray(essr_forward(p, x, cfg))

    def snr(q):
        err = np.asarray(q) - fp
        return 10 * np.log10(np.mean(fp ** 2) / max(np.mean(err ** 2), 1e-12))

    snr10 = snr(quantized_essr_forward(p, scales, x, cfg, qc10))
    snr8 = snr(quantized_essr_forward(p, scales, x, cfg, qc8))
    assert snr10 > 25.0                      # FXP10 near-transparent
    assert snr10 >= snr8 + 3.0               # 2 extra bits must help clearly


def test_weight_quant_skips_biases():
    p = init_essr(jax.random.PRNGKey(0), ESSR_X4)
    qp = quantize_weight_tree(p, QuantConfig(bits=10))
    np.testing.assert_array_equal(np.asarray(qp["first"]["pw_b"]),
                                  np.asarray(p["first"]["pw_b"]))
    assert not np.allclose(np.asarray(qp["first"]["pw"]), np.asarray(p["first"]["pw"]))
