"""PAMS quantization (Sec. IV-H): FXP10 + int8 modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.essr import ESSR_X4, ESSRConfig, essr_forward, init_essr
from repro.quant.pams import (QuantConfig, QuantPack, build_quant_pack,
                              calibrate_act_scales, calibrate_subnet_scales,
                              int_codes, load_quant_pack, params_fingerprint,
                              quantize, quantized_essr_forward,
                              quantize_weight_tree, save_quant_pack)

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 10]))
def test_quant_error_bounded_by_half_step(seed, bits):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,)) * 2.0
    alpha = jnp.asarray(2.5)
    qmax = 2 ** (bits - 1) - 1
    q = quantize(x, alpha, qmax)
    step = float(alpha) / qmax
    inside = np.abs(np.asarray(x)) <= float(alpha)
    err = np.abs(np.asarray(q - x))[inside]
    assert (err <= step / 2 + 1e-6).all()


def test_int_codes_in_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
    for bits in (8, 10):
        qmax = 2 ** (bits - 1) - 1
        codes = np.asarray(int_codes(x, jnp.asarray(1.5), qmax))
        assert codes.min() >= -qmax and codes.max() <= qmax


def test_ste_gradient_passthrough():
    f = lambda x: jnp.sum(quantize(x, jnp.asarray(1.0), 511))
    g = jax.grad(f)(jnp.asarray([0.3, -0.2, 0.9]))
    np.testing.assert_allclose(np.asarray(g), 1.0)      # identity inside clip


def test_quantized_forward_close_to_fp_fxp10():
    """Paper: whole-model FXP10 costs only ~0.03 dB. An untrained net has
    exploding activations (He init x 17 layers), so we measure SNR relative
    to the fp output rather than absolute PSNR."""
    cfg = ESSRConfig(scale=2)
    p = init_essr(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 12, 12, 3))
    # max-calibration (percentile 100): isolates pure rounding error
    qc10 = QuantConfig(bits=10, act_percentile=100.0)
    qc8 = QuantConfig(bits=8, act_percentile=100.0)
    scales = calibrate_act_scales(p, cfg, x, qc10)
    fp = np.asarray(essr_forward(p, x, cfg))

    def snr(q):
        err = np.asarray(q) - fp
        return 10 * np.log10(np.mean(fp ** 2) / max(np.mean(err ** 2), 1e-12))

    snr10 = snr(quantized_essr_forward(p, scales, x, cfg, qc10))
    snr8 = snr(quantized_essr_forward(p, scales, x, cfg, qc8))
    assert snr10 > 25.0                      # FXP10 near-transparent
    assert snr10 >= snr8 + 3.0               # 2 extra bits must help clearly


def test_weight_quant_skips_biases():
    p = init_essr(jax.random.PRNGKey(0), ESSR_X4)
    qp = quantize_weight_tree(p, QuantConfig(bits=10))
    np.testing.assert_array_equal(np.asarray(qp["first"]["pw_b"]),
                                  np.asarray(p["first"]["pw_b"]))
    assert not np.allclose(np.asarray(qp["first"]["pw"]), np.asarray(p["first"]["pw"]))


# ---------------------------------------------------------------------------
# quantize / int_codes invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 10]),
       st.floats(1e-3, 8.0))
def test_fake_quant_idempotent(seed, bits, alpha):
    """quantize is a projection onto the lattice: applying it twice changes
    nothing (requires the divide and the dequant to use the SAME step)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 3
    a = jnp.asarray(alpha, jnp.float32)
    qmax = 2 ** (bits - 1) - 1
    q1 = quantize(x, a, qmax)
    q2 = quantize(q1, a, qmax)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 10]),
       st.floats(1e-3, 8.0))
def test_quant_symmetry(seed, bits, alpha):
    """Symmetric quantizer: negating the input negates codes and dequant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 3
    a = jnp.asarray(alpha, jnp.float32)
    qmax = 2 ** (bits - 1) - 1
    np.testing.assert_array_equal(np.asarray(quantize(-x, a, qmax)),
                                  np.asarray(-quantize(x, a, qmax)))
    np.testing.assert_array_equal(np.asarray(int_codes(-x, a, qmax)),
                                  np.asarray(-int_codes(x, a, qmax)))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 10]),
       st.floats(1e-30, 8.0))
def test_qmax_saturation(seed, bits, alpha):
    """Codes never leave [-qmax, qmax] and dequant never leaves
    [-alpha, alpha], however extreme the inputs or tiny the alpha."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 100
    a = jnp.asarray(alpha, jnp.float32)
    qmax = 2 ** (bits - 1) - 1
    codes = np.asarray(int_codes(x, a, qmax))
    assert np.abs(codes).max() <= qmax
    q = np.asarray(quantize(x, a, qmax))
    # the relative term covers scale-rounding at normal alphas, the absolute
    # term the epsilon-floored step (alpha below qmax*1e-12 quantizes on a
    # coarser-than-alpha lattice by design)
    assert np.abs(q).max() <= float(a) * (1 + 1e-6) + 1e-12


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([8, 10]),
       st.floats(0.0, 1e-10))
def test_alpha_to_zero_collapses_to_zero(bits, alpha):
    """alpha -> 0 degenerates gracefully: once the true step underflows the
    epsilon floor, everything clips into a vanishing range and both codes
    and dequant collapse to exactly 0 — the old mismatched-epsilon form
    instead produced codes that dequantized inconsistently."""
    x = jnp.asarray([-2.0, -1e-11, 0.0, 1e-11, 2.0], jnp.float32)
    a = jnp.asarray(alpha, jnp.float32)
    qmax = 2 ** (bits - 1) - 1
    q = quantize(x, a, qmax)
    codes = int_codes(x, a, qmax)
    assert np.abs(np.asarray(codes)).max() <= qmax
    # still idempotent and consistent: dequant(codes) == fake-quant value
    np.testing.assert_array_equal(np.asarray(quantize(q, a, qmax)),
                                  np.asarray(q))
    # codes and step agree: dequant reproduces codes * step exactly
    step = max(float(a) / qmax, 1e-12)
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(codes, np.float32) * np.float32(step))
    if alpha < 0.5e-12:
        # everything clips under half the floored step -> exactly zero
        np.testing.assert_array_equal(np.asarray(codes), 0)
        np.testing.assert_array_equal(np.asarray(q), 0.0)


# ---------------------------------------------------------------------------
# PTQ calibration: padded patches must not bias the percentile
# ---------------------------------------------------------------------------

def test_calibration_ignores_padded_patches():
    """Bucket padding repeats the LAST patch; feeding such a batch to the
    percentile without masking weights that patch's activations pad+1 times.
    With ``n_valid`` the padded batch calibrates exactly like the clean one."""
    cfg = ESSRConfig(scale=2, channels=8, n_sfb=2)
    p = init_essr(jax.random.PRNGKey(0), cfg)
    clean = jax.random.uniform(jax.random.PRNGKey(1), (6, 12, 12, 3))
    # an outlier-heavy last patch, then bucket-style padding that repeats it
    clean = clean.at[-1].set(clean[-1] * 5.0)
    padded = jnp.concatenate([clean, jnp.repeat(clean[-1:], 10, axis=0)])

    want = calibrate_act_scales(p, cfg, clean, QuantConfig())
    got = calibrate_act_scales(p, cfg, padded, QuantConfig(), n_valid=6)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-6)
    # and without the mask the repeated outlier really does bias the alphas
    biased = calibrate_act_scales(p, cfg, padded, QuantConfig())
    assert any(float(biased[k]) > float(want[k]) * 1.05 for k in want)
    with pytest.raises(ValueError):
        calibrate_act_scales(p, cfg, padded, QuantConfig(), n_valid=0)


def test_subnet_scales_cover_conv_widths():
    cfg = ESSRConfig(scale=2, channels=8, n_sfb=2)
    p = init_essr(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 12, 12, 3))
    by_width = calibrate_subnet_scales(p, cfg, x)
    assert sorted(by_width) == [4, 8]             # bilinear (0) excluded
    # C27-vs-C54 activations genuinely differ through the shared weights
    assert by_width[4] != by_width[8]


def test_quant_pack_roundtrip_and_fingerprint(tmp_path):
    cfg = ESSRConfig(scale=2, channels=8, n_sfb=2)
    p = init_essr(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 12, 12, 3))
    pack = build_quant_pack(p, cfg, "int8", x)
    fp = params_fingerprint(p)
    path = str(tmp_path / "alphas.json")
    save_quant_pack(path, pack, fp)
    loaded = load_quant_pack(path, fp)
    assert loaded == pack                         # exact, hash-stable
    assert isinstance(loaded, QuantPack) and hash(loaded) == hash(pack)
    # alphas calibrated for other weights never load
    other = params_fingerprint(init_essr(jax.random.PRNGKey(9), cfg))
    assert other != fp
    assert load_quant_pack(path, other) is None
    assert load_quant_pack(str(tmp_path / "missing.json"), fp) is None
