"""Sharding-agnostic, async, keep-K checkpointing (no orbax in container).

Layout:  <dir>/step_<N>/
           manifest.msgpack.zst   — tree structure, dtypes, shapes, meta
           a_<i>.npy              — one file per leaf (host/global view)

Properties needed at 1000-node scale, implemented and tested here:
  * atomicity      — write to ``.tmp-step_<N>`` then os.rename (POSIX atomic);
  * async          — ``save(..., blocking=False)`` snapshots to host memory on
                     the caller's thread (cheap) and writes on a background
                     thread, off the training step path;
  * elasticity     — leaves are stored as *global* arrays with a mesh-free
                     manifest; ``restore(..., shardings=...)`` re-shards onto
                     whatever mesh the restarted job has (data-axis resize);
  * retention      — keep the newest ``keep`` steps, delete older atomically.

On a multi-host fleet the per-leaf write would be sharded per host; the file
format (leaf-per-file + manifest) is chosen so that extension is local.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax

try:
    import msgpack
    import zstandard as zstd
    _HAVE_MSGPACK = True
except Exception:                                    # pragma: no cover
    _HAVE_MSGPACK = False


def _tree_paths(tree) -> Tuple[List[str], List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [f"a_{i}" for i in range(len(leaves))]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write --------------------------------------------------------------

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        paths, leaves, treedef = _tree_paths(tree)
        # snapshot to host memory NOW (so the training step may mutate buffers)
        host_leaves = [np.asarray(x) for x in leaves]
        manifest = {
            "step": int(step),
            "treedef": str(treedef),           # structural fingerprint for checks
            "tree_template": json.dumps(jax.tree_util.tree_map(lambda _: 0, tree)),
            "leaves": [{"file": p, "dtype": str(a.dtype), "shape": list(a.shape)}
                       for p, a in zip(paths, host_leaves)],
            "meta": meta or {},
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp-step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for p, a in zip(paths, host_leaves):
                np.save(os.path.join(tmp, p + ".npy"), a)
            blob = msgpack.packb(manifest) if _HAVE_MSGPACK else json.dumps(manifest).encode()
            if _HAVE_MSGPACK:
                blob = zstd.ZstdCompressor().compress(blob)
            with open(os.path.join(tmp, "manifest.bin"), "wb") as f:
                f.write(blob)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- read ---------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> Dict:
        """Parsed manifest of ``step`` (newest by default) — tree structure,
        per-leaf dtype/shape, meta — WITHOUT loading any arrays. Lets callers
        inspect what a checkpoint holds (e.g. whether an "ema" tree exists)
        before committing to a restore template."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.bin"), "rb") as f:
            blob = f.read()
        if _HAVE_MSGPACK:
            return msgpack.unpackb(zstd.ZstdDecompressor().decompress(blob))
        return json.loads(blob.decode())            # pragma: no cover

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``template``. If ``shardings`` (a
        pytree of jax.sharding.Sharding matching template) is given, leaves are
        device_put with it — this is the elastic-resize path."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        manifest = self.read_manifest(step)
        paths, leaves, treedef = _tree_paths(template)
        assert len(paths) == len(manifest["leaves"]), \
            f"checkpoint has {len(manifest['leaves'])} leaves, template {len(paths)}"
        arrays = [np.load(os.path.join(d, e["file"] + ".npy")) for e in manifest["leaves"]]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays), dict(manifest["meta"], step=step)
