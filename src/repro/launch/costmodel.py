"""Analytic per-cell cost model: FLOPs + HBM bytes (EXPERIMENTS §Roofline).

Why analytic: XLA's ``cost_analysis()`` counts while/scan bodies ONCE, so a
scan-over-layers model is undercounted ~n_layers-fold (verified empirically —
see EXPERIMENTS.md §Dry-run "measurement notes"). FLOPs therefore come from
two independent sources that cross-check each other:

  * measured   — roofline.parse_dot_flops: trip-count-aware HLO walk (exact
                 for matmuls, excludes elementwise);
  * analytic   — the closed forms below (validated against an UNROLLED
                 compile of the smoke configs in tests/test_roofline.py).

HBM bytes are analytic only (coefficients documented inline); XLA's raw
"bytes accessed" is recorded as a per-body lower bound.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import LMConfig, ShapeSpec
from repro.configs.base import param_count_estimate

BF16 = 2
F32 = 4

# train matmul multiplier: fwd(1) + bwd(2) + remat re-forward(1)
TRAIN_MATMUL_X = 4.0
HEAD_MATMUL_X = 3.0          # logits head is not rematted


def _attn_flops_per_tok(cfg: LMConfig, ctx: float) -> float:
    """Projections + score/out matmuls at average context ``ctx``."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, g = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
        proj = 2 * (d * qr + qr * h * (dn + dr) + d * (kr + dr)
                    + kr * h * (dn + dv) + h * dv * d)
        attn = 2 * ctx * h * (dn + dr) + 2 * ctx * h * dv
        return proj + attn
    proj = 2 * d * hd * (h + 2 * g) + 2 * h * hd * d
    attn = 2 * ctx * h * hd * 2
    return proj + attn


def _ffn_flops_per_tok(cfg: LMConfig) -> float:
    d = cfg.d_model
    if cfg.n_experts:
        f = cfg.moe_d_ff or cfg.d_ff
        router = 2 * d * cfg.n_experts
        routed = cfg.n_experts_per_tok * cfg.capacity_factor * 3 * 2 * d * f
        shared = cfg.n_shared_experts * 3 * 2 * d * f
        return router + routed + shared
    mats = 2 if cfg.act == "relu2" else 3
    return mats * 2 * d * cfg.d_ff


def _ssm_flops_per_tok(cfg: LMConfig) -> float:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    if cfg.family == "ssm":
        r = cfg.dt_rank
        proj = 2 * d * 2 * di + 2 * cfg.ssm_conv * di + 2 * di * (r + 2 * n) + 2 * r * di
        scan = 8.0 * di * n                     # exp/mul/add elementwise recurrence
        out = 2 * di * n + 2 * di * d
        return proj + scan + out
    heads = di // cfg.ssm_head_dim
    proj = 2 * d * (2 * di + 2 * n + heads) + 2 * cfg.ssm_conv * (di + 2 * n)
    scan = 8.0 * di * n
    out = 2 * di * n + 2 * di * d
    return proj + scan + out


def _layer_flops_per_tok(cfg: LMConfig, ctx: float) -> float:
    if cfg.family == "ssm":
        return _ssm_flops_per_tok(cfg)
    if cfg.family == "hybrid":
        per = _ssm_flops_per_tok(cfg)
        if cfg.shared_attn_every:
            shared = (_attn_flops_per_tok(cfg, ctx) + 3 * 2 * cfg.d_model * cfg.d_ff)
            per += shared / cfg.shared_attn_every
        return per
    return _attn_flops_per_tok(cfg, ctx) + _ffn_flops_per_tok(cfg)


@dataclasses.dataclass
class CellCost:
    flops_global: float
    hbm_bytes_global: float
    notes: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


def cell_cost(cfg: LMConfig, shape: ShapeSpec, n_chips: int) -> CellCost:
    b, s = shape.global_batch, shape.seq_len
    d, v = cfg.d_model, cfg.vocab_padded
    n_params = param_count_estimate(cfg)
    kind = shape.kind

    if kind in ("train", "prefill"):
        tokens = b * s
        ctx = s / 2.0                            # causal average context
        enc_tokens = tokens if cfg.is_encoder_decoder else 0
        layer = _layer_flops_per_tok(cfg, ctx) * cfg.n_layers * tokens
        if cfg.is_encoder_decoder:               # bidirectional enc + cross attn
            enc_layer = (_attn_flops_per_tok(cfg, s) + 3 * 2 * d * cfg.d_ff)
            layer += enc_layer * cfg.n_encoder_layers * enc_tokens
            layer += 2 * s * cfg.n_heads * cfg.resolved_head_dim * 2 * cfg.n_layers * tokens  # cross
        head = 2.0 * d * v * tokens
        if kind == "train":
            flops = TRAIN_MATMUL_X * layer + HEAD_MATMUL_X * head
            if cfg.mtp:
                flops += TRAIN_MATMUL_X * _layer_flops_per_tok(cfg, ctx) * tokens \
                         + HEAD_MATMUL_X * head / 1.0
        else:
            flops = layer + head
    else:                                        # decode: 1 token per sequence
        tokens = b
        ctx = s                                  # full cache attended
        layer = _layer_flops_per_tok(cfg, ctx if cfg.has_attention else 0) * cfg.n_layers * tokens
        head = 2.0 * d * v * tokens
        flops = layer + head

    # ---------------- HBM bytes (documented coefficients) -----------------
    p_bytes = n_params * BF16
    if kind == "train":
        # weights: 3 reads (fwd/bwd/remat) + grad w+r + adam m,v r+w (f32) + update
        weight_traffic = 3 * p_bytes + 2 * p_bytes + 4 * n_params * F32 + p_bytes
        act_per_tok_layer = BF16 * (8 * d + 4 * _ffn_width(cfg) + 4 * _attn_width(cfg))
        act_traffic = 3 * act_per_tok_layer * cfg.n_layers * tokens   # fwd+bwd+remat
        ce_traffic = 2.0 * tokens * (v / max(1, _mp_guess(n_chips))) * F32 * _mp_guess(n_chips)
        hbm = weight_traffic + act_traffic + ce_traffic
    elif kind == "prefill":
        act_per_tok_layer = BF16 * (6 * d + 2 * _ffn_width(cfg) + 2 * _attn_width(cfg))
        hbm = p_bytes + act_per_tok_layer * cfg.n_layers * tokens + _cache_bytes(cfg, b, s)
    else:
        hbm = p_bytes + _cache_bytes(cfg, b, s) + BF16 * 12 * d * cfg.n_layers * tokens
    return CellCost(flops_global=float(flops), hbm_bytes_global=float(hbm))


def _ffn_width(cfg: LMConfig) -> float:
    if cfg.n_experts:
        return (cfg.n_experts_per_tok * cfg.capacity_factor + cfg.n_shared_experts) \
            * (cfg.moe_d_ff or cfg.d_ff)
    if cfg.family in ("ssm", "hybrid"):
        return 2 * cfg.d_inner
    return cfg.d_ff


def _attn_width(cfg: LMConfig) -> float:
    if not cfg.has_attention:
        return 0.0
    return cfg.n_heads * cfg.resolved_head_dim


def _mp_guess(n_chips: int) -> int:
    return 16


def _cache_bytes(cfg: LMConfig, b: int, s: int) -> float:
    """Total KV/state cache bytes (read once per decode step)."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        return F32 * b * L * cfg.d_inner * cfg.ssm_state
    if cfg.family == "hybrid":
        heads = cfg.d_inner // cfg.ssm_head_dim
        ssm = F32 * b * L * heads * cfg.ssm_head_dim * cfg.ssm_state
        n_inv = L // cfg.shared_attn_every if cfg.shared_attn_every else 0
        kv = BF16 * 2 * b * n_inv * s * cfg.n_kv_heads * cfg.resolved_head_dim
        return ssm + kv
    if cfg.use_mla:
        return BF16 * b * L * s * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
    return BF16 * 2 * b * L * s * cfg.n_kv_heads * cfg.resolved_head_dim
