"""Step builders + abstract input specs for every (arch x shape) cell.

Everything here works on ShapeDtypeStructs (jax.eval_shape) so the dry-run
never allocates a byte of the 314B/671B models. The same builders, fed real
arrays, are the production train/serve step functions (launch/train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig, ShapeSpec
from repro.distributed import sharding as SH
from repro.distributed.ctx import use_ctx
from repro.models.lm import encdec as E
from repro.models.lm import transformer as T
from repro.train import optimizer as O

SRC_LEN_CAP = 4096        # enc-dec source length for decode cells (DESIGN §5)


# ---------------------------------------------------------------------------
# abstract params / state
# ---------------------------------------------------------------------------

def abstract_params(cfg: LMConfig):
    init = E.init_encdec if cfg.is_encoder_decoder else T.init_lm
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))


def make_optimizer(moment_dtype=jnp.float32) -> O.Optimizer:
    return O.chain_clip(O.adam(O.cosine_decay(3e-4, 100_000, warmup=2000),
                               moment_dtype=moment_dtype), 1.0)


def abstract_train_state(cfg: LMConfig, opt: O.Optimizer):
    p = abstract_params(cfg)
    return {"params": p, "opt": jax.eval_shape(opt.init, p)}


def train_state_specs(state, cfg: LMConfig, mi: SH.MeshInfo):
    pspec = SH.param_specs(state["params"], cfg, mi)
    ospec = {"step": P(),
             "m": jax.tree_util.tree_map(lambda _: None, state["opt"]["m"]),
             "v": None}
    # moments shard exactly like their parameters (ZeRO)
    ospec["m"] = pspec
    ospec["v"] = pspec
    return {"params": pspec, "opt": ospec}


# ---------------------------------------------------------------------------
# batches (abstract)
# ---------------------------------------------------------------------------

def train_batch_abstract(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if cfg.is_encoder_decoder:
        return {"src_embeds": sds((b, s, cfg.d_model), bf16),
                "tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    if cfg.frontend == "vision":
        st = s - cfg.n_frontend_tokens
        return {"embeds": sds((b, cfg.n_frontend_tokens, cfg.d_model), bf16),
                "tokens": sds((b, st), i32), "labels": sds((b, st), i32)}
    return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}


def prefill_batch_abstract(cfg: LMConfig, shape: ShapeSpec):
    return train_batch_abstract(cfg, shape)  # same inputs minus labels (kept: unused)


def decode_batch_abstract(cfg: LMConfig, shape: ShapeSpec):
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    return {"token": sds((b, 1), jnp.int32), "pos": sds((), jnp.int32)}


def abstract_caches(cfg: LMConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return jax.eval_shape(
            lambda: E.init_encdec_caches(cfg, b, s, min(s, SRC_LEN_CAP)))
    return jax.eval_shape(lambda: T.init_caches(cfg, b, s))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: LMConfig, remat: bool = True) -> Callable:
    if cfg.is_encoder_decoder:
        def loss_fn(params, batch):
            return E.encdec_loss(params, cfg, batch["src_embeds"], batch["tokens"],
                                 batch["labels"], remat=remat)
    elif cfg.frontend == "vision":
        def loss_fn(params, batch):
            return T.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                             prefix_embeds=batch["embeds"], remat=remat)
    else:
        def loss_fn(params, batch):
            return T.lm_loss(params, cfg, batch["tokens"], batch["labels"], remat=remat)
    return loss_fn


def make_train_step(cfg: LMConfig, opt: O.Optimizer, remat: bool = True) -> Callable:
    loss_fn = make_loss_fn(cfg, remat)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        params = O.apply_updates(state["params"], updates)
        return {"params": params, "opt": opt_state}, {"loss": loss}

    return step


def make_prefill_step(cfg: LMConfig, shape: ShapeSpec) -> Callable:
    max_len = shape.seq_len

    if cfg.is_encoder_decoder:
        def step(params, batch):
            return E.encdec_prefill(params, cfg, batch["src_embeds"],
                                    batch["tokens"], max_len)
    elif cfg.frontend == "vision":
        def step(params, batch):
            return T.lm_prefill(params, cfg, batch["tokens"], max_len,
                                prefix_embeds=batch["embeds"])
    else:
        def step(params, batch):
            return T.lm_prefill(params, cfg, batch["tokens"], max_len)
    return step


def make_decode_step(cfg: LMConfig) -> Callable:
    if cfg.is_encoder_decoder:
        def step(params, caches, token, pos):
            return E.encdec_decode_step(params, cfg, token, caches, pos)
    else:
        def step(params, caches, token, pos):
            return T.lm_decode_step(params, cfg, token, caches, pos)
    return step


# ---------------------------------------------------------------------------
# lowering helpers (the dry-run entry points)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredCell:
    lowered: Any
    kind: str


def _shardings(tree_specs, mi: SH.MeshInfo):
    return jax.tree_util.tree_map(
        lambda s: mi.named(s) if isinstance(s, P) else mi.named(P()), tree_specs,
        is_leaf=lambda s: isinstance(s, P) or s is None)


def lower_cell(cfg: LMConfig, shape: ShapeSpec, mi: SH.MeshInfo, *,
               remat: bool = True, moment_dtype=jnp.float32) -> LoweredCell:
    """Build + .lower() the right step for this (arch x shape) on this mesh."""
    ctx = mi.ctx()
    with use_ctx(ctx):
        if shape.kind == "train":
            opt = make_optimizer(moment_dtype)
            state = abstract_train_state(cfg, opt)
            sspec = _shardings(train_state_specs(state, cfg, mi), mi)
            batch = train_batch_abstract(cfg, shape)
            bspec = _shardings(SH.batch_specs(batch, mi), mi)
            state = jax.tree_util.tree_map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), state, sspec)
            batch = jax.tree_util.tree_map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), batch, bspec)
            fn = make_train_step(cfg, opt, remat=remat)
            lowered = jax.jit(fn, donate_argnums=(0,)).lower(state, batch)
            return LoweredCell(lowered, "train")

        if shape.kind == "prefill":
            params = abstract_params(cfg)
            pspec = _shardings(SH.param_specs(params, cfg, mi), mi)
            params = jax.tree_util.tree_map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params, pspec)
            batch = prefill_batch_abstract(cfg, shape)
            bspec = _shardings(SH.batch_specs(batch, mi), mi)
            batch = jax.tree_util.tree_map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), batch, bspec)
            caches = abstract_caches(cfg, shape)
            cspec = _shardings(SH.cache_specs(caches, cfg, mi, shape.global_batch), mi)
            fn = make_prefill_step(cfg, shape)
            lowered = jax.jit(fn, out_shardings=(None, cspec)).lower(params, batch)
            return LoweredCell(lowered, "prefill")

        # decode
        params = abstract_params(cfg)
        pspec = _shardings(SH.param_specs(params, cfg, mi), mi)
        params = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params, pspec)
        caches = abstract_caches(cfg, shape)
        cspec = _shardings(SH.cache_specs(caches, cfg, mi, shape.global_batch), mi)
        caches = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), caches, cspec)
        db = decode_batch_abstract(cfg, shape)
        dspec = _shardings(SH.batch_specs(db, mi), mi)
        db = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), db, dspec)
        fn = make_decode_step(cfg)
        lowered = jax.jit(fn, donate_argnums=(1,)).lower(params, caches,
                                                         db["token"], db["pos"])
        return LoweredCell(lowered, "decode")
