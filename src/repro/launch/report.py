"""Render §Dry-run / §Roofline markdown tables from results/dryrun JSONs.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

ARCH_ORDER = ["grok-1-314b", "deepseek-v3-671b", "seamless-m4t-medium",
              "granite-8b", "qwen2-0.5b", "minitron-8b", "granite-3-2b",
              "falcon-mamba-7b", "zamba2-1.2b", "internvl2-26b", "essr-x4"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "serve_8k", "train_patch"]


def _fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}us"
    if x < 0.1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def load(mesh: str, tag_filter=""):
    rows = []
    for f in sorted(glob.glob(os.path.join(os.path.abspath(RESULTS), mesh, "*.json"))):
        d = json.load(open(f))
        if (d.get("tag") or "") != tag_filter:
            continue
        rows.append(d)
    key = lambda d: (ARCH_ORDER.index(d["arch"]) if d["arch"] in ARCH_ORDER else 99,
                     SHAPE_ORDER.index(d["shape"]) if d["shape"] in SHAPE_ORDER else 99)
    return sorted(rows, key=key)


def dryrun_table(mesh: str) -> str:
    out = [f"### Mesh: {mesh} "
           + ("(2 pods x 16 x 16 = 512 chips)" if mesh == "multi" else "(16 x 16 = 256 chips)"),
           "",
           "| arch | shape | status | compile | bytes/dev | HLO dot-flops/dev | collective B/dev | #colls |",
           "|---|---|---|---|---|---|---|---|"]
    for d in load(mesh):
        if d["status"] != "ok":
            reason = d.get("reason", d.get("error", ""))[:60]
            out.append(f"| {d['arch']} | {d['shape']} | **{d['status']}** — {reason} | | | | | |")
            continue
        mem = d["memory_per_device"]
        coll = d["collectives_per_device_bytes"]
        coll_total = sum(v for k, v in coll.items() if k != "count")
        out.append(
            f"| {d['arch']} | {d['shape']} | ok | {d['compile_s']:.1f}s "
            f"| {mem['total_gb']:.2f} GB | {d.get('measured_dot_flops_per_device', 0):.3g} "
            f"| {coll_total:.3g} | {coll['count']} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful ratio |",
           "|---|---|---|---|---|---|---|---|"]
    for d in load(mesh):
        if d["status"] != "ok" or "roofline" not in d:
            continue
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} "
            f"| {_fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['model_flops_global']:.3g} | {r['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


def perf_table(arch: str, shape: str, mesh: str = "single") -> str:
    """Iteration log rows for one hillclimbed cell (all tags)."""
    files = glob.glob(os.path.join(os.path.abspath(RESULTS), mesh, f"{arch}__{shape}*.json"))
    rows = []
    for f in sorted(files):
        d = json.load(open(f))
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        coll = d["collectives_per_device_bytes"]
        rows.append((d.get("tag") or "baseline",
                     f"| {d.get('tag') or 'baseline'} | {_fmt_s(r['compute_s'])} "
                     f"| {_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} "
                     f"| {d['memory_per_device']['total_gb']:.1f} GB "
                     f"| {sum(v for k, v in coll.items() if k != 'count')/2**40:.2f} TB "
                     f"| {r['useful_flops_ratio']:.2f} |"))
    head = ["| iteration | compute | memory | collective | mem/dev | coll bytes/dev | useful |",
            "|---|---|---|---|---|---|---|"]
    return "\n".join(head + [r[1] for r in sorted(rows)])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--perf", default="")
    args = ap.parse_args()
    if args.perf:
        arch, shape = args.perf.split(":")
        print(perf_table(arch, shape))
        return
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        print(dryrun_table(m))
        print()
        print(roofline_table(m))
        print()


if __name__ == "__main__":
    main()
