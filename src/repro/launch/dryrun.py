"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOMs, or unsupported collectives fail HERE.
Records memory_analysis / cost_analysis / collective bytes per cell to
results/dryrun/<mesh>/<arch>__<shape>.json for §Dry-run and §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k --mesh multi
"""
# The dry-run (and ONLY the dry-run) fakes 512 devices; smoke tests and
# benches must see 1 device, so this is NOT set in conftest/pyproject.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ALL_SHAPES, active_param_count_estimate,
                                shape_applicable)
from repro.configs.registry import ARCH_NAMES, get_config
from repro.distributed import sharding as SH
from repro.launch import costmodel as CM
from repro.launch import roofline as RL
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

ESSR_ARCHS = ("essr-x4",)
ESSR_SHAPES = ("serve_8k", "train_patch")


def _essr_lower(shape_name: str, mi: SH.MeshInfo, opts: str = ""):
    """ESSR cells: the paper's own workload on the production mesh.
    serve_8k: one 8K frame (2304 slim-overlap 32x32 patches + halo) through
    C54, patches sharded over every chip. train_patch: one supernet step.
    opts 'int8': PAMS-int8 storage (paper §IV-H adapted to the TPU int8
    datapath) — weights int8 + per-tensor scale, input frames uint8; this is
    the §Perf E1 iteration (the cell is memory-bound, int8 halves bytes)."""
    from repro.models.essr import ESSR_X4, init_essr, essr_forward
    from repro.train import losses as Ls
    from repro.train import optimizer as O
    from jax.sharding import PartitionSpec as P

    int8 = "int8" in opts
    cfg = ESSR_X4
    params = jax.eval_shape(lambda k: init_essr(k, cfg, jnp.bfloat16),
                            jax.random.PRNGKey(0))
    rep = mi.named(P())
    pspec = jax.tree_util.tree_map(lambda _: rep, params)
    all_axes = tuple(mi.dp) + (mi.mp,)

    n_chips = 1
    for a in all_axes:
        n_chips *= mi.mesh.shape[a]

    if shape_name == "serve_8k":
        n = 2304                                   # 64 x 36 patches per frame
        n = -(-n // n_chips) * n_chips             # pad to the chip count
        in_dtype = jnp.uint8 if int8 else jnp.bfloat16
        patches = jax.ShapeDtypeStruct((n, 32, 32, 3), in_dtype,
                                       sharding=mi.named(P(all_axes, None, None, None)))
        if int8:
            params = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.int8), params)

        params = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params, pspec)

        if int8:
            def fn(p, x):
                xf = x.astype(jnp.bfloat16) * (1.0 / 255.0)
                pf = jax.tree_util.tree_map(
                    lambda w: w.astype(jnp.bfloat16) * jnp.bfloat16(1 / 64.), p)
                y = essr_forward(pf, xf, cfg)
                return jnp.clip(y * 255.0, 0, 255).astype(jnp.uint8)
        else:
            fn = lambda p, x: essr_forward(p, x, cfg)
        return jax.jit(fn).lower(params, patches), 52326 * 2 * n * 1024  # 2*MACs*pixels

    # train_patch: supernet step, paper's batch 256 scaled to the chip count
    opt = O.lamb(3e-3)
    state = {"params": params, "opt": jax.eval_shape(opt.init, params)}
    sspec = jax.tree_util.tree_map(lambda _: rep, state)
    state = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), state, sspec)
    gb = max(256, n_chips)
    lr = jax.ShapeDtypeStruct((gb, 32, 32, 3), jnp.bfloat16,
                              sharding=mi.named(P(all_axes, None, None, None)))
    hr = jax.ShapeDtypeStruct((gb, 128, 128, 3), jnp.bfloat16,
                              sharding=mi.named(P(all_axes, None, None, None)))

    def step(state, lr_img, hr_img):
        def loss_fn(p):
            return Ls.l1_loss(essr_forward(p, lr_img, cfg), hr_img)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        upd, opt_state = opt.update(grads, state["opt"], state["params"])
        return {"params": O.apply_updates(state["params"], upd), "opt": opt_state}, loss

    return (jax.jit(step, donate_argnums=(0,)).lower(state, lr, hr),
            6 * 52326 * 256 * 1024)


def apply_opts(cfg, opts: str):
    """§Perf iteration knobs, comma-separated:
    token_shard (G1/D2), ssd (Z1), cf1 (capacity factor 1.0), chunk64."""
    import dataclasses
    for opt in [o for o in opts.split(",") if o]:
        if opt == "token_shard":
            cfg = dataclasses.replace(cfg, moe_dispatch_token_shard=True)
        elif opt == "moe_shardmap":
            cfg = dataclasses.replace(cfg, moe_impl="shard_map")
        elif opt == "mla_lazy":
            cfg = dataclasses.replace(cfg, mla_lazy_kv=True)
        elif opt == "ssd":
            cfg = dataclasses.replace(cfg, mamba2_impl="ssd")
        elif opt == "cf1":
            cfg = dataclasses.replace(cfg, capacity_factor=1.0)
        elif opt.startswith("chunk"):
            cfg = dataclasses.replace(cfg, ssm_chunk=int(opt[5:]))
        elif opt.startswith("attnchunk"):
            cfg = dataclasses.replace(cfg, attn_chunk=int(opt[9:]))
        else:
            raise ValueError(f"unknown opt {opt}")
    return cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, remat: bool = True,
             moment_dtype="float32", force: bool = False,
             out_dir: Optional[str] = None, tag: str = "", opts: str = "") -> dict:
    out_dir = out_dir or os.path.abspath(RESULTS)
    os.makedirs(os.path.join(out_dir, mesh_kind), exist_ok=True)
    fname = os.path.join(out_dir, mesh_kind, f"{arch}__{shape_name}{tag}.json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "status": "ok"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        mi = SH.mesh_info(mesh)
        n_chips = int(np.prod(list(mesh.shape.values())))

        if arch in ESSR_ARCHS:
            lowered, mflops = _essr_lower(shape_name, mi, opts)
        else:
            cfg = apply_opts(get_config(arch), opts)
            shape = {s.name: s for s in ALL_SHAPES}[shape_name]
            ok, reason = shape_applicable(cfg, shape)
            if not ok:
                rec.update(status="skip", reason=reason)
                _write(fname, rec)
                return rec
            cell = ST.lower_cell(cfg, shape, mi, remat=remat,
                                 moment_dtype=getattr(jnp, moment_dtype))
            lowered = cell.lowered
            mflops = RL.model_flops(cfg, shape, active_param_count_estimate(cfg))

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory_per_device"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "total_gb": round((mem.argument_size_in_bytes + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        }
        ca = compiled.cost_analysis() or {}
        raw_flops = float(ca.get("flops", 0.0))
        raw_bytes = float(ca.get("bytes accessed", 0.0))
        txt = compiled.as_text()
        colls = RL.parse_collective_bytes(txt)
        dot_flops = RL.parse_dot_flops(txt)       # trip-count-aware, per device
        rec["cost_per_device_raw_xla"] = {        # while-bodies counted ONCE (lower bound)
            "flops": raw_flops, "bytes_accessed": raw_bytes}
        rec["collectives_per_device_bytes"] = colls
        coll_total = sum(v for k, v in colls.items() if k != "count")

        if arch in ESSR_ARCHS:
            analytic = None
            flops_dev = max(dot_flops, raw_flops)
            bytes_dev = raw_bytes
        else:
            analytic = CM.cell_cost(cfg, shape, n_chips)
            rec["analytic_global"] = analytic.as_dict()
            # flops: measured trip-aware dot walk (+ analytic SSM elementwise
            # which the dot walk cannot see); bytes: analytic model.
            flops_dev = dot_flops if dot_flops > 0 else analytic.flops_global / n_chips
            if cfg.family in ("ssm", "hybrid"):
                flops_dev = max(flops_dev, analytic.flops_global / n_chips)
            bytes_dev = analytic.hbm_bytes_global / n_chips
        rec["measured_dot_flops_per_device"] = dot_flops
        rec["roofline"] = RL.roofline(flops_dev, bytes_dev, coll_total, n_chips,
                                      mflops).as_dict()
        rec["n_chips"] = n_chips
    except Exception as e:                                    # noqa: BLE001
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["total_s"] = round(time.time() - t0, 2)
    _write(fname, rec)
    return rec


def _write(fname, rec):
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"all | essr-x4 | {','.join(ARCH_NAMES)}")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="true")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration records")
    ap.add_argument("--opts", default="", help="see apply_opts")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) + list(ESSR_ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh_kind in meshes:
        for arch in archs:
            shapes = (list(ESSR_SHAPES) if arch in ESSR_ARCHS
                      else [s.name for s in ALL_SHAPES])
            if args.shape != "all":
                shapes = [s for s in shapes if s in args.shape.split(",")]
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_kind, force=args.force,
                               remat=args.remat == "true",
                               moment_dtype=args.moment_dtype, tag=args.tag,
                               opts=args.opts)
                r = rec.get("roofline", {})
                print(f"[{mesh_kind}] {arch:24s} {shape_name:12s} {rec['status']:4s} "
                      f"compile={rec.get('compile_s', '-'):>7}s "
                      f"dom={r.get('dominant', '-'):10s} "
                      f"mem/dev={rec.get('memory_per_device', {}).get('total_gb', '-')}GB",
                      flush=True)


if __name__ == "__main__":
    main()
