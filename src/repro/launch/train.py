"""Training launcher.

Two modes:
  * ``--arch essr-x4`` (default): the paper's workload — edge-selective SR
    supernet training (PSNR phase; ``--gan`` adds the perceptual phase),
    with checkpointing + fault-tolerant supervision.
  * ``--arch <lm-arch> --smoke``: one real optimizer step of the reduced LM
    config (full configs are exercised via dryrun.py only).

Example:
    PYTHONPATH=src python -m repro.launch.train --steps 200 --batch 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_essr(args):
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.data.synthetic import patch_batches, random_image, degrade
    from repro.models.essr import ESSRConfig, init_essr, essr_forward
    from repro.train import optimizer as O
    from repro.train.losses import psnr_y
    from repro.train.trainer import train_essr_supernet

    cfg = ESSRConfig(scale=args.scale)
    key = jax.random.PRNGKey(args.seed)
    params = init_essr(key, cfg)
    data = patch_batches(args.seed, batch=args.batch, lr_patch=args.patch,
                         scale=args.scale, pool=8, pool_hw=128)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    t0 = time.time()
    params, ema, hist = train_essr_supernet(
        params, cfg, data, steps=args.steps,
        opt=O.lamb(O.cosine_decay(args.lr, args.steps)), seed=args.seed,
        log_every=max(1, args.steps // 10))
    print(f"PSNR phase: {args.steps} steps in {time.time()-t0:.1f}s "
          f"(loss {hist[0]:.4f} -> {np.mean(hist[-10:]):.4f})")
    ckpt.save(args.steps, {"params": params, "ema": ema}, blocking=True)

    if args.gan_steps:
        from repro.train.gan import train_essr_gan
        params, _, ghist = train_essr_gan(params, cfg, data, steps=args.gan_steps,
                                          seed=args.seed,
                                          log_every=max(1, args.gan_steps // 5))
        ckpt.save(args.steps + args.gan_steps, {"params": params, "ema": ema},
                  blocking=True)

    # eval: PSNR on a held-out synthetic image, per subnet
    hr = jnp.asarray(random_image(args.seed + 9999, 128, 128))
    lr = degrade(hr, args.scale)
    for width in cfg.subnet_widths():
        sr = essr_forward(ema, lr[None], cfg, width=width)[0]
        print(f"  eval width={width:2d}: PSNR_Y {float(psnr_y(sr, hr)):.2f} dB")
    print(f"checkpoints in {args.ckpt_dir}")


def train_lm_smoke(args):
    from repro.configs.registry import get_config
    from repro.launch import steps as ST
    cfg = get_config(args.arch, smoke=True)
    opt = ST.make_optimizer()
    step = jax.jit(ST.make_train_step(cfg, opt, remat=False))
    key = jax.random.PRNGKey(0)
    ST.abstract_train_state(cfg, opt)   # shape-checks cfg before init
    from repro.models.lm import transformer as T
    from repro.models.lm import encdec as E
    p = (E.init_encdec if cfg.is_encoder_decoder else T.init_lm)(key, cfg)
    state = {"params": p, "opt": opt.init(p)}
    b, s = 2, 32
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(key, (b, cfg.n_frontend_tokens, cfg.d_model),
                                            jnp.bfloat16)
    for i in range(args.steps):
        state, metrics = step(state, batch)
        if i % max(1, args.steps // 5) == 0:
            print(f"step {i}: loss {float(metrics['loss']):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="essr-x4")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--gan-steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--patch", type=int, default=24)
    ap.add_argument("--scale", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/essr_ckpt")
    args = ap.parse_args()
    if args.arch.startswith("essr"):
        train_essr(args)
    else:
        train_lm_smoke(args)


if __name__ == "__main__":
    main()
