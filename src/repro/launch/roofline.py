"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms, per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips * peak)         [peak 197 TFLOP/s bf16]
    memory     = HLO_bytes / (chips * HBM_bw)       [819 GB/s]
    collective = collective_bytes / (chips * link)  [~50 GB/s/link ICI]

XLA's cost_analysis reports the PER-DEVICE program (post-SPMD), so
per-device quantities divide by per-chip rates directly; the global/chips
formulation above is identical. Collective bytes are parsed from the
compiled HLO text (operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, including -start async forms).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class hardware constants (assignment-provided)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"=\s*(?P<ret>\([^)]*\)|\S+)\s+(?P<op>" + "|".join(_COLL_OPS) +
    r")(?:-start)?\((?P<args>[^\n]*?)\)", re.MULTILINE)
_WHILE_RE = re.compile(
    r"while\(%?[\w.\-]+\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
    r"(?:[^\n]*?known_trip_count\":{\"n\":\"(\d+)\")?")
_CALL_RE = re.compile(r"(?:\bcall|\bconditional)\([^\n]*?to_apply=%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> instruction text. Headers are non-indented
    lines '[ENTRY] %name (args) -> type {' (args may nest parens)."""
    comps: Dict[str, str] = {}
    cur, buf, entry = None, [], None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        is_header = (stripped.endswith("{") and " -> " in stripped
                     and not line.startswith(" ") and not line.startswith("}"))
        if is_header:
            tok = stripped.split()[1] if stripped.startswith("ENTRY") else stripped.split()[0]
            cur = tok.lstrip("%")
            if stripped.startswith("ENTRY"):
                entry = cur
            buf = []
            comps[cur] = ""
        elif cur is not None:
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur = None
            else:
                buf.append(line)
    if entry is not None:
        comps["__entry_name__"] = entry
    return comps


def _comp_multipliers(comps: Dict[str, str]) -> Dict[str, int]:
    """Execution-count multiplier per computation: while bodies scale by their
    known_trip_count (nested loops compose). Unknown trips default to 1
    (undercount is flagged by the caller)."""
    entry = comps.get("__entry_name__")
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps or name.startswith("__"):
            return
        mult[name] = mult.get(name, 0) + m
        text = comps[name]
        for wm in _WHILE_RE.finditer(text):
            cond, body, trip = wm.group(1), wm.group(2), wm.group(3)
            n = int(trip) if trip else 1
            visit(body, m * n)
            visit(cond, m * (n + 1))
        for cm in _CALL_RE.finditer(text):
            visit(cm.group(1), m)

    if entry:
        visit(entry, 1)
    return mult


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device operand bytes per collective kind, TRIP-COUNT AWARE:
    collectives inside scan/while bodies are multiplied by the loop's
    known_trip_count (XLA emits it in backend_config), composed through
    nesting. Without this, anything inside scan-over-layers is undercounted
    by ~n_layers."""
    out: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    out["count"] = 0
    comps = _split_computations(hlo_text)
    if comps.get("__entry_name__"):
        mults = _comp_multipliers(comps)
        items = [(name, comps[name], mults.get(name, 0))
                 for name in comps if not name.startswith("__")]
    else:                                   # fallback: flat scan of the text
        items = [("flat", hlo_text, 1)]
    for _, text, mult in items:
        if mult == 0:
            continue
        for m in _LINE_RE.finditer(text):
            op = m.group("op")
            arg_bytes = _type_bytes(m.group("args"))
            if arg_bytes == 0:
                arg_bytes = _type_bytes(m.group("ret"))
            out[op] += arg_bytes * mult
            out["count"] += mult
    return out


_INSTR_RE = re.compile(r"^\s+%?([\w.\-]+)\s*=\s*([\w\[\],{}()\s]+?)\s+([a-z][\w\-]*)\(")
_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_shape(type_str: str):
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def parse_dot_flops(hlo_text: str) -> float:
    """Per-device matmul FLOPs, TRIP-COUNT AWARE (XLA's cost_analysis counts
    while bodies once — useless under scan-over-layers). Walks every
    computation, multiplies each dot's 2*prod(out)*K by its loop multiplier.
    Elementwise FLOPs are excluded (matmul-dominated workloads; the SSM scan
    term is added analytically by the cost model)."""
    comps = _split_computations(hlo_text)
    if not comps.get("__entry_name__"):
        return 0.0
    mults = _comp_multipliers(comps)
    total = 0.0
    for name, text in comps.items():
        if name.startswith("__"):
            continue
        mult = mults.get(name, 0)
        if mult == 0:
            continue
        shapes = {}
        for line in text.splitlines():
            im = _INSTR_RE.match(line)
            if not im:
                continue
            iname, ret_type, op = im.groups()
            sh = _parse_shape(ret_type)
            if sh:
                shapes[iname] = sh
            if op == "dot":
                args = re.search(r"dot\(%?([\w.\-]+),\s*%?([\w.\-]+)\)", line)
                cd = _DOT_DIMS_RE.search(line)
                if not (args and cd and sh):
                    continue
                lhs = shapes.get(args.group(1))
                k = 1
                if lhs and cd.group(1):
                    for d in cd.group(1).split(","):
                        if int(d) < len(lhs[1]):
                            k *= lhs[1][int(d)]
                out_elems = 1
                for d in sh[1]:
                    out_elems *= d
                total += 2.0 * out_elems * k * mult
            elif op == "convolution" and sh:
                kern = re.search(r"window=\{size=([\dx]+)", line)
                ksize = 1
                if kern:
                    for d in kern.group(1).split("x"):
                        ksize *= int(d)
                out_elems = 1
                for d in sh[1]:
                    out_elems *= d
                total += 2.0 * out_elems * ksize * mult   # depthwise-style lower bound
    return total


def top_collectives(hlo_text: str, k: int = 12):
    """The k biggest collectives by bytes x trip-count, with op metadata —
    the 'profile' used to pick each §Perf iteration's target."""
    comps = _split_computations(hlo_text)
    mults = _comp_multipliers(comps) if comps.get("__entry_name__") else {}
    rows = []
    for name, text in comps.items():
        if name.startswith("__"):
            continue
        mult = mults.get(name, 0)
        if mult == 0:
            continue
        for m in _LINE_RE.finditer(text):
            b = _type_bytes(m.group("args")) or _type_bytes(m.group("ret"))
            meta = re.search(r'op_name="([^"]+)"', text[m.start():m.start() + 1500])
            rows.append({"op": m.group("op"), "bytes": b * mult, "trips": mult,
                         "shape": m.group("ret")[:60],
                         "op_name": (meta.group(1)[:110] if meta else "?")})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    useful_flops_ratio: float          # MODEL_FLOPS / (flops_per_device * chips)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(flops_per_device: float, bytes_per_device: float,
             collective_bytes_per_device: float, n_chips: int,
             model_flops_global: float) -> RooflineTerms:
    c = flops_per_device / PEAK_FLOPS
    m = bytes_per_device / HBM_BW
    k = collective_bytes_per_device / ICI_BW
    dom = max((("compute", c), ("memory", m), ("collective", k)), key=lambda t: t[1])[0]
    total_flops = flops_per_device * n_chips
    return RooflineTerms(
        compute_s=c, memory_s=m, collective_s=k, dominant=dom,
        flops_per_device=flops_per_device, bytes_per_device=bytes_per_device,
        collective_bytes_per_device=collective_bytes_per_device,
        model_flops_global=model_flops_global,
        useful_flops_ratio=(model_flops_global / total_flops) if total_flops else 0.0)


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6ND (train) / 2ND (inference); D = tokens processed this step."""
    if shape.kind == "train":
        return 6.0 * n_params_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_params_active * shape.global_batch * shape.seq_len
    return 2.0 * n_params_active * shape.global_batch          # decode: 1 tok/seq
