"""Serving launcher: the paper's deployment loop at reduced scale.

Streams synthetic frames through ``SREngine.stream`` (edge scores ->
Algorithm-1 adaptive thresholds -> per-subnet batched ESSR -> overlap+average
fusion) and prints the Table-XI-style summary (subnet shares, MAC saving,
latency). ``--quant fxp10|int8`` serves the PAMS quantized datapath instead
of fp32 (see docs/api.md "Quantized serving"). ``--dispatch fused`` serves
every frame as ONE compiled executable (in-graph capacity routing), and
``--inflight 2`` double-buffers the stream on top of it (see docs/api.md
"Dispatch modes & async streaming").

    PYTHONPATH=src python -m repro.launch.serve --frames 4 --hw 96
"""
from __future__ import annotations

import argparse
import collections

import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--hw", type=int, default=96, help="LR frame size (square)")
    ap.add_argument("--scale", type=int, default=4)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir from train.py")
    ap.add_argument("--budget", type=int, default=25500)
    ap.add_argument("--deadline-ms", type=float, default=0.0)
    ap.add_argument("--backend", default="ref", choices=("ref", "pallas"),
                    help="forward path: pure-JAX jit or fused Pallas kernels")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-parallel patch-stream shards (each gets its "
                         "own Algorithm-1 controller; dispatch uses up to "
                         "this many devices, degrading to one transparently)")
    ap.add_argument("--quant", default="none",
                    choices=("none", "fxp10", "int8"),
                    help="PAMS quantized serving: fxp10 (paper Sec. IV-H) or "
                         "int8 (TPU MXU datapath); alphas PTQ-calibrate at "
                         "engine construction")
    ap.add_argument("--dispatch", default="host", choices=("host", "fused"),
                    help="frame dispatch: host routing (default) or the "
                         "fused single-dispatch frame executable (capacity-"
                         "slotted in-graph routing; see docs/api.md)")
    ap.add_argument("--inflight", type=int, default=1,
                    help="async double-buffering depth for fused-dispatch "
                         "streaming: >= 2 overlaps frame N's compute with "
                         "frame N+1's ingest (one-frame control delay)")
    args = ap.parse_args()

    from repro.api import ExecutionPlan, SREngine
    from repro.core.adaptive import SwitchingConfig
    from repro.data.synthetic import degrade, random_image
    from repro.models.essr import ESSRConfig
    from repro.train.losses import psnr_y

    # frame counts scaled down from 8K: thresholds adapt around per-frame C54 share
    n_patches = (args.hw // 30 + 1) ** 2
    sw = SwitchingConfig(c54_per_sec_budget=args.budget,
                         frame_high=max(2, int(n_patches * 0.45)),
                         frame_low=max(1, int(n_patches * 0.30)))
    engine = SREngine.from_checkpoint(
        args.ckpt, cfg=ESSRConfig(scale=args.scale), backend=args.backend,
        plan=ExecutionPlan(shards=args.shards,
                           quant=None if args.quant == "none" else args.quant,
                           dispatch=args.dispatch, inflight=args.inflight),
        switching=sw, deadline_s=args.deadline_ms / 1e3 or None, verbose=True)
    print(f"serving backend: {engine.backend_label} "
          f"(dispatch={args.dispatch}, inflight={args.inflight})")
    engine.warmup((args.hw, args.hw))   # pre-pay trace+compile; the printed
                                        # per-frame latencies are steady-state

    # lazy frame source: only the in-flight window of HR frames stays live
    # (stream() pulls at most plan.inflight ahead of the results it yields,
    # so hr_pending never holds more than that — an 8K stream must not
    # materialize every frame up front)
    hr_pending = collections.deque()

    def lr_stream():
        for i in range(args.frames):
            hr = jnp.asarray(random_image(100 + i, args.hw * args.scale,
                                          args.hw * args.scale))
            hr_pending.append(hr)
            yield degrade(hr, args.scale)

    psnrs = []
    # stream() rather than per-frame serve(): under --dispatch fused with
    # --inflight >= 2 this is the double-buffered async executor
    for i, res in enumerate(engine.stream(lr_stream())):
        psnrs.append(float(psnr_y(res.image, hr_pending.popleft())))
        line = (f"frame {i}: PSNR_Y {psnrs[-1]:.2f} dB  "
                f"thresholds={res.thresholds}")
        if res.dispatch == "fused" and any(res.spill_counts):
            line += f"  spilled={res.spill_counts}"
        if res.shard_counts is not None and res.shard_deadline_missed is not None:
            line += (f"  shard_c54={[c[2] for c in res.shard_counts]}"
                     f"  demoted={list(res.shard_deadline_missed)}")
        print(line)
    s = engine.summary()
    print("\nsummary:", {k: v for k, v in s.items()})
    print(f"mean PSNR_Y {np.mean(psnrs):.2f} dB")


if __name__ == "__main__":
    main()
