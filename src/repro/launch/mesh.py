"""Production mesh definition (assignment-mandated shapes).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import (dryrun.py)."""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType

    def _axis_kw(n: int):
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:                     # older jax: every axis is Auto already
    AxisType = None

    def _axis_kw(n: int):
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips (pod = DP by default;
    the optional pipeline mode maps stages onto it instead)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU distributed tests (requires >=4 host devices)."""
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


#: Axis name of the 1-D patch-stream mesh (the SR serving data-parallel axis).
PATCH_AXIS = "shard"


def make_patch_mesh(shards: int):
    """1-D ``(shard,)`` mesh over the first ``shards`` devices — the SR patch
    stream's data-parallel axis (each device runs a slice of a frame's routed
    patch buckets; see repro.core.pipeline)."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards > jax.device_count():
        raise ValueError(f"requested {shards} shards but only "
                         f"{jax.device_count()} devices are visible")
    return jax.make_mesh((shards,), (PATCH_AXIS,), **_axis_kw(1))
