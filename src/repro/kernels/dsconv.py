"""Fused DSConv Pallas kernel — the GLNPU "DSConv fusion" group (Fig. 12).

3x3 depthwise THEN 1x1 pointwise (the order that kills the pixel-shuffle
checkerboard, Sec. III-B-3). Last conv of the model: on the ASIC its output
goes through boundary processing to DRAM; here the fused result goes straight
back to HBM once.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bsconv import _dw3x3
from repro.kernels.dispatch import pad_batch, resolve_block, resolve_interpret


def dsconv_kernel(x_ref, dw_ref, dwb_ref, pw_ref, pwb_ref, o_ref, *, relu: bool):
    x = x_ref[...]
    b, h, w, cin = x.shape
    cout = pw_ref.shape[-1]
    y = _dw3x3(x, dw_ref[...]) + dwb_ref[...]
    y = jnp.dot(y.reshape(b * h * w, cin), pw_ref[...],
                preferred_element_type=jnp.float32) + pwb_ref[...]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y.reshape(b, h, w, cout).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("relu", "block_patches", "interpret"))
def dsconv_fused(x, dw, dw_b, pw, pw_b, *, relu: bool = False,
                 block_patches: int = 4, interpret: Optional[bool] = None):
    """x: (N,H,W,Cin); dw: (3,3,Cin); pw: (Cin,Cout).

    ``interpret``: None = auto (compiled on TPU/GPU, interpreter on CPU);
    non-divisible batches are zero-padded and re-sliced."""
    interpret = resolve_interpret(interpret)
    cout = pw.shape[-1]
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        return jnp.zeros((0,) + x.shape[1:3] + (cout,), x.dtype)
    bblk = resolve_block(x.shape[0], block_patches)
    x, n = pad_batch(x, bblk)
    _, h, w, cin = x.shape
    return pl.pallas_call(
        functools.partial(dsconv_kernel, relu=relu),
        grid=(x.shape[0] // bblk,),
        in_specs=[
            pl.BlockSpec((bblk, h, w, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, cin), lambda i: (0, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bblk, h, w, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], h, w, cout), x.dtype),
        interpret=interpret,
    )(x, dw, dw_b.reshape(1, cin), pw, pw_b.reshape(1, cout))[:n]
