"""Public jit'd wrappers over the Pallas kernels (`ops.py` of the kernel set).

``essr_forward_kernels`` runs the whole ESSR patch-batch through the fused
groups exactly as the GLNPU schedules them (Figs. 10-12, 15):

    BSConv fusion -> 5 x SFB fusion -> DSConv fusion -> pixel shuffle

``block_patches`` doubles for the C27 subnet at equal VMEM budget — the
"configurable group of layer mapping" (C27 moves 2x the patches per grid
step through the same kernels, mirroring 4x 1x1 + 2x 3x3 concurrent PE use).

The quantized serving path (`ExecutionPlan.quant`) has its own fused chain,
``essr_forward_qkernels`` (kernels/qconv.py): same group structure on the
PAMS integer lattice.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax

from repro.kernels.bsconv import bsconv_fused
import jax.numpy as jnp

from repro.kernels.dispatch import (default_interpret, pad_batch,
                                    resolve_block, resolve_interpret)
from repro.kernels.dsconv import dsconv_fused
from repro.kernels.edge import edge_score_fused
from repro.kernels.qconv import (essr_forward_qkernels, essr_forward_qref,
                                 qbsconv_fused, qdsconv_fused, qsfb_fused,
                                 quantize_fused)
from repro.kernels.megakernel import (autotune_block_patches,
                                      essr_forward_megakernel,
                                      essr_forward_qmegakernel)
from repro.kernels.sfb import sfb_fused
from repro.models.essr import ESSRConfig, slice_width
from repro.models.layers import pixel_shuffle


def _flat_sfb(p: Dict[str, Any]) -> Dict[str, jax.Array]:
    return {
        "b1_pw": p["b1"]["pw"][0, 0], "b1_pwb": p["b1"]["pw_b"],
        "b1_dw": p["b1"]["dw"][:, :, 0, :], "b1_dwb": p["b1"]["dw_b"],
        "b2_pw": p["b2"]["pw"][0, 0], "b2_pwb": p["b2"]["pw_b"],
        "b2_dw": p["b2"]["dw"][:, :, 0, :], "b2_dwb": p["b2"]["dw_b"],
        "fuse": p["fuse"][0, 0], "fuse_b": p["fuse_b"],
    }


def default_block_patches(width: int, channels: int = 54, base: int = 4) -> int:
    """C27 processes 2x patches per grid step at the same VMEM budget."""
    return base * max(1, channels // max(width, 1))


@functools.partial(jax.jit, static_argnames=("cfg", "width", "block_patches", "interpret"))
def essr_forward_kernels(params, x, cfg: ESSRConfig, width: Optional[int] = None,
                         block_patches: Optional[int] = None,
                         interpret: Optional[bool] = None):
    """Patch-batch ESSR forward entirely through the fused Pallas groups.

    x: (N,p,p,3). width in {27,54}; bilinear patches never reach the kernels
    (the router handles them, as on the ASIC).

    The batch is zero-padded ONCE to a multiple of ``block_patches`` and
    sliced after the chain, so prime batch sizes keep the full grid block
    (the seed walked ``block_patches`` down to 1, a silent throughput cliff).
    ``interpret``: None = auto (compiled on TPU/GPU, interpreter on CPU)."""
    w = width if width is not None else cfg.channels
    assert w > 0, "bilinear subnet does not use the conv kernels"
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        s = cfg.scale
        return jnp.zeros((0, x.shape[1] * s, x.shape[2] * s, cfg.in_channels),
                         x.dtype)
    if w != cfg.channels:
        params = slice_width(params, w)
    bp = block_patches if block_patches is not None else default_block_patches(w, cfg.channels)
    bp = resolve_block(x.shape[0], bp)
    x, n = pad_batch(x, bp)

    f = bsconv_fused(x, params["first"]["pw"][0, 0], params["first"]["pw_b"],
                     params["first"]["dw"][:, :, 0, :], params["first"]["dw_b"],
                     relu=False, block_patches=bp, interpret=interpret)
    for p in params["sfbs"]:
        f = sfb_fused(f, _flat_sfb(p), block_patches=bp, interpret=interpret)
    up = dsconv_fused(f, params["recon"]["dw"][:, :, 0, :], params["recon"]["dw_b"],
                      params["recon"]["pw"][0, 0], params["recon"]["pw_b"],
                      relu=False, block_patches=bp, interpret=interpret)
    return pixel_shuffle(up, cfg.scale)[:n]


__all__ = ["bsconv_fused", "dsconv_fused", "sfb_fused", "edge_score_fused",
           "essr_forward_kernels", "default_block_patches",
           "default_interpret", "resolve_interpret",
           "quantize_fused", "qbsconv_fused", "qsfb_fused", "qdsconv_fused",
           "essr_forward_qkernels", "essr_forward_qref",
           "essr_forward_megakernel", "essr_forward_qmegakernel",
           "autotune_block_patches"]
