"""Edge-score Pallas kernel — the paper's edge-threshold computing unit.

luma (BT.601) -> 3x3 Laplacian (VALID) -> |.| clamp [0,255] -> mean, one
scalar per patch. On the ASIC this is a dedicated small block; on TPU it is a
tiny VPU kernel fused over a patch-batch block so the router never needs a
second pass over HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import pad_batch, resolve_interpret


def edge_kernel(x_ref, o_ref):
    x = x_ref[...]                                   # (B,h,w,3) in [0,1]
    b, h, w, _ = x.shape
    luma = (65.481 * x[..., 0] + 128.553 * x[..., 1] + 24.966 * x[..., 2]) + 16.0
    # 4-neighbour Laplacian on the interior (VALID)
    c = luma[:, 1:h - 1, 1:w - 1]
    lap = (luma[:, :h - 2, 1:w - 1] + luma[:, 2:, 1:w - 1]
           + luma[:, 1:h - 1, :w - 2] + luma[:, 1:h - 1, 2:] - 4.0 * c)
    resp = jnp.clip(jnp.abs(lap), 0.0, 255.0)
    o_ref[...] = resp.mean(axis=(1, 2)).reshape(b, 1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_patches", "interpret"))
def edge_score_fused(x, *, block_patches: int = 64,
                     interpret: Optional[bool] = None):
    """x: (N,h,w,3) -> (N,) edge scores.

    ``interpret``: None = auto (compiled on TPU/GPU, interpreter on CPU);
    non-divisible batches are zero-padded and re-sliced."""
    interpret = resolve_interpret(interpret)
    bblk = min(block_patches, x.shape[0])
    x, n = pad_batch(x, bblk)
    _, h, w, c = x.shape
    out = pl.pallas_call(
        edge_kernel,
        grid=(x.shape[0] // bblk,),
        in_specs=[pl.BlockSpec((bblk, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((bblk, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:n, 0]
