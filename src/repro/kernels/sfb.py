"""Fused whole-SFB Pallas kernel — the GLNPU "SFB mapping" (Fig. 15).

The entire Structure-Friendly Fusion Block — BSConv, ReLU, BSConv, ReLU,
shortcut add, 1x1 fuse, ReLU — runs in ONE pallas_call. Five intermediate
tensors that a layer-by-layer schedule would round-trip through HBM stay in
VMEM: the TPU analog of the paper's *79% feature-SRAM-access* saving (the
exact HBM-byte saving is measured in benchmarks/table_fusion.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bsconv import _dw3x3
from repro.kernels.dispatch import pad_batch, resolve_block, resolve_interpret


def sfb_kernel(x_ref, b1pw_ref, b1pwb_ref, b1dw_ref, b1dwb_ref,
               b2pw_ref, b2pwb_ref, b2dw_ref, b2dwb_ref,
               fuse_ref, fuseb_ref, o_ref):
    x = x_ref[...]
    b, h, w, c = x.shape

    def bs(v, pw, pwb, dw, dwb):
        y = jnp.dot(v.reshape(b * h * w, c), pw, preferred_element_type=jnp.float32)
        y = (y + pwb).reshape(b, h, w, c)
        return _dw3x3(y, dw) + dwb

    y = jnp.maximum(bs(x, b1pw_ref[...], b1pwb_ref[...], b1dw_ref[...], b1dwb_ref[...]), 0.0)
    y = jnp.maximum(bs(y, b2pw_ref[...], b2pwb_ref[...], b2dw_ref[...], b2dwb_ref[...]), 0.0)
    y = y + x                                            # shortcut adder
    y = jnp.dot(y.reshape(b * h * w, c), fuse_ref[...],
                preferred_element_type=jnp.float32) + fuseb_ref[...]
    o_ref[...] = jnp.maximum(y, 0.0).reshape(b, h, w, c).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_patches", "interpret"))
def sfb_fused(x, p, *, block_patches: int = 4, interpret: Optional[bool] = None):
    """x: (N,H,W,C); p: flat dict (see kernels/ref.py sfb_ref).

    ``interpret``: None = auto (compiled on TPU/GPU, interpreter on CPU);
    non-divisible batches are zero-padded and re-sliced."""
    interpret = resolve_interpret(interpret)
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        return jnp.zeros(x.shape, x.dtype)
    bblk = resolve_block(x.shape[0], block_patches)
    x, n = pad_batch(x, bblk)
    _, h, w, c = x.shape
    r2 = lambda v: v.reshape(1, -1)
    stationary_w = lambda: pl.BlockSpec((c, c), lambda i: (0, 0))
    stationary_b = lambda: pl.BlockSpec((1, c), lambda i: (0, 0))
    stationary_d = lambda: pl.BlockSpec((3, 3, c), lambda i: (0, 0, 0))
    return pl.pallas_call(
        sfb_kernel,
        grid=(x.shape[0] // bblk,),
        in_specs=[
            pl.BlockSpec((bblk, h, w, c), lambda i: (i, 0, 0, 0)),
            stationary_w(), stationary_b(), stationary_d(), stationary_b(),
            stationary_w(), stationary_b(), stationary_d(), stationary_b(),
            stationary_w(), stationary_b(),
        ],
        out_specs=pl.BlockSpec((bblk, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], h, w, c), x.dtype),
        interpret=interpret,
    )(x, p["b1_pw"], r2(p["b1_pwb"]), p["b1_dw"], r2(p["b1_dwb"]),
      p["b2_pw"], r2(p["b2_pwb"]), p["b2_dw"], r2(p["b2_dwb"]),
      p["fuse"], r2(p["fuse_b"]))[:n]
