"""Fused BSConv Pallas kernel — the GLNPU "BSConv fusion" group (Fig. 10).

One ``pallas_call`` executes 1x1 pointwise (MXU matmul) + 3x3 depthwise
(VPU shifted-accumulate) back-to-back: the intermediate feature lives only in
VMEM/VREGs, never round-tripping HBM — the TPU analog of the paper's 43%
feature-SRAM-access saving.

Tiling: grid over patch-batch; block = (Bblk, H, W, C). Weights use a
constant index_map (block 0 every step) so Mosaic keeps them VMEM-resident
across grid steps — "weights remain stationary during computing" (Sec. IV-G).
The pointwise runs as an (Bblk*H*W, Cin)@(Cin, Cout) matmul: rows are a
multiple of 256 for 32x32 patches, MXU-aligned; channels (54) are lane-padded
by Mosaic (the C=54-vs-128 padding loss is immaterial — the op is HBM-bound,
see EXPERIMENTS.md §Roofline/ESSR).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import pad_batch, resolve_block, resolve_interpret


def _dw3x3(y: jax.Array, dw: jax.Array) -> jax.Array:
    """3x3 depthwise, SAME zero-pad, via 9 shifted multiply-accumulates.

    y: (B,H,W,C); dw: (3,3,C). Static slices only — Mosaic-friendly."""
    b, h, w, c = y.shape
    yp = jnp.pad(y, ((0, 0), (1, 1), (1, 1), (0, 0)))
    acc = jnp.zeros_like(y)
    for dy in range(3):
        for dx in range(3):
            acc = acc + yp[:, dy:dy + h, dx:dx + w, :] * dw[dy, dx]
    return acc


def bsconv_kernel(x_ref, pw_ref, pwb_ref, dw_ref, dwb_ref, o_ref, *, relu: bool):
    x = x_ref[...]
    b, h, w, cin = x.shape
    cout = pw_ref.shape[-1]
    # --- 1x1 pointwise on the MXU -----------------------------------------
    y = jnp.dot(x.reshape(b * h * w, cin), pw_ref[...],
                preferred_element_type=jnp.float32)
    y = (y + pwb_ref[...]).reshape(b, h, w, cout)
    # --- 3x3 depthwise on the VPU (feature never leaves VMEM) -------------
    y = _dw3x3(y, dw_ref[...]) + dwb_ref[...]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("relu", "block_patches", "interpret"))
def bsconv_fused(x, pw, pw_b, dw, dw_b, *, relu: bool = False,
                 block_patches: int = 4, interpret: Optional[bool] = None):
    """x: (N,H,W,Cin); pw: (Cin,Cout); dw: (3,3,Cout); biases (Cout,).

    ``block_patches``: patches per grid step. The C27 subnet doubles it at the
    same VMEM budget (ops.py) — the "configurable group of layer mapping".
    ``interpret``: None = auto (compiled on TPU/GPU, interpreter on CPU).
    Batches not divisible by the block are zero-padded and re-sliced.
    """
    interpret = resolve_interpret(interpret)
    cout = pw.shape[-1]
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        return jnp.zeros((0,) + x.shape[1:3] + (cout,), x.dtype)
    bblk = resolve_block(x.shape[0], block_patches)
    x, n = pad_batch(x, bblk)
    _, h, w, cin = x.shape
    pwb2 = pw_b.reshape(1, cout)
    dwb2 = dw_b.reshape(1, cout)
    grid = (x.shape[0] // bblk,)
    return pl.pallas_call(
        functools.partial(bsconv_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bblk, h, w, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),      # stationary
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
            pl.BlockSpec((3, 3, cout), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bblk, h, w, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], h, w, cout), x.dtype),
        interpret=interpret,
    )(x, pw, pwb2, dw, dwb2)[:n]
