"""Integer-domain quantized ESSR kernels (PAMS serving path, Sec. IV-H).

The fp kernel stack (bsconv/sfb/dsconv) re-expressed on the PAMS integer
lattice: activations travel between fused groups as **integer codes**
(int8 for the TPU-native ``"int8"`` mode, int32 for the paper-faithful
``"fxp10"`` mode), every 1x1 pointwise whose input sits on a lattice runs as
a genuine integer matmul — int codes in, int32 accumulate
(``preferred_element_type=jnp.int32``, the MXU int8 datapath), dequantize +
bias on the way out — and each fused group requantizes its output once before
it returns to HBM. Codes at int8 halve the inter-group HBM bytes vs fp32.

Where a conv reads a *wide* intermediate instead of a lattice (the 3x3
depthwise inside BSConv, the trailing 1x1 of DSConv — the fake-quant
reference has no activation-quant site there; on the ASIC these feed the
24-bit accumulator chain), it runs in fp with **fake-quantized weights**:
exactly the values ``quant.pams.quantize_weight_tree`` produces, so the
integer path stays layer-for-layer consistent with the fake-quant reference.

The SFB shortcut adder sums two different lattices (block input at the
previous site's step, b2 output at its own), so the fuse 1x1 distributes over
them: two integer matmuls against the same weight codes, combined in fp —
``fuse(y + x) == fuse(y) + fuse(x)``.

Conformance contract (tests/test_quant_conformance.py):
  * every code tensor is bit-exact vs ``quant.pams.int_codes`` of the value
    it quantizes (the kernel bodies and the pure-jnp reference
    ``essr_forward_qref`` share the `_*_math` functions below, so kernel
    vs reference is bit-exact by construction in interpret mode);
  * each fused group is allclose to the fake-quant emulation of the same
    layers (`quantized_essr_forward`) within a few quantization steps.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bsconv import _dw3x3
from repro.kernels.dispatch import pad_batch, resolve_block, resolve_interpret
from repro.models.essr import ESSRConfig, slice_width
from repro.models.layers import pixel_shuffle
from repro.quant.pams import (EPS, QuantPack, code_dtype, step_size,
                              weight_alpha)


# ---------------------------------------------------------------------------
# scalar quant constants — computed in float32 numpy so the compile-time
# closures match the float32 jnp arithmetic of quant.pams bit-for-bit
# ---------------------------------------------------------------------------

def act_qconsts(alpha_raw: float, qmax: int) -> Tuple[float, float]:
    """(clip, step) for an activation site: the same ``|alpha| + 1e-8`` clip
    and epsilon-floored step that `quant.pams.effective_alpha`/`step_size`
    produce, evaluated in f32 so kernel constants equal traced scalars."""
    a = np.float32(np.abs(np.float32(alpha_raw)) + np.float32(1e-8))
    s = np.maximum(a / np.float32(qmax), np.float32(EPS))
    return float(a), float(s)


def _dw3x3_i32(y: jax.Array, dw: jax.Array) -> jax.Array:
    """`_dw3x3` on the integer lattice: int32 shifted multiply-accumulate
    (exact — FXP10 worst case 511*511*9 ≈ 2.4e6 is far from overflow)."""
    b, h, w, c = y.shape
    yp = jnp.pad(y, ((0, 0), (1, 1), (1, 1), (0, 0)))
    acc = jnp.zeros_like(y)
    for dy in range(3):
        for dx in range(3):
            acc = acc + yp[:, dy:dy + h, dx:dx + w, :] * dw[dy, dx]
    return acc


# ---------------------------------------------------------------------------
# shared math — the kernel bodies AND the jnp reference call these, so the
# Pallas path is bit-exact vs `essr_forward_qref` by construction
# ---------------------------------------------------------------------------

def _quantize_math(x, a: float, s: float, dtype):
    return jnp.round(jnp.clip(x, -a, a) / s).astype(dtype)


def _qbsconv_math(xq, pwq, pw_scale, pw_b, dw_fq, dw_b, *, relu: bool,
                  a_out: float, s_out: float):
    """Lattice codes -> lattice codes through one BSConv group.

    1x1 pointwise: integer matmul, int32 accumulate; dequant folds the input
    step and the per-channel weight step into one scale array. 3x3 depthwise:
    fp on the wide intermediate with fake-quant weights."""
    b, h, w, cin = xq.shape
    acc = jnp.dot(xq.reshape(b * h * w, cin), pwq,
                  preferred_element_type=jnp.int32)
    y = (acc.astype(jnp.float32) * pw_scale + pw_b).reshape(b, h, w, -1)
    y = _dw3x3(y, dw_fq) + dw_b
    if relu:
        y = jnp.maximum(y, 0.0)
    return _quantize_math(y, a_out, s_out, xq.dtype)


def _qsfb_math(xq, q, *, a_out: float, s_out: float):
    """Whole SFB on the lattice: two quantized BSConv groups, then the fuse
    1x1 distributed over the two input lattices (shortcut adder)."""
    b, h, w, c = xq.shape
    y1 = _qbsconv_math(xq, q["b1_pwq"], q["b1_pw_scale"], q["b1_pwb"],
                       q["b1_dw_fq"], q["b1_dwb"], relu=True,
                       a_out=q["a_b1"], s_out=q["s_b1"])
    y2 = _qbsconv_math(y1, q["b2_pwq"], q["b2_pw_scale"], q["b2_pwb"],
                       q["b2_dw_fq"], q["b2_dwb"], relu=True,
                       a_out=q["a_b2"], s_out=q["s_b2"])
    acc_y = jnp.dot(y2.reshape(b * h * w, c), q["fuseq"],
                    preferred_element_type=jnp.int32)
    acc_x = jnp.dot(xq.reshape(b * h * w, c), q["fuseq"],
                    preferred_element_type=jnp.int32)
    y = (acc_y.astype(jnp.float32) * q["fuse_scale_y"]
         + acc_x.astype(jnp.float32) * q["fuse_scale_x"] + q["fuseb"])
    y = jnp.maximum(y, 0.0).reshape(b, h, w, c)
    return _quantize_math(y, a_out, s_out, xq.dtype)


def _qdsconv_math(xq, dwq, dw_scale, dw_b, pw_fq, pw_b, *, a_out: float,
                  s_out: float):
    """DSConv on the lattice: 3x3 depthwise as an exact int32 shifted MAC
    (input IS a lattice here), then the 1x1 pointwise in fp with fake-quant
    weights (its input is the wide depthwise output)."""
    b, h, w, cin = xq.shape
    acc = _dw3x3_i32(xq.astype(jnp.int32), dwq)
    y = acc.astype(jnp.float32) * dw_scale + dw_b
    y = jnp.dot(y.reshape(b * h * w, cin), pw_fq,
                preferred_element_type=jnp.float32) + pw_b
    y = y.reshape(b, h, w, -1)
    return _quantize_math(y, a_out, s_out, xq.dtype)


# ---------------------------------------------------------------------------
# Pallas kernels: grid over the patch batch, weights stationary (constant
# index_map), exactly like the fp stack in bsconv/sfb/dsconv.py
# ---------------------------------------------------------------------------

def _quantize_kernel(x_ref, o_ref, *, a: float, s: float):
    o_ref[...] = _quantize_math(x_ref[...], a, s, o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("a", "s", "bits",
                                             "block_patches", "interpret"))
def quantize_fused(x, *, a: float, s: float, bits: int,
                   block_patches: int = 4, interpret: Optional[bool] = None):
    """fp tensor -> integer lattice codes (`int_codes` bit-exact)."""
    interpret = resolve_interpret(interpret)
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        return jnp.zeros(x.shape, code_dtype(bits))
    bblk = resolve_block(x.shape[0], block_patches)
    x, n = pad_batch(x, bblk)
    shp = x.shape[1:]
    return pl.pallas_call(
        functools.partial(_quantize_kernel, a=a, s=s),
        grid=(x.shape[0] // bblk,),
        in_specs=[pl.BlockSpec((bblk,) + shp, lambda i: (i,) + (0,) * len(shp))],
        out_specs=pl.BlockSpec((bblk,) + shp, lambda i: (i,) + (0,) * len(shp)),
        out_shape=jax.ShapeDtypeStruct(x.shape, code_dtype(bits)),
        interpret=interpret,
    )(x)[:n]


def _qbsconv_kernel(x_ref, pwq_ref, pws_ref, pwb_ref, dw_ref, dwb_ref, o_ref,
                    *, relu: bool, a_out: float, s_out: float):
    o_ref[...] = _qbsconv_math(x_ref[...], pwq_ref[...], pws_ref[...],
                               pwb_ref[...], dw_ref[...], dwb_ref[...],
                               relu=relu, a_out=a_out, s_out=s_out)


@functools.partial(jax.jit, static_argnames=("relu", "a_out", "s_out",
                                             "block_patches", "interpret"))
def qbsconv_fused(xq, pwq, pw_scale, pw_b, dw_fq, dw_b, *, relu: bool,
                  a_out: float, s_out: float, block_patches: int = 4,
                  interpret: Optional[bool] = None):
    """xq: (N,H,W,Cin) codes; pwq: (Cin,Cout) codes; pw_scale: (Cout,) folded
    input*weight step; dw_fq: (3,3,Cout) fake-quant fp. Returns codes."""
    interpret = resolve_interpret(interpret)
    cout = pwq.shape[-1]
    if xq.shape[0] == 0:     # emptied routing bucket: no grid to launch
        return jnp.zeros((0,) + xq.shape[1:3] + (cout,), xq.dtype)
    bblk = resolve_block(xq.shape[0], block_patches)
    xq, n = pad_batch(xq, bblk)
    _, h, w, cin = xq.shape
    return pl.pallas_call(
        functools.partial(_qbsconv_kernel, relu=relu, a_out=a_out,
                          s_out=s_out),
        grid=(xq.shape[0] // bblk,),
        in_specs=[
            pl.BlockSpec((bblk, h, w, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),      # stationary
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
            pl.BlockSpec((3, 3, cout), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bblk, h, w, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((xq.shape[0], h, w, cout), xq.dtype),
        interpret=interpret,
    )(xq, pwq, pw_scale.reshape(1, cout), pw_b.reshape(1, cout), dw_fq,
      dw_b.reshape(1, cout))[:n]


def _qsfb_kernel(x_ref, b1pw_ref, b1s_ref, b1pwb_ref, b1dw_ref, b1dwb_ref,
                 b2pw_ref, b2s_ref, b2pwb_ref, b2dw_ref, b2dwb_ref,
                 fuse_ref, fsy_ref, fsx_ref, fuseb_ref, o_ref, *,
                 consts: Tuple[float, ...]):
    a_b1, s_b1, a_b2, s_b2, a_out, s_out = consts
    q = {"b1_pwq": b1pw_ref[...], "b1_pw_scale": b1s_ref[...],
         "b1_pwb": b1pwb_ref[...], "b1_dw_fq": b1dw_ref[...],
         "b1_dwb": b1dwb_ref[...], "a_b1": a_b1, "s_b1": s_b1,
         "b2_pwq": b2pw_ref[...], "b2_pw_scale": b2s_ref[...],
         "b2_pwb": b2pwb_ref[...], "b2_dw_fq": b2dw_ref[...],
         "b2_dwb": b2dwb_ref[...], "a_b2": a_b2, "s_b2": s_b2,
         "fuseq": fuse_ref[...], "fuse_scale_y": fsy_ref[...],
         "fuse_scale_x": fsx_ref[...], "fuseb": fuseb_ref[...]}
    o_ref[...] = _qsfb_math(x_ref[...], q, a_out=a_out, s_out=s_out)


@functools.partial(jax.jit, static_argnames=("consts", "block_patches",
                                             "interpret"))
def qsfb_fused(xq, q: Dict[str, jax.Array], *, consts: Tuple[float, ...],
               block_patches: int = 4, interpret: Optional[bool] = None):
    """Whole SFB on the lattice in ONE pallas_call: the five wide
    intermediates AND the two internal code tensors stay in VMEM.

    ``q``: array operands from `prepare_qparams`; ``consts``: the six scalar
    quant constants (a_b1, s_b1, a_b2, s_b2, a_out, s_out)."""
    interpret = resolve_interpret(interpret)
    if xq.shape[0] == 0:     # emptied routing bucket: no grid to launch
        return jnp.zeros(xq.shape, xq.dtype)
    bblk = resolve_block(xq.shape[0], block_patches)
    xq, n = pad_batch(xq, bblk)
    _, h, w, c = xq.shape
    r2 = lambda v: v.reshape(1, c)
    stationary_w = lambda: pl.BlockSpec((c, c), lambda i: (0, 0))
    stationary_b = lambda: pl.BlockSpec((1, c), lambda i: (0, 0))
    stationary_d = lambda: pl.BlockSpec((3, 3, c), lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_qsfb_kernel, consts=consts),
        grid=(xq.shape[0] // bblk,),
        in_specs=[
            pl.BlockSpec((bblk, h, w, c), lambda i: (i, 0, 0, 0)),
            stationary_w(), stationary_b(), stationary_b(),
            stationary_d(), stationary_b(),
            stationary_w(), stationary_b(), stationary_b(),
            stationary_d(), stationary_b(),
            stationary_w(), stationary_b(), stationary_b(), stationary_b(),
        ],
        out_specs=pl.BlockSpec((bblk, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((xq.shape[0], h, w, c), xq.dtype),
        interpret=interpret,
    )(xq, q["b1_pwq"], r2(q["b1_pw_scale"]), r2(q["b1_pwb"]), q["b1_dw_fq"],
      r2(q["b1_dwb"]), q["b2_pwq"], r2(q["b2_pw_scale"]), r2(q["b2_pwb"]),
      q["b2_dw_fq"], r2(q["b2_dwb"]), q["fuseq"], r2(q["fuse_scale_y"]),
      r2(q["fuse_scale_x"]), r2(q["fuseb"]))[:n]


def _qdsconv_kernel(x_ref, dwq_ref, dws_ref, dwb_ref, pw_ref, pwb_ref, o_ref,
                    *, a_out: float, s_out: float):
    o_ref[...] = _qdsconv_math(x_ref[...], dwq_ref[...], dws_ref[...],
                               dwb_ref[...], pw_ref[...], pwb_ref[...],
                               a_out=a_out, s_out=s_out)


@functools.partial(jax.jit, static_argnames=("a_out", "s_out",
                                             "block_patches", "interpret"))
def qdsconv_fused(xq, dwq, dw_scale, dw_b, pw_fq, pw_b, *, a_out: float,
                  s_out: float, block_patches: int = 4,
                  interpret: Optional[bool] = None):
    """xq: (N,H,W,Cin) codes; dwq: (3,3,Cin) int32 codes; pw_fq: (Cin,Cout)
    fake-quant fp. Returns (N,H,W,Cout) codes at the recon site."""
    interpret = resolve_interpret(interpret)
    cout = pw_fq.shape[-1]
    if xq.shape[0] == 0:     # emptied routing bucket: no grid to launch
        return jnp.zeros((0,) + xq.shape[1:3] + (cout,), xq.dtype)
    bblk = resolve_block(xq.shape[0], block_patches)
    xq, n = pad_batch(xq, bblk)
    _, h, w, cin = xq.shape
    return pl.pallas_call(
        functools.partial(_qdsconv_kernel, a_out=a_out, s_out=s_out),
        grid=(xq.shape[0] // bblk,),
        in_specs=[
            pl.BlockSpec((bblk, h, w, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, cin), lambda i: (0, 0)),
            pl.BlockSpec((1, cin), lambda i: (0, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bblk, h, w, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((xq.shape[0], h, w, cout), xq.dtype),
        interpret=interpret,
    )(xq, dwq, dw_scale.reshape(1, cin), dw_b.reshape(1, cin), pw_fq,
      pw_b.reshape(1, cout))[:n]


# ---------------------------------------------------------------------------
# operand preparation: weight codes + folded scales, per subnet width
# ---------------------------------------------------------------------------

def _qweight(w: jax.Array, per_channel: bool, qmax: int):
    """Weight -> (integer codes fp-valued, per-channel step). The codes times
    the step reproduce `quantize_weight_tree`'s fake-quant values exactly.

    The step always comes back (1,1,1,Cout)-shaped: per-tensor alphas
    (``per_channel=False``) produce a 0-d step from `weight_alpha`, which is
    broadcast up so the downstream ``[..., 0, :]``/``[0, 0, 0]`` scale
    extraction is shape-uniform across both weight-quant modes."""
    a = weight_alpha(w, per_channel)
    s = step_size(a, qmax)
    codes = jnp.round(jnp.clip(w, -a, a) / s)
    if s.ndim == 0:
        s = jnp.broadcast_to(s, (1, 1, 1, w.shape[-1]))
    return codes, s


def prepare_qparams(params, cfg: ESSRConfig, width: int, pack: QuantPack
                    ) -> Tuple[Dict[str, Any], Dict[str, float]]:
    """Width-sliced param tree -> kernel operands + scalar site constants.

    Folds each integer matmul's dequant into one per-channel scale array
    (input step x weight step) and bakes every activation site's (clip, step)
    into compile-time floats, so the kernels carry no quant bookkeeping."""
    if width != cfg.channels:
        params = slice_width(params, width)
    qmax, pc = pack.qmax, pack.per_channel_weights
    cdt = code_dtype(pack.bits)
    alphas = pack.act_scales(width)
    consts: Dict[str, float] = {}
    for site, raw in alphas.items():
        consts[f"a_{site}"], consts[f"s_{site}"] = act_qconsts(raw, qmax)

    def pw_ops(p, key, s_in: float):
        codes, s_w = _qweight(p[key], pc, qmax)
        return {f"{key}q": codes[0, 0].astype(cdt),
                f"{key}_scale": (s_in * s_w)[0, 0, 0],
                f"{key}b": p.get(f"{key}_b",
                                 jnp.zeros(p[key].shape[-1], jnp.float32))}

    def dw_fq(p):
        codes, s_w = _qweight(p["dw"], pc, qmax)
        return (codes * s_w)[:, :, 0, :], p["dw_b"]

    q: Dict[str, Any] = {}
    first = pw_ops(params["first"], "pw", consts["s_in"])
    first["dw_fq"], first["dwb"] = dw_fq(params["first"])
    q["first"] = first

    q["sfbs"] = []
    prev = "first"
    for i, p in enumerate(params["sfbs"]):
        sfb: Dict[str, Any] = {}
        b1 = pw_ops(p["b1"], "pw", consts[f"s_{prev}"])
        sfb.update({"b1_pwq": b1["pwq"], "b1_pw_scale": b1["pw_scale"],
                    "b1_pwb": b1["pwb"]})
        sfb["b1_dw_fq"], sfb["b1_dwb"] = dw_fq(p["b1"])
        b2 = pw_ops(p["b2"], "pw", consts[f"s_sfb{i}_b1"])
        sfb.update({"b2_pwq": b2["pwq"], "b2_pw_scale": b2["pw_scale"],
                    "b2_pwb": b2["pwb"]})
        sfb["b2_dw_fq"], sfb["b2_dwb"] = dw_fq(p["b2"])
        fcodes, fs = _qweight(p["fuse"], pc, qmax)
        sfb["fuseq"] = fcodes[0, 0].astype(cdt)
        sfb["fuse_scale_y"] = (consts[f"s_sfb{i}_b2"] * fs)[0, 0, 0]
        sfb["fuse_scale_x"] = (consts[f"s_{prev}"] * fs)[0, 0, 0]
        sfb["fuseb"] = p.get("fuse_b", jnp.zeros(width, jnp.float32))
        q["sfbs"].append(sfb)
        prev = f"sfb{i}_out"

    rcodes, rs = _qweight(params["recon"]["dw"], pc, qmax)
    pw_fq_codes, pw_fq_s = _qweight(params["recon"]["pw"], pc, qmax)
    q["recon"] = {
        "dwq": rcodes[:, :, 0, :].astype(jnp.int32),
        "dw_scale": (consts[f"s_{prev}"] * rs)[0, 0, 0],
        "dwb": params["recon"]["dw_b"],
        "pw_fq": (pw_fq_codes * pw_fq_s)[0, 0],
        "pwb": params["recon"]["pw_b"],
    }
    return q, consts


def _sfb_consts(consts: Dict[str, float], i: int) -> Tuple[float, ...]:
    return (consts[f"a_sfb{i}_b1"], consts[f"s_sfb{i}_b1"],
            consts[f"a_sfb{i}_b2"], consts[f"s_sfb{i}_b2"],
            consts[f"a_sfb{i}_out"], consts[f"s_sfb{i}_out"])


# ---------------------------------------------------------------------------
# whole-model chains: Pallas serving path + the pure-jnp reference spec
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "width", "pack",
                                             "block_patches", "interpret"))
def essr_forward_qkernels(params, x, cfg: ESSRConfig,
                          width: Optional[int] = None, *,
                          pack: QuantPack, block_patches: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """Patch-batch quantized ESSR forward through the fused integer groups.

    x: (N,p,p,3) fp in [0,1]. Quantize once at the input site, run every
    group on the lattice, dequantize once after the recon site. Bilinear
    patches (width 0) never reach these kernels (the router handles them)."""
    from repro.kernels.ops import default_block_patches
    w = width if width is not None else cfg.channels
    assert w > 0, "bilinear subnet does not use the conv kernels"
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        s = cfg.scale
        return jnp.zeros((0, x.shape[1] * s, x.shape[2] * s, cfg.in_channels),
                         x.dtype)
    q, c = prepare_qparams(params, cfg, w, pack)
    bp = block_patches if block_patches is not None else \
        default_block_patches(w, cfg.channels)
    bp = resolve_block(x.shape[0], bp)
    x, n = pad_batch(x, bp)
    # Zero-pad rows re-quantize to NONZERO codes (the dequant folds biases
    # back in before the requantize clip), so without masking they flow as
    # garbage through every later group's int32 accumulate. Force pad rows
    # back to exact-zero codes after each group — integer multiply by
    # {0,1}, exact, and a no-op for the valid rows sliced out at the end.
    valid = (jnp.arange(x.shape[0]) < n)[:, None, None, None]

    def mask(codes):
        return codes * valid.astype(codes.dtype)

    f = mask(quantize_fused(x, a=c["a_in"], s=c["s_in"], bits=pack.bits,
                            block_patches=bp, interpret=interpret))
    f = mask(qbsconv_fused(f, q["first"]["pwq"], q["first"]["pw_scale"],
                           q["first"]["pwb"], q["first"]["dw_fq"],
                           q["first"]["dwb"], relu=False, a_out=c["a_first"],
                           s_out=c["s_first"], block_patches=bp,
                           interpret=interpret))
    for i, sfb in enumerate(q["sfbs"]):
        f = mask(qsfb_fused(f, sfb, consts=_sfb_consts(c, i),
                            block_patches=bp, interpret=interpret))
    r = qdsconv_fused(f, q["recon"]["dwq"], q["recon"]["dw_scale"],
                      q["recon"]["dwb"], q["recon"]["pw_fq"],
                      q["recon"]["pwb"], a_out=c["a_recon"],
                      s_out=c["s_recon"], block_patches=bp,
                      interpret=interpret)
    up = r.astype(jnp.float32) * c["s_recon"]         # single dequant
    return pixel_shuffle(up, cfg.scale)[:n]


@functools.partial(jax.jit, static_argnames=("cfg", "width", "pack",
                                             "return_codes"))
def essr_forward_qref(params, x, cfg: ESSRConfig, width: Optional[int] = None,
                      *, pack: QuantPack, return_codes: bool = False):
    """Pure-jnp integer-domain reference — the spec `essr_forward_qkernels`
    must match bit-exactly (same `_*_math` bodies, no Pallas).

    jit'd like the serving path: XLA's fp contraction (mul+add -> fma) must
    be decided identically on both sides, or a 1-ulp excess-precision
    difference can flip a code sitting exactly on a .5 rounding boundary
    (observed in practice; the integer dots themselves are always exact).

    ``return_codes``: also return the {site: codes} dict for the
    integer-consistency tests."""
    w = width if width is not None else cfg.channels
    assert w > 0
    q, c = prepare_qparams(params, cfg, w, pack)
    codes: Dict[str, jax.Array] = {}

    f = _quantize_math(x, c["a_in"], c["s_in"], code_dtype(pack.bits))
    codes["in"] = f
    f = _qbsconv_math(f, q["first"]["pwq"], q["first"]["pw_scale"],
                      q["first"]["pwb"], q["first"]["dw_fq"],
                      q["first"]["dwb"], relu=False, a_out=c["a_first"],
                      s_out=c["s_first"])
    codes["first"] = f
    for i, sfb in enumerate(q["sfbs"]):
        a_b1, s_b1, a_b2, s_b2, a_out, s_out = _sfb_consts(c, i)
        f = _qsfb_math(f, {**sfb, "a_b1": a_b1, "s_b1": s_b1,
                           "a_b2": a_b2, "s_b2": s_b2},
                       a_out=a_out, s_out=s_out)
        codes[f"sfb{i}_out"] = f
    r = _qdsconv_math(f, q["recon"]["dwq"], q["recon"]["dw_scale"],
                      q["recon"]["dwb"], q["recon"]["pw_fq"],
                      q["recon"]["pwb"], a_out=c["a_recon"],
                      s_out=c["s_recon"])
    codes["recon"] = r
    img = pixel_shuffle(r.astype(jnp.float32) * c["s_recon"], cfg.scale)
    return (img, codes) if return_codes else img
