"""Whole-subnet-group Pallas megakernel (the paper's "configurable group of
layer mapping" + "structure-friendly fusion block", Secs. IV-F/G).

The per-op kernel stack (bsconv/sfb/dsconv/qconv) already fuses *within* each
layer group, but still round-trips the feature map through HBM *between*
groups: BSConv -> HBM -> SFB -> HBM -> ... -> DSConv is exactly the feature
traffic the ASIC's 79% SRAM-access reduction eliminates. This module fuses a
subnet's FULL layer group — BSConv, every SFB (shortcut adders and trailing
1x1 fuses included), DSConv — into ONE ``pallas_call``: the patch block is
staged HBM->VMEM once on entry, the running feature lives in a VMEM scratch
buffer across all layers (the mamba-kernel idiom: fused residual, scratch
reuse), and one HBM store on exit. Weights use constant index maps, so Mosaic
keeps them VMEM-resident across grid steps ("weights remain stationary
during computing").

Two datapaths, selected by ``ExecutionPlan(fusion="group")``:

  * fp32 (``essr_forward_megakernel``): composes the same pointwise-dot +
    shifted-MAC depthwise bodies as the per-op kernels, wrapped in
    ``jax.custom_jvp`` whose tangent defers to a pure-JAX twin of
    ``models.essr.essr_forward`` — the fused serving path stays trainable
    in BOTH autodiff modes (grad via transpose, jvp natively).
  * integer (``essr_forward_qmegakernel``): composes the shared
    ``kernels.qconv._*_math`` bodies, so it is bit-exact vs
    ``essr_forward_qref`` by construction — and the inter-group lattice
    codes NEVER leave VMEM (the per-op quant chain at least halves their
    width; the megakernel removes them from HBM entirely).

Block sizing is the roofline-driven ``autotune_block_patches``: the fused
group's arithmetic intensity (MACs per streamed feature byte) is fixed by
the model, so the block is the largest patch count whose live VMEM working
set (weights + staged block + scratch feature + output block, double
buffered) fits the per-core budget, floored so the pointwise matmuls keep
full MXU rows. `launch/roofline.py`'s hardware constants decide which side
of the ridge the fused group lands on (reported by ``autotune_report``).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bsconv import _dw3x3
from repro.kernels.dispatch import pad_batch, resolve_block, resolve_interpret
from repro.kernels.qconv import (_qbsconv_math, _qdsconv_math, _qsfb_math,
                                 _quantize_math, _sfb_consts, prepare_qparams)
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.models import layers as L
from repro.models.essr import (ESSRConfig, essr_macs_per_lr_pixel,
                               slice_width)
from repro.models.layers import pixel_shuffle
from repro.quant.pams import QuantPack, code_dtype


# ---------------------------------------------------------------------------
# roofline-driven block-size autotuner (static — shapes and dtypes only)
# ---------------------------------------------------------------------------

#: Per-core VMEM budget (v5e-class, launch/roofline.py's hardware family).
VMEM_BYTES = 16 * 2 ** 20

#: MXU systolic array rows: pointwise matmuls want at least this many rows
#: per grid step, or the array runs partially empty.
_MXU_ROWS = 256


def _group_weight_bytes(width: int, n_sfb: int, out_channels: int,
                        in_channels: int = 3) -> int:
    """fp32 bytes of every stationary operand the fused group keeps in VMEM
    (weights + biases/scales; the quant variants are smaller, so sizing by
    fp32 is the conservative bound)."""
    c = width
    first = in_channels * c + c + 9 * c + c
    sfb = 2 * (c * c + c + 9 * c + c) + c * c + c
    recon = 9 * c + c + c * out_channels + out_channels
    return 4 * (first + n_sfb * sfb + recon)


def autotune_report(width: int, patch: int, scale: int, n_sfb: int = 5,
                    *, in_channels: int = 3,
                    vmem_bytes: int = VMEM_BYTES) -> Dict[str, Any]:
    """Static roofline sizing of the fused group at one (width, patch) point.

    The streamed HBM traffic per patch is fixed (input block in, SR block
    out — intermediates never leave VMEM), so arithmetic intensity does not
    depend on the block size; what the block controls is VMEM occupancy
    (upper bound: weights + staged input + scratch feature + output, double
    buffered into half the budget) and MXU row utilization (lower bound:
    ``block * patch^2 >= 256`` rows). The tuner takes the largest block in
    that feasible band."""
    out_channels = in_channels * scale * scale
    weight_b = _group_weight_bytes(width, n_sfb, out_channels, in_channels)
    # live per-patch VMEM: staged input + scratch feature + one wide SFB
    # temporary + pre-shuffle output, all fp32
    per_patch_b = 4 * patch * patch * (in_channels + 2 * width + out_channels)
    budget = max(0, vmem_bytes // 2 - weight_b)
    vmem_cap = max(1, budget // max(1, per_patch_b))
    mxu_floor = max(1, -(-_MXU_ROWS // (patch * patch)))
    block = max(mxu_floor, vmem_cap)
    block = min(block, 512)                      # grid-step sanity ceiling
    macs_pp = essr_macs_per_lr_pixel(
        ESSRConfig(channels=width, n_sfb=n_sfb, scale=scale,
                   in_channels=in_channels)) * patch * patch
    stream_bpp = 4 * patch * patch * (in_channels + out_channels)
    intensity = macs_pp / stream_bpp
    ridge = PEAK_FLOPS / (2.0 * HBM_BW)          # MAC/byte at the ridge
    return {
        "block_patches": int(block),
        "weight_bytes": int(weight_b),
        "per_patch_bytes": int(per_patch_b),
        "vmem_budget_bytes": int(vmem_bytes),
        "mxu_row_floor": int(mxu_floor),
        "arith_intensity_mac_per_byte": float(intensity),
        "roofline_ridge_mac_per_byte": float(ridge),
        "bound": "compute" if intensity >= ridge else "memory",
    }


@functools.lru_cache(maxsize=256)
def autotune_block_patches(width: int, patch: int, scale: int,
                           n_sfb: int = 5, *, in_channels: int = 3,
                           vmem_bytes: int = VMEM_BYTES) -> int:
    """The block size `autotune_report` picks (cached — pure shape math)."""
    return autotune_report(width, patch, scale, n_sfb,
                           in_channels=in_channels,
                           vmem_bytes=vmem_bytes)["block_patches"]


# ---------------------------------------------------------------------------
# operand flattening: the param tree -> the kernel's positional ref list
# ---------------------------------------------------------------------------

def _flat_fp_operands(params) -> list:
    """Width-sliced fp param tree -> kernel operand list, in the exact order
    `_mega_kernel` consumes them (biases pre-reshaped to (1, C) rows)."""
    r2 = lambda v: v.reshape(1, -1)
    ops = [params["first"]["pw"][0, 0], r2(params["first"]["pw_b"]),
           params["first"]["dw"][:, :, 0, :], r2(params["first"]["dw_b"])]
    for p in params["sfbs"]:
        for b in ("b1", "b2"):
            ops += [p[b]["pw"][0, 0], r2(p[b]["pw_b"]),
                    p[b]["dw"][:, :, 0, :], r2(p[b]["dw_b"])]
        ops += [p["fuse"][0, 0], r2(p["fuse_b"])]
    ops += [params["recon"]["dw"][:, :, 0, :], r2(params["recon"]["dw_b"]),
            params["recon"]["pw"][0, 0], r2(params["recon"]["pw_b"])]
    return ops


def _flat_q_operands(q) -> list:
    """`prepare_qparams` tree -> kernel operand list (scales/biases as (1,C)
    rows), in the exact order `_qmega_kernel` consumes them."""
    r2 = lambda v: v.reshape(1, -1)
    ops = [q["first"]["pwq"], r2(q["first"]["pw_scale"]),
           r2(q["first"]["pwb"]), q["first"]["dw_fq"], r2(q["first"]["dwb"])]
    for sfb in q["sfbs"]:
        for b in ("b1", "b2"):
            ops += [sfb[f"{b}_pwq"], r2(sfb[f"{b}_pw_scale"]),
                    r2(sfb[f"{b}_pwb"]), sfb[f"{b}_dw_fq"],
                    r2(sfb[f"{b}_dwb"])]
        ops += [sfb["fuseq"], r2(sfb["fuse_scale_y"]),
                r2(sfb["fuse_scale_x"]), r2(sfb["fuseb"])]
    ops += [q["recon"]["dwq"], r2(q["recon"]["dw_scale"]),
            r2(q["recon"]["dwb"]), q["recon"]["pw_fq"],
            r2(q["recon"]["pwb"])]
    return ops


def _weight_specs(ops) -> list:
    """Stationary BlockSpecs (constant index map: block 0 every grid step,
    so Mosaic keeps every weight VMEM-resident across the whole grid)."""
    specs = []
    for arr in ops:
        zero = (0,) * arr.ndim
        specs.append(pl.BlockSpec(arr.shape, lambda i, _z=zero: _z))
    return specs


# ---------------------------------------------------------------------------
# fp32 megakernel
# ---------------------------------------------------------------------------

def _mega_kernel(*refs, n_sfb: int):
    """One grid step of the fused fp32 group: the staged patch block runs
    BSConv -> n_sfb x SFB -> DSConv with the running feature ping-ponging
    through the VMEM scratch — no HBM touch until the single output store."""
    x_ref, wrefs, o_ref, feat_ref = refs[0], refs[1:-2], refs[-2], refs[-1]
    x = x_ref[...]
    b, h, w, cin = x.shape
    it = iter(wrefs)

    def take(k):
        return [next(it)[...] for _ in range(k)]

    def bs(v, pw, pwb, dw, dwb):
        c_in = v.shape[-1]
        y = jnp.dot(v.reshape(b * h * w, c_in), pw,
                    preferred_element_type=jnp.float32)
        y = (y + pwb).reshape(b, h, w, -1)
        return _dw3x3(y, dw) + dwb

    pw, pwb, dw, dwb = take(4)
    feat_ref[...] = bs(x, pw, pwb, dw, dwb)
    for _ in range(n_sfb):
        b1 = take(4)
        b2 = take(4)
        fuse, fuseb = take(2)
        xin = feat_ref[...]
        c = xin.shape[-1]
        y = jnp.maximum(bs(xin, *b1), 0.0)
        y = jnp.maximum(bs(y, *b2), 0.0)
        y = y + xin                                  # shortcut adder
        y = jnp.dot(y.reshape(b * h * w, c), fuse,
                    preferred_element_type=jnp.float32) + fuseb
        feat_ref[...] = jnp.maximum(y, 0.0).reshape(b, h, w, c)
    rdw, rdwb, rpw, rpwb = take(4)
    f = feat_ref[...]
    y = _dw3x3(f, rdw) + rdwb
    y = jnp.dot(y.reshape(b * h * w, f.shape[-1]), rpw,
                preferred_element_type=jnp.float32) + rpwb
    o_ref[...] = y.reshape(b, h, w, -1).astype(o_ref.dtype)


def _jvp_forward(params, x, cfg: ESSRConfig):
    """`essr_forward` on pre-sliced params with the depthwise conv in raw
    shift form: `layers._dw3` is a custom_vjp (reverse-only), so the
    megakernel's JVP rule needs this forward-differentiable twin — same
    math to the op (`_dw3` merely wraps `_dw3_shift`)."""
    def bs(p, v):
        y = L.pointwise(v, p["pw"], p.get("pw_b"))
        y = L._dw3_shift(y, p["dw"][:, :, 0, :])
        return y + p["dw_b"] if "dw_b" in p else y

    f = bs(params["first"], x)
    for p in params["sfbs"]:
        y = jax.nn.relu(bs(p["b1"], f))
        y = jax.nn.relu(bs(p["b2"], y))
        f = jax.nn.relu(L.pointwise(y + f, p["fuse"], p.get("fuse_b")))
    r = params["recon"]
    y = L._dw3_shift(f, r["dw"][:, :, 0, :])
    if "dw_b" in r:
        y = y + r["dw_b"]
    up = L.pointwise(y, r["pw"], r.get("pw_b"))
    return pixel_shuffle(up, cfg.scale)


@functools.partial(jax.custom_jvp, nondiff_argnums=(2, 3, 4, 5))
def _mega_forward(params, x, cfg: ESSRConfig, width: int,
                  block_patches: int, interpret: Optional[bool]):
    """(width-sliced params, padded-ready batch) -> SR patches, one
    pallas_call for the whole group. Differentiable via the custom JVP
    below — the Pallas primal with the pure-JAX tangent."""
    interp = resolve_interpret(interpret)
    bblk = resolve_block(x.shape[0], block_patches)
    x, n = pad_batch(x, bblk)
    _, h, w, cin = x.shape
    cout = cfg.out_channels
    wops = _flat_fp_operands(params)
    up = pl.pallas_call(
        functools.partial(_mega_kernel, n_sfb=cfg.n_sfb),
        grid=(x.shape[0] // bblk,),
        in_specs=[pl.BlockSpec((bblk, h, w, cin), lambda i: (i, 0, 0, 0))]
        + _weight_specs(wops),
        out_specs=pl.BlockSpec((bblk, h, w, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], h, w, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((bblk, h, w, width), jnp.float32)],
        interpret=interp,
    )(x, *wops)
    return pixel_shuffle(up, cfg.scale)[:n]


@_mega_forward.defjvp
def _mega_forward_jvp(cfg, width, block_patches, interpret,
                      primals, tangents):
    # primal through the fused kernel, tangent through the pure-JAX forward:
    # the two forwards are the same math, so the pairing is consistent and
    # the fp32 serving path stays trainable without a Pallas transpose rule
    params, x = primals
    dparams, dx = tangents
    primal_out = _mega_forward(params, x, cfg, width, block_patches,
                               interpret)
    _, tangent_out = jax.jvp(lambda p, v: _jvp_forward(p, v, cfg),
                             (params, x), (dparams, dx))
    return primal_out, tangent_out


@functools.partial(jax.jit, static_argnames=("cfg", "width", "block_patches",
                                             "interpret"))
def essr_forward_megakernel(params, x, cfg: ESSRConfig,
                            width: Optional[int] = None,
                            block_patches: Optional[int] = None,
                            interpret: Optional[bool] = None):
    """Patch-batch ESSR forward through ONE fused Pallas kernel per grid
    step (`ExecutionPlan(fusion="group")`'s fp32 path).

    Same contract as `kernels.ops.essr_forward_kernels`: x (N,p,p,3), width
    in {27, 54} (bilinear never reaches the kernels), zero-pad + re-slice
    for non-divisible batches, empty batches return an empty output."""
    w = width if width is not None else cfg.channels
    assert w > 0, "bilinear subnet does not use the conv kernels"
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        s = cfg.scale
        return jnp.zeros((0, x.shape[1] * s, x.shape[2] * s, cfg.in_channels),
                         x.dtype)
    if w != cfg.channels:
        params = slice_width(params, w)
    bp = block_patches if block_patches is not None else \
        autotune_block_patches(w, int(x.shape[1]), cfg.scale, cfg.n_sfb,
                               in_channels=cfg.in_channels)
    return _mega_forward(params, x, cfg, w, bp, interpret)


# ---------------------------------------------------------------------------
# integer-domain megakernel (quant x group fusion)
# ---------------------------------------------------------------------------

def _qmega_kernel(*refs, n_sfb: int, consts: Tuple[float, ...],
                  code_dt):
    """One grid step of the fused integer group: quantize-once at the input
    site, then the whole lattice chain — the inter-group codes that the
    per-op stack writes to HBM stay in the VMEM scratch."""
    x_ref, wrefs, o_ref, feat_ref = refs[0], refs[1:-2], refs[-2], refs[-1]
    it = iter(wrefs)

    def take(k):
        return [next(it)[...] for _ in range(k)]

    a_in, s_in = consts[0], consts[1]
    a_first, s_first = consts[2], consts[3]
    xq = _quantize_math(x_ref[...], a_in, s_in, code_dt)
    pwq, pws, pwb, dwf, dwb = take(5)
    feat_ref[...] = _qbsconv_math(xq, pwq, pws, pwb, dwf, dwb, relu=False,
                                  a_out=a_first, s_out=s_first)
    for i in range(n_sfb):
        a_b1, s_b1, a_b2, s_b2, a_out, s_out = consts[4 + 6 * i:10 + 6 * i]
        b1 = take(5)
        b2 = take(5)
        fuseq, fsy, fsx, fuseb = take(4)
        q = {"b1_pwq": b1[0], "b1_pw_scale": b1[1], "b1_pwb": b1[2],
             "b1_dw_fq": b1[3], "b1_dwb": b1[4],
             "a_b1": a_b1, "s_b1": s_b1,
             "b2_pwq": b2[0], "b2_pw_scale": b2[1], "b2_pwb": b2[2],
             "b2_dw_fq": b2[3], "b2_dwb": b2[4],
             "a_b2": a_b2, "s_b2": s_b2,
             "fuseq": fuseq, "fuse_scale_y": fsy, "fuse_scale_x": fsx,
             "fuseb": fuseb}
        feat_ref[...] = _qsfb_math(feat_ref[...], q, a_out=a_out,
                                   s_out=s_out)
    a_recon, s_recon = consts[-2], consts[-1]
    dwq, dws, dwb, pwf, pwb = take(5)
    o_ref[...] = _qdsconv_math(feat_ref[...], dwq, dws, dwb, pwf, pwb,
                               a_out=a_recon, s_out=s_recon)


@functools.partial(jax.jit, static_argnames=("cfg", "width", "pack",
                                             "block_patches", "interpret"))
def essr_forward_qmegakernel(params, x, cfg: ESSRConfig,
                             width: Optional[int] = None, *,
                             pack: QuantPack,
                             block_patches: Optional[int] = None,
                             interpret: Optional[bool] = None):
    """Quantized patch-batch forward through ONE fused integer Pallas kernel
    (`ExecutionPlan(fusion="group")` composed with `quant`).

    Same contract as `kernels.qconv.essr_forward_qkernels` and bit-exact
    against it (and `essr_forward_qref`): the kernel body composes the same
    shared `_*_math` group functions with the same compile-time site
    constants — but the integer codes between groups never leave VMEM."""
    w = width if width is not None else cfg.channels
    assert w > 0, "bilinear subnet does not use the conv kernels"
    if x.shape[0] == 0:      # emptied routing bucket: no grid to launch
        s = cfg.scale
        return jnp.zeros((0, x.shape[1] * s, x.shape[2] * s, cfg.in_channels),
                         x.dtype)
    interp = resolve_interpret(interpret)
    q, c = prepare_qparams(params, cfg, w, pack)
    bp = block_patches if block_patches is not None else \
        autotune_block_patches(w, int(x.shape[1]), cfg.scale, cfg.n_sfb,
                               in_channels=cfg.in_channels)
    bblk = resolve_block(x.shape[0], bp)
    x, n = pad_batch(x, bblk)
    _, h, wdim, cin = x.shape
    cout = cfg.out_channels
    cdt = code_dtype(pack.bits)
    consts = (c["a_in"], c["s_in"], c["a_first"], c["s_first"])
    for i in range(cfg.n_sfb):
        consts += _sfb_consts(c, i)
    consts += (c["a_recon"], c["s_recon"])
    wops = _flat_q_operands(q)
    r = pl.pallas_call(
        functools.partial(_qmega_kernel, n_sfb=cfg.n_sfb, consts=consts,
                          code_dt=cdt),
        grid=(x.shape[0] // bblk,),
        in_specs=[pl.BlockSpec((bblk, h, wdim, cin), lambda i: (i, 0, 0, 0))]
        + _weight_specs(wops),
        out_specs=pl.BlockSpec((bblk, h, wdim, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], h, wdim, cout), cdt),
        scratch_shapes=[pltpu.VMEM((bblk, h, wdim, w), cdt)],
        interpret=interp,
    )(x, *wops)
    up = r.astype(jnp.float32) * c["s_recon"]         # single dequant
    return pixel_shuffle(up, cfg.scale)[:n]


__all__ = ["essr_forward_megakernel", "essr_forward_qmegakernel",
           "autotune_block_patches", "autotune_report", "VMEM_BYTES"]
