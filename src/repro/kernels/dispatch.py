"""Interpret-vs-compiled policy for the Pallas kernel stack.

Every fused kernel takes ``interpret: Optional[bool]``:

  * ``None``  (default) — auto: compile the Pallas kernel when an accelerator
    backend (TPU/GPU) is present; fall back to the interpreter on CPU, where
    Mosaic cannot compile and interpret mode is the correctness path.
  * ``True`` / ``False`` — explicit override (tests force ``True``; a TPU
    deployment that has validated the kernels may force ``False``).

Resolution happens at trace time (``interpret`` is a static argument), so the
policy costs nothing per call. ``ExecutionPlan.interpret`` carries the same
tri-state through `SREngine`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

#: Backends whose Pallas lowering is compiled (Mosaic / Triton).
COMPILED_BACKENDS = ("tpu", "gpu")


def default_interpret() -> bool:
    """True when only the interpreter can run Pallas (CPU hosts)."""
    return jax.default_backend() not in COMPILED_BACKENDS


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Tri-state -> concrete bool (None = auto-select, see module docstring)."""
    return default_interpret() if interpret is None else bool(interpret)


def pad_batch(x: jax.Array, block: int):
    """Pad axis 0 of ``x`` up to a multiple of ``block`` (zeros).

    Returns ``(padded, n)`` where ``n`` is the original length; callers slice
    the kernel output back to ``n``. Replaces the seed's hard
    ``assert n % block == 0`` (a trap for direct callers) and the silent
    ``block -= 1`` walk-down that destroyed throughput for prime batch sizes.
    """
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, n
