"""Interpret-vs-compiled policy for the Pallas kernel stack.

Every fused kernel takes ``interpret: Optional[bool]``:

  * ``None``  (default) — auto: compile the Pallas kernel when an accelerator
    backend (TPU/GPU) is present; fall back to the interpreter on CPU, where
    Mosaic cannot compile and interpret mode is the correctness path.
  * ``True`` / ``False`` — explicit override (tests force ``True``; a TPU
    deployment that has validated the kernels may force ``False``).

Resolution happens at trace time (``interpret`` is a static argument), so the
policy costs nothing per call. ``ExecutionPlan.interpret`` carries the same
tri-state through `SREngine`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

#: Backends whose Pallas lowering is compiled (Mosaic / Triton).
COMPILED_BACKENDS = ("tpu", "gpu")


def default_interpret() -> bool:
    """True when only the interpreter can run Pallas (CPU hosts)."""
    return jax.default_backend() not in COMPILED_BACKENDS


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Tri-state -> concrete bool (None = auto-select, see module docstring)."""
    return default_interpret() if interpret is None else bool(interpret)


def resolve_block(n: int, block_patches: int) -> int:
    """Batch length + requested block -> the grid block actually launched.

    ``min(block_patches, n)`` alone has two failure modes this fixes:

      * ``n == 0`` (an emptied routing bucket) yields block 0, and
        ``pad_batch`` divides by zero — empty batches return 0 here and
        every fused wrapper early-returns an empty output before padding;
      * a remainder batch pads up to a full extra block (``n=9, block=8``
        padded 9 -> 16): the padded rows burn MACs and inflate the static
        cost model. Keeping the same grid step count but shrinking the
        block to ``ceil(n / steps)`` gives the minimal zero-pad
        (9 -> 2 steps of 5, one pad row instead of seven).
    """
    if n <= 0:
        return 0
    blk = min(block_patches, n)
    steps = -(-n // blk)
    return -(-n // steps)


def pad_batch(x: jax.Array, block: int):
    """Pad axis 0 of ``x`` up to a multiple of ``block`` (zeros).

    Returns ``(padded, n)`` where ``n`` is the original length; callers slice
    the kernel output back to ``n``. Replaces the seed's hard
    ``assert n % block == 0`` (a trap for direct callers) and the silent
    ``block -= 1`` walk-down that destroyed throughput for prime batch sizes.
    """
    if block < 1:
        raise ValueError(
            f"pad_batch block must be >= 1, got {block}: empty batches must "
            f"early-return before padding (see resolve_block)")
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, n
