"""Pure-jnp oracles for every Pallas kernel (the `ref.py` of each kernel).

These are compositions of ``repro.models.layers`` primitives — the exact
semantics the fused kernels must reproduce (asserted with allclose across
shape/dtype sweeps in tests/test_kernels.py).
"""
from __future__ import annotations

import jax

from repro.core.edge_score import edge_score as _edge_score_ref
from repro.models import layers as L


def bsconv_ref(x, pw, pw_b, dw, dw_b, *, relu: bool = False):
    """x:(N,H,W,Ci), pw:(Ci,Co), dw:(3,3,Co) -> (N,H,W,Co). SAME zero-pad."""
    y = L.pointwise(x, pw[None, None], pw_b)
    y = L.dwconv2d(y, dw[:, :, None, :], dw_b)
    return jax.nn.relu(y) if relu else y


def dsconv_ref(x, dw, dw_b, pw, pw_b, *, relu: bool = False):
    """x:(N,H,W,Ci), dw:(3,3,Ci), pw:(Ci,Co) -> (N,H,W,Co)."""
    y = L.dwconv2d(x, dw[:, :, None, :], dw_b)
    y = L.pointwise(y, pw[None, None], pw_b)
    return jax.nn.relu(y) if relu else y


def sfb_ref(x, p):
    """Whole SFB: relu(BSConv) -> relu(BSConv) -> (+x) -> 1x1 -> relu.

    p: dict with b1_pw, b1_pwb, b1_dw, b1_dwb, b2_*, fuse, fuse_b."""
    y = bsconv_ref(x, p["b1_pw"], p["b1_pwb"], p["b1_dw"], p["b1_dwb"], relu=True)
    y = bsconv_ref(y, p["b2_pw"], p["b2_pwb"], p["b2_dw"], p["b2_dwb"], relu=True)
    y = L.pointwise(y + x, p["fuse"][None, None], p["fuse_b"])
    return jax.nn.relu(y)


def edge_score_ref(patches):
    """(N,h,w,3) RGB in [0,1] -> (N,) edge scores (Sec. II-A)."""
    return _edge_score_ref(patches)
