"""FSRCNN baseline (the lightweight backbone the paper's rivals use).

FSRCNN(d=56, s=12, m=4): conv5(1->d) -> conv1(d->s) -> m x conv3(s->s) ->
conv1(s->d) -> deconv9(d->1, stride=scale). PReLU activations. ~12.5K params
(paper Tables V/VI list 13K). Operates on the luma channel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class FSRCNNConfig:
    d: int = 56
    s: int = 12
    m: int = 4
    scale: int = 4


def _prelu(x, a):
    return jnp.where(x >= 0, x, a * x)


def init_fsrcnn(key, cfg: FSRCNNConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.m + 4)
    p: Dict[str, Any] = {
        "feat": {"w": L.conv_init(ks[0], (5, 5, 1, cfg.d)), "b": jnp.zeros(cfg.d), "a": jnp.full(cfg.d, 0.25)},
        "shrink": {"w": L.conv_init(ks[1], (1, 1, cfg.d, cfg.s)), "b": jnp.zeros(cfg.s), "a": jnp.full(cfg.s, 0.25)},
        "maps": [],
        "expand": {"w": L.conv_init(ks[-2], (1, 1, cfg.s, cfg.d)), "b": jnp.zeros(cfg.d), "a": jnp.full(cfg.d, 0.25)},
        "deconv": {"w": L.conv_init(ks[-1], (9, 9, cfg.d, 1)), "b": jnp.zeros(1)},
    }
    for i in range(cfg.m):
        p["maps"].append({"w": L.conv_init(ks[2 + i], (3, 3, cfg.s, cfg.s)),
                          "b": jnp.zeros(cfg.s), "a": jnp.full(cfg.s, 0.25)})
    return p


def fsrcnn_forward(params: Dict[str, Any], y: jax.Array, cfg: FSRCNNConfig) -> jax.Array:
    """y: (N,H,W,1) luma in [0,1] -> (N,H*s,W*s,1)."""
    t = _prelu(L.conv2d(y, params["feat"]["w"], params["feat"]["b"]), params["feat"]["a"])
    t = _prelu(L.conv2d(t, params["shrink"]["w"], params["shrink"]["b"]), params["shrink"]["a"])
    for p in params["maps"]:
        t = _prelu(L.conv2d(t, p["w"], p["b"]), p["a"])
    t = _prelu(L.conv2d(t, params["expand"]["w"], params["expand"]["b"]), params["expand"]["a"])
    s = cfg.scale
    out = lax.conv_transpose(t, params["deconv"]["w"], strides=(s, s), padding="SAME",
                             dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + params["deconv"]["b"]
