"""SSM substrate: Mamba-1 selective scan (falcon-mamba) and Mamba-2/SSD
(zamba2), both in *chunked* form.

Chunking is the TPU adaptation of the CUDA selective-scan kernel: within a
chunk the first-order recurrence is a lax.associative_scan (parallel,
VPU-friendly); across chunks a lax.scan carries the (B, d, N) state. Live
memory is O(chunk * d * N), independent of sequence length — which is what
makes the 512K long-context cell compile. Decode is an O(1) single-token
state update (the "KV cache" of an SSM is its state — constant in seq_len).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig


def _assoc_combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B,S,C); w: (k,C); returns (y, new_state)
    where state carries the last k-1 inputs for decode."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return y + b, xp[:, -(k - 1):, :]


# ===========================================================================
# Mamba-1 (falcon-mamba-7b)
# ===========================================================================

def init_mamba1(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, di, n, r, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "in_proj": (std * jax.random.normal(ks[0], (d, 2 * di))).astype(dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (k, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (di ** -0.5 * jax.random.normal(ks[2], (di, r + 2 * n))).astype(dtype),
        "dt_proj": (r ** -0.5 * jax.random.normal(ks[3], (r, di))).astype(dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[4], (di,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (di ** -0.5 * jax.random.normal(ks[5], (di, d))).astype(dtype),
    }


def _scan_chunked(a_fn, b_fn, y_fn, h0, n_chunks):
    """Generic chunked linear recurrence: chunk i provides elementwise decay
    a and input b; within-chunk via associative_scan, across via lax.scan."""
    def body(h, i):
        a, b = a_fn(i), b_fn(i)
        ac, bc = lax.associative_scan(_assoc_combine, (a, b), axis=1)
        h_all = ac * h[:, None] + bc                   # states at every step
        y = y_fn(i, h_all)
        return h_all[:, -1], y
    return lax.scan(body, h0, jnp.arange(n_chunks))


def mamba1_forward(p: Dict[str, Any], u: jax.Array, cfg: LMConfig,
                   return_state: bool = False):
    """u: (B,S,D) -> (B,S,D) [, final {'h','conv'} state]. Chunked scan.
    Padded tail steps get dt=0 (identity state update) so the returned state
    is exact regardless of S % chunk."""
    bsz, s, _ = u.shape
    di, n, r, ck = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_chunk
    xz = u @ p["in_proj"]
    x_raw, z = xz[..., :di], xz[..., di:]
    x, conv_state = _causal_conv(x_raw, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :r] @ p["dt_proj"] + p["dt_bias"])   # (B,S,di)
    Bm, Cm = proj[..., r:r + n], proj[..., r + n:]                       # (B,S,n)
    A = -jnp.exp(p["A_log"])                                             # (di,n)

    pad = (-s) % ck
    if pad:
        x, dt = jnp.pad(x, ((0, 0), (0, pad), (0, 0))), jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm, Cm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))), jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // ck
    xc = x.reshape(bsz, nc, ck, di)
    dtc = dt.reshape(bsz, nc, ck, di).astype(jnp.float32)
    Bc = Bm.reshape(bsz, nc, ck, n).astype(jnp.float32)
    Cc = Cm.reshape(bsz, nc, ck, n).astype(jnp.float32)

    def a_fn(i):
        return jnp.exp(dtc[:, i, :, :, None] * A)                        # (B,ck,di,n)

    def b_fn(i):
        return (dtc[:, i] * xc[:, i].astype(jnp.float32))[..., None] * Bc[:, i, :, None, :]

    def y_fn(i, h_all):                                                  # (B,ck,di,n)
        return jnp.einsum("bkdn,bkn->bkd", h_all, Cc[:, i])

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    h_final, ys = _scan_chunked(a_fn, b_fn, y_fn, h0, nc)                # (nc,B,ck,di)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, nc * ck, di)[:, :s]
    y = y + x[:, :s].astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z[:, :s].astype(jnp.float32))).astype(u.dtype)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"h": h_final, "conv": x_raw[:, -(cfg.ssm_conv - 1):, :]}
    return out


def mamba1_init_cache(cfg: LMConfig, batch: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)}


def mamba1_decode(p, u, cfg: LMConfig, cache):
    """u: (B,1,D); O(1) state update."""
    di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = u @ p["in_proj"]
    x, z = xz[..., :di], xz[..., di:]
    x, conv_state = _causal_conv(x, p["conv_w"], p["conv_b"], cache["conv"])
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :r] @ p["dt_proj"] + p["dt_bias"])[:, 0].astype(jnp.float32)
    Bm = proj[:, 0, r:r + n].astype(jnp.float32)
    Cm = proj[:, 0, r + n:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    xf = x[:, 0].astype(jnp.float32)
    h = jnp.exp(dt[..., None] * A) * cache["h"] + (dt * xf)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + xf * p["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(u.dtype)
    return (y @ p["out_proj"])[:, None], {"h": h, "conv": conv_state}


# ===========================================================================
# Mamba-2 / SSD (zamba2)
# ===========================================================================

def init_mamba2(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Projections are stored SPLIT (w_z/w_x/w_bc/w_dt + per-part convs)
    instead of HF's merged in_proj/conv (§Perf Z4): the merged layout's
    output slices straddle shard boundaries, forcing mp-replicated compute;
    split, the z/x/head dims TP cleanly (depthwise conv splits exactly)."""
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    heads = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "w_z": (std * jax.random.normal(ks[0], (d, di))).astype(dtype),
        "w_x": (std * jax.random.normal(ks[1], (d, di))).astype(dtype),
        "w_bc": (std * jax.random.normal(ks[2], (d, 2 * n))).astype(dtype),
        "w_dt": (std * jax.random.normal(ks[3], (d, heads))).astype(dtype),
        "conv_w": (0.1 * jax.random.normal(ks[4], (k, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_w_bc": (0.1 * jax.random.normal(ks[5], (k, 2 * n))).astype(dtype),
        "conv_b_bc": jnp.zeros((2 * n,), dtype),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads).astype(jnp.float32)),
        "D": jnp.ones((heads,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": (di ** -0.5 * jax.random.normal(ks[3], (di, d))).astype(dtype),
    }


def _mamba2_split(p, u, cfg: LMConfig):
    z = u @ p["w_z"]
    x = u @ p["w_x"]
    bc = u @ p["w_bc"]
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    return z, x, bc, dt


def mamba2_ssd_forward(p: Dict[str, Any], u: jax.Array, cfg: LMConfig,
                       return_state: bool = False):
    """Mamba-2 via the SSD block-matmul form (§Perf Z1).

    The chunked associative scan materializes (B,chunk,H,P,N) — 34 GB/device
    for zamba2's train_4k cell. SSD reformulates the intra-chunk recurrence
    as causal-masked matmuls:  Y = ((C Bᵀ) ⊙ decay) @ (dt⊙x) + C·(decay·S),
    with only the (B,H,K,K) kernel and (B,H,P,N) states live — ~50x less
    memory, and the FLOPs move from the VPU to the MXU.
    """
    bsz, s, _ = u.shape
    di, n, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_chunk
    hds = cfg.ssm_head_dim
    heads = di // hds
    z, x_raw, bc_raw, dt = _mamba2_split(p, u, cfg)
    x, _ = _causal_conv(x_raw, p["conv_w"], p["conv_b"])
    bc, _ = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    Bm, Cm = bc[..., :n], bc[..., n:]
    A = -jnp.exp(p["A_log"])                                         # (H,)

    pad = (-s) % ck
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // ck
    xh = x.reshape(bsz, nc, ck, heads, hds).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, ck, heads)                             # f32 already
    Bc = Bm.reshape(bsz, nc, ck, n).astype(jnp.float32)
    Cc = Cm.reshape(bsz, nc, ck, n).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((ck, ck), bool))

    def chunk(S, i):
        dti, xi, Bi, Ci = dtc[:, i], xh[:, i], Bc[:, i], Cc[:, i]
        a = dti * A                                                  # (B,K,H) logs
        ca = jnp.cumsum(a, axis=1)
        dtx = dti[..., None] * xi                                    # (B,K,H,P)
        # intra-chunk: ((C Bᵀ) ⊙ exp(ca_i - ca_j) ⊙ causal) @ dtx
        cb = jnp.einsum("bin,bjn->bij", Ci, Bi)                      # (B,K,K)
        decay = jnp.exp(ca[:, :, None, :] - ca[:, None, :, :])       # (B,K,K,H)
        kern = cb[..., None] * jnp.where(causal[None, :, :, None], decay, 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", kern, dtx)
        # inter-chunk: carry-in state decayed to step i
        y = y + jnp.exp(ca)[..., None] * jnp.einsum("bin,bhpn->bihp", Ci, S)
        # state update
        tail = jnp.exp(ca[:, -1:, :] - ca)                           # (B,K,H)
        S_new = (jnp.exp(ca[:, -1])[:, :, None, None] * S
                 + jnp.einsum("bkhp,bkn->bhpn", tail[..., None] * dtx, Bi))
        return S_new, y

    S0 = jnp.zeros((bsz, heads, hds, n), jnp.float32)
    S_final, ys = lax.scan(chunk, S0, jnp.arange(nc))                # (nc,B,K,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * ck, heads, hds)[:, :s]
    y = y + xh.reshape(bsz, nc * ck, heads, hds)[:, :s] * p["D"][:, None]
    y = y.reshape(bsz, s, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    from repro.models.lm.attention import rmsnorm
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"h": S_final, "conv": x_raw[:, -(cfg.ssm_conv - 1):, :],
                     "conv_bc": bc_raw[:, -(cfg.ssm_conv - 1):, :]}
    return out


def mamba2_forward(p: Dict[str, Any], u: jax.Array, cfg: LMConfig,
                   return_state: bool = False):
    if cfg.mamba2_impl == "ssd":
        return mamba2_ssd_forward(p, u, cfg, return_state)
    bsz, s, _ = u.shape
    di, n, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_chunk
    hds = cfg.ssm_head_dim
    heads = di // hds
    z, x_raw, bc_raw, dt = _mamba2_split(p, u, cfg)
    x, _ = _causal_conv(x_raw, p["conv_w"], p["conv_b"])
    bc, _ = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    Bm, Cm = bc[..., :n], bc[..., n:]
    A = -jnp.exp(p["A_log"])                                             # (H,)

    pad = (-s) % ck
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // ck
    xh = x.reshape(bsz, nc, ck, heads, hds).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, ck, heads)
    Bc = Bm.reshape(bsz, nc, ck, n).astype(jnp.float32)
    Cc = Cm.reshape(bsz, nc, ck, n).astype(jnp.float32)

    def a_fn(i):
        return jnp.exp(dtc[:, i] * A)[..., None, None]                   # (B,ck,H,1,1)

    def b_fn(i):
        return (dtc[:, i][..., None, None] * xh[:, i][..., None]
                * Bc[:, i, :, None, None, :])                            # (B,ck,H,P,n)

    def y_fn(i, h_all):
        return jnp.einsum("bkhpn,bkn->bkhp", h_all, Cc[:, i])

    h0 = jnp.zeros((bsz, heads, hds, n), jnp.float32)
    h_final, ys = _scan_chunked(a_fn, b_fn, y_fn, h0, nc)                # (nc,B,ck,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * ck, heads, hds)[:, :s]
    y = y + xh.reshape(bsz, nc * ck, heads, hds)[:, :s] * p["D"][:, None]
    y = y.reshape(bsz, s, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    from repro.models.lm.attention import rmsnorm
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"h": h_final, "conv": x_raw[:, -(cfg.ssm_conv - 1):, :],
                     "conv_bc": bc_raw[:, -(cfg.ssm_conv - 1):, :]}
    return out


def mamba2_init_cache(cfg: LMConfig, batch: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    di, n = cfg.d_inner, cfg.ssm_state
    heads = di // cfg.ssm_head_dim
    return {"h": jnp.zeros((batch, heads, cfg.ssm_head_dim, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
            "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * n), dtype)}


def mamba2_decode(p, u, cfg: LMConfig, cache):
    di, n = cfg.d_inner, cfg.ssm_state
    hds = cfg.ssm_head_dim
    heads = di // hds
    z, x_raw, bc_raw, dt = _mamba2_split(p, u, cfg)
    x, conv_state = _causal_conv(x_raw, p["conv_w"], p["conv_b"], cache["conv"])
    bc, conv_bc_state = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"], cache["conv_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    Bm, Cm = bc[..., :n], bc[..., n:]
    A = -jnp.exp(p["A_log"])
    xf = x[:, 0].reshape(-1, heads, hds).astype(jnp.float32)
    dt1 = dt[:, 0]                                                       # (B,H)
    Bf, Cf = Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32)
    a = jnp.exp(dt1 * A)[..., None, None]
    h = a * cache["h"] + (dt1[..., None, None] * xf[..., None]) * Bf[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cf) + xf * p["D"][:, None]
    y = y.reshape(-1, di)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(u.dtype)
    from repro.models.lm.attention import rmsnorm
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], {"h": h, "conv": conv_state,
                                          "conv_bc": conv_bc_state}
