"""Attention substrate: RoPE, GQA, MLA (deepseek), blockwise-flash attention.

All attention math is *chunked* (lazy softmax over KV blocks via lax.scan):
the S x S score matrix is never materialized, which is what makes the 32K
prefill and 4K x 256 train cells compile-and-fit on the production mesh (and
on the CPU dry-run host). A Pallas flash kernel is the TPU fast path for the
same math; the chunked form is the portable/compile-path implementation.

Decode attention is a plain einsum over the cache: under GSPMD a
sequence-sharded cache is handled with partial-reduction collectives
(the flash-decoding combine), which we also expose explicitly via shard_map
in repro/distributed/collectives.py for the hillclimb.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig

NEG_INF = -1e30


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(x.dtype) * w


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) -> cos/sin of shape (..., head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D//2) or broadcastable (..., S, 1, D//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:                      # (S, D/2) -> (1, S, 1, D/2)
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash-style) causal attention — pure JAX, scan over KV chunks
# ---------------------------------------------------------------------------

def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, chunk: int = 512,
                        q_offset: int = 0) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,G,D) with H = n*G (GQA). Lazy softmax:
    O(Sq*chunk) live memory instead of O(Sq*Sk)."""
    b, sq, h, d = q.shape
    sk, g = k.shape[1], k.shape[2]
    dv = v.shape[-1]                                   # MLA: d_v != d_qk
    rep = h // g
    scale = d ** -0.5
    nc = -(-sk // chunk)
    pad = nc * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nc, chunk, g, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, g, dv).transpose(1, 0, 2, 3, 4)
    qh = q.reshape(b, sq, g, rep, d)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, blk):
        m, l, o = carry
        kb, vb, ci = blk                                   # (B,C,G,D), (B,C,G,D), ()
        s = jnp.einsum("bqgrd,bcgd->bqgrc", qh, kb,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = ci * chunk + jnp.arange(chunk)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else (kv_pos[None, :] < sk)
        mask = mask & (kv_pos[None, :] < sk)               # padding mask
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bqgrc,bcgd->bqgrd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, sq, g, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, g, rep), jnp.float32)
    o0 = jnp.zeros((b, sq, g, rep, dv), jnp.float32)
    # K/V stream through the scan in their model precision (bf16 for the
    # production configs — halves TP resharding bytes vs the f32 baseline);
    # scores/accumulators stay f32 via preferred_element_type (§Perf D3).
    kvdt = k.dtype
    (m, l, o), _ = lax.scan(body, (m0, l0, o0),
                            (kc.astype(kvdt), vc.astype(kvdt),
                             jnp.arange(nc)))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array) -> jax.Array:
    """One-token attention over a (possibly sequence-sharded) cache.

    q: (B,1,H,D); caches: (B,S,G,D); length: () current cache fill."""
    b, _, h, d = q.shape
    s, g = k_cache.shape[1], k_cache.shape[2]
    rep = h // g
    qh = q.reshape(b, g, rep, d)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qh.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * d ** -0.5
    mask = jnp.arange(s)[None, None, None, :] < length
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, g = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": (std * jax.random.normal(ks[0], (d, h * hd))).astype(dtype),
        "wk": (std * jax.random.normal(ks[1], (d, g * hd))).astype(dtype),
        "wv": (std * jax.random.normal(ks[2], (d, g * hd))).astype(dtype),
        "wo": (std * jax.random.normal(ks[3], (h * hd, d))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((g * hd,), dtype)
        p["bv"] = jnp.zeros((g * hd,), dtype)
    return p


def gqa_qkv(p, x: jax.Array, cfg: LMConfig, positions: jax.Array):
    b, s, _ = x.shape
    hd, h, g = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, s, h, hd)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(b, s, g, hd)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(b, s, g, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_self_attention(p, x: jax.Array, cfg: LMConfig, *, causal: bool = True,
                       q_offset: int = 0) -> jax.Array:
    s = x.shape[1]
    positions = q_offset + jnp.arange(s)
    q, k, v = gqa_qkv(p, x, cfg, positions)
    o = blockwise_attention(q, k, v, causal=causal, chunk=min(cfg.attn_chunk, s),
                            q_offset=q_offset)
    return o.reshape(x.shape[0], s, -1) @ p["wo"]


def gqa_decode(p, x: jax.Array, cfg: LMConfig, cache: Dict[str, jax.Array],
               pos: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,1,D); cache: {'k','v'}: (B,S,G,hd); pos: () int32 fill count."""
    b = x.shape[0]
    q, k, v = gqa_qkv(p, x, cfg, pos[None] if pos.ndim == 0 else pos)
    k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    return o.reshape(b, 1, -1) @ p["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): low-rank q/kv + decoupled RoPE; absorbed decode
# ---------------------------------------------------------------------------

def init_mla(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    n = lambda i, shape, s=std: (s * jax.random.normal(ks[i], shape)).astype(dtype)
    return {
        "wdq": n(0, (d, qr)),                       # q down
        "q_norm": jnp.ones((qr,), dtype),
        "wuq": n(1, (qr, h * (dn + dr)), qr ** -0.5),   # q up (nope+rope)
        "wdkv": n(2, (d, kr)),                      # kv down (the cached latent)
        "kv_norm": jnp.ones((kr,), dtype),
        "wukv": n(3, (kr, h * (dn + dv)), kr ** -0.5),  # kv up
        "wkr": n(4, (d, dr)),                       # shared rope key
        "wo": n(5, (h * dv, d)),
    }


def _mla_qkr(p, x, cfg: LMConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps) @ p["wuq"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope((x @ p["wkr"]).reshape(b, s, 1, dr), cos, sin)
    return q_nope, q_rope, k_rope


def mla_blockwise_attention_lazy(q_nope, q_rope, c_kv, k_rope, wukv, cfg: LMConfig, *,
                                 chunk: int = 512, q_offset: int = 0) -> jax.Array:
    """§Perf D4 (REFUTED — kept for the record): lazy per-chunk K/V expansion
    from the latent. Napkin math predicted a 4x collective win (the latent is
    43x smaller than reconstructed K/V); measured, GSPMD re-sharded the
    in-loop expansion and the step REGRESSED 107.6s -> 453.5s (all-gather
    2.9 TB -> 19 TB/device). Default path is mla_blockwise_attention below;
    enable this with --opts mla_lazy to reproduce the refutation."""
    b, sq, h, dn = q_nope.shape
    sk = c_kv.shape[1]
    dr = q_rope.shape[-1]
    kr = cfg.kv_lora_rank
    dv = cfg.v_head_dim
    scale = (dn + dr) ** -0.5
    nc = -(-sk // chunk)
    pad = nc * chunk - sk
    if pad:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    ckvc = c_kv.reshape(b, nc, chunk, kr).transpose(1, 0, 2, 3)
    krc = k_rope.reshape(b, nc, chunk, dr).transpose(1, 0, 2, 3)
    q_pos = q_offset + jnp.arange(sq)
    kvdt = c_kv.dtype
    qn = q_nope.astype(kvdt)
    qr = q_rope.astype(kvdt)
    w_uk = wukv.reshape(kr, h, dn + dv)[..., :dn]
    w_uv = wukv.reshape(kr, h, dn + dv)[..., dn:]

    def body(carry, blk):
        m, l, o = carry
        ckvb, krb, ci = blk
        kb = jnp.einsum("bcr,rhd->bchd", ckvb, w_uk)          # lazy K expansion
        vb = jnp.einsum("bcr,rhd->bchd", ckvb, w_uv)          # lazy V expansion
        s = jnp.einsum("bqhd,bchd->bqhc", qn, kb,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bqhr,bcr->bqhc", qr, krb,
                           preferred_element_type=jnp.float32)
        s = s * scale
        kv_pos = ci * chunk + jnp.arange(chunk)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (kv_pos[None, :] < sk)
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pexp.sum(axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", pexp.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    o0 = jnp.zeros((b, sq, h, dv), jnp.float32)
    (m, l, o), _ = lax.scan(body, (m0, l0, o0),
                            (ckvc, krc.astype(kvdt), jnp.arange(nc)))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q_nope.dtype)


def mla_blockwise_attention(q_nope, q_rope, k_nope, k_rope, v, *,
                            chunk: int = 512, q_offset: int = 0) -> jax.Array:
    """Blockwise attention with MLA's decoupled score (§Perf D2, the winner):
        s = q_nope.k_nope (per-head) + q_rope.k_rope (HEAD-SHARED).
    The rope term contracts the shared (B,S,dr) key directly — it never
    materializes broadcast_to(k_rope, heads), which forced an all-gather of
    K over the head axis (366 GB x 488 per step measured before D2)."""
    b, sq, h, dn = q_nope.shape
    sk = k_nope.shape[1]
    dv = v.shape[-1]
    dr = q_rope.shape[-1]
    scale = (dn + dr) ** -0.5
    nc = -(-sk // chunk)
    pad = nc * chunk - sk
    if pad:
        k_nope = jnp.pad(k_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k_nope.reshape(b, nc, chunk, h, dn).transpose(1, 0, 2, 3, 4)
    krc = k_rope.reshape(b, nc, chunk, dr).transpose(1, 0, 2, 3)
    vc = v.reshape(b, nc, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(sq)
    kvdt = k_nope.dtype
    qn = q_nope.astype(kvdt)
    qr = q_rope.astype(kvdt)

    def body(carry, blk):
        m, l, o = carry
        kb, krb, vb, ci = blk
        s = jnp.einsum("bqhd,bchd->bqhc", qn, kb,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bqhr,bcr->bqhc", qr, krb,
                           preferred_element_type=jnp.float32)
        s = s * scale
        kv_pos = ci * chunk + jnp.arange(chunk)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (kv_pos[None, :] < sk)
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pexp.sum(axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", pexp.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    o0 = jnp.zeros((b, sq, h, dv), jnp.float32)
    (m, l, o), _ = lax.scan(body, (m0, l0, o0),
                            (kc, krc.astype(kvdt), vc, jnp.arange(nc)))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q_nope.dtype)


def mla_self_attention(p, x: jax.Array, cfg: LMConfig, *, q_offset: int = 0) -> jax.Array:
    """Prefill/train path. Default (D2): reconstruct per-head K/V from the
    latent once, head-shared rope key. cfg.mla_lazy_kv selects the refuted
    D4 lazy-expansion variant (kept for reproducibility)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = q_offset + jnp.arange(s)
    q_nope, q_rope, k_rope = _mla_qkr(p, x, cfg, positions)
    c_kv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)
    if cfg.mla_lazy_kv:
        o = mla_blockwise_attention_lazy(q_nope, q_rope, c_kv, k_rope[:, :, 0],
                                         p["wukv"], cfg,
                                         chunk=min(cfg.attn_chunk, s),
                                         q_offset=q_offset)
    else:
        kv = (c_kv @ p["wukv"]).reshape(b, s, h, dn + dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        o = mla_blockwise_attention(q_nope, q_rope, k_nope, k_rope[:, :, 0], v,
                                    chunk=min(cfg.attn_chunk, s),
                                    q_offset=q_offset)
    return o.reshape(b, s, h * cfg.v_head_dim) @ p["wo"]


def mla_decode(p, x: jax.Array, cfg: LMConfig, cache: Dict[str, jax.Array],
               pos: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed decode: scores/outputs computed in the 512-dim latent space —
    the cache stays (B, S, kv_lora_rank + rope_dim), never expanded to heads."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv, kr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q_nope, q_rope, k_rope = _mla_qkr(p, x, cfg, pos[None])
    c_kv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)      # (B,1,kr)
    ckv_cache = lax.dynamic_update_slice(cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0))
    kr_cache = lax.dynamic_update_slice(cache["kr"], k_rope[:, :, 0].astype(cache["kr"].dtype), (0, pos, 0))

    wukv = p["wukv"].reshape(kr, h, dn + dv)
    w_uk, w_uv = wukv[..., :dn], wukv[..., dn:]                    # (kr,h,dn),(kr,h,dv)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)         # absorb W_uk
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        kr_cache.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    scores = (s_lat + s_rope) * scale
    mask = jnp.arange(scores.shape[-1])[None, None, :] <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    pattn = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pattn, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))  # absorb W_uv
    out = o.reshape(b, 1, h * dv).astype(x.dtype) @ p["wo"]
    return out, {"ckv": ckv_cache, "kr": kr_cache}
