"""Unified LM stack for every assigned architecture family.

MaxText-style scan-over-layers: per-layer params are stacked on a leading L
axis and the layer body is compiled ONCE (lax.scan), keeping HLO size O(1) in
depth — this is what makes 61-64-layer 300B+ dry-runs compile on one CPU core
and keeps the real-TPU compile times sane. Remat (activation checkpointing)
wraps the scanned body; the policy is a config knob hillclimbed in §Perf.

Families: dense GQA (granite/qwen2/minitron), MoE (grok-1/deepseek-v3 + MLA),
SSM (falcon-mamba), hybrid mamba2+shared-attn (zamba2), VLM backbone
(internvl2, stub vision frontend), and the enc-dec wrapper in encdec.py.

Cross-entropy is *chunked over the sequence* (lax.scan): the (B,S,V) logits
tensor — 550 TB for grok-1's train_4k cell — is never materialized.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.distributed import ctx as shard
from repro.models.lm import attention as A
from repro.models.lm import ffn as F
from repro.models.lm import ssm as S

MOE_AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3


# ===========================================================================
# init
# ===========================================================================

def init_block(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    if cfg.family == "ssm":
        return {"ln1": jnp.ones((d,), dtype), "mamba": S.init_mamba1(k1, cfg, dtype)}
    if cfg.family == "hybrid":
        return {"ln1": jnp.ones((d,), dtype), "mamba": S.init_mamba2(k1, cfg, dtype)}
    p: Dict[str, Any] = {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype)}
    p["attn"] = A.init_mla(k1, cfg, dtype) if cfg.use_mla else A.init_gqa(k1, cfg, dtype)
    if cfg.n_experts:
        p["moe"] = F.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = F.init_mlp(k2, d, cfg.d_ff, cfg.act, dtype)
    return p


def init_shared_block(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """zamba2's weight-shared attention+MLP block (one set of weights, applied
    every ``shared_attn_every`` layers — the paper-spirit 'shared subnet')."""
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
            "attn": A.init_gqa(k1, cfg, dtype),
            "mlp": F.init_mlp(k2, d, cfg.d_ff, cfg.act, dtype)}


def init_lm(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    lkeys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(lkeys)
    params: Dict[str, Any] = {
        "embed": (cfg.d_model ** -0.5 *
                  jax.random.normal(ks[1], (cfg.vocab_padded, cfg.d_model))).astype(dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (cfg.d_model ** -0.5 * jax.random.normal(
            ks[2], (cfg.d_model, cfg.vocab_padded))).astype(dtype)
    if cfg.shared_attn_every:
        params["shared_block"] = init_shared_block(ks[3], cfg, dtype)
    if cfg.mtp:
        km = jax.random.split(ks[3], 3)
        params["mtp"] = {
            "proj": (cfg.d_model ** -0.5 * jax.random.normal(
                km[0], (2 * cfg.d_model, cfg.d_model))).astype(dtype),
            "block": init_block(km[1], cfg, dtype),
            "ln": jnp.ones((cfg.d_model,), dtype),
        }
    if cfg.frontend == "vision":
        params["vision_proj"] = (cfg.d_model ** -0.5 * jax.random.normal(
            ks[3], (cfg.d_model, cfg.d_model))).astype(dtype)
    return params


# ===========================================================================
# block forward (one layer; compiled once under scan)
# ===========================================================================

def block_forward(p, x: jax.Array, cfg: LMConfig, *, q_offset: int = 0
                  ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence (train/prefill) layer. Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        return x + S.mamba1_forward(p["mamba"], A.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg), aux
    if cfg.family == "hybrid":
        return x + S.mamba2_forward(p["mamba"], A.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg), aux
    h = A.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        x = x + A.mla_self_attention(p["attn"], h, cfg, q_offset=q_offset)
    else:
        x = x + A.gqa_self_attention(p["attn"], h, cfg, q_offset=q_offset)
    h = A.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, aux = F.moe_forward(p["moe"], h, cfg)
    elif cfg.dynamic_width:
        y = F.dynamic_width_ffn(p["mlp"], h, cfg.act)
    else:
        y = F.mlp(p["mlp"], h, cfg.act)
    return x + y, aux


def shared_block_forward(p, x: jax.Array, cfg: LMConfig) -> jax.Array:
    h = A.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + A.gqa_self_attention(p["attn"], h, cfg)
    h = A.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + F.mlp(p["mlp"], h, cfg.act)


# ===========================================================================
# full-sequence forward (train / prefill hidden states)
# ===========================================================================

def lm_hidden(params, cfg: LMConfig, tokens: Optional[jax.Array] = None,
              prefix_embeds: Optional[jax.Array] = None, *,
              remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """-> (final hidden (B,S,D), moe aux loss). S = prefix + token length."""
    parts = []
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(params["embed"].dtype)
        if "vision_proj" in params:
            pe = pe @ params["vision_proj"]
        parts.append(pe)
    if tokens is not None:
        parts.append(jnp.take(params["embed"], tokens, axis=0))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    # Megatron-SP (seq over model) for attention archs. SSM/hybrid layers
    # have mp-replicated mixer weights, so SP only buys per-chunk all-gathers
    # of the scan tensors (§Perf Z2: 125 GB/dev of gathers on zamba2) — their
    # sequence stays dp-only.
    seq_mp = None if cfg.family in ("ssm", "hybrid") else "mp"
    x = shard.constrain(x, "dp", seq_mp, None)

    shared = params.get("shared_block")
    every = cfg.shared_attn_every

    def body(carry, inp):
        x, aux = carry
        lp, i = inp
        x, a = block_forward(lp, x, cfg)
        if shared is not None and every:
            x = lax.cond((i + 1) % every == 0,
                         lambda v: shared_block_forward(shared, v, cfg),
                         lambda v: v, x)
        x = shard.constrain(x, "dp", seq_mp, None)
        return (x, aux + a), None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                           (params["layers"], jnp.arange(cfg.n_layers)))
    return A.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


# ===========================================================================
# chunked cross-entropy (never materializes (B,S,V))
# ===========================================================================

def head_weight(params) -> jax.Array:
    return params.get("lm_head", params["embed"].T if "lm_head" not in params else None)


def chunked_ce(h: jax.Array, w: jax.Array, labels: jax.Array,
               chunk: int = 512) -> jax.Array:
    """h: (B,S,D); w: (D,V); labels: (B,S) with -1 = masked. Mean over valid."""
    b, s, d = h.shape
    h = shard.constrain(h, "dp", None, None)      # un-SP before the seq-chunk reshape
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hc = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(acc, inp):
        hh, ll = inp
        logits = (hh @ w).astype(jnp.float32)                 # (B,c,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        mask = (ll >= 0).astype(jnp.float32)
        loss_sum, n = acc
        return (loss_sum + jnp.sum((lse - gold) * mask), n + mask.sum()), None

    (loss_sum, n), _ = lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                                (hc, lc))
    return loss_sum / jnp.maximum(n, 1.0)


def lm_loss(params, cfg: LMConfig, tokens: jax.Array, labels: jax.Array,
            prefix_embeds: Optional[jax.Array] = None, *, remat: bool = True) -> jax.Array:
    h, aux = lm_hidden(params, cfg, tokens, prefix_embeds, remat=remat)
    if prefix_embeds is not None:                    # loss only on text positions
        h = h[:, prefix_embeds.shape[1]:]
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    loss = chunked_ce(h, w, labels)
    if cfg.n_experts:
        loss = loss + MOE_AUX_WEIGHT * aux / cfg.n_layers
    if cfg.mtp and "mtp" in params:
        # deepseek MTP: predict t+2 from [h_t ; emb(t+1)] through one extra block
        emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
        mtp_in = jnp.concatenate([h[:, :-1], emb_next], axis=-1) @ params["mtp"]["proj"]
        mtp_h, _ = block_forward(params["mtp"]["block"], mtp_in, cfg)
        mtp_h = A.rmsnorm(mtp_h, params["mtp"]["ln"], cfg.norm_eps)
        mtp_labels = jnp.pad(labels[:, 2:], ((0, 0), (0, 1)), constant_values=-1)
        loss = loss + MTP_WEIGHT * chunked_ce(mtp_h, w, mtp_labels[:, :mtp_h.shape[1]])
    return loss


# ===========================================================================
# KV/state caches + decode
# ===========================================================================

def init_caches(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    L = cfg.n_layers
    if cfg.family == "ssm":
        c = S.mamba1_init_cache(cfg, batch, dtype)
        return {"ssm": jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (L,) + x.shape).copy(), c)}
    if cfg.family == "hybrid":
        c = S.mamba2_init_cache(cfg, batch, dtype)
        out = {"ssm": jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (L,) + x.shape).copy(), c)}
        if cfg.shared_attn_every:
            n_inv = cfg.n_layers // cfg.shared_attn_every
            hd, g = cfg.resolved_head_dim, cfg.n_kv_heads
            out["shared_kv"] = {
                "k": jnp.zeros((n_inv, batch, max_len, g, hd), dtype),
                "v": jnp.zeros((n_inv, batch, max_len, g, hd), dtype)}
        return out
    if cfg.use_mla:
        return {"ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((L, batch, max_len, cfg.qk_rope_head_dim), dtype)}
    hd, g = cfg.resolved_head_dim, cfg.n_kv_heads
    return {"k": jnp.zeros((L, batch, max_len, g, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, g, hd), dtype)}


def block_decode(p, x, cfg: LMConfig, cache_l, pos):
    """One layer, one token. cache_l: this layer's cache slice."""
    if cfg.family in ("ssm", "hybrid"):
        h = A.rmsnorm(x, p["ln1"], cfg.norm_eps)
        fn = S.mamba1_decode if cfg.family == "ssm" else S.mamba2_decode
        y, new = fn(p["mamba"], h, cfg, cache_l)
        return x + y, new
    h = A.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        o, new = A.mla_decode(p["attn"], h, cfg, cache_l, pos)
    else:
        o, new = A.gqa_decode(p["attn"], h, cfg, cache_l, pos)
    x = x + o
    h = A.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, _ = F.moe_forward(p["moe"], h, cfg)
    elif cfg.dynamic_width:
        y = F.dynamic_width_ffn(p["mlp"], h, cfg.act)
    else:
        y = F.mlp(p["mlp"], h, cfg.act)
    return x + y, new


def shared_block_decode(p, x, cfg: LMConfig, kv, pos):
    h = A.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, new_kv = A.gqa_decode(p["attn"], h, cfg, kv, pos)
    x = x + o
    h = A.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + F.mlp(p["mlp"], h, cfg.act), new_kv


def lm_decode_step(params, cfg: LMConfig, token: jax.Array, caches: Dict[str, Any],
                   pos: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """token: (B,1) int32; pos: () int32 fill count. -> (logits (B,V), caches)."""
    x = jnp.take(params["embed"], token, axis=0)
    shared = params.get("shared_block")
    every = cfg.shared_attn_every

    if cfg.family in ("ssm", "hybrid"):
        layer_caches = caches["ssm"]
    elif cfg.use_mla:
        layer_caches = {"ckv": caches["ckv"], "kr": caches["kr"]}
    else:
        layer_caches = {"k": caches["k"], "v": caches["v"]}

    if shared is not None and every:
        def body(carry, inp):
            x, sh_kv = carry
            lp, cache_l, i = inp
            x, new_c = block_decode(lp, x, cfg, cache_l, pos)
            inv = (i + 1) // every - 1

            def apply(args):
                x, sh_kv = args
                kv = jax.tree_util.tree_map(lambda c: c[inv], sh_kv)
                x, new_kv = shared_block_decode(shared, x, cfg, kv, pos)
                sh_kv = jax.tree_util.tree_map(
                    lambda c, n: lax.dynamic_update_index_in_dim(c, n, inv, 0),
                    sh_kv, new_kv)
                return x, sh_kv

            x, sh_kv = lax.cond((i + 1) % every == 0, apply, lambda a: a, (x, sh_kv))
            return (x, sh_kv), new_c

        (x, sh_kv), new_caches = lax.scan(
            body, (x, caches["shared_kv"]),
            (params["layers"], layer_caches, jnp.arange(cfg.n_layers)))
        out_caches = {"ssm": new_caches, "shared_kv": sh_kv}
    else:
        def body(x, inp):
            lp, cache_l = inp
            x, new_c = block_decode(lp, x, cfg, cache_l, pos)
            return x, new_c

        x, new_caches = lax.scan(body, x, (params["layers"], layer_caches))
        if cfg.family == "ssm":
            out_caches = {"ssm": new_caches}
        else:
            out_caches = new_caches

    h = A.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (h[:, 0] @ w).astype(jnp.float32)
    return logits, out_caches


# ===========================================================================
# prefill: full forward that also fills the caches
# ===========================================================================

def lm_prefill(params, cfg: LMConfig, tokens: jax.Array, max_len: int,
               prefix_embeds: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Runs the full sequence AND builds caches for subsequent decode.
    Returns (last-token logits (B,V), caches). For attention archs the caches
    are the per-layer K/V (or MLA latents); for SSMs the final states."""
    x0 = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x0.dtype)
        if "vision_proj" in params:
            pe = pe @ params["vision_proj"]
        x0 = jnp.concatenate([pe, x0], axis=1)
    b, s, _ = x0.shape

    shared = params.get("shared_block")
    every = cfg.shared_attn_every

    if cfg.family == "hybrid" and shared is not None and every:
        n_inv = cfg.n_layers // every
        hd, g = cfg.resolved_head_dim, cfg.n_kv_heads
        sh_kv0 = {"k": jnp.zeros((n_inv, b, max_len, g, hd), x0.dtype),
                  "v": jnp.zeros((n_inv, b, max_len, g, hd), x0.dtype)}

        def body(carry, inp):
            x, sh_kv = carry
            lp, i = inp
            h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, st = S.mamba2_forward(lp["mamba"], h, cfg, return_state=True)
            x = x + y
            inv = (i + 1) // every - 1

            def apply(args):
                x, sh_kv = args
                x, k, v = _shared_block_prefill(shared, x, cfg)
                k = jnp.pad(k, ((0, 0), (0, max_len - s), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, max_len - s), (0, 0), (0, 0)))
                sh_kv = {"k": lax.dynamic_update_index_in_dim(sh_kv["k"], k.astype(sh_kv["k"].dtype), inv, 0),
                         "v": lax.dynamic_update_index_in_dim(sh_kv["v"], v.astype(sh_kv["v"].dtype), inv, 0)}
                return x, sh_kv

            x, sh_kv = lax.cond((i + 1) % every == 0, apply, lambda a: a, (x, sh_kv))
            return (x, sh_kv), st

        (x, sh_kv), caches = lax.scan(body, (x0, sh_kv0),
                                      (params["layers"], jnp.arange(cfg.n_layers)))
        caches = {"ssm": caches, "shared_kv": sh_kv}
    else:
        def body(x, lp):
            if cfg.family in ("ssm", "hybrid"):
                h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                fwd = S.mamba1_forward if cfg.family == "ssm" else S.mamba2_forward
                y, st = fwd(lp["mamba"], h, cfg, return_state=True)
                return x + y, st
            new_cache = _prefill_layer_cache(lp, x, cfg, s, max_len)
            x, _ = block_forward(lp, x, cfg)
            return x, new_cache

        x, caches = lax.scan(body, x0, params["layers"])
        if cfg.family in ("ssm", "hybrid"):
            caches = {"ssm": caches}

    h = A.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (h[:, -1] @ w).astype(jnp.float32)
    return logits, caches


def _shared_block_prefill(p, x, cfg: LMConfig):
    """Shared block full-seq forward that also returns its K/V for the cache."""
    bsz, s, _ = x.shape
    h = A.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = A.gqa_qkv(p["attn"], h, cfg, jnp.arange(s))
    o = A.blockwise_attention(q, k, v, causal=True, chunk=min(cfg.attn_chunk, s))
    x = x + o.reshape(bsz, s, -1) @ p["attn"]["wo"]
    h = A.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + F.mlp(p["mlp"], h, cfg.act), k, v


def _prefill_layer_cache(lp, x, cfg: LMConfig, s: int, max_len: int):
    """Attention-arch cache from a prefill layer input (K/V or MLA latents)."""
    h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    pad = max_len - s
    positions = jnp.arange(s)
    if cfg.use_mla:
        c_kv = A.rmsnorm(h @ lp["attn"]["wdkv"], lp["attn"]["kv_norm"], cfg.norm_eps)
        cos, sin = A.rope_freqs(cfg.qk_rope_head_dim, cfg.rope_theta, positions)
        kr = A.apply_rope((h @ lp["attn"]["wkr"]).reshape(x.shape[0], s, 1, -1), cos, sin)[:, :, 0]
        return {"ckv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                "kr": jnp.pad(kr, ((0, 0), (0, pad), (0, 0)))}
    _, k, v = A.gqa_qkv(lp["attn"], h, cfg, positions)
    return {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
