"""Encoder-decoder stack (seamless-m4t backbone; [audio] frontend is a stub —
``input_specs`` supplies precomputed frame embeddings, per the assignment).

Encoder: bidirectional GQA blocks (scan). Decoder: causal self-attention +
cross-attention + MLP (scan). Decode caches = per-layer self-attn K/V plus
the cross-attn K/V precomputed once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.models.lm import attention as A
from repro.models.lm import ffn as F
from repro.models.lm.transformer import chunked_ce


def _init_cross(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, g = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    return {"wq": (std * jax.random.normal(ks[0], (d, h * hd))).astype(dtype),
            "wk": (std * jax.random.normal(ks[1], (d, g * hd))).astype(dtype),
            "wv": (std * jax.random.normal(ks[2], (d, g * hd))).astype(dtype),
            "wo": (std * jax.random.normal(ks[3], (h * hd, d))).astype(dtype)}


def init_encdec(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    d = cfg.d_model

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
                "attn": A.init_gqa(k1, cfg, dtype),
                "mlp": F.init_mlp(k2, d, cfg.d_ff, cfg.act, dtype)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((d,), dtype), "lnx": jnp.ones((d,), dtype),
                "ln2": jnp.ones((d,), dtype),
                "attn": A.init_gqa(k1, cfg, dtype),
                "cross": _init_cross(k2, cfg, dtype),
                "mlp": F.init_mlp(k3, d, cfg.d_ff, cfg.act, dtype)}

    return {
        "enc_layers": jax.vmap(enc_block)(jax.random.split(ks[0], cfg.n_encoder_layers)),
        "dec_layers": jax.vmap(dec_block)(jax.random.split(ks[1], cfg.n_layers)),
        "embed": (d ** -0.5 * jax.random.normal(ks[2], (cfg.vocab_padded, d))).astype(dtype),
        "enc_norm": jnp.ones((d,), dtype),
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": (d ** -0.5 * jax.random.normal(ks[3], (d, cfg.vocab_padded))).astype(dtype),
    }


def encode(params, cfg: LMConfig, src_embeds: jax.Array, *, remat: bool = True) -> jax.Array:
    x = src_embeds.astype(params["embed"].dtype)

    def body(x, lp):
        h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + A.gqa_self_attention(lp["attn"], h, cfg, causal=False)
        h = A.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + F.mlp(lp["mlp"], h, cfg.act), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(fn, x, params["enc_layers"])
    return A.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attend(cp, x, enc, cfg: LMConfig):
    b, s, _ = x.shape
    hd, h, g = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ cp["wq"]).reshape(b, s, h, hd)
    k = (enc @ cp["wk"]).reshape(b, enc.shape[1], g, hd)
    v = (enc @ cp["wv"]).reshape(b, enc.shape[1], g, hd)
    o = A.blockwise_attention(q, k, v, causal=False, chunk=min(cfg.attn_chunk, enc.shape[1]))
    return o.reshape(b, s, -1) @ cp["wo"]


def decode_train(params, cfg: LMConfig, enc: jax.Array, tokens: jax.Array,
                 *, remat: bool = True) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(x, lp):
        h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + A.gqa_self_attention(lp["attn"], h, cfg, causal=True)
        h = A.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + _cross_attend(lp["cross"], h, enc, cfg)
        h = A.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + F.mlp(lp["mlp"], h, cfg.act), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(fn, x, params["dec_layers"])
    return A.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(params, cfg: LMConfig, src_embeds, tokens, labels, *,
                remat: bool = True) -> jax.Array:
    enc = encode(params, cfg, src_embeds, remat=remat)
    h = decode_train(params, cfg, enc, tokens, remat=remat)
    return chunked_ce(h, params["lm_head"], labels)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_prefill(params, cfg: LMConfig, src_embeds, tokens, max_len: int
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    enc = encode(params, cfg, src_embeds, remat=False)
    b, s = tokens.shape
    hd, g = cfg.resolved_head_dim, cfg.n_kv_heads
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(x, lp):
        h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        _, k, v = A.gqa_qkv(lp["attn"], h, cfg, jnp.arange(s))
        x = x + A.gqa_self_attention(lp["attn"], h, cfg, causal=True)
        h = A.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + _cross_attend(lp["cross"], h, enc, cfg)
        h = A.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + F.mlp(lp["mlp"], h, cfg.act)
        ck = (enc @ lp["cross"]["wk"]).reshape(b, enc.shape[1], g, hd)
        cv = (enc @ lp["cross"]["wv"]).reshape(b, enc.shape[1], g, hd)
        cache = {"k": jnp.pad(k, ((0, 0), (0, max_len - s), (0, 0), (0, 0))),
                 "v": jnp.pad(v, ((0, 0), (0, max_len - s), (0, 0), (0, 0))),
                 "ck": ck, "cv": cv}
        return x, cache

    x, caches = lax.scan(body, x, params["dec_layers"])
    h = A.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (h[:, -1] @ params["lm_head"]).astype(jnp.float32)
    return logits, caches


def init_encdec_caches(cfg: LMConfig, batch: int, max_len: int, src_len: int,
                       dtype=jnp.bfloat16) -> Dict[str, Any]:
    L, hd, g = cfg.n_layers, cfg.resolved_head_dim, cfg.n_kv_heads
    return {"k": jnp.zeros((L, batch, max_len, g, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, g, hd), dtype),
            "ck": jnp.zeros((L, batch, src_len, g, hd), dtype),
            "cv": jnp.zeros((L, batch, src_len, g, hd), dtype)}


def encdec_decode_step(params, cfg: LMConfig, token, caches, pos
                       ) -> Tuple[jax.Array, Dict[str, Any]]:
    x = jnp.take(params["embed"], token, axis=0)

    def body(x, inp):
        lp, cache_l = inp
        h = A.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        o, new_kv = A.gqa_decode(lp["attn"], h, cfg, {"k": cache_l["k"], "v": cache_l["v"]}, pos)
        x = x + o
        h = A.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        b = x.shape[0]
        hd, hh, g = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        q = (h @ lp["cross"]["wq"]).reshape(b, 1, hh, hd)
        o = A.decode_attention(q, cache_l["ck"], cache_l["cv"],
                               jnp.asarray(cache_l["ck"].shape[1]))
        x = x + o.reshape(b, 1, -1) @ lp["cross"]["wo"]
        h = A.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + F.mlp(lp["mlp"], h, cfg.act)
        return x, {"k": new_kv["k"], "v": new_kv["v"], "ck": cache_l["ck"], "cv": cache_l["cv"]}

    x, new_caches = lax.scan(body, x, (params["dec_layers"], caches))
    h = A.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ params["lm_head"]).astype(jnp.float32)
    return logits, new_caches
