"""FFN substrate: dense (gated) MLP, MoE, and the ESSR-style dynamic-width FFN.

MoE uses capacity-based dispatch written as gather/scatter einsum math under
jit: the SAME lowering serves both parallelism modes — which mode you get is
purely a function of the expert-weight PartitionSpec (DESIGN.md §6):

  * expert_tp   (grok-1, E=8):   experts replicated, expert hidden dim TP'd
                                 over 'model' (all-reduce combine);
  * ep_alltoall (deepseek, E=256): experts sharded over 'model'; GSPMD turns
                                 the dispatch scatter into all-to-alls. The
                                 explicit shard_map variant is the §Perf
                                 hillclimb comparison point.

Dynamic-width FFN = the paper's edge-selective subnet idea transplanted:
per-token "edge score" (RMS of the pre-FFN hidden state) routes the top
``capacity`` tokens through the full-width FFN and the rest through the
weight-shared half-width slice (C54 vs C27, ARM-style shared weights).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed import ctx as shard


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, act: str, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {"w_in": (std_in * jax.random.normal(ks[0], (d, f))).astype(dtype),
         "w_out": (std_out * jax.random.normal(ks[1], (f, d))).astype(dtype)}
    if act != "relu2":                       # gated (SwiGLU-family)
        p["w_gate"] = (std_in * jax.random.normal(ks[2], (d, f))).astype(dtype)
    return p


def mlp(p: Dict[str, Any], x: jax.Array, act: str) -> jax.Array:
    a = _act(act)
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = h * a(x @ p["w_gate"])
    else:
        h = a(h)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# MoE (capacity dispatch, einsum/gather-scatter form)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": (std_in * jax.random.normal(ks[0], (d, e))).astype(jnp.float32),
        "w_in": (std_in * jax.random.normal(ks[1], (e, d, f))).astype(dtype),
        "w_gate": (std_in * jax.random.normal(ks[2], (e, d, f))).astype(dtype),
        "w_out": (std_out * jax.random.normal(ks[3], (e, f, d))).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts, cfg.act, dtype)
    return p


def moe_capacity(n_tokens: int, cfg: LMConfig) -> int:
    c = int(n_tokens * cfg.n_experts_per_tok * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)            # pad to 8 for TPU sublane alignment


def moe_forward(p: Dict[str, Any], x: jax.Array, cfg: LMConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss). Top-k, capacity-dropped, softmax-weighted."""
    if cfg.moe_impl == "shard_map":
        c = shard.current()
        if c is not None:
            from repro.distributed.moe import moe_forward_shardmap
            return moe_forward_shardmap(p, x, cfg, c.mesh, c.resolve("dp"), c.mp)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    t = b * s
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    aux = e * jnp.mean(density * jnp.mean(probs, axis=0))

    # capacity assignment: position of each (token, slot) within its expert
    cap = moe_capacity(t, cfg)
    flat_e = idx.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (T*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0), flat_e[:, None], axis=1)[:, 0] - 1
    valid = pos < cap
    slot = jnp.where(valid, flat_e * cap + pos, e * cap)      # drops -> scratch row

    tok = jnp.repeat(jnp.arange(t), k)
    disp = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(xf[tok] * valid[:, None])
    disp = disp[:-1].reshape(e, cap, d)
    # EP: experts over 'model' (GSPMD turns the scatter into all-to-alls);
    # expert-TP: dispatch replicated over 'model', hidden dim TP'd via w specs.
    ep = "mp" if cfg.moe_mode == "ep_alltoall" else None
    if cfg.moe_dispatch_token_shard:
        # §Perf G1/D2: shard the CAPACITY dim over dp. Without this the
        # expert einsum contracts over the FSDP-sharded d of a replicated
        # dispatch buffer -> per-layer TB-scale partial-sum all-reduces
        # (measured in EXPERIMENTS.md §Perf); with it, GSPMD all-gathers the
        # (much smaller) expert weights instead — ZeRO-3 semantics.
        disp = shard.constrain(disp, ep, "dp", None)
    else:
        disp = shard.constrain(disp, ep, None, None)

    a = _act(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", disp, p["w_in"])
    h = h * a(jnp.einsum("ecd,edf->ecf", disp, p["w_gate"]))
    if cfg.moe_dispatch_token_shard:
        h = shard.constrain(h, ep, "dp", "mp" if ep is None else None)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"]).reshape(e * cap, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)

    w = (gate.reshape(-1) * valid).astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok].add(y[slot] * w[:, None])
    if "shared" in p:
        out = out + mlp(p["shared"], xf, cfg.act)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# ESSR-style dynamic-width FFN (the paper's technique, generalized)
# ---------------------------------------------------------------------------

def token_edge_score(x: jax.Array) -> jax.Array:
    """The LM analog of the paper's edge score: token 'difficulty' as the RMS
    of the pre-FFN hidden state (cheap, input-derived, no learned router)."""
    return jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1))


def dynamic_width_ffn(p: Dict[str, Any], x: jax.Array, act: str,
                      capacity_frac: float = 0.5) -> jax.Array:
    """Top-``capacity`` tokens by edge score -> full width; the rest -> the
    weight-shared half-width slice (the C54/C27 duality, static shapes)."""
    b, s, d = x.shape
    t = b * s
    f = p["w_in"].shape[-1]
    fh = f // 2
    xf = x.reshape(t, d)
    score = token_edge_score(xf)
    n_full = max(1, int(t * capacity_frac))
    _, order = jax.lax.top_k(score, t)                        # descending
    full_idx, half_idx = order[:n_full], order[n_full:]

    def run(idx, sl):
        xi = xf[idx]
        h = xi @ p["w_in"][:, :sl]
        if "w_gate" in p:
            h = h * _act(act)(xi @ p["w_gate"][:, :sl])
        else:
            h = _act(act)(h)
        return h @ p["w_out"][:sl, :]

    out = jnp.zeros((t, d), x.dtype)
    out = out.at[full_idx].set(run(full_idx, f))
    if t - n_full > 0:
        out = out.at[half_idx].set(run(half_idx, fh))
    return out.reshape(b, s, d)
