"""ESSR — Edge Selective Super-Resolution network (paper Sec. III, Fig. 8).

Architecture:  BSConv(3->C)  ->  N x SFB(C)  ->  DSConv(C -> 3*scale^2)  ->
pixel-shuffle.  No global shortcut, no ESA (both removed by the paper's
hardware-friendly surgery).

SFB (Structure-Friendly Fusion Block, Fig. 14):
    y = ReLU(BSConv(x)); y = ReLU(BSConv(y)); y = ReLU(Conv1x1(y + x))
The trailing ReLU is the paper's addition ("enabling zero gating in the
subsequent BSConv layer").

The network is a *supernet*: ``width`` selects the C54 (full) or C27 (first
half of every channel dim) subnet — all subnets share weights (Sec. II-B).

Exact parameter counts reproduced (asserted in tests/benchmarks):
    x4, C=54, 5 SFB, bias:  53 886  (paper Table II: 53.9K)
    x2, C=54, 5 SFB, bias:  51 906  (paper Table V: 51K)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class ESSRConfig:
    channels: int = 54          # C54 supernet width
    n_sfb: int = 5              # paper Table II ablation -> 5
    scale: int = 4              # x2 or x4
    bias: bool = True
    in_channels: int = 3

    @property
    def out_channels(self) -> int:
        return self.in_channels * self.scale * self.scale

    def subnet_widths(self) -> tuple:
        """(bilinear, C/2, C) — the paper's trio. width 0 == bilinear."""
        return (0, self.channels // 2, self.channels)


ESSR_X4 = ESSRConfig(scale=4)
ESSR_X2 = ESSRConfig(scale=2)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_essr(key: jax.Array, cfg: ESSRConfig = ESSR_X4, dtype=jnp.float32) -> Dict[str, Any]:
    keys = jax.random.split(key, 2 + cfg.n_sfb)
    params: Dict[str, Any] = {
        "first": L.init_bsconv(keys[0], cfg.in_channels, cfg.channels, bias=cfg.bias, dtype=dtype),
        "sfbs": [],
        "recon": L.init_dsconv(keys[1], cfg.channels, cfg.out_channels, bias=cfg.bias, dtype=dtype),
    }
    for i in range(cfg.n_sfb):
        k1, k2, k3 = jax.random.split(keys[2 + i], 3)
        sfb = {
            "b1": L.init_bsconv(k1, cfg.channels, cfg.channels, bias=cfg.bias, dtype=dtype),
            "b2": L.init_bsconv(k2, cfg.channels, cfg.channels, bias=cfg.bias, dtype=dtype),
            "fuse": L.conv_init(k3, (1, 1, cfg.channels, cfg.channels), dtype),
        }
        if cfg.bias:
            sfb["fuse_b"] = jnp.zeros((cfg.channels,), dtype)
        params["sfbs"].append(sfb)
    return params


# ---------------------------------------------------------------------------
# supernet width slicing (C27 = first-27-channel slice of C54; Sec. II-B)
# ---------------------------------------------------------------------------

def _slice_bsconv(p: Dict[str, Any], cin: Optional[int], cout: int) -> Dict[str, Any]:
    out = {
        "pw": p["pw"][:, :, :cin, :cout] if cin is not None else p["pw"][..., :cout],
        "dw": p["dw"][..., :cout],
    }
    if "pw_b" in p:
        out["pw_b"] = p["pw_b"][:cout]
        out["dw_b"] = p["dw_b"][:cout]
    return out


def slice_width(params: Dict[str, Any], width: int) -> Dict[str, Any]:
    """Return the weight-shared subnet of channel width ``width``.

    Output channel count of the reconstruction DSConv stays full (pixel
    shuffle needs 3*scale^2 channels) — matching the paper's DSConv(27, 48).
    """
    w = width
    first = _slice_bsconv(params["first"], None, w)     # in stays 3
    sfbs = []
    for p in params["sfbs"]:
        s = {
            "b1": _slice_bsconv(p["b1"], w, w),
            "b2": _slice_bsconv(p["b2"], w, w),
            "fuse": p["fuse"][:, :, :w, :w],
        }
        if "fuse_b" in p:
            s["fuse_b"] = p["fuse_b"][:w]
        sfbs.append(s)
    recon = {
        "dw": params["recon"]["dw"][..., :w],
        "pw": params["recon"]["pw"][:, :, :w, :],
    }
    if "dw_b" in params["recon"]:
        recon["dw_b"] = params["recon"]["dw_b"][:w]
        recon["pw_b"] = params["recon"]["pw_b"]
    return {"first": first, "sfbs": sfbs, "recon": recon}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def sfb_forward(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    y = jax.nn.relu(L.bsconv(p["b1"], x))
    y = jax.nn.relu(L.bsconv(p["b2"], y))
    y = L.pointwise(y + x, p["fuse"], p.get("fuse_b"))
    return jax.nn.relu(y)


def essr_forward(params: Dict[str, Any], x: jax.Array, cfg: ESSRConfig = ESSR_X4,
                 width: Optional[int] = None) -> jax.Array:
    """x: (N,H,W,3) in [0,1] -> (N,H*s,W*s,3).

    ``width``: None/cfg.channels -> C54 path; cfg.channels//2 -> C27 path;
    0 -> bilinear interpolation (no conv at all).
    """
    if width == 0:
        return L.bilinear_resize(x, cfg.scale)
    if width is not None and width != cfg.channels:
        params = slice_width(params, width)
    f = L.bsconv(params["first"], x)
    for p in params["sfbs"]:
        f = sfb_forward(p, f)
    up = L.dsconv(params["recon"], f)
    return L.pixel_shuffle(up, cfg.scale)


# ---------------------------------------------------------------------------
# exact parameter / MAC accounting (paper Tables II, V, VI)
# ---------------------------------------------------------------------------

def essr_param_count(cfg: ESSRConfig) -> int:
    c, b = cfg.channels, (1 if cfg.bias else 0)
    first = cfg.in_channels * c + b * c + 9 * c + b * c
    sfb = 2 * (c * c + b * c + 9 * c + b * c) + c * c + b * c
    recon = 9 * c + b * c + c * cfg.out_channels + b * cfg.out_channels
    return first + cfg.n_sfb * sfb + recon


def essr_macs_per_lr_pixel(cfg: ESSRConfig, width: Optional[int] = None) -> int:
    """Multiply-accumulates per *LR* pixel (bias adds not counted, per convention)."""
    if width == 0:
        # bilinear: 4 taps x 3 channels per HR pixel
        return 4 * cfg.in_channels * cfg.scale * cfg.scale
    c = width if width is not None else cfg.channels
    first = cfg.in_channels * c + 9 * c
    sfb = 2 * (c * c + 9 * c) + c * c
    recon = 9 * c + c * cfg.out_channels
    return first + cfg.n_sfb * sfb + recon


def essr_macs(cfg: ESSRConfig, lr_hw, width: Optional[int] = None) -> int:
    return essr_macs_per_lr_pixel(cfg, width) * int(lr_hw[0]) * int(lr_hw[1])
