"""RLFN (paper's reference model, Sec. III-A) and its pruned variant.

RLFN = conv3 -> N x RLFB -> conv3 -> +global shortcut -> conv3 upsampler ->
pixel shuffle.  RLFB = 3 x (conv3 + ReLU) -> +local shortcut -> conv1 -> ESA.

The paper's "fair comparison" baseline is the *pruned* RLFN: 4 RLFBs,
channels 52 -> 46. ESSR then removes the global shortcut and ESA, and
factorizes the convolutions (models/essr.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class RLFNConfig:
    channels: int = 52
    n_blocks: int = 6
    esa_channels: int = 16
    scale: int = 4
    in_channels: int = 3


RLFN_BASE_X2 = RLFNConfig(scale=2)
RLFN_BASE_X4 = RLFNConfig(scale=4)
RLFN_PRUNED_X2 = RLFNConfig(channels=46, n_blocks=4, scale=2)
RLFN_PRUNED_X4 = RLFNConfig(channels=46, n_blocks=4, scale=4)


def _conv(key, cin, cout, k):
    return {"w": L.conv_init(key, (k, k, cin, cout)), "b": jnp.zeros((cout,))}


def init_esa(key, c: int, f: int) -> Dict[str, Any]:
    k = jax.random.split(key, 5)
    return {
        "c1": _conv(k[0], c, f, 1),       # reduce
        "cf": _conv(k[1], f, f, 1),       # skip path
        "c2": _conv(k[2], f, f, 3),       # stride-2
        "c3": _conv(k[3], f, f, 3),
        "c4": _conv(k[4], f, c, 1),       # expand -> sigmoid gate
    }


def esa_forward(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    n, h, w, _ = x.shape
    f = L.conv2d(x, p["c1"]["w"], p["c1"]["b"])
    v = L.conv2d(f, p["c2"]["w"], p["c2"]["b"], stride=2)
    v = jax.lax.reduce_window(v, -jnp.inf, jax.lax.max, (1, 7, 7, 1), (1, 3, 3, 1), "SAME")
    v = L.conv2d(v, p["c3"]["w"], p["c3"]["b"])
    v = jax.image.resize(v, (n, h, w, v.shape[-1]), method="bilinear")
    v = v + L.conv2d(f, p["cf"]["w"], p["cf"]["b"])
    m = jax.nn.sigmoid(L.conv2d(v, p["c4"]["w"], p["c4"]["b"]))
    return x * m


def init_rlfb(key, c: int, f: int) -> Dict[str, Any]:
    k = jax.random.split(key, 5)
    return {
        "c1": _conv(k[0], c, c, 3),
        "c2": _conv(k[1], c, c, 3),
        "c3": _conv(k[2], c, c, 3),
        "fuse": _conv(k[3], c, c, 1),
        "esa": init_esa(k[4], c, f),
    }


def rlfb_forward(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    y = jax.nn.relu(L.conv2d(x, p["c1"]["w"], p["c1"]["b"]))
    y = jax.nn.relu(L.conv2d(y, p["c2"]["w"], p["c2"]["b"]))
    y = jax.nn.relu(L.conv2d(y, p["c3"]["w"], p["c3"]["b"]))
    y = L.conv2d(y + x, p["fuse"]["w"], p["fuse"]["b"])
    return esa_forward(p["esa"], y)


def init_rlfn(key, cfg: RLFNConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_blocks + 3)
    c = cfg.channels
    return {
        "head": _conv(keys[0], cfg.in_channels, c, 3),
        "blocks": [init_rlfb(keys[1 + i], c, cfg.esa_channels) for i in range(cfg.n_blocks)],
        "mid": _conv(keys[-2], c, c, 3),
        "up": _conv(keys[-1], c, cfg.in_channels * cfg.scale ** 2, 3),
    }


def rlfn_forward(params: Dict[str, Any], x: jax.Array, cfg: RLFNConfig) -> jax.Array:
    f0 = L.conv2d(x, params["head"]["w"], params["head"]["b"])
    f = f0
    for p in params["blocks"]:
        f = rlfb_forward(p, f)
    f = L.conv2d(f, params["mid"]["w"], params["mid"]["b"]) + f0   # global shortcut
    up = L.conv2d(f, params["up"]["w"], params["up"]["b"])
    return L.pixel_shuffle(up, cfg.scale)


def rlfn_macs_per_lr_pixel(cfg: RLFNConfig) -> int:
    """MACs/LR-pixel (ESA's downsampled interior approximated at 1/4 area)."""
    c, f = cfg.channels, cfg.esa_channels
    esa = c * f + f * f + 9 * f * f // 4 + 9 * f * f // 4 + f * c
    block = 3 * 9 * c * c + c * c + esa
    head = 9 * cfg.in_channels * c
    mid = 9 * c * c
    up = 9 * c * cfg.in_channels * cfg.scale ** 2
    return head + cfg.n_blocks * block + mid + up
