"""Convolution / resampling primitives used by every SR model in the repo.

Pure JAX (lax.conv_general_dilated), NHWC layout, HWIO weights. These are the
*reference* implementations; the Pallas kernels in ``repro.kernels`` implement
the fused GLNPU-style groups and are validated against compositions of these.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]

DIMSPEC = ("NHWC", "HWIO", "NHWC")


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _fan_in(shape: Tuple[int, ...]) -> int:
    # HWIO: fan_in = H*W*I  (for depthwise, I==1 so fan_in = H*W)
    return int(shape[0] * shape[1] * shape[2])


def conv_init(key: jax.Array, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    """He-normal initializer for HWIO conv weights (matches the paper's PyTorch default lineage)."""
    std = math.sqrt(2.0 / max(1, _fan_in(shape)))
    return std * jax.random.normal(key, shape, dtype=dtype)


# ---------------------------------------------------------------------------
# conv primitives
# ---------------------------------------------------------------------------

def conv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
           stride: int = 1, padding: str | Tuple = "SAME") -> jax.Array:
    """Standard conv. x: (N,H,W,Cin), w: (kh,kw,Cin,Cout)."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=DIMSPEC)
    if b is not None:
        y = y + b
    return y


def _dw3_shift(x: jax.Array, w3: jax.Array) -> jax.Array:
    """3x3 SAME depthwise via 9 shifted multiply-accumulates. w3: (3,3,C)."""
    n, h, ww, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    y = jnp.zeros_like(x)
    for dy in range(3):
        for dx in range(3):
            y = y + xp[:, dy:dy + h, dx:dx + ww, :] * w3[dy, dx]
    return y


@jax.custom_vjp
def _dw3(x: jax.Array, w3: jax.Array) -> jax.Array:
    return _dw3_shift(x, w3)


def _dw3_fwd(x, w3):
    return _dw3_shift(x, w3), (x, w3)


def _dw3_bwd(res, g):
    x, w3 = res
    n, h, ww, c = x.shape
    # dx = correlation of g with the 180deg-rotated kernel (same shift form)
    gx = _dw3_shift(g, w3[::-1, ::-1])
    # dw[dy,dx,c] = sum_{n,i,j} xpad[n,i+dy,j+dx,c] * g[n,i,j,c]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    gw = jnp.stack([
        jnp.stack([jnp.sum(xp[:, dy:dy + h, dx:dx + ww, :] * g, axis=(0, 1, 2))
                   for dx in range(3)])
        for dy in range(3)])
    return gx, gw


_dw3.defvjp(_dw3_fwd, _dw3_bwd)


def dwconv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
             padding: str | Tuple = "SAME") -> jax.Array:
    """Depthwise conv. x: (N,H,W,C), w: (kh,kw,1,C).

    3x3/SAME uses a shifted multiply-accumulate with a custom VJP (bwd is the
    same shift form with the rotated kernel) — identical math, ~50x faster
    fwd and ~40x faster bwd than feature_group_count on XLA:CPU, and exactly
    the VPU form the Pallas kernels use. Other shapes fall back."""
    kh, kw = w.shape[0], w.shape[1]
    if (kh, kw) == (3, 3) and padding == "SAME":
        y = _dw3(x, w[:, :, 0, :])
    else:
        y = lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding=padding,
            dimension_numbers=DIMSPEC, feature_group_count=x.shape[-1])
    if b is not None:
        y = y + b
    return y


def pointwise(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """1x1 conv as a matmul over the channel dim. w: (1,1,Cin,Cout) or (Cin,Cout)."""
    if w.ndim == 4:
        w = w[0, 0]
    y = jnp.einsum("nhwc,cd->nhwd", x, w)
    if b is not None:
        y = y + b
    return y


# ---------------------------------------------------------------------------
# BSConv / DSConv — the paper's two factorized-conv variants (Fig. 7/8)
# ---------------------------------------------------------------------------

def init_bsconv(key: jax.Array, cin: int, cout: int, *, bias: bool = True,
                dtype=jnp.float32) -> Params:
    """BSConv = 1x1 pointwise (cin->cout) followed by 3x3 depthwise (cout)."""
    k1, k2 = jax.random.split(key)
    p: Params = {
        "pw": conv_init(k1, (1, 1, cin, cout), dtype),
        "dw": conv_init(k2, (3, 3, 1, cout), dtype),
    }
    if bias:
        p["pw_b"] = jnp.zeros((cout,), dtype)
        p["dw_b"] = jnp.zeros((cout,), dtype)
    return p


def bsconv(p: Params, x: jax.Array) -> jax.Array:
    y = pointwise(x, p["pw"], p.get("pw_b"))
    y = dwconv2d(y, p["dw"], p.get("dw_b"))
    return y


def init_dsconv(key: jax.Array, cin: int, cout: int, *, bias: bool = True,
                dtype=jnp.float32) -> Params:
    """DSConv = 3x3 depthwise (cin) followed by 1x1 pointwise (cin->cout).

    The paper uses DSConv (not BSConv) for the upsampler: the trailing 1x1
    mixes channels *after* the spatial filter, which kills the pixel-shuffle
    checkerboard that a trailing depthwise causes (Sec. III-B-3).
    """
    k1, k2 = jax.random.split(key)
    p: Params = {
        "dw": conv_init(k1, (3, 3, 1, cin), dtype),
        "pw": conv_init(k2, (1, 1, cin, cout), dtype),
    }
    if bias:
        p["dw_b"] = jnp.zeros((cin,), dtype)
        p["pw_b"] = jnp.zeros((cout,), dtype)
    return p


def dsconv(p: Params, x: jax.Array) -> jax.Array:
    y = dwconv2d(x, p["dw"], p.get("dw_b"))
    y = pointwise(y, p["pw"], p.get("pw_b"))
    return y


# ---------------------------------------------------------------------------
# resampling
# ---------------------------------------------------------------------------

def pixel_shuffle(x: jax.Array, scale: int) -> jax.Array:
    """(N,H,W,C*s^2) -> (N,H*s,W*s,C), PyTorch-compatible ordering."""
    n, h, w, c = x.shape
    s = scale
    cout = c // (s * s)
    x = x.reshape(n, h, w, cout, s, s)          # torch: (N, C, s, s, H, W) order; ours NHWC
    x = x.transpose(0, 1, 4, 2, 5, 3)            # n, h, s, w, s, cout
    return x.reshape(n, h * s, w * s, cout)


def bilinear_resize(x: jax.Array, scale: int) -> jax.Array:
    """Bilinear upsample by integer scale (the paper's simplest subnet)."""
    n, h, w, c = x.shape
    return jax.image.resize(x, (n, h * scale, w * scale, c), method="bilinear")


def bicubic_resize(x: jax.Array, out_hw: Tuple[int, int]) -> jax.Array:
    n, _, _, c = x.shape
    return jax.image.resize(x, (n, out_hw[0], out_hw[1], c), method="cubic")


# ---------------------------------------------------------------------------
# luminance (BT.601, the usual SR Y-channel convention)
# ---------------------------------------------------------------------------

def rgb_to_luma(x: jax.Array) -> jax.Array:
    """(..., 3) RGB in [0,1] -> (...,) luma in [0,255] (paper clamps to 0..255)."""
    r, g, b = x[..., 0], x[..., 1], x[..., 2]
    return (65.481 * r + 128.553 * g + 24.966 * b) + 16.0


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
