"""Public inference API: one engine, one plan, one result shape.

    from repro.api import SREngine, ExecutionPlan

    engine = SREngine.from_checkpoint(scale=4)
    result = engine.upscale(lr_frame)            # FrameResult
    for r in engine.stream(frames): ...          # Algorithm-1 serving
"""
from repro.api.engine import SREngine
from repro.api.plan import ExecutionPlan, QUANT_MODES, SUBNET_POLICIES
from repro.api.result import FrameResult

__all__ = ["SREngine", "ExecutionPlan", "FrameResult", "QUANT_MODES",
           "SUBNET_POLICIES"]
