"""FrameResult — the one structured return type of every SREngine call.

Replaces the previous zoo of shapes: `SRResult` (edge_selective_sr), bare
`jax.Array` (sr_whole / sr_all_patches / FrameServer.serve_frame) and
side-channel `FrameStats`. Fields that a mode does not produce (e.g. edge
scores for whole-frame reference) are None / zero rather than absent, so
downstream code can treat all modes uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax


@dataclasses.dataclass
class FrameResult:
    # image is None only in the compact records SREngine.stats retains
    # (holding every streamed SR frame would grow without bound)
    image: Optional[jax.Array]                # (H*s, W*s, 3)
    mode: str                                 # "edge_select"|"all_patches"|"whole"
    backend: str                              # "ref" | "pallas" (compiled)
                                              # | "pallas-interpret" (CPU
                                              # interpreter fallback)
    ids: Optional[np.ndarray] = None          # (N,) subnet id per patch
    scores: Optional[np.ndarray] = None       # (N,) edge score per patch
    counts: Tuple[int, int, int] = (0, 0, 0)  # (bilinear, C27, C54) patches
    mac_saving: float = 0.0                   # vs all-C54 pipeline
    latency_s: float = 0.0                    # wall-clock incl. device sync
    # (t1, t2): for upscale() the values used for routing ((0,0) when routing
    # ignored them); for streamed frames the switcher's live thresholds AFTER
    # this frame's adaptation (matching the old FrameServer/ summary()
    # "final_thresholds" semantics)
    thresholds: Tuple[float, float] = (0.0, 0.0)
    deadline_missed: bool = False             # streaming only
    # -- sharded streaming (plan.shards > 1); None on single-shard runs ------
    shards: int = 1                           # logical patch-stream shards
    # per-shard (bilinear, C27, C54) patch counts, raster-strip order
    shard_counts: Optional[Tuple[Tuple[int, int, int], ...]] = None
    # per-shard (t1, t2) AFTER this frame's adaptation + straggler demotion
    shard_thresholds: Optional[Tuple[Tuple[float, float], ...]] = None
    # which shards were demoted as stragglers on this frame
    shard_deadline_missed: Optional[Tuple[bool, ...]] = None

    @property
    def n_patches(self) -> int:
        return 0 if self.ids is None else int(len(self.ids))


def summarize_stats(stats) -> dict:
    """Table-XI-style aggregate over frame records (FrameResult or any
    object with counts/mac_saving/latency_s/thresholds/deadline_missed).
    Shared by `SREngine.summary` and the legacy `FrameServer` shim."""
    from repro.core import subnet_policy as sp
    if not stats:
        return {}
    counts = np.array([s.counts for s in stats])
    total = counts.sum()
    out = {
        "frames": len(stats),
        "subnet_share": dict(zip(sp.SUBNET_NAMES,
                                 (counts.sum(0) / max(total, 1)).round(4).tolist())),
        "mean_mac_saving": float(np.mean([s.mac_saving for s in stats])),
        "mean_latency_s": float(np.mean([s.latency_s for s in stats])),
        "deadline_misses": int(sum(s.deadline_missed for s in stats)),
        "final_thresholds": stats[-1].thresholds,
    }
    shards = max((getattr(s, "shards", 1) or 1) for s in stats)
    if shards > 1:
        out["shards"] = shards
        # straggler demotions per shard over the window (frames where that
        # shard's overload forced a threshold raise)
        misses = np.zeros(shards, np.int64)
        for s in stats:
            m = getattr(s, "shard_deadline_missed", None)
            if m is not None:
                misses[: len(m)] += np.asarray(m, np.int64)
        out["shard_deadline_misses"] = misses.tolist()
        last = next((s for s in reversed(stats)
                     if getattr(s, "shard_thresholds", None) is not None), None)
        if last is not None:
            out["final_shard_thresholds"] = last.shard_thresholds
    return out
