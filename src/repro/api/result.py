"""FrameResult — the one structured return type of every SREngine call.

Replaces the previous zoo of shapes: `SRResult` (edge_selective_sr), bare
`jax.Array` (sr_whole / sr_all_patches) and the retired serving shim's
side-channel stats. Fields that a mode does not produce (e.g. edge scores
for whole-frame reference) are None / zero rather than absent, so
downstream code can treat all modes uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax


@dataclasses.dataclass
class FrameResult:
    # image is None only in the compact records SREngine.stats retains
    # (holding every streamed SR frame would grow without bound)
    image: Optional[jax.Array]                # (H*s, W*s, 3)
    mode: str                                 # "edge_select"|"all_patches"|"whole"
    backend: str                              # "ref" | "pallas" (compiled)
                                              # | "pallas-interpret" (CPU
                                              # interpreter fallback)
    # (N,) subnet id / edge score per patch. Host dispatch stores writable
    # NumPy arrays; fused dispatch stores (immutable) jax device arrays —
    # the control loop never forces them, consumers np.asarray on use
    ids: Optional[np.ndarray] = None
    scores: Optional[np.ndarray] = None
    counts: Tuple[int, int, int] = (0, 0, 0)  # (bilinear, C27, C54) patches
    mac_saving: float = 0.0                   # vs all-C54 pipeline
    latency_s: float = 0.0                    # wall-clock incl. device sync
    # (t1, t2): for upscale() the values used for routing ((0,0) when routing
    # ignored them); for streamed frames the switcher's live thresholds AFTER
    # this frame's adaptation (the summary() "final_thresholds" semantics)
    thresholds: Tuple[float, float] = (0.0, 0.0)
    deadline_missed: bool = False             # streaming only
    # which dispatch path actually ran this frame: "host" (routing on the
    # host) or "fused" (the single-dispatch frame executable). A fused-plan
    # call that a mode forces back to host dispatch says "host" here.
    dispatch: str = "host"
    # fused dispatch only: per-subnet DEMOTION-HOP counts — entry k is how
    # many patches were demoted from subnet k to k-1 because k's capacity
    # slots were full (including patches that arrived at k by spilling in
    # from k+1, so a patch cascading C54->C27->bilinear appears in both
    # conv entries: the sum counts hops, not distinct patches; entry 0 —
    # bilinear, the dense floor — is always 0). None under host dispatch.
    spill_counts: Optional[Tuple[int, ...]] = None
    # False when this call paid trace+compile for its executable (the first
    # frame of a geometry — and, under fused dispatch, of a capacity
    # profile). summarize_stats excludes such warm-up frames from latency
    # aggregates; SREngine.warmup() pre-pays them.
    compiled: bool = True
    # -- sharded streaming (plan.shards > 1); None on single-shard runs ------
    shards: int = 1                           # logical patch-stream shards
    # per-shard (bilinear, C27, C54) patch counts, raster-strip order
    shard_counts: Optional[Tuple[Tuple[int, int, int], ...]] = None
    # per-shard (t1, t2) AFTER this frame's adaptation + straggler demotion
    shard_thresholds: Optional[Tuple[Tuple[float, float], ...]] = None
    # which shards were demoted as stragglers on this frame
    shard_deadline_missed: Optional[Tuple[bool, ...]] = None
    # -- multi-stream serving (plan.streams > 1) -----------------------------
    # which tenant stream this frame belongs to (its index in the
    # serve_streams() argument); None outside multi-stream serving. For
    # multiplexed frames, deadline_missed means THIS stream was attributed
    # as an overload source of a missed shared tick (share-weighted cost
    # attribution), and latency_s is the tick's marginal service time —
    # the live streams of a tick are served concurrently, so per-stream fps
    # is 1/latency_s and aggregate fps is live_streams/latency_s.
    stream_id: Optional[int] = None
    # -- serving resilience (plan.on_poison / runtime.guard) -----------------
    # (nan, inf, out-of-[0,1]) pixel counts of the RAW input frame — the
    # in-graph health verdict under fused dispatch, a jitted reduce under
    # host dispatch. None when plan.on_poison == "off" (verdicts disabled).
    health: Optional[Tuple[int, int, int]] = None
    # degradation-ladder steps newly applied while serving THIS frame/tick
    # (e.g. "backend:->ref"); earlier frames' sticky steps do not reappear.
    # The full ledger lives in SREngine.summary()["degradations"].
    degraded: Tuple[str, ...] = ()

    @property
    def n_patches(self) -> int:
        return 0 if self.ids is None else int(len(self.ids))

    def summary(self) -> dict:
        """Compact per-frame telemetry dict (no arrays): what ran, how it
        routed, and the live occupancy of the process-wide compiled caches
        (`fused_frame_fn` / `fused_stream_frame_fn` / `get_geometry`) — a
        nonzero eviction count under a steady geometry set means the bound
        from ``configure_compiled_caches`` is too small and frames are
        silently re-tracing."""
        from repro.core.pipeline import compiled_cache_occupancy
        out = {
            "mode": self.mode,
            "backend": self.backend,
            "dispatch": self.dispatch,
            "n_patches": self.n_patches,
            "counts": tuple(int(c) for c in self.counts),
            "mac_saving": float(self.mac_saving),
            "latency_s": float(self.latency_s),
            "compiled": bool(self.compiled),
            "compiled_caches": compiled_cache_occupancy(),
        }
        if self.stream_id is not None:
            out["stream_id"] = int(self.stream_id)
        if self.shards > 1:
            out["shards"] = int(self.shards)
        if self.health is not None:
            out["health"] = tuple(int(c) for c in self.health)
        if self.degraded:
            out["degraded"] = tuple(self.degraded)
        return out


def summarize_stats(stats) -> dict:
    """Table-XI-style aggregate over frame records (FrameResult or any
    object with counts/mac_saving/latency_s/thresholds/deadline_missed).
    The aggregate behind `SREngine.summary`."""
    from repro.core import subnet_policy as sp
    if not stats:
        return {}
    stats = list(stats)                  # may arrive as a bounded deque
    counts = np.array([s.counts for s in stats])
    total = counts.sum()
    # latency/fps aggregate over steady-state frames only: a frame that paid
    # trace+compile (compiled=False) would smear a one-off host cost into
    # the throughput signal. When every frame was a warm-up (a 1-frame
    # stream), fall back to the full set rather than reporting nothing.
    steady = [s for s in stats if getattr(s, "compiled", True)]
    warmups = len(stats) - len(steady)
    lat = [s.latency_s for s in (steady if steady else stats)]
    out = {
        "frames": len(stats),
        "subnet_share": dict(zip(sp.SUBNET_NAMES,
                                 (counts.sum(0) / max(total, 1)).round(4).tolist())),
        "mean_mac_saving": float(np.mean([s.mac_saving for s in stats])),
        "mean_latency_s": float(np.mean(lat)),
        "deadline_misses": int(sum(s.deadline_missed for s in stats)),
        "final_thresholds": stats[-1].thresholds,
    }
    if warmups:
        out["warmup_frames_excluded"] = warmups
    spills = [s.spill_counts for s in stats
              if getattr(s, "spill_counts", None) is not None]
    if spills:
        out["spilled_patches"] = np.asarray(spills).sum(0).tolist()
    poisoned = sum(1 for s in stats if any(getattr(s, "health", None) or ()))
    if poisoned:
        out["poison_frames"] = poisoned
    shards = max((getattr(s, "shards", 1) or 1) for s in stats)
    if shards > 1:
        out["shards"] = shards
        # straggler demotions per shard over the window (frames where that
        # shard's overload forced a threshold raise)
        misses = np.zeros(shards, np.int64)
        for s in stats:
            m = getattr(s, "shard_deadline_missed", None)
            if m is not None:
                misses[: len(m)] += np.asarray(m, np.int64)
        out["shard_deadline_misses"] = misses.tolist()
        last = next((s for s in reversed(stats)
                     if getattr(s, "shard_thresholds", None) is not None), None)
        if last is not None:
            out["final_shard_thresholds"] = last.shard_thresholds
    sids = sorted({s.stream_id for s in stats
                   if getattr(s, "stream_id", None) is not None})
    if sids:
        # per-tenant QoS ledger: each stream's own routing mix, overload
        # attributions and live thresholds over the window
        per = {}
        for sid in sids:
            recs = [s for s in stats if getattr(s, "stream_id", None) == sid]
            c = np.array([r.counts for r in recs])
            per[sid] = {
                "frames": len(recs),
                "subnet_share": dict(zip(
                    sp.SUBNET_NAMES,
                    (c.sum(0) / max(c.sum(), 1)).round(4).tolist())),
                "mean_mac_saving": float(np.mean([r.mac_saving
                                                  for r in recs])),
                "deadline_misses": int(sum(r.deadline_missed for r in recs)),
                "final_thresholds": recs[-1].thresholds,
            }
        out["streams"] = per
    return out
