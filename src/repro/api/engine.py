"""SREngine — the single facade over every ESSR inference entry point.

One engine owns:
  * ``params``  — the supernet weights (all subnets weight-shared, Sec. II-B),
  * ``cfg``     — the `ESSRConfig` architecture description,
  * ``plan``    — an `ExecutionPlan` (patch geometry, thresholds, bucket
                  schedule, subnet policy), frozen at construction,
  * ``backend`` — "ref" (pure-JAX jit) or "pallas" (fused kernel groups),
                  chosen ONCE instead of per call. For "pallas",
                  ``plan.interpret`` picks compiled vs interpreter dispatch
                  (None = auto: compiled on TPU/GPU, interpreter on CPU);
                  what actually ran is surfaced as FrameResult.backend
                  ("pallas" vs "pallas-interpret").

With ``plan.quant`` set ("fxp10" | "int8") the engine serves the PAMS
quantized datapath: per-subnet activation alphas are PTQ-calibrated at
construction (``calibrate=`` batch, or a deterministic synthetic default)
and cached as JSON alongside the bench-model cache; the "ref" backend serves
fake-quant emulation, "pallas" the integer-domain kernel stack
(`repro.kernels.qconv`). The served mode is appended to the backend label
("ref-fxp10", "pallas-int8", "pallas-interpret-int8", ...).

and exposes the paper's modes as methods returning one `FrameResult` shape:

  * ``upscale(frame)``                    — Fig. 1 edge-selective pipeline
  * ``upscale(frame, mode="all_patches")``— every patch through one subnet
  * ``reference(frame)``                  — whole-image convolution (Table III)
  * ``stream(frames)``                    — Algorithm-1 adaptive serving with
                                            deadline/straggler handling

Construction absorbs the checkpoint / cached-bench-model discovery that was
previously copy-pasted across `launch/serve.py` and the benchmarks:
``SREngine.from_config`` (fresh init) and ``SREngine.from_checkpoint``.
"""
from __future__ import annotations

import collections
import dataclasses
import glob
import json
import os
import re
import time
import warnings
from typing import Any, Deque, Dict, Iterable, Iterator, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.api.plan import ExecutionPlan
from repro.api.result import FrameResult, summarize_stats
from repro.core import subnet_policy as sp
from repro.core.adaptive import (AdaptiveSwitcher, ShardSwitcherBank,
                                 StreamSwitcherBank, SwitchingConfig)
from repro.core.edge_score import edge_score
from repro.core.pipeline import (compiled_cache_occupancy,
                                 configure_compiled_caches,
                                 edge_selective_sr, frame_health,
                                 fused_frame_fn, resolve_backend,
                                 sanitize_frame, snap_capacity,
                                 sr_all_patches_result, sr_whole)
from repro.kernels.dispatch import resolve_interpret
from repro.launch.mesh import make_patch_mesh
from repro.models.essr import ESSRConfig, init_essr
from repro.runtime.guard import (FaultInjector, PoisonFrameError,
                                 ResilienceGuard)

#: Default location of the cached briefly-trained benchmark supernets
#: (written by benchmarks/common.get_trained_essr).
DEFAULT_BENCH_CACHE = os.environ.get("BENCH_CACHE", "/root/repo/results/bench_models")

MODES = ("edge_select", "all_patches", "whole")


def default_calibration_batch(patch: int, scale: int, n: int = 16,
                              seed: int = 1234) -> jax.Array:
    """Deterministic PTQ calibration batch: ``n`` synthetic LR patches in
    [0,1], one per procedural frame (the plain/texture/edges mixture the
    edge-selective router discriminates), sized to the plan's patch so the
    calibration forward sees serving-shaped batches."""
    from repro.data.synthetic import degrade, random_image
    return jnp.stack([
        degrade(jnp.asarray(random_image(seed + i, patch * scale,
                                         patch * scale)), scale)
        for i in range(n)])


class SREngine:
    """Facade over the ESSR inference pipeline. See module docstring."""

    def __init__(self, params: Dict[str, Any], cfg: ESSRConfig,
                 plan: Optional[ExecutionPlan] = None, backend: str = "ref",
                 switching: Optional[SwitchingConfig] = None,
                 deadline_s: Optional[float] = None,
                 calibrate: Optional[jax.Array] = None,
                 quant_cache: Optional[str] = None):
        resolve_backend(backend)            # fail fast on typos
        self.params = params
        self.cfg = cfg
        self.plan = plan if plan is not None else ExecutionPlan()
        self.backend = backend
        self.deadline_s = deadline_s
        # quantized serving (plan.quant): PTQ-calibrate per-subnet alphas
        # once, here — the pack is engine state like the mesh, so every
        # frame reuses the same lattice. ``calibrate`` is a (N,h,w,3) LR
        # batch in [0,1]; None falls back to a deterministic synthetic
        # batch covering the three content classes. ``quant_cache`` is a
        # directory to cache the alphas in (from_checkpoint passes the
        # bench-model cache), only consulted for the default batch.
        self.qpack = self._resolve_quant_pack(calibrate, quant_cache)
        # serving resilience (repro.runtime.guard): the sticky degradation
        # ladder from this engine's configured serving point, plus the
        # optional seeded fault harness (plan.faults). Engine state like the
        # mesh — the ladder level survives across frames by design. The
        # ladder is built from the RESOLVED interpret policy so the
        # pallas->interpret rung only exists where compiled kernels actually
        # run (on CPU the interpreter is already the resolved mode).
        self.guard = ResilienceGuard(
            backend=backend,
            interpret=(resolve_interpret(self.plan.interpret)
                       if backend == "pallas" else self.plan.interpret),
            quant_on=self.plan.quant is not None, fusion=self.plan.fusion,
            max_retries=self.plan.max_retries)
        self.injector = (FaultInjector(self.plan.faults)
                         if self.plan.faults is not None else None)
        self._frame_idx = 0            # monotone launch index (fault coins)
        base_switching = (switching if switching is not None
                          else SwitchingConfig(t1=self.plan.t1, t2=self.plan.t2))
        self.switcher = AdaptiveSwitcher(base_switching)
        # sharded patch stream (plan.shards > 1): routing/straggler control is
        # per-shard regardless of hardware (one Algorithm-1 controller each,
        # budgets split evenly); the device mesh only exists when more than
        # one device is visible — otherwise dispatch degrades transparently
        # to the single-device path with identical numerics.
        self.bank: Optional[ShardSwitcherBank] = None
        self.mesh = None
        if self.plan.shards > 1:
            self.bank = ShardSwitcherBank(base_switching,
                                          shards=self.plan.shards)
            avail = jax.device_count()
            if avail > 1:
                self.mesh = make_patch_mesh(min(self.plan.shards, avail))
                if avail < self.plan.shards:
                    warnings.warn(
                        f"plan.shards={self.plan.shards} but only {avail} "
                        f"devices visible; dispatching over {avail} "
                        f"(per-shard routing control unchanged)")
            else:
                warnings.warn(
                    f"plan.shards={self.plan.shards} on a single-device "
                    f"host; dispatch falls back to one device "
                    f"(per-shard routing control unchanged)")
        # multi-stream serving (plan.streams > 1): one Algorithm-1 controller
        # per tenant stream, budgets split by normalized QoS share. Engine
        # state like the shard bank — a per-call plan cannot change the
        # tenant set. serve_streams() drives it via StreamMultiplexer.
        self.stream_bank: Optional[StreamSwitcherBank] = None
        if self.plan.streams > 1:
            self.stream_bank = StreamSwitcherBank(
                base_switching, streams=self.plan.streams,
                shares=self.plan.stream_shares)
        self._macs = sp.SubnetMacs.make(cfg, self.plan.patch)
        # per-frame stream records, bounded: a long-running stream must not
        # grow host memory without limit (plan.stats_window newest frames;
        # summary() notes the window)
        self.stats: Deque[FrameResult] = collections.deque(
            maxlen=self.plan.stats_window)
        # fused dispatch state: the live capacity profile per geometry
        # (plan.capacity pins it; otherwise probed on the first frame of a
        # geometry and grown after any frame that spilled), and the set of
        # executables this engine has already traced+compiled — the
        # bookkeeping behind FrameResult.compiled / warmup()
        self._fused_caps: Dict[Tuple, Tuple[int, ...]] = {}
        self._warm: set = set()
        self._fused_last_done = 0.0    # marginal-latency clock (async stream)
        # compiled-object caches (frame executables, admission ticks, patch
        # geometries) are process-wide BoundedCaches; size them from the
        # plan's serving horizon — stats_window // 32, floored at 16 and
        # capped at 512, which lands on the historical 128 at the default
        # window of 4096. Last-constructed engine wins (the caches are
        # shared), which is the right bias: the most recent plan reflects
        # the live serving regime. Occupancy: FrameResult.summary() /
        # SREngine.summary().
        configure_compiled_caches(
            max(16, min(512, self.plan.stats_window // 32)))

    def _resolve_quant_pack(self, calibrate, quant_cache):
        """plan.quant -> calibrated `QuantPack` (None for fp32 serving)."""
        mode = self.plan.quant
        if mode is None:
            return None
        from repro.quant.pams import (build_quant_pack, load_quant_pack,
                                      params_fingerprint, save_quant_pack)
        if calibrate is None:
            calibrate = default_calibration_batch(self.plan.patch,
                                                  self.cfg.scale)
            cache_path = None
            if quant_cache:
                # keyed by the weights' content hash AND the plan's patch
                # size (the default calibration batch is patch-shaped, so
                # alphas from one patch size must not serve another): alphas
                # calibrated for other weights/configs never serve here
                fp = params_fingerprint(self.params)
                cache_path = os.path.join(
                    quant_cache, f"quant_alphas_{mode}_x{self.cfg.scale}"
                                 f"_sfb{self.cfg.n_sfb}_p{self.plan.patch}"
                                 f"_{fp}.json")
                cached = load_quant_pack(cache_path, fp)
                if cached is not None:
                    return cached
            pack = build_quant_pack(self.params, self.cfg, mode, calibrate)
            if cache_path:
                try:
                    os.makedirs(quant_cache, exist_ok=True)
                    save_quant_pack(cache_path, pack, fp)
                except OSError as e:
                    warnings.warn(f"quant alpha cache write failed: {e!r}")
            return pack
        # user-supplied calibration data: always calibrate fresh (the cache
        # is keyed by weights only and cannot tell batches apart)
        return build_quant_pack(self.params, self.cfg, mode,
                                jnp.asarray(calibrate))

    def _backend_label(self, plan: ExecutionPlan) -> str:
        """What actually executes, surfaced in FrameResult.backend: "pallas"
        only when the kernels compile (TPU/GPU or interpret=False); the CPU
        interpreter fallback is labeled "pallas-interpret" so consumers never
        mistake the correctness path for the fast one. A quant mode is
        appended ("ref-fxp10", "pallas-int8", "pallas-interpret-int8", ...)
        so a quantized frame can never masquerade as fp32."""
        base = self.backend
        if self.backend == "pallas" and resolve_interpret(plan.interpret):
            base = "pallas-interpret"
        return base if plan.quant is None else f"{base}-{plan.quant}"

    @property
    def backend_label(self) -> str:
        return self._backend_label(self.plan)

    def _variant_label(self, plan: ExecutionPlan, v) -> str:
        """`_backend_label` for a degradation-ladder rung: labels what the
        (possibly stepped-down) variant actually executes, so a frame served
        at a degraded level can never masquerade as the planned one."""
        base = v.backend
        if v.backend == "pallas" and resolve_interpret(v.interpret):
            base = "pallas-interpret"
        return (base if (plan.quant is None or not v.quant)
                else f"{base}-{plan.quant}")

    # -- serving resilience (plan.on_poison / plan.faults) -------------------

    def _next_index(self) -> int:
        """Monotone launch index — the deterministic coordinate fault coins
        and degradation events key on."""
        i = self._frame_idx
        self._frame_idx += 1
        return i

    def _ingest_frame(self, frame, p: ExecutionPlan, index: int):
        """Host-side dtype gate on every entry path. Wrong-dtype frames are
        the one poison class the traced graph cannot express (the executable
        is typed), so they resolve here: "raise" rejects, every other policy
        normalizes integer payloads by their dtype range (uint8 -> /255, so
        the content recovers instead of serving garbage)."""
        if not isinstance(frame, (jax.Array, np.ndarray)):
            frame = jnp.asarray(frame)
        if jnp.issubdtype(frame.dtype, jnp.floating):
            return jnp.asarray(frame)
        if p.on_poison == "raise":
            self.guard.record(index, "poison",
                              f"non-float frame dtype {frame.dtype}")
            raise PoisonFrameError(
                f"frame dtype {frame.dtype} is not floating point "
                f"(plan.on_poison='raise')")
        if p.on_poison != "off":
            self.guard.record(index, "poison",
                              f"non-float frame dtype {frame.dtype} "
                              f"normalized to float32")
        try:
            span = float(np.iinfo(np.dtype(str(frame.dtype))).max)
        except ValueError:
            span = 1.0
        return jnp.asarray(frame).astype(jnp.float32) / max(span, 1.0)

    def _host_health(self, frame, p: ExecutionPlan, index: int):
        """Health verdict + on_poison policy for the host-dispatch paths
        (they already sync per frame, so the jitted reduce costs nothing;
        fused dispatch computes the same verdict in-graph instead).
        Returns (frame, health tuple or None, route-to-bilinear flag)."""
        if p.on_poison == "off":
            return frame, None, False
        health_t = tuple(int(c) for c in np.asarray(frame_health(frame)))
        if not any(health_t):
            return frame, health_t, False
        self.guard.record(index, "poison",
                          f"frame health nan/inf/oob={health_t} "
                          f"(policy {p.on_poison})")
        if p.on_poison == "raise":
            raise PoisonFrameError(
                f"frame failed health verdict nan/inf/oob={health_t} "
                f"(plan.on_poison='raise')", health=health_t)
        return sanitize_frame(frame), health_t, p.on_poison == "bilinear"

    def _guarded_frames(self, frames: Iterable, stream_id: int = 0,
                        ) -> Iterator:
        """Iterate a tenant stream under the fault harness: ``plan.faults``
        wraps the iterator with seeded poison/error injection, and an
        iterator that raises ends the stream with a recorded retirement
        instead of killing the serving loop (the solo-stream analog of the
        multiplexer's per-tenant quarantine)."""
        it = iter(frames)
        if self.injector is not None:
            it = self.injector.wrap_stream(stream_id, it)
        n = 0
        while True:
            try:
                frame = next(it)
            except StopIteration:
                return
            except Exception as e:
                self.guard.record(n, "retire",
                                  f"stream {stream_id} iterator raised: "
                                  f"{e!r}")
                return
            yield frame
            n += 1

    # -- fused dispatch (plan.dispatch == "fused") ---------------------------

    def _mark_warm(self, key) -> bool:
        """True when ``key``'s executable was already compiled by this
        engine; marks it warm either way (the caller is about to run it).

        Best-effort bookkeeping: it mirrors the process-wide executable
        caches (`fused_frame_fn` / `get_geometry` BoundedCaches — sized from
        ``plan.stats_window`` at construction, 128 at the default window —
        and XLA's own jit cache) without sharing their eviction — an engine
        cycling through more combos than those caches hold can see a
        re-tracing frame reported ``compiled=True``. Cache occupancy (and
        the eviction count that diagnoses this) rides
        `FrameResult.summary()` / `SREngine.summary()`."""
        warm = key in self._warm
        self._warm.add(key)
        return warm

    def _snap_profile(self, desired, geom, p: ExecutionPlan
                      ) -> Tuple[int, ...]:
        """Per-subnet desired counts -> a capacity profile: entry 0 is 0
        (the bilinear lane runs dense), conv entries snap to the plan's
        bucket ladder (bounded recompilation). Profiles are cached
        UNclamped — the streaming C54 budget ceiling is applied per call
        in `_fused_caps_for`, so the same geometry serves both upscale()
        (full profile) and the stream (ceiling enforced) correctly no
        matter which seeded the cache."""
        return tuple([0] + [snap_capacity(int(d), p.buckets, geom.n)
                            for d in desired[1:]])

    def _c54_frame_budget(self) -> int:
        """Per-frame share of the Algorithm-1 C54/sec budget — the hard
        ceiling fused streaming enforces in-graph via the C54 capacity
        (overflow spills to C27, the paper's "the rest of the patches run
        with C27")."""
        c = self.switcher.cfg
        return max(1, int(c.c54_per_sec_budget) // max(c.fps, 1))

    def _fused_caps_for(self, geom, p: ExecutionPlan, frame,
                        thresholds: Tuple[float, float],
                        streaming: bool) -> Tuple[int, ...]:
        """Resolve the capacity profile for one frame. ``plan.capacity``
        pins it; otherwise the FIRST frame of a geometry is probed on the
        host (the only host routing sync fused dispatch ever pays — later
        frames reuse/grow the cached profile with no sync)."""
        widths = self.cfg.subnet_widths()
        if p.capacity is not None:
            if len(p.capacity) != len(widths):
                raise ValueError(
                    f"plan.capacity {p.capacity} must have one entry per "
                    f"subnet width {widths}")
            # a pinned profile is served verbatim, streaming or not: the
            # operator fixed the compiled shape, so its C54 entry IS the
            # per-frame ceiling (the budget-derived clamp below applies
            # only to auto profiles) — documented on ExecutionPlan.capacity
            return p.capacity
        key = geom.cache_key
        caps = self._fused_caps.get(key)
        if caps is None:
            t1, t2 = thresholds
            if p.on_poison != "off":
                # probe on the sanitized frame: a poisoned first frame must
                # not seed a garbage capacity profile for its whole geometry
                frame = sanitize_frame(frame)
            scores = np.asarray(edge_score(geom.extract(frame)))
            counts = sp.subnet_counts(sp.decide(scores, t1, t2))
            caps = self._snap_profile(counts, geom, p)
            self._fused_caps[key] = caps
        if streaming:
            # the hard C54 ceiling applies to the STREAM only, per call:
            # the cached profile stays unclamped so a warmup()/upscale()
            # seeding cannot smuggle an over-budget capacity into serving,
            # and a stream-seeded profile does not force spills on later
            # single-frame upscale() calls
            caps = caps[:-1] + (min(caps[-1], self._c54_frame_budget()),)
        return caps

    def _grow_caps(self, geom, p: ExecutionPlan, counts, spills) -> None:
        """After a frame that spilled, grow the geometry's capacity profile
        to the bucket ceiling of the demand actually seen (served + spilled)
        so the next frame routes without demotion. Grow-only: shrinking
        would churn recompiles; the bucket ladder bounds total growth."""
        if p.capacity is not None or not any(spills[1:]):
            return
        old = self._fused_caps.get(geom.cache_key)
        if old is None:
            return
        desired = [c + s for c, s in zip(counts, spills)]
        new = self._snap_profile(desired, geom, p)
        merged = tuple(max(o, n) for o, n in zip(old, new))
        if merged != old:
            self._fused_caps[geom.cache_key] = merged

    def _launch_fused(self, frame, p: ExecutionPlan,
                      thresholds: Tuple[float, float],
                      streaming: bool) -> dict:
        """Dispatch one frame into the fused executable WITHOUT blocking.
        Returns the in-flight record the double-buffered stream finalizes
        later; host work here is bounded (geometry/caps lookups + the async
        dispatch), so frame N+1's ingest overlaps frame N's compute."""
        t0 = time.perf_counter()
        index = self._next_index()
        frame = self._ingest_frame(frame, p, index)
        geom = p.geometry(frame.shape[0], frame.shape[1], self.cfg.scale)
        caps = self._fused_caps_for(geom, p, frame, thresholds, streaming)
        if self.injector is not None:
            self.injector.maybe_delay(index)
        t1, t2 = thresholds

        def attempt(v):
            if self.injector is not None:
                self.injector.maybe_fail_launch(index)
            fn = fused_frame_fn(geom, caps, self.cfg, v.backend, v.interpret,
                                self.mesh, self.qpack if v.quant else None,
                                v.fusion, p.on_poison)
            return fn(self.params, frame, t1, t2)

        # the degradation ladder owns retries: a failed launch (injected or
        # genuine) steps down fusion -> interpret -> ref -> fp32 and re-runs
        outs, steps = self.guard.run(attempt, index)
        v = self.guard.variant
        compiled = self._mark_warm(("fused", geom.cache_key, caps,
                                    v.backend, v.interpret, v.quant,
                                    v.fusion, p.on_poison))
        return {"outs": outs, "geom": geom, "caps": caps, "t0": t0,
                "plan": p, "thresholds": (t1, t2), "compiled": compiled,
                "streaming": streaming, "variant": v, "steps": steps,
                "index": index}

    def _finalize_fused(self, rec: dict) -> FrameResult:
        """Block on one in-flight fused frame, materialize its routing
        telemetry (ids/scores/counts/spills), and run the host-side control
        that fused dispatch deferred: Algorithm-1 threshold trim from the
        (possibly one-frame-old) counts, straggler demotion on a missed
        deadline, and capacity growth after spill."""
        img, ids, scores, counts, spills, health = rec["outs"]
        img.block_until_ready()
        done = time.perf_counter()
        # marginal frame time: under async streaming a frame's launch-to-
        # ready wall clock includes the device time of EARLIER in-flight
        # frames — clocking from whichever is later (this frame's launch or
        # the previous frame's completion) reports the pipelined per-frame
        # service time, so fps aggregates are meaningful and a per-frame
        # deadline does not fire spuriously on every steady-state frame.
        # Synchronous calls are unaffected (the previous finalize always
        # precedes the next launch).
        dt = done - max(rec["t0"], self._fused_last_done)
        self._fused_last_done = done
        p, geom, streaming = rec["plan"], rec["geom"], rec["streaming"]
        # materialize the in-graph health verdict (counts sync here anyway)
        # and apply the host-visible side of the on_poison policy
        health_t = None
        if p.on_poison != "off":
            health_t = tuple(int(c) for c in np.asarray(health))
            if any(health_t):
                self.guard.record(rec["index"], "poison",
                                  f"frame health nan/inf/oob={health_t} "
                                  f"(policy {p.on_poison})")
                if p.on_poison == "raise":
                    raise PoisonFrameError(
                        f"frame failed health verdict "
                        f"nan/inf/oob={health_t} (plan.on_poison='raise')",
                        health=health_t)
        steps = rec["steps"]
        if streaming and p.watchdog_s is not None and dt > p.watchdog_s:
            steps = steps + self.guard.note_watchdog(rec["index"], dt,
                                                     p.watchdog_s)
        counts_t = tuple(int(c) for c in np.asarray(counts))
        spills_t = tuple(int(s) for s in np.asarray(spills))
        macs = (self._macs if p.patch == self.plan.patch
                else sp.SubnetMacs.make(self.cfg, p.patch))
        saving = macs.saving_vs_c54(counts_t)
        self._grow_caps(geom, p, counts_t, spills_t)
        live = rec["thresholds"]
        missed = False
        shard_counts = None
        if streaming:
            self.switcher.observe_frame(counts_t[sp.C54])
            missed = bool(self.deadline_s and dt > self.deadline_s)
            if missed:
                self.switcher.demote_for_straggler(severity=1.0)
            live = self.switcher.thresholds
            if self.bank is not None:
                # reporting only: fused routing is one in-graph decision, so
                # per-shard threshold control is a host-dispatch feature —
                # strip counts are still surfaced for observability
                shard_counts = tuple(
                    sp.subnet_counts(np.asarray(ids)[sl])
                    for sl in geom.shard_slices(self.plan.shards))
        # ids/scores stay device arrays: the control loop only needs the
        # scalar counts/spills, so the per-patch telemetry transfers lazily
        # — consumers that index it (np.asarray) pay the copy, the
        # steady-state stream does not
        out = FrameResult(image=img, mode="edge_select",
                          backend=self._variant_label(p, rec["variant"]),
                          ids=ids, scores=scores, counts=counts_t,
                          mac_saving=saving, latency_s=dt, thresholds=live,
                          deadline_missed=missed, shards=self.plan.shards,
                          shard_counts=shard_counts, dispatch="fused",
                          spill_counts=spills_t, compiled=rec["compiled"],
                          health=health_t, degraded=steps)
        if streaming:
            self.stats.append(dataclasses.replace(out, image=None,
                                                  ids=None, scores=None))
        return out

    def _upscale_fused(self, frame, p: ExecutionPlan) -> FrameResult:
        """upscale()'s fused path: launch + finalize back-to-back (single
        frames have nothing to overlap with)."""
        return self._finalize_fused(
            self._launch_fused(frame, p, (p.t1, p.t2), streaming=False))

    def warmup(self, shape: Tuple[int, int]) -> FrameResult:
        """Pre-pay trace+compile for an ``(h, w)`` LR frame shape.

        Runs one deterministic synthetic frame — thirds of smooth gradient /
        mild texture / checkerboard, so all three subnets populate — through
        the plan's dispatch path without touching ``stats`` or the adaptive
        thresholds. Returns its FrameResult (``compiled=False`` on a cold
        engine); the next real frame of this shape reports
        ``compiled=True`` and a latency free of compile time. Under fused
        dispatch with ``plan.capacity=None`` this also seeds the capacity
        profile from the synthetic routing — live content that routes past
        it still spills once (that frame's ``spill_counts`` say so; it runs
        the already-warm executable, so ``compiled`` stays True) and the
        profile regrows, with the NEXT frame paying the recompile and
        reporting ``compiled=False``."""
        h, w = int(shape[0]), int(shape[1])
        yy, xx = jnp.meshgrid(jnp.linspace(0.0, 1.0, h),
                              jnp.linspace(0.0, 1.0, w), indexing="ij")
        checker = ((jnp.arange(h)[:, None] + jnp.arange(w)[None, :]) % 2
                   ).astype(jnp.float32)
        smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
        frame = jnp.where((xx < 1 / 3)[..., None], smooth,
                          jnp.where((xx < 2 / 3)[..., None],
                                    smooth + 0.03 * checker[..., None],
                                    checker[..., None] * jnp.ones(3)))
        return self.upscale(jnp.clip(frame, 0.0, 1.0))

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_config(cls, cfg: Optional[ESSRConfig] = None, *, seed: int = 0,
                    plan: Optional[ExecutionPlan] = None, backend: str = "ref",
                    switching: Optional[SwitchingConfig] = None,
                    deadline_s: Optional[float] = None,
                    calibrate: Optional[jax.Array] = None) -> "SREngine":
        """Fresh engine with randomly initialised supernet weights.

        ``calibrate``: PTQ calibration batch for ``plan.quant`` modes
        ((N,h,w,3) LR in [0,1]; None = deterministic synthetic default)."""
        cfg = cfg if cfg is not None else ESSRConfig()
        params = init_essr(jax.random.PRNGKey(seed), cfg)
        return cls(params, cfg, plan=plan, backend=backend,
                   switching=switching, deadline_s=deadline_s,
                   calibrate=calibrate)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: Optional[str] = None, *,
                        cfg: Optional[ESSRConfig] = None, scale: int = 4,
                        prefer: str = "ema",
                        bench_cache: Optional[str] = DEFAULT_BENCH_CACHE,
                        plan: Optional[ExecutionPlan] = None,
                        backend: str = "ref",
                        switching: Optional[SwitchingConfig] = None,
                        deadline_s: Optional[float] = None,
                        calibrate: Optional[jax.Array] = None,
                        verbose: bool = False) -> "SREngine":
        """Engine with trained weights, resolved in priority order:

        1. ``ckpt_dir`` — a train.py checkpoint holding {"params", "ema"};
           ``prefer`` selects which tree serves ("ema" by default).
        2. the newest cached benchmark supernet under ``bench_cache``
           matching this config (``essr_x<scale>_sfb<n>_*``);
        3. fresh random init (so demos never hard-fail on a cold cache).

        ``calibrate``: PTQ calibration batch for ``plan.quant`` modes; when
        None the deterministic synthetic default is used and the resulting
        alphas are cached as JSON alongside ``bench_cache`` (keyed by the
        weights' content hash, so new weights always recalibrate).
        """
        from repro.ckpt.checkpoint import CheckpointManager

        cfg = cfg if cfg is not None else ESSRConfig(scale=scale)
        params = init_essr(jax.random.PRNGKey(0), cfg)
        if ckpt_dir:
            cm = CheckpointManager(ckpt_dir)
            # peek at the stored tree so a checkpoint written without an
            # "ema" tree is detected instead of silently mis-restored
            template = {"params": params, "ema": params}
            try:
                top = set(json.loads(cm.read_manifest()["tree_template"]))
            except Exception as e:
                top = None                       # legacy/unreadable manifest
                warnings.warn(f"checkpoint manifest unreadable for "
                              f"{ckpt_dir} ({e!r}); restoring with the "
                              f"default template")
            if top is not None and top and top <= {"params", "ema"}:
                template = {k: params for k in top}
            try:
                restored, _ = cm.restore(template)
            except Exception as e:
                # truncated/corrupted payload: degrade to fresh init rather
                # than dying mid-construction (demos and serving stay up)
                restored = None
                warnings.warn(f"checkpoint restore failed for {ckpt_dir}: "
                              f"{e!r}; serving fresh random init")
            if restored is not None:
                use = prefer
                if use not in restored:
                    # fall back to whatever tree the checkpoint does hold
                    # ("params" when present, else e.g. an ema-only one)
                    use = ("params" if "params" in restored
                           else next(iter(sorted(restored))))
                    warnings.warn(
                        f"checkpoint {ckpt_dir} has no {prefer!r} tree "
                        f"(found {sorted(restored)}); serving {use!r} instead")
                params = restored[use]
                if verbose:
                    print(f"(restored {use!r} weights from {ckpt_dir})")
        elif bench_cache:
            pattern = os.path.join(bench_cache, f"essr_x{cfg.scale}_sfb{cfg.n_sfb}_*")

            def _steps(d: str) -> int:
                # names are essr_x<scale>_sfb<n>_<steps><tag>; "newest" means
                # highest step count, not lexicographic order (800 > 6000)
                m = re.match(r"(\d+)", d.rsplit("_", 1)[-1])
                return int(m.group(1)) if m else -1

            cands = sorted(glob.glob(pattern), key=_steps, reverse=True)
            restored_ok = False
            for cand in cands:
                try:
                    restored, _ = CheckpointManager(cand).restore({"params": params})
                    params = restored["params"]
                    restored_ok = True
                    if verbose:
                        print(f"(using trained weights from {cand})")
                    break
                except Exception as e:
                    warnings.warn(f"bench-cache restore failed for {cand}: "
                                  f"{e!r}; trying next candidate")
            if cands and not restored_ok:
                warnings.warn(f"no bench-cache candidate under {bench_cache} "
                              f"restored cleanly; serving fresh random init")
        return cls(params, cfg, plan=plan, backend=backend,
                   switching=switching, deadline_s=deadline_s,
                   calibrate=calibrate, quant_cache=bench_cache)

    # -- single-frame inference ---------------------------------------------

    def upscale(self, frame: jax.Array, mode: str = "edge_select",
                width: Optional[int] = None,
                ids_override: Optional[np.ndarray] = None,
                plan: Optional[ExecutionPlan] = None) -> FrameResult:
        """One frame through the pipeline. ``frame``: (H,W,3) in [0,1].

        ``mode``:
          * "edge_select"  — routing per the plan's subnet policy (or an
            explicit ``ids_override``);
          * "all_patches"  — every patch through the subnet of ``width``
            (the non-edge-selective ablation reference);
          * "whole"        — whole-image convolution, no patching (the
            lossless software reference; ``width`` optional). Always fp32,
            even on a quantized engine — it is the baseline the quant
            accuracy budget is measured against.

        ``plan`` overrides the engine's plan for this call only (benchmark
        sweeps over the patch-based modes; "whole" has no plan knobs).
        """
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        if mode == "edge_select" and width is not None:
            raise ValueError("width only applies to mode='all_patches'/'whole'; "
                             "for forced routing use mode='all_patches'")
        if mode != "edge_select" and ids_override is not None:
            raise ValueError("ids_override requires mode='edge_select'")
        p = plan if plan is not None else self.plan
        if p.quant != self.plan.quant:
            # quant is engine state (calibrated alphas + compiled lattice
            # executables), exactly like backend/shards
            raise ValueError(
                f"plan.quant is engine-level: engine was built with "
                f"{self.plan.quant!r}, per-call plan asks for {p.quant!r}; "
                f"construct a second engine for a different quant mode")
        if (p.dispatch == "fused" and mode == "edge_select"
                and ids_override is None and p.subnet_policy == "threshold"):
            # the single-dispatch frame executable; every other combination
            # (forced policies, ids_override, all_patches, whole) routes on
            # the host and says so in FrameResult.dispatch
            return self._upscale_fused(frame, p)
        t0 = time.perf_counter()
        index = self._next_index()
        frame = self._ingest_frame(frame, p, index)
        # host dispatch syncs per frame anyway, so the verdict runs eagerly;
        # under "bilinear" a poisoned threshold-routed frame is forced to the
        # dense fallback lane below (forced-width modes serve the sanitized
        # frame through the requested subnet — the caller pinned the route)
        frame, health_t, force_bilinear = self._host_health(frame, p, index)

        widths = self.cfg.subnet_widths()
        if mode == "whole":
            if width is not None and width not in widths:
                raise ValueError(f"mode='whole' needs width in {widths} "
                                 f"(or None for full), got {width}")
            compiled = self._mark_warm(
                ("whole", (int(frame.shape[0]), int(frame.shape[1])), width))
            img = sr_whole(self.params, frame, self.cfg, width=width)
            img.block_until_ready()
            # sr_whole always runs the pure-JAX path; label it honestly
            return FrameResult(image=img, mode=mode, backend="ref",
                               latency_s=time.perf_counter() - t0,
                               compiled=compiled, health=health_t)

        # cached gather/scatter maps for this frame shape (zero host setup
        # after the first frame of a given geometry)
        geom = p.geometry(frame.shape[0], frame.shape[1], self.cfg.scale)
        # first frame of a geometry pays trace+compile (an approximation for
        # host dispatch, where unseen bucket sizes can still recompile later;
        # exact for the fused path, which keys on its capacity profile)
        compiled = self._mark_warm(("host", geom.cache_key))
        scored = False
        routed_by_thresholds = False
        result_mode = mode
        if mode == "all_patches":
            if width not in widths:
                raise ValueError(f"mode='all_patches' needs width in {widths}, "
                                 f"got {width}")
            res = sr_all_patches_result(self.params, frame, self.cfg, width,
                                        patch=p.patch, overlap=p.overlap,
                                        buckets=p.buckets, backend=self.backend,
                                        interpret=p.interpret, geometry=geom,
                                        mesh=self.mesh, quant=self.qpack,
                                        fusion=p.fusion)
        elif ids_override is None and p.subnet_policy != "threshold":
            # forced policies ignore edge scores — reuse the no-scoring path;
            # plan.decide is the single policy-name -> subnet-id mapping.
            # Label what actually ran, so consumers keying on mode don't
            # expect edge scores from a forced run.
            result_mode = "all_patches"
            forced = widths[int(p.decide(np.zeros(1))[0])]
            res = sr_all_patches_result(self.params, frame, self.cfg, forced,
                                        patch=p.patch, overlap=p.overlap,
                                        buckets=p.buckets, backend=self.backend,
                                        interpret=p.interpret, geometry=geom,
                                        mesh=self.mesh, quant=self.qpack,
                                        fusion=p.fusion)
        else:
            if force_bilinear and ids_override is None:
                # poisoned frame under on_poison="bilinear": the dense
                # fallback lane serves every patch (sanitized above)
                ids_override = np.zeros(geom.n, np.int64)
            # an explicit ids_override skips the edge unit entirely, so there
            # are no scores to report for that path
            scored = ids_override is None
            routed_by_thresholds = ids_override is None
            res = edge_selective_sr(self.params, frame, self.cfg,
                                    t1=p.t1, t2=p.t2,
                                    patch=p.patch, overlap=p.overlap,
                                    ids_override=ids_override,
                                    buckets=p.buckets, backend=self.backend,
                                    interpret=p.interpret, geometry=geom,
                                    mesh=self.mesh, quant=self.qpack,
                                    fusion=p.fusion)
        res.image.block_until_ready()
        return FrameResult(image=res.image, mode=result_mode,
                           backend=self._backend_label(p), ids=res.ids,
                           scores=res.scores if scored else None,
                           counts=res.counts, mac_saving=res.mac_saving,
                           latency_s=time.perf_counter() - t0,
                           # thresholds only meaningful when routing used them
                           thresholds=(p.thresholds if routed_by_thresholds
                                       else (0.0, 0.0)),
                           # sharding is engine-level (like backend): a
                           # per-call plan cannot rebuild the mesh
                           shards=self.plan.shards, compiled=compiled,
                           health=health_t)

    def reference(self, frame: jax.Array, width: Optional[int] = None) -> FrameResult:
        """Whole-image convolution — the lossless reference of Table III."""
        return self.upscale(frame, mode="whole", width=width)

    # -- streaming (Algorithm 1 + deadline control loop) ---------------------

    def serve(self, frame: jax.Array) -> FrameResult:
        """One frame of the adaptive stream: edge scores -> Algorithm-1
        thresholds (with per-second C54 ceiling) -> edge-selective SR.
        Appends to ``self.stats``; a missed deadline raises the thresholds
        (the paper's resource-adaptive mechanism as straggler mitigation).

        With ``plan.shards > 1`` the frame's raster strips are routed by
        per-shard controllers (`ShardSwitcherBank`), the routed buckets run
        data-parallel over the patch mesh, and a missed deadline demotes
        only the shards whose estimated MAC cost exceeds the balanced share
        (a host-side load model — the deadline itself is the frame's global
        wall clock) — their next-frame C54 share drops while balanced shards
        keep their thresholds. Per-shard counts/thresholds/demotions are
        surfaced on the `FrameResult`."""
        if self.plan.streams > 1:
            raise ValueError(
                f"plan.streams={self.plan.streams}: multi-stream serving "
                f"admits one frame per tenant per tick — use serve_streams()")
        if self.plan.subnet_policy != "threshold":
            raise ValueError(
                f"streaming routes adaptively and cannot honour forced "
                f"subnet_policy {self.plan.subnet_policy!r}; use upscale() "
                f"for forced routing")
        if self.plan.dispatch == "fused":
            # the single-dispatch stream path: routing + the C54 ceiling run
            # in-graph (capacity slots), Algorithm-1 trim runs host-side
            # from the materialized counts (see _finalize_fused)
            return self._finalize_fused(self._launch_fused(
                frame, self.plan, self.switcher.thresholds, streaming=True))
        t0 = time.perf_counter()
        index = self._next_index()
        frame = self._ingest_frame(frame, self.plan, index)
        frame, health_t, force_bilinear = self._host_health(frame, self.plan,
                                                            index)
        geom = self.plan.geometry(frame.shape[0], frame.shape[1],
                                  self.cfg.scale)
        compiled = self._mark_warm(("host", geom.cache_key))
        patches, pos = geom.extract(frame), geom.pos
        scores = np.asarray(edge_score(patches))
        sharded = self.bank is not None
        slices = (geom.shard_slices(self.plan.shards) if sharded else None)
        if force_bilinear:
            # poisoned frame under on_poison="bilinear": serve the dense
            # fallback lane; the switcher still observes (zero C54 load)
            ids = np.zeros(len(scores), np.int64)
        elif sharded:
            ids = self.bank.assign(scores, slices)
        else:
            ids = self.switcher.assign(scores)
        res = edge_selective_sr(self.params, frame, self.cfg,
                                patch=self.plan.patch, overlap=self.plan.overlap,
                                ids_override=ids, buckets=self.plan.buckets,
                                backend=self.backend,
                                interpret=self.plan.interpret, geometry=geom,
                                mesh=self.mesh, quant=self.qpack,
                                fusion=self.plan.fusion,
                                precomputed=(patches, pos, scores))
        res.image.block_until_ready()
        dt = time.perf_counter() - t0
        missed = bool(self.deadline_s and dt > self.deadline_s)
        shard_counts = shard_thresholds = shard_missed = None
        if sharded:
            shard_counts = tuple(sp.subnet_counts(ids[sl]) for sl in slices)
            shard_missed = self.bank.note_frame(
                missed, [self._macs.total(c) for c in shard_counts])
            shard_thresholds = self.bank.thresholds
            # scalar thresholds field: across-shard mean (the per-shard truth
            # is in shard_thresholds)
            live = tuple(float(np.mean([t[i] for t in shard_thresholds]))
                         for i in (0, 1))
        else:
            if missed:
                self.switcher.demote_for_straggler(severity=1.0)
            live = self.switcher.thresholds
        out = FrameResult(image=res.image, mode="edge_select",
                          backend=self.backend_label, ids=ids, scores=scores,
                          counts=res.counts, mac_saving=res.mac_saving,
                          latency_s=dt, thresholds=live,
                          deadline_missed=missed, shards=self.plan.shards,
                          shard_counts=shard_counts,
                          shard_thresholds=shard_thresholds,
                          shard_deadline_missed=shard_missed,
                          compiled=compiled, health=health_t)
        # retain only the compact record: holding every SR image would grow
        # unboundedly over a long stream (one 8K frame is ~100s of MB)
        self.stats.append(dataclasses.replace(out, image=None,
                                              ids=None, scores=None))
        return out

    def stream(self, frames: Iterable[jax.Array]) -> Iterator[FrameResult]:
        """Serve a frame stream; yields one FrameResult per frame.

        Under fused dispatch with ``plan.inflight >= 2`` the stream is
        double-buffered: up to ``inflight`` frames stay in flight, so frame
        N's device compute overlaps frame N+1's host-side ingest and the
        per-frame Python round-trip leaves the steady-state critical path.
        The cost is a documented one-frame control delay: the Algorithm-1
        switcher (and capacity growth) adapt from the newest *materialized*
        frame, which trails the newest *launched* frame by up to
        ``inflight - 1``. Results still arrive strictly in frame order."""
        if self.plan.streams > 1:
            raise ValueError(
                f"plan.streams={self.plan.streams}: multi-stream serving "
                f"admits one frame per tenant per tick — use serve_streams()")
        # fault harness + iterator isolation: an iterator that raises ends
        # the stream with a recorded retirement, never a serving-loop crash
        frames = self._guarded_frames(frames)
        if self.plan.dispatch == "fused" and self.plan.inflight > 1:
            yield from self._stream_fused_async(frames)
            return
        for frame in frames:
            yield self.serve(frame)

    def _stream_fused_async(self, frames: Iterable[jax.Array]
                            ) -> Iterator[FrameResult]:
        pending: Deque[dict] = collections.deque()
        for frame in frames:
            pending.append(self._launch_fused(
                frame, self.plan, self.switcher.thresholds, streaming=True))
            while len(pending) >= self.plan.inflight:
                yield self._finalize_fused(pending.popleft())
        while pending:
            yield self._finalize_fused(pending.popleft())

    def serve_streams(self, streams: Iterable[Iterable[jax.Array]]
                      ) -> Iterator[FrameResult]:
        """Serve ``plan.streams`` tenant frame streams through ONE fused
        dispatch per admission tick (the multi-tenant front door).

        ``streams``: one frame iterable per tenant, ``plan.streams`` of
        them, in stream-id order. Each admission tick pulls the next frame
        from every still-live stream (round-robin admission — no tenant can
        starve another), packs the tick's routed patches from ALL streams
        into the same capacity-slotted fused executable, and yields one
        `FrameResult` per live stream (tagged ``stream_id``), ticks in
        admission order and streams in id order within a tick. Per-stream
        QoS: every stream keeps its own Algorithm-1 switcher with a
        share-weighted budget split (``plan.stream_shares``); under
        aggregate overload C54 slots degrade per stream in share proportion,
        raster-deterministically — frames are never dropped. Streams may
        have different lengths: exhausted streams leave the tick (one
        recompile per distinct live-stream count). ``plan.inflight >= 2``
        double-buffers whole ticks, with the same one-tick control delay as
        the single-stream async path.

        With ``plan.streams == 1`` this is exactly ``stream()`` over the
        single iterable."""
        streams = list(streams)
        if len(streams) != self.plan.streams:
            raise ValueError(
                f"serve_streams got {len(streams)} streams for "
                f"plan.streams={self.plan.streams}")
        if self.plan.streams == 1:
            yield from self.stream(streams[0])
            return
        from repro.runtime.multiplex import StreamMultiplexer
        yield from StreamMultiplexer(self).serve(streams)

    # -- aggregate reporting -------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        """Table-XI-style aggregate over all streamed frames."""
        s = summarize_stats(self.stats)
        if s:
            s["backend"] = self.backend_label
            # the record list is a bounded deque: aggregates cover at most
            # the newest stats_window streamed frames
            s["stats_window"] = self.plan.stats_window
            # process-wide compiled/geometry cache pressure (satellite of the
            # bounded-cache work): nonzero evictions under a steady geometry
            # set means executables are silently re-tracing.
            s["compiled_caches"] = compiled_cache_occupancy()
        if self.guard.events:
            # the resilience ledger: every degradation-ladder step, poison
            # verdict, quarantine/retire and watchdog event, deterministic
            # under a seeded FaultPlan (watchdog events are timing-dependent
            # and excluded from determinism assertions)
            s["degradations"] = self.guard.summary()
        return s
