"""ExecutionPlan — the frozen, hashable description of HOW a frame is run.

Consolidates every knob that used to travel as loose keyword arguments
through `edge_selective_sr` / `FrameServer` / the benchmark helpers:
patch geometry, edge thresholds, the jit bucket schedule, the subnet
policy, and the Pallas interpret policy. One plan == one compilation/routing
regime; `SREngine` holds exactly one and every call reuses it (override per
call with ``plan.replace(...)`` only when a benchmark sweeps a knob).

``plan.geometry(h, w, scale)`` resolves the cached `PatchGeometry` (gather/
scatter index maps + overlap counts) for a frame shape under this plan's
patch/overlap — computed once per geometry, so repeated frames of a stream
pay zero host-side setup.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import subnet_policy as sp
from repro.core.patching import PatchGeometry, get_geometry
from repro.core.pipeline import DEFAULT_BUCKETS
from repro.quant.pams import QUANT_MODES as pams_quant_modes

#: Subnet-policy names accepted by :class:`ExecutionPlan`.
#: ``threshold``     — paper Sec. II-C routing on the (t1, t2) edge thresholds
#: ``all_bilinear``  / ``all_c27`` / ``all_c54`` — force every patch through
#:                     one subnet (the ablation references of Tables III/IX).
SUBNET_POLICIES = ("threshold", "all_bilinear", "all_c27", "all_c54")

#: Dispatch modes accepted by :class:`ExecutionPlan`:
#: ``"host"``  — routing on the host: per-frame edge-score sync, Python loop
#:               over subnet buckets (supports every mode/policy/override)
#: ``"fused"`` — one compiled frame executable per (geometry, capacity
#:               profile): extract -> edge-score -> threshold routing ->
#:               capacity-slotted per-subnet forward -> scatter-add fusion,
#:               no host in the loop (threshold-routed edge_select only;
#:               other modes fall back to host dispatch, documented in
#:               docs/api.md "Dispatch modes & async streaming").
DISPATCH_MODES = ("host", "fused")

#: Serving quantization modes accepted by :class:`ExecutionPlan`:
#: ``None``    — fp32 serving (the default)
#: ``"fxp10"`` — the paper's whole-model FXP10 (Sec. IV-H)
#: ``"int8"``  — TPU-native int8 (the MXU integer datapath)
#: Derived from `repro.quant.pams.QUANT_MODES` (the mode -> bits mapping),
#: the single source of truth for which lattices exist.
QUANT_MODES = (None, *pams_quant_modes)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    patch: int = 32
    overlap: int = 2
    t1: float = sp.DEFAULT_T1
    t2: float = sp.DEFAULT_T2
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    subnet_policy: str = "threshold"
    #: Pallas dispatch: None = auto (compiled on TPU/GPU, interpreter as the
    #: CPU-correctness fallback); True/False force it. Only consulted by the
    #: "pallas" backend.
    interpret: Optional[bool] = None
    #: Serving quantization: None (fp32), "fxp10" (paper Sec. IV-H) or
    #: "int8" (TPU MXU datapath). Engine-level like ``shards``: the engine
    #: PTQ-calibrates per-subnet activation alphas at construction, so a
    #: per-call plan override cannot change the mode. The "ref" backend
    #: serves fake-quant emulation; "pallas" serves the integer-domain
    #: kernel stack (kernels/qconv.py). Surfaced as a FrameResult.backend
    #: suffix ("ref-fxp10", "pallas-int8", "pallas-interpret-int8", ...).
    quant: Optional[str] = None
    #: Frame dispatch: "host" (routing on the host, the default) or "fused"
    #: (one compiled executable per (geometry, capacity profile) — see
    #: DISPATCH_MODES above and docs/api.md). Applies to threshold-routed
    #: edge_select calls; forced policies / ids_override / all_patches /
    #: whole always run host dispatch.
    dispatch: str = "host"
    #: Fused-dispatch per-subnet slot capacities, aligned with
    #: ``cfg.subnet_widths()`` (entry 0 — bilinear — is ignored: that lane
    #: runs dense as the spill floor). None = automatic: the engine probes
    #: the first frame of each geometry on the host, snaps counts to
    #: ``buckets`` (`core.pipeline.snap_capacity`), and grows a subnet's
    #: capacity after any frame that spilled; when streaming, the C54 entry
    #: is additionally clamped to the per-frame share of the Algorithm-1
    #: C54/sec budget. Pin explicitly to fix the compiled shape (tests;
    #: validated deployments) — a pinned profile is served VERBATIM, so its
    #: C54 entry *replaces* the budget-derived ceiling: the pin is the
    #: per-frame hard ceiling, and it is on the operator to size it within
    #: the deployment's compute budget.
    capacity: Optional[Tuple[int, ...]] = None
    #: Async double-buffering depth for ``SREngine.stream`` under fused
    #: dispatch: 1 (default) serves synchronously; >= 2 keeps that many
    #: frames in flight — frame N's device compute overlaps frame N+1's
    #: host-side ingest, and the Algorithm-1 switcher reads routing
    #: telemetry one frame behind (a documented control delay).
    inflight: int = 1
    #: Bound on the per-frame records ``SREngine.stats`` retains (a deque:
    #: the newest ``stats_window`` streamed frames). Generous by default;
    #: ``summary()`` aggregates over at most this window and says so.
    stats_window: int = 4096
    #: Data-parallel patch-stream shards. 1 = the single-device path. > 1
    #: splits each frame's routed patch buckets across that many devices
    #: (shard_map over a 1-D mesh) and gives each shard its own Algorithm-1
    #: controller in the streaming path. When fewer devices are visible the
    #: engine degrades transparently: routing/straggler control stays
    #: per-shard, dispatch falls back to one device.
    shards: int = 1

    def __post_init__(self):
        # keep the frozen/hashable contract even when callers pass a list
        object.__setattr__(self, "buckets", tuple(self.buckets))
        if self.subnet_policy not in SUBNET_POLICIES:
            raise ValueError(f"subnet_policy {self.subnet_policy!r} not in "
                             f"{SUBNET_POLICIES}")
        if self.overlap >= self.patch:
            raise ValueError(f"overlap {self.overlap} must be < patch {self.patch}")
        if self.t2 < self.t1:
            raise ValueError(f"t2 {self.t2} must be >= t1 {self.t1}")
        if (not self.buckets or any(b <= 0 for b in self.buckets)
                or list(self.buckets) != sorted(set(self.buckets))):
            raise ValueError(f"buckets must be ascending positive ints, "
                             f"got {self.buckets}")
        if self.interpret not in (None, True, False):
            raise ValueError(f"interpret must be None/True/False, "
                             f"got {self.interpret!r}")
        if self.quant not in QUANT_MODES:
            raise ValueError(f"quant must be one of {QUANT_MODES}, "
                             f"got {self.quant!r}")
        if self.dispatch not in DISPATCH_MODES:
            raise ValueError(f"dispatch {self.dispatch!r} not in "
                             f"{DISPATCH_MODES}")
        if self.capacity is not None:
            try:
                caps = tuple(int(c) for c in self.capacity)
            except (TypeError, ValueError):
                raise ValueError(f"capacity must be a tuple of ints >= 0, "
                                 f"got {self.capacity!r}")
            if any(c < 0 for c in caps):
                raise ValueError(f"capacity entries must be >= 0, got {caps}")
            object.__setattr__(self, "capacity", caps)
        if not isinstance(self.inflight, int) or self.inflight < 1:
            raise ValueError(f"inflight must be a positive int, "
                             f"got {self.inflight!r}")
        if self.inflight > 1 and self.dispatch != "fused":
            # host dispatch blocks per frame, so the combination would be
            # silently inert — refuse rather than let a user believe the
            # stream is double-buffered
            raise ValueError(f"inflight={self.inflight} requires "
                             f"dispatch='fused' (host dispatch serves "
                             f"synchronously)")
        if not isinstance(self.stats_window, int) or self.stats_window < 1:
            raise ValueError(f"stats_window must be a positive int, "
                             f"got {self.stats_window!r}")
        if not isinstance(self.shards, int) or self.shards < 1:
            raise ValueError(f"shards must be a positive int, "
                             f"got {self.shards!r}")

    def replace(self, **kw) -> "ExecutionPlan":
        """Functional update (plans are frozen)."""
        return dataclasses.replace(self, **kw)

    def decide(self, scores: np.ndarray) -> np.ndarray:
        """Edge scores -> subnet ids under this plan's policy.

        (The streaming path does not use this: there `AdaptiveSwitcher.assign`
        owns the live thresholds and the per-second C54 ceiling.)
        """
        scores = np.asarray(scores)
        if self.subnet_policy == "threshold":
            return np.asarray(sp.decide(scores, self.t1, self.t2))
        fixed = {"all_bilinear": sp.BILINEAR, "all_c27": sp.C27,
                 "all_c54": sp.C54}[self.subnet_policy]
        return np.full(scores.shape, fixed, dtype=np.int64)

    def geometry(self, h: int, w: int, scale: int) -> PatchGeometry:
        """Cached gather/scatter maps for an (h, w) frame under this plan.

        Backed by the process-wide LRU in `repro.core.patching`; the first
        frame of a given shape pays the host-side index build, every later
        frame of the stream reuses it."""
        return get_geometry(int(h), int(w), self.patch, self.overlap,
                            int(scale))

    @property
    def thresholds(self) -> Tuple[float, float]:
        return (self.t1, self.t2)
