"""ExecutionPlan — the frozen, hashable description of HOW a frame is run.

Consolidates every knob that used to travel as loose keyword arguments
through the legacy free functions and benchmark helpers: patch geometry,
edge thresholds, the jit bucket schedule, the subnet policy, and the Pallas
interpret policy. One plan == one compilation/routing regime; `SREngine`
holds exactly one and every call reuses it (override per call with
``plan.replace(...)`` only when a benchmark sweeps a knob).

``plan.geometry(h, w, scale)`` resolves the cached `PatchGeometry` (gather/
scatter index maps + overlap counts) for a frame shape under this plan's
patch/overlap — computed once per geometry, so repeated frames of a stream
pay zero host-side setup.

Validation is declarative: ``_FIELD_RULES`` (one predicate + allowed-set
description per field) and ``_CROSS_RULES`` (cross-field constraints like
``inflight -> fused``), both enforced in ``__post_init__`` with ONE error
format — ``ExecutionPlan.<field>=<got!r>: allowed <set>`` — so every
rejection names the field, the offending value, and what would have passed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import subnet_policy as sp
from repro.core.patching import PatchGeometry, get_geometry
from repro.core.pipeline import DEFAULT_BUCKETS, FUSION_MODES, HEALTH_POLICIES
from repro.quant.pams import QUANT_MODES as pams_quant_modes
from repro.runtime.guard import FaultPlan

#: Subnet-policy names accepted by :class:`ExecutionPlan`.
#: ``threshold``     — paper Sec. II-C routing on the (t1, t2) edge thresholds
#: ``all_bilinear``  / ``all_c27`` / ``all_c54`` — force every patch through
#:                     one subnet (the ablation references of Tables III/IX).
SUBNET_POLICIES = ("threshold", "all_bilinear", "all_c27", "all_c54")

#: Dispatch modes accepted by :class:`ExecutionPlan`:
#: ``"host"``  — routing on the host: per-frame edge-score sync, Python loop
#:               over subnet buckets (supports every mode/policy/override)
#: ``"fused"`` — one compiled frame executable per (geometry, capacity
#:               profile): extract -> edge-score -> threshold routing ->
#:               capacity-slotted per-subnet forward -> scatter-add fusion,
#:               no host in the loop (threshold-routed edge_select only;
#:               other modes fall back to host dispatch, documented in
#:               docs/api.md "Dispatch modes & async streaming").
DISPATCH_MODES = ("host", "fused")

#: Serving quantization modes accepted by :class:`ExecutionPlan`:
#: ``None``    — fp32 serving (the default)
#: ``"fxp10"`` — the paper's whole-model FXP10 (Sec. IV-H)
#: ``"int8"``  — TPU-native int8 (the MXU integer datapath)
#: Derived from `repro.quant.pams.QUANT_MODES` (the mode -> bits mapping),
#: the single source of truth for which lattices exist.
QUANT_MODES = (None, *pams_quant_modes)


def _plan_error(field: str, got, allowed: str) -> ValueError:
    """The one validation error shape every rule raises through."""
    return ValueError(f"ExecutionPlan.{field}={got!r}: allowed {allowed}")


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _pos_int(v) -> bool:
    return _is_int(v) and v >= 1


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


#: Declarative per-field validation: field -> (predicate over the normalized
#: value, human-readable allowed set). Single-field rules only; constraints
#: spanning fields live in `_CROSS_RULES`. New plan knobs add one row here
#: instead of growing an if-chain in ``__post_init__``.
_FIELD_RULES: Dict[str, Tuple[Callable, str]] = {
    "patch": (_pos_int, "a positive int"),
    "overlap": (lambda v: _is_int(v) and v >= 0, "an int >= 0"),
    "t1": (_is_num, "a number"),
    "t2": (_is_num, "a number"),
    "buckets": (lambda v: bool(v) and all(_pos_int(b) for b in v)
                and list(v) == sorted(set(v)),
                "a non-empty ascending tuple of positive ints"),
    "subnet_policy": (lambda v: v in SUBNET_POLICIES,
                      f"one of {SUBNET_POLICIES}"),
    "interpret": (lambda v: v in (None, True, False), "None/True/False"),
    "quant": (lambda v: v in QUANT_MODES, f"one of {QUANT_MODES}"),
    "dispatch": (lambda v: v in DISPATCH_MODES, f"one of {DISPATCH_MODES}"),
    "fusion": (lambda v: v in FUSION_MODES, f"one of {FUSION_MODES}"),
    "capacity": (lambda v: v is None or all(c >= 0 for c in v),
                 "None or a tuple of ints >= 0"),
    "inflight": (_pos_int, "a positive int"),
    "stats_window": (_pos_int, "a positive int"),
    "shards": (_pos_int, "a positive int"),
    "streams": (_pos_int, "a positive int"),
    "stream_shares": (lambda v: v is None or (bool(v)
                      and all(s > 0 and np.isfinite(s) for s in v)),
                      "None or a tuple of finite floats > 0"),
    "on_poison": (lambda v: v in HEALTH_POLICIES, f"one of {HEALTH_POLICIES}"),
    "faults": (lambda v: v is None or isinstance(v, FaultPlan),
               "None or a repro.runtime.guard.FaultPlan"),
    "max_retries": (lambda v: _is_int(v) and v >= 0, "an int >= 0"),
    "quarantine_ticks": (lambda v: _is_int(v) and v >= 0,
                         "an int >= 0 (0 retires a quarantined stream "
                         "permanently)"),
    "watchdog_s": (lambda v: v is None or (_is_num(v) and v > 0),
                   "None or a number > 0"),
}

#: Cross-field constraints: (field to blame, predicate over the whole plan,
#: allowed-set description builder). Same error format as `_FIELD_RULES`.
_CROSS_RULES: Tuple[Tuple[str, Callable, Callable], ...] = (
    ("overlap", lambda p: p.overlap < p.patch,
     lambda p: f"an int < patch ({p.patch})"),
    ("t2", lambda p: p.t2 >= p.t1,
     lambda p: f"a number >= t1 ({p.t1})"),
    # host dispatch blocks per frame, so inflight > 1 would be silently
    # inert — refuse rather than let a user believe the stream is
    # double-buffered
    ("inflight", lambda p: p.inflight == 1 or p.dispatch == "fused",
     lambda p: "1 unless dispatch='fused' (host dispatch serves "
               "synchronously)"),
    # multi-stream admission packs every tick into the fused executable;
    # there is no host-dispatch multiplexer
    ("streams", lambda p: p.streams == 1 or p.dispatch == "fused",
     lambda p: "1 unless dispatch='fused' (stream packing rides the fused "
               "executable)"),
    # per-stream QoS is Algorithm-1 threshold control; a forced policy has
    # no thresholds to degrade
    ("streams", lambda p: p.streams == 1 or p.subnet_policy == "threshold",
     lambda p: "1 unless subnet_policy='threshold' (per-stream QoS adapts "
               "thresholds)"),
    ("stream_shares", lambda p: (p.stream_shares is None
                                 or len(p.stream_shares) == p.streams),
     lambda p: f"None or a tuple of exactly streams={p.streams} shares"),
    # the watchdog meters fused admission ticks / frame launches; host
    # dispatch has no tick clock to meter
    ("watchdog_s", lambda p: p.watchdog_s is None or p.dispatch == "fused",
     lambda p: "None unless dispatch='fused' (the watchdog meters fused "
               "admission ticks)"),
)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    patch: int = 32
    overlap: int = 2
    t1: float = sp.DEFAULT_T1
    t2: float = sp.DEFAULT_T2
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    subnet_policy: str = "threshold"
    #: Pallas dispatch: None = auto (compiled on TPU/GPU, interpreter as the
    #: CPU-correctness fallback); True/False force it. Only consulted by the
    #: "pallas" backend.
    interpret: Optional[bool] = None
    #: Serving quantization: None (fp32), "fxp10" (paper Sec. IV-H) or
    #: "int8" (TPU MXU datapath). Engine-level like ``shards``: the engine
    #: PTQ-calibrates per-subnet activation alphas at construction, so a
    #: per-call plan override cannot change the mode. The "ref" backend
    #: serves fake-quant emulation; "pallas" serves the integer-domain
    #: kernel stack (kernels/qconv.py). Surfaced as a FrameResult.backend
    #: suffix ("ref-fxp10", "pallas-int8", "pallas-interpret-int8", ...).
    quant: Optional[str] = None
    #: Frame dispatch: "host" (routing on the host, the default) or "fused"
    #: (one compiled executable per (geometry, capacity profile) — see
    #: DISPATCH_MODES above and docs/api.md). Applies to threshold-routed
    #: edge_select calls; forced policies / ids_override / all_patches /
    #: whole always run host dispatch.
    dispatch: str = "host"
    #: Kernel fusion granularity of the "pallas" backend
    #: (`core.pipeline.FUSION_MODES`): "layer" (default) runs one Pallas
    #: kernel per layer group — BSConv, each SFB, DSConv — with the feature
    #: map round-tripping HBM between groups; "group" runs a subnet's WHOLE
    #: layer group in ONE megakernel (`kernels.megakernel`) with the feature
    #: (and, under ``quant``, the integer lattice codes) held in VMEM
    #: scratch across the chain — the TPU analog of the paper's 79%
    #: feature-SRAM-access saving. Numerics: fp32 group fusion is allclose
    #: to layer fusion; quantized group fusion is BIT-EXACT (same shared
    #: integer math, same site constants). The "ref" backend has no kernels
    #: to fuse and serves identically under both values.
    fusion: str = "layer"
    #: Fused-dispatch per-subnet slot capacities, aligned with
    #: ``cfg.subnet_widths()`` (entry 0 — bilinear — is ignored: that lane
    #: runs dense as the spill floor). None = automatic: the engine probes
    #: the first frame of each geometry on the host, snaps counts to
    #: ``buckets`` (`core.pipeline.snap_capacity`), and grows a subnet's
    #: capacity after any frame that spilled; when streaming, the C54 entry
    #: is additionally clamped to the per-frame share of the Algorithm-1
    #: C54/sec budget. Pin explicitly to fix the compiled shape (tests;
    #: validated deployments) — a pinned profile is served VERBATIM, so its
    #: C54 entry *replaces* the budget-derived ceiling: the pin is the
    #: per-frame hard ceiling, and it is on the operator to size it within
    #: the deployment's compute budget.
    capacity: Optional[Tuple[int, ...]] = None
    #: Async double-buffering depth for ``SREngine.stream`` under fused
    #: dispatch: 1 (default) serves synchronously; >= 2 keeps that many
    #: frames in flight — frame N's device compute overlaps frame N+1's
    #: host-side ingest, and the Algorithm-1 switcher reads routing
    #: telemetry one frame behind (a documented control delay).
    inflight: int = 1
    #: Bound on the per-frame records ``SREngine.stats`` retains (a deque:
    #: the newest ``stats_window`` streamed frames). Generous by default;
    #: ``summary()`` aggregates over at most this window and says so.
    stats_window: int = 4096
    #: Data-parallel patch-stream shards. 1 = the single-device path. > 1
    #: splits each frame's routed patch buckets across that many devices
    #: (shard_map over a 1-D mesh) and gives each shard its own Algorithm-1
    #: controller in the streaming path. When fewer devices are visible the
    #: engine degrades transparently: routing/straggler control stays
    #: per-shard, dispatch falls back to one device.
    shards: int = 1
    #: Concurrent tenant streams multiplexed into ONE fused dispatch per
    #: admission tick (`SREngine.serve_streams`). 1 = today's single-stream
    #: serving; >= 2 requires dispatch="fused" and the threshold policy
    #: (per-stream QoS is Algorithm-1 control). Every stream keeps its own
    #: switcher; the compiled (geometry, capacity-profile) executable — and
    #: the PTQ calibration and warmup behind it — is shared across tenants.
    streams: int = 1
    #: Relative QoS weight per stream (len == streams), normalized by the
    #: engine: stream s gets share_s/sum(shares) of the aggregate C54/sec
    #: budget and of the per-frame trim bands. None = equal shares. Under
    #: aggregate overload the capacity router degrades each stream's C54
    #: share raster-deterministically in this proportion — frames are never
    #: dropped.
    stream_shares: Optional[Tuple[float, ...]] = None
    #: Poison-frame policy (`core.pipeline.HEALTH_POLICIES`): what serving
    #: does about a frame with NaN/Inf/out-of-[0,1] pixels. "raise" (default)
    #: raises `PoisonFrameError` (multi-tenant serving quarantines the
    #: offending stream instead — see ``quarantine_ticks``); "sanitize"
    #: clamps in-graph (bit-identical on clean frames); "bilinear" routes the
    #: poisoned frame to the dense fallback lane; "off" disables the health
    #: verdict entirely (`FrameResult.health` is None — the unguarded
    #: baseline `bench_gate.py` measures overhead against).
    on_poison: str = "raise"
    #: Optional seeded chaos schedule (`repro.runtime.guard.FaultPlan`):
    #: injects poison pixels, tenant-iterator errors, simulated backend
    #: failures and launch delays deterministically. None = no injection
    #: (production). Fault handling itself (the degradation ladder, the
    #: quarantine loop) is always on.
    faults: Optional[FaultPlan] = None
    #: Extra launch attempts the degradation ladder may spend per frame/tick
    #: (`runtime.guard.ResilienceGuard`): on a failed launch the engine steps
    #: down (fusion group->layer, backend pallas->interpret->ref, quant
    #: ->fp32; sticky) or retries at the ref/fp32/layer floor, at most this
    #: many times, then re-raises.
    max_retries: int = 2
    #: Multi-tenant poison quarantine (`SREngine.serve_streams` under
    #: on_poison="raise"): a poisoned stream stops being admitted for this
    #: many ticks, then re-admits; 0 retires it permanently. Iterator errors
    #: always retire permanently (a raised iterator cannot resume).
    quarantine_ticks: int = 0
    #: Optional wall-clock budget (seconds) per fused frame launch/admission
    #: tick: a slower tick steps the degradation ladder down one rung
    #: (recorded as a "watchdog" event; timing-dependent, so excluded from
    #: determinism assertions). None = no watchdog.
    watchdog_s: Optional[float] = None

    def __post_init__(self):
        # -- normalization (keeps the frozen/hashable contract when callers
        # pass lists; coercion failures blame the field like any rule) ------
        object.__setattr__(self, "buckets", tuple(self.buckets))
        if self.capacity is not None:
            try:
                caps = tuple(int(c) for c in self.capacity)
            except (TypeError, ValueError) as e:
                raise _plan_error("capacity", self.capacity,
                                  _FIELD_RULES["capacity"][1]) from e
            object.__setattr__(self, "capacity", caps)
        if self.stream_shares is not None:
            try:
                shares = tuple(float(s) for s in self.stream_shares)
            except (TypeError, ValueError) as e:
                raise _plan_error("stream_shares", self.stream_shares,
                                  _FIELD_RULES["stream_shares"][1]) from e
            object.__setattr__(self, "stream_shares", shares)
        # -- the declarative tables ----------------------------------------
        for field, (ok, allowed) in _FIELD_RULES.items():
            value = getattr(self, field)
            if not ok(value):
                raise _plan_error(field, value, allowed)
        for field, ok, allowed in _CROSS_RULES:
            if not ok(self):
                raise _plan_error(field, getattr(self, field), allowed(self))

    def replace(self, **kw) -> "ExecutionPlan":
        """Functional update (plans are frozen)."""
        return dataclasses.replace(self, **kw)

    def decide(self, scores: np.ndarray) -> np.ndarray:
        """Edge scores -> subnet ids under this plan's policy.

        (The streaming path does not use this: there `AdaptiveSwitcher.assign`
        owns the live thresholds and the per-second C54 ceiling.)
        """
        scores = np.asarray(scores)
        if self.subnet_policy == "threshold":
            return np.asarray(sp.decide(scores, self.t1, self.t2))
        fixed = {"all_bilinear": sp.BILINEAR, "all_c27": sp.C27,
                 "all_c54": sp.C54}[self.subnet_policy]
        return np.full(scores.shape, fixed, dtype=np.int64)

    def geometry(self, h: int, w: int, scale: int) -> PatchGeometry:
        """Cached gather/scatter maps for an (h, w) frame under this plan.

        Backed by the process-wide LRU in `repro.core.patching`; the first
        frame of a given shape pays the host-side index build, every later
        frame of the stream reuses it."""
        return get_geometry(int(h), int(w), self.patch, self.overlap,
                            int(scale))

    @property
    def thresholds(self) -> Tuple[float, float]:
        return (self.t1, self.t2)
