"""ExecutionPlan — the frozen, hashable description of HOW a frame is run.

Consolidates every knob that used to travel as loose keyword arguments
through `edge_selective_sr` / `FrameServer` / the benchmark helpers:
patch geometry, edge thresholds, the jit bucket schedule, the subnet
policy, and the Pallas interpret policy. One plan == one compilation/routing
regime; `SREngine` holds exactly one and every call reuses it (override per
call with ``plan.replace(...)`` only when a benchmark sweeps a knob).

``plan.geometry(h, w, scale)`` resolves the cached `PatchGeometry` (gather/
scatter index maps + overlap counts) for a frame shape under this plan's
patch/overlap — computed once per geometry, so repeated frames of a stream
pay zero host-side setup.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import subnet_policy as sp
from repro.core.patching import PatchGeometry, get_geometry
from repro.core.pipeline import DEFAULT_BUCKETS
from repro.quant.pams import QUANT_MODES as pams_quant_modes

#: Subnet-policy names accepted by :class:`ExecutionPlan`.
#: ``threshold``     — paper Sec. II-C routing on the (t1, t2) edge thresholds
#: ``all_bilinear``  / ``all_c27`` / ``all_c54`` — force every patch through
#:                     one subnet (the ablation references of Tables III/IX).
SUBNET_POLICIES = ("threshold", "all_bilinear", "all_c27", "all_c54")

#: Serving quantization modes accepted by :class:`ExecutionPlan`:
#: ``None``    — fp32 serving (the default)
#: ``"fxp10"`` — the paper's whole-model FXP10 (Sec. IV-H)
#: ``"int8"``  — TPU-native int8 (the MXU integer datapath)
#: Derived from `repro.quant.pams.QUANT_MODES` (the mode -> bits mapping),
#: the single source of truth for which lattices exist.
QUANT_MODES = (None, *pams_quant_modes)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    patch: int = 32
    overlap: int = 2
    t1: float = sp.DEFAULT_T1
    t2: float = sp.DEFAULT_T2
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    subnet_policy: str = "threshold"
    #: Pallas dispatch: None = auto (compiled on TPU/GPU, interpreter as the
    #: CPU-correctness fallback); True/False force it. Only consulted by the
    #: "pallas" backend.
    interpret: Optional[bool] = None
    #: Serving quantization: None (fp32), "fxp10" (paper Sec. IV-H) or
    #: "int8" (TPU MXU datapath). Engine-level like ``shards``: the engine
    #: PTQ-calibrates per-subnet activation alphas at construction, so a
    #: per-call plan override cannot change the mode. The "ref" backend
    #: serves fake-quant emulation; "pallas" serves the integer-domain
    #: kernel stack (kernels/qconv.py). Surfaced as a FrameResult.backend
    #: suffix ("ref-fxp10", "pallas-int8", "pallas-interpret-int8", ...).
    quant: Optional[str] = None
    #: Data-parallel patch-stream shards. 1 = the single-device path. > 1
    #: splits each frame's routed patch buckets across that many devices
    #: (shard_map over a 1-D mesh) and gives each shard its own Algorithm-1
    #: controller in the streaming path. When fewer devices are visible the
    #: engine degrades transparently: routing/straggler control stays
    #: per-shard, dispatch falls back to one device.
    shards: int = 1

    def __post_init__(self):
        # keep the frozen/hashable contract even when callers pass a list
        object.__setattr__(self, "buckets", tuple(self.buckets))
        if self.subnet_policy not in SUBNET_POLICIES:
            raise ValueError(f"subnet_policy {self.subnet_policy!r} not in "
                             f"{SUBNET_POLICIES}")
        if self.overlap >= self.patch:
            raise ValueError(f"overlap {self.overlap} must be < patch {self.patch}")
        if self.t2 < self.t1:
            raise ValueError(f"t2 {self.t2} must be >= t1 {self.t1}")
        if (not self.buckets or any(b <= 0 for b in self.buckets)
                or list(self.buckets) != sorted(set(self.buckets))):
            raise ValueError(f"buckets must be ascending positive ints, "
                             f"got {self.buckets}")
        if self.interpret not in (None, True, False):
            raise ValueError(f"interpret must be None/True/False, "
                             f"got {self.interpret!r}")
        if self.quant not in QUANT_MODES:
            raise ValueError(f"quant must be one of {QUANT_MODES}, "
                             f"got {self.quant!r}")
        if not isinstance(self.shards, int) or self.shards < 1:
            raise ValueError(f"shards must be a positive int, "
                             f"got {self.shards!r}")

    def replace(self, **kw) -> "ExecutionPlan":
        """Functional update (plans are frozen)."""
        return dataclasses.replace(self, **kw)

    def decide(self, scores: np.ndarray) -> np.ndarray:
        """Edge scores -> subnet ids under this plan's policy.

        (The streaming path does not use this: there `AdaptiveSwitcher.assign`
        owns the live thresholds and the per-second C54 ceiling.)
        """
        scores = np.asarray(scores)
        if self.subnet_policy == "threshold":
            return np.asarray(sp.decide(scores, self.t1, self.t2))
        fixed = {"all_bilinear": sp.BILINEAR, "all_c27": sp.C27,
                 "all_c54": sp.C54}[self.subnet_policy]
        return np.full(scores.shape, fixed, dtype=np.int64)

    def geometry(self, h: int, w: int, scale: int) -> PatchGeometry:
        """Cached gather/scatter maps for an (h, w) frame under this plan.

        Backed by the process-wide LRU in `repro.core.patching`; the first
        frame of a given shape pays the host-side index build, every later
        frame of the stream reuses it."""
        return get_geometry(int(h), int(w), self.patch, self.overlap,
                            int(scale))

    @property
    def thresholds(self) -> Tuple[float, float]:
        return (self.t1, self.t2)
