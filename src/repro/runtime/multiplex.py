"""StreamMultiplexer — N tenant frame streams through ONE fused dispatch.

`SREngine.serve_streams` delegates here when ``plan.streams >= 2``; this
module is never a public entry point of its own (the ESSR206 lint holds the
line: stream serving lives on the `repro.api` facade).

Admission model: each *tick* pulls the next frame from every still-live
stream (strict round-robin — a tenant is admitted exactly once per tick, so
no stream can starve another) and packs all of them into one
`fused_stream_frame_fn` call. Patch provenance ``(stream_id, patch_id)`` is
positional — the flat patch axis is stream-major — so the aggregate
capacity cascade runs on the shared pool unchanged while scatter-back fuses
each stream's frame independently. The compiled (geometry, live-count,
capacity-profile) executable — and the PTQ calibration and warmup behind it
— is shared by every tenant; per-stream thresholds and C54 quotas are
*traced* arguments, so Algorithm-1 adaptation and share rebalancing never
recompile a tick.

QoS: every stream owns an `AdaptiveSwitcher` seeded with its share of the
aggregate budget (`StreamSwitcherBank`). The in-graph per-stream quota is
the hard ceiling — under aggregate overload each stream's C54 slots degrade
in share proportion, raster-deterministically, and frames are never
dropped. A missed tick deadline is attributed by share-weighted MAC cost:
only the streams running past their entitlement are demoted, so one
tenant's heavy content never lowers another tenant's quality.

Fault isolation (per tenant): a stream whose iterator raises is RETIRED —
the exception is recorded in the engine's degradation ledger and the tick
proceeds for every other tenant. A stream whose frame fails its health
verdict under ``plan.on_poison="raise"`` is QUARANTINED instead of raising
(the per-tenant analog of the solo raise): its result for that tick is
suppressed, admission pauses for ``plan.quarantine_ticks`` ticks (0 retires
it permanently), then the stream re-admits. Healthy tenants' outputs are
unperturbed either way — the fp32 conv forward is row-wise bit-identical
across batch content, so with a pinned capacity profile a healthy stream's
frames are bit-equal to a no-fault run (asserted in tests/test_guard.py).
Launch failures step the engine's shared degradation ladder exactly like
the solo fused path; ``plan.watchdog_s`` meters the tick wall clock.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, Iterable, Iterator, List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.api.result import FrameResult
from repro.core import subnet_policy as sp
from repro.core.edge_score import edge_score
from repro.core.pipeline import fused_stream_frame_fn, snap_capacity


class StreamMultiplexer:
    """The admission-tick loop behind `SREngine.serve_streams`.

    Holds no state of its own beyond the engine it drives: capacity
    profiles live in the engine's fused-caps cache (keyed by geometry AND
    live-stream count, so they survive across serve_streams calls), control
    state lives in the engine's `StreamSwitcherBank`.
    """

    def __init__(self, engine):
        if engine.plan.streams < 2:
            raise ValueError(f"StreamMultiplexer needs plan.streams >= 2, "
                             f"got {engine.plan.streams}")
        if engine.stream_bank is None:
            raise ValueError("engine has no stream bank (was the plan "
                             "replaced after construction?)")
        self.engine = engine
        self.bank = engine.stream_bank
        # stream ids whose finalized tick failed the health verdict under
        # on_poison="raise"; drained by serve() into quarantine bookkeeping
        self._poisoned: List[int] = []

    # -- the admission loop --------------------------------------------------

    def serve(self, streams: Sequence[Iterable[jax.Array]]
              ) -> Iterator[FrameResult]:
        """Multiplex the tenant iterables; yields FrameResults tick by tick
        (live streams in id order within a tick). ``plan.inflight >= 2``
        keeps that many whole ticks in flight (device compute of tick T
        overlaps admission of tick T+1), at the cost of the per-stream
        controllers adapting from a tick-old frame — the same documented
        control delay as the single-stream async path, per tick instead of
        per frame.

        Per-tenant fault isolation happens here: an iterator exception
        retires THAT stream (recorded in the engine's guard ledger) and the
        tick proceeds for the rest; a poison verdict under
        ``plan.on_poison="raise"`` quarantines the stream for
        ``plan.quarantine_ticks`` ticks (0 = permanent retirement) and then
        re-admits it. The loop keeps ticking while quarantined streams wait
        even if no stream is currently admissible."""
        eng = self.engine
        p = eng.plan
        iters = []
        for s, src in enumerate(streams):
            it = iter(src)
            if eng.injector is not None:
                it = eng.injector.wrap_stream(s, it)
            iters.append(it)
        live: List[int] = list(range(len(iters)))
        quarantined: Dict[int, int] = {}     # stream id -> re-admission tick
        pending: Deque[dict] = collections.deque()
        inflight = p.inflight
        tick = 0
        while live or quarantined or pending:
            self._drain_poisoned(live, quarantined, tick)
            for s in sorted(sid for sid, t in quarantined.items()
                            if tick >= t):
                del quarantined[s]
                live.append(s)
                live.sort()
                eng.guard.record(tick, "readmit",
                                 f"stream {s} re-admitted after quarantine")
            frames, nxt = [], []
            for s in live:
                try:
                    frames.append(jnp.asarray(next(iters[s])))
                    nxt.append(s)
                except StopIteration:
                    pass
                except Exception as e:
                    # one tenant's iterator failure must not abort the tick:
                    # retire that stream with the reason on the ledger and
                    # keep serving everyone else
                    eng.guard.record(tick, "retire",
                                     f"stream {s} iterator raised: {e!r}")
            live = nxt
            if frames:
                pending.append(self._launch_tick(live, frames))
                while len(pending) >= inflight:
                    yield from self._finalize_tick(pending.popleft())
            elif pending:
                # nothing admissible right now: drain a tick (its verdicts
                # may quarantine or re-route streams) before advancing
                yield from self._finalize_tick(pending.popleft())
            elif not quarantined:
                break
            tick += 1
        while pending:
            yield from self._finalize_tick(pending.popleft())
        self._drain_poisoned(live, quarantined, tick)

    def _drain_poisoned(self, live: List[int], quarantined: Dict[int, int],
                        tick: int) -> List[int]:
        """Move streams flagged by finalized ticks out of admission: into
        quarantine for ``plan.quarantine_ticks`` ticks, or permanent
        retirement when that knob is 0."""
        eng = self.engine
        q = eng.plan.quarantine_ticks
        moved = []
        for s in self._poisoned:
            if s in live:
                live.remove(s)
                moved.append(s)
                if q > 0:
                    quarantined[s] = tick + q
                    eng.guard.record(
                        tick, "quarantine",
                        f"stream {s} quarantined for {q} tick(s) after "
                        f"poison verdict")
                else:
                    eng.guard.record(
                        tick, "retire",
                        f"stream {s} retired after poison verdict "
                        f"(quarantine_ticks=0)")
        self._poisoned = []
        return moved

    # -- one tick ------------------------------------------------------------

    def _launch_tick(self, live: Sequence[int], frames: List[jax.Array]
                     ) -> dict:
        """Dispatch one admission tick WITHOUT blocking (the tick analog of
        the engine's ``_launch_fused``)."""
        eng = self.engine
        p = eng.plan
        t0 = time.perf_counter()
        shape = tuple(frames[0].shape)
        for s, f in zip(live, frames):
            if tuple(f.shape) != shape:
                raise ValueError(
                    f"stream {s} frame shape {tuple(f.shape)} != {shape}: "
                    f"one admission tick packs one geometry; serve "
                    f"same-shaped streams together")
        geom = p.geometry(shape[0], shape[1], eng.cfg.scale)
        quotas_all = self.bank.tick_quotas()
        quotas = tuple(quotas_all[s] for s in live)
        thresholds = tuple(self.bank.switchers[s].thresholds for s in live)
        batch = jnp.stack(frames)
        caps = self._caps_for_tick(geom, p, batch, thresholds, quotas)
        t1s = jnp.asarray([t[0] for t in thresholds], jnp.float32)
        t2s = jnp.asarray([t[1] for t in thresholds], jnp.float32)
        quotas_t = jnp.asarray(quotas, jnp.int32)
        index = eng._next_index()
        if eng.injector is not None:
            eng.injector.maybe_delay(index)

        def attempt(v):
            if eng.injector is not None:
                eng.injector.maybe_fail_launch(index)
            fn = fused_stream_frame_fn(geom, len(live), caps, eng.cfg,
                                       v.backend, v.interpret, eng.mesh,
                                       eng.qpack if v.quant else None,
                                       v.fusion, p.on_poison)
            return fn(eng.params, batch, t1s, t2s, quotas_t)

        outs, steps = eng.guard.run(attempt, index)
        v = eng.guard.variant
        compiled = eng._mark_warm(("mux", geom.cache_key, len(live), caps,
                                   v.backend, v.interpret, v.quant,
                                   v.fusion, p.on_poison))
        return {"outs": outs, "geom": geom, "plan": p, "live": tuple(live),
                "t0": t0, "compiled": compiled, "variant": v,
                "steps": steps, "index": index}

    def _caps_for_tick(self, geom, p, batch, thresholds, quotas
                       ) -> Tuple[int, ...]:
        """Aggregate capacity profile for one tick. ``plan.capacity`` pins
        the PER-STREAM profile (scaled by the live count — the knob should
        not need to know how many tenants are up); otherwise the first tick
        of a (geometry, live-count) is probed on the host and the profile
        cached in the engine's fused-caps map, grown after spills like the
        solo path. The C54 entry is clamped per call to the sum of the live
        streams' quotas — the aggregate hard ceiling the in-graph per-stream
        quotas already enforce, so the clamp never adds spills, it only
        keeps the compiled pool from outgrowing the budget."""
        eng = self.engine
        n_live = len(quotas)
        widths = eng.cfg.subnet_widths()
        if p.capacity is not None:
            if len(p.capacity) != len(widths):
                raise ValueError(
                    f"plan.capacity {p.capacity} must have one entry per "
                    f"subnet width {widths}")
            return tuple(int(c) * n_live for c in p.capacity)
        key = ("mux", geom.cache_key, n_live)
        caps = eng._fused_caps.get(key)
        if caps is None:
            # the one host routing sync multiplexed serving ever pays, per
            # (geometry, live-count): probe aggregate demand under each
            # stream's live thresholds
            patches = jax.vmap(geom.extract)(batch)
            flat = patches.reshape((-1,) + patches.shape[2:])
            scores = np.asarray(edge_score(flat)).reshape(n_live, geom.n)
            agg = np.zeros(len(widths), np.int64)
            for i, (t1, t2) in enumerate(thresholds):
                agg += np.asarray(
                    sp.subnet_counts(sp.decide(scores[i], t1, t2)))
            caps = self._snap(agg, geom, p, n_live)
            eng._fused_caps[key] = caps
        return caps[:-1] + (min(caps[-1], int(sum(quotas))),)

    def _snap(self, desired, geom, p, n_live: int) -> Tuple[int, ...]:
        """Aggregate desired counts -> pool profile: bilinear lane dense
        (entry 0), conv entries snapped to the bucket ladder, clamped to the
        tick's total patch count."""
        return tuple([0] + [snap_capacity(int(d), p.buckets,
                                          n_live * geom.n)
                            for d in desired[1:]])

    def _grow(self, key, p, geom, n_live: int, counts_agg, spills_agg
              ) -> None:
        """Grow-only aggregate capacity growth after a tick that spilled,
        mirroring the engine's ``_grow_caps`` (quota demotions register as
        C54 spills but the per-call quota clamp keeps the served C54 entry
        at the budget, so growth there never churns recompiles)."""
        if p.capacity is not None or not any(spills_agg[1:]):
            return
        old = self.engine._fused_caps.get(key)
        if old is None:
            return
        desired = [c + s for c, s in zip(counts_agg, spills_agg)]
        new = self._snap(desired, geom, p, n_live)
        merged = tuple(max(o, n) for o, n in zip(old, new))
        if merged != old:
            self.engine._fused_caps[key] = merged

    def _finalize_tick(self, rec: dict) -> List[FrameResult]:
        """Block on one in-flight tick, split its outputs per stream, and
        run the deferred host-side control: per-stream Algorithm-1 trim from
        the materialized counts, share-weighted overload attribution on a
        missed tick deadline, and aggregate capacity growth after spill."""
        eng = self.engine
        images, eff, scores, counts, spills, health = rec["outs"]
        images.block_until_ready()
        done = time.perf_counter()
        # marginal tick time, same clock as the engine's fused stream: under
        # inflight >= 2 a tick's launch-to-ready wall time includes earlier
        # in-flight ticks' device time
        dt = done - max(rec["t0"], eng._fused_last_done)
        eng._fused_last_done = done
        live, geom, p = rec["live"], rec["geom"], rec["plan"]
        n = geom.n
        counts_np = np.asarray(counts)           # (live, n_subnets)
        spills_np = np.asarray(spills)
        health_np = (np.asarray(health) if p.on_poison != "off" else None)
        steps = rec["steps"]
        if p.watchdog_s is not None and dt > p.watchdog_s:
            steps = steps + eng.guard.note_watchdog(rec["index"], dt,
                                                    p.watchdog_s)
        self._grow(("mux", geom.cache_key, len(live)), p, geom, len(live),
                   counts_np.sum(0).tolist(), spills_np.sum(0).tolist())
        macs = (eng._macs if p.patch == eng.plan.patch
                else sp.SubnetMacs.make(eng.cfg, p.patch))
        # a poisoned frame under "raise" routes on garbage scores; keep its
        # controller state frozen while it heads into quarantine
        quarantining = set()
        if health_np is not None and p.on_poison == "raise":
            quarantining = {s for i, s in enumerate(live)
                            if health_np[i].any()}
        # per-stream trim first (each controller sees its own frame), then
        # the shared-deadline attribution on top — the same order as the
        # solo streaming path (observe_frame, then straggler demotion)
        for i, s in enumerate(live):
            if s not in quarantining:
                self.bank.observe(s, int(counts_np[i][sp.C54]))
        missed = bool(eng.deadline_s and dt > eng.deadline_s)
        costs = [float(macs.total(tuple(int(c) for c in counts_np[i])))
                 for i in range(len(live))]
        demoted = self.bank.note_tick(missed, costs, streams=live)
        results: List[FrameResult] = []
        for i, s in enumerate(live):
            health_t = (tuple(int(x) for x in health_np[i])
                        if health_np is not None else None)
            poisoned = health_t is not None and any(health_t)
            if poisoned:
                eng.guard.record(
                    rec["index"], "poison",
                    f"stream {s} frame failed health verdict "
                    f"(nan={health_t[0]}, inf={health_t[1]}, "
                    f"oob={health_t[2]})")
                if p.on_poison == "raise":
                    # the per-tenant analog of the solo raise: suppress this
                    # stream's output for the tick and hand it to serve()'s
                    # quarantine bookkeeping; every other tenant's results
                    # stand untouched
                    self._poisoned.append(s)
                    continue
            counts_t = tuple(int(c) for c in counts_np[i])
            out = FrameResult(
                image=images[i], mode="edge_select",
                backend=eng._variant_label(p, rec["variant"]),
                # per-stream slices of the flat (stream-major) telemetry;
                # kept as lazy device arrays like the solo fused path
                ids=eff[i * n:(i + 1) * n],
                scores=scores[i * n:(i + 1) * n],
                counts=counts_t, mac_saving=macs.saving_vs_c54(counts_t),
                latency_s=dt,
                thresholds=self.bank.switchers[s].thresholds,
                deadline_missed=bool(demoted[s]),
                dispatch="fused",
                spill_counts=tuple(int(x) for x in spills_np[i]),
                compiled=rec["compiled"], shards=eng.plan.shards,
                stream_id=s, health=health_t, degraded=steps)
            eng.stats.append(dataclasses.replace(out, image=None,
                                                 ids=None, scores=None))
            results.append(out)
        return results
