"""Fault-tolerant training supervisor + straggler handling.

Design for 1000+ nodes, exercised here at simulation scale:

  * checkpoint/restart — periodic async checkpoints; any step exception
    (injected in tests; a real fleet surfaces NaN-loss, device loss, or a
    heartbeat timeout the same way) triggers restore-from-latest and replay;
  * elastic scaling   — on restore the supervisor may be handed a *different*
    mesh/sharding set (fewer data shards after losing hosts); checkpoints are
    sharding-agnostic so resume is transparent;
  * straggler policy  — per-shard step-time EMA; shards slower than
    ``k x median`` get demoted work (the paper's own resource-adaptive
    mechanism — AdaptiveSwitcher.demote_for_straggler — doubles as the SR
    serving-side mitigation; for training we flag for re-balancing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import numpy as np

from repro.ckpt.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_every: int = 20
    max_restarts: int = 8
    async_ckpt: bool = True


class TrainSupervisor:
    """Runs ``step_fn(state, batch) -> (state, metrics)`` with checkpointing,
    failure recovery and deterministic replay.

    ``state`` must be a pytree; ``make_batch(step)`` must be deterministic in
    ``step`` so that replay after restore is bit-identical (tested)."""

    def __init__(self, step_fn: Callable, make_batch: Callable[[int], Any],
                 ckpt: CheckpointManager, cfg: SupervisorConfig = SupervisorConfig()):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.ckpt = ckpt
        self.cfg = cfg
        self.restarts = 0
        self.failures: List[str] = []

    def run(self, state: Any, start_step: int, n_steps: int,
            failure_hook: Optional[Callable[[int], None]] = None,
            reshard: Optional[Callable[[Any], Any]] = None) -> Any:
        step = start_step
        end = start_step + n_steps
        while step < end:
            try:
                if failure_hook is not None:
                    failure_hook(step)      # may raise InjectedFailure
                state, _ = self.step_fn(state, self.make_batch(step))
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step, state, meta={"step": step},
                                   blocking=not self.cfg.async_ckpt)
            except InjectedFailure as e:
                self.restarts += 1
                self.failures.append(f"step {step}: {e}")
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:          # crashed before first checkpoint
                    raise
                state, meta = self.ckpt.restore(state)
                step = int(meta["step"])
                if reshard is not None:     # elastic resize after host loss
                    state = reshard(state)
        self.ckpt.wait()
        self.ckpt.save(step, state, meta={"step": step}, blocking=True)
        return state


class StragglerMonitor:
    """Per-shard step-time EMA; flags shards slower than k x median."""

    def __init__(self, n_shards: int, k: float = 1.5, decay: float = 0.8):
        self.t = np.zeros(n_shards)
        self.k, self.decay = k, decay
        self._init = np.zeros(n_shards, dtype=bool)

    def record(self, shard: int, dt: float) -> None:
        if not self._init[shard]:
            self.t[shard], self._init[shard] = dt, True
        else:
            self.t[shard] = self.decay * self.t[shard] + (1 - self.decay) * dt

    def stragglers(self) -> np.ndarray:
        if not self._init.any():
            return np.zeros(0, dtype=int)
        med = np.median(self.t[self._init])
        return np.flatnonzero(self._init & (self.t > self.k * med))
