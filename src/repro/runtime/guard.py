"""Serving-side resilience: fault injection, poison quarantine, degradation.

The serving stack adapts to *load* (Algorithm-1 switching, capacity spill
cascades) but, before this module, not to *faults*: a NaN pixel, a tenant
iterator that raises mid-tick, or a corrupted QuantPack cache killed the
whole `SREngine`.  Real-time SR parts (ACNPU, the Tilted-Layer-Fusion
accelerator) are 30FPS video pipelines where a garbage frame must degrade,
never crash.  Three pieces live here:

* `FaultPlan` / `FaultInjector` — a **deterministic, seeded** chaos harness.
  Every injection decision is a pure function of
  ``sha256(f"{seed}:{kind}:{stream}:{index}")``, so two runs with the same
  plan inject the identical fault sequence regardless of timing, and the
  degradation ledger can be asserted bit-for-bit in CI.
* `ResilienceGuard` — the **degradation ladder**.  From the configured
  serving point it precomputes the deterministic step-down order
  (fusion ``group→layer``, backend ``pallas→interpret→ref``, quant
  ``int8/fxp10→fp32``); on a failed launch it steps down (or retries at the
  floor) up to ``plan.max_retries`` times, recording every step.  The
  ladder is *sticky*: later frames serve at the degraded level.
* Typed faults — `PoisonFrameError` (a frame failed its health verdict
  under ``plan.on_poison="raise"``) and the injected-fault family the
  harness raises.

Engine/multiplexer integration, the in-graph health verdicts themselves
(`core.pipeline.frame_health` / the 6th fused output) and the per-tenant
quarantine loop live in `api/engine.py`, `core/pipeline.py` and
`runtime/multiplex.py`; everything is configured through validated
`ExecutionPlan` fields (``faults``, ``on_poison``, ``max_retries``,
``quarantine_ticks``, ``watchdog_s``) — no free-function entry points.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "FaultPlan",
    "FaultInjector",
    "InjectedFault",
    "InjectedBackendFailure",
    "InjectedStreamError",
    "PoisonFrameError",
    "LadderVariant",
    "ResilienceGuard",
    "build_ladder",
    "POISON_KINDS",
]

POISON_KINDS = ("nan", "inf", "range", "dtype")


class PoisonFrameError(RuntimeError):
    """A frame failed its health verdict under ``plan.on_poison="raise"``.

    ``health`` carries the ``(nan, inf, out_of_range)`` pixel counts when the
    verdict came from the in-graph check (None for host-side dtype rejects).
    """

    def __init__(self, msg: str, health: Optional[Tuple[int, int, int]] = None):
        super().__init__(msg)
        self.health = health


class InjectedFault(RuntimeError):
    """Base class for faults raised by the `FaultInjector` harness."""


class InjectedBackendFailure(InjectedFault):
    """Simulated backend/kernel launch failure (chaos harness)."""


class InjectedStreamError(InjectedFault):
    """Simulated tenant-iterator exception (chaos harness)."""


def _check(field_name: str, ok: bool, got, allowed: str) -> None:
    if not ok:
        raise ValueError(f"FaultPlan.{field_name}={got!r}: allowed {allowed}")


@dataclass(frozen=True)
class FaultPlan:
    """Declarative, seeded chaos schedule — attach via ``ExecutionPlan.faults``.

    All rates are per-event probabilities in [0, 1]; decisions are derived
    from ``seed`` alone (see `FaultInjector`), never from wall-clock or RNG
    state, so identical plans replay identical fault sequences.
    """

    seed: int = 0
    # probability that a given (stream, frame) gets its pixels poisoned
    poison_rate: float = 0.0
    # which corruptions to draw from: nan / inf / range (1e6 pixels) / dtype
    poison_kinds: Tuple[str, ...] = ("nan",)
    # probability that a given stream frame raises from the tenant iterator
    iterator_error_rate: float = 0.0
    # probability a launch index raises InjectedBackendFailure (once per index)
    backend_failure_rate: float = 0.0
    # probability / duration of an injected delay before a launch (for
    # exercising plan.watchdog_s; excluded from determinism assertions)
    delay_rate: float = 0.0
    delay_s: float = 0.0
    # restrict stream-level faults to these stream ids (None = all streams)
    target_streams: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        _check("seed", isinstance(self.seed, int) and not isinstance(self.seed, bool),
               self.seed, "an int")
        for name in ("poison_rate", "iterator_error_rate", "backend_failure_rate",
                     "delay_rate"):
            v = getattr(self, name)
            _check(name, isinstance(v, (int, float)) and not isinstance(v, bool)
                   and 0.0 <= float(v) <= 1.0, v, "a number in [0, 1]")
        _check("delay_s", isinstance(self.delay_s, (int, float))
               and not isinstance(self.delay_s, bool) and float(self.delay_s) >= 0.0,
               self.delay_s, "a number >= 0")
        object.__setattr__(self, "poison_kinds", tuple(self.poison_kinds))
        _check("poison_kinds", bool(self.poison_kinds)
               and all(k in POISON_KINDS for k in self.poison_kinds),
               self.poison_kinds, f"a non-empty subset of {POISON_KINDS}")
        if self.target_streams is not None:
            object.__setattr__(self, "target_streams", tuple(self.target_streams))
            _check("target_streams",
                   all(isinstance(s, int) and not isinstance(s, bool) and s >= 0
                       for s in self.target_streams),
                   self.target_streams, "None or a tuple of stream ids >= 0")


class FaultInjector:
    """Deterministic fault harness driven by a `FaultPlan`.

    Every decision is a coin ``sha256(f"{seed}:{kind}:{stream}:{index}")``
    mapped to [0, 1) — order-independent and replayable.  Backend failures
    fire **at most once per launch index** (the injector remembers indices it
    already failed), so a guarded retry at the degraded ladder level succeeds
    and the recorded degradation sequence is deterministic.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._failed_launches: set = set()

    def _coin(self, kind: str, stream: int, index: int) -> float:
        key = f"{self.plan.seed}:{kind}:{stream}:{index}".encode()
        return int.from_bytes(hashlib.sha256(key).digest()[:8], "big") / 2.0 ** 64

    def _targets(self, stream: int) -> bool:
        t = self.plan.target_streams
        return t is None or stream in t

    # -- pixel poison -----------------------------------------------------
    def poison_frame(self, frame, stream: int, index: int):
        """Corrupt ``frame`` deterministically; returns a numpy array."""
        kinds = self.plan.poison_kinds
        kind = kinds[int(self._coin("poison-kind", stream, index) * len(kinds))
                     % len(kinds)]
        arr = np.array(frame, dtype=np.float32, copy=True)
        if kind == "dtype":
            return (np.clip(arr, 0.0, 1.0) * 255.0).astype(np.uint8)
        h = max(1, arr.shape[0] // 8)
        w = max(1, arr.shape[1] // 8) if arr.ndim > 1 else 1
        y = int(self._coin("poison-y", stream, index) * max(1, arr.shape[0] - h))
        x = int(self._coin("poison-x", stream, index) * max(1, arr.shape[1] - w))
        val = {"nan": np.nan, "inf": np.inf, "range": 1.0e6}[kind]
        arr[y:y + h, x:x + w] = val
        return arr

    def wrap_stream(self, stream: int, frames: Iterable) -> Iterator:
        """Wrap a tenant iterator with seeded poison / iterator-error faults."""
        for index, frame in enumerate(frames):
            if self._targets(stream):
                if self._coin("iter-error", stream, index) < self.plan.iterator_error_rate:
                    raise InjectedStreamError(
                        f"injected iterator error (stream {stream}, frame {index})")
                if self._coin("poison", stream, index) < self.plan.poison_rate:
                    frame = self.poison_frame(frame, stream, index)
            yield frame

    # -- launch-level faults ----------------------------------------------
    def maybe_fail_launch(self, index: int) -> None:
        """Raise `InjectedBackendFailure` for this launch index, once ever."""
        if index in self._failed_launches:
            return
        if self._coin("backend", 0, index) < self.plan.backend_failure_rate:
            self._failed_launches.add(index)
            raise InjectedBackendFailure(
                f"injected backend failure (launch {index})")

    def maybe_delay(self, index: int) -> None:
        """Sleep ``delay_s`` before this launch (exercises the watchdog)."""
        if self.plan.delay_s > 0.0 and \
                self._coin("delay", 0, index) < self.plan.delay_rate:
            time.sleep(self.plan.delay_s)

    # -- payload corruption (for cache-robustness tests) -------------------
    @staticmethod
    def corrupt_file(path: str) -> None:
        """Overwrite a cache/checkpoint payload with garbage bytes."""
        with open(path, "wb") as f:
            f.write(b'{"mode": "int8", "scales": [NOT JSON')


@dataclass(frozen=True)
class LadderVariant:
    """One rung of the degradation ladder: a complete serving variant."""

    backend: str
    interpret: Optional[bool]
    quant: bool          # serve the calibrated QuantPack (False = fp32)
    fusion: str
    step: str = ""       # the step label that produced this rung ("" = as planned)


def build_ladder(backend: str, interpret: Optional[bool], quant_on: bool,
                 fusion: str) -> Tuple[LadderVariant, ...]:
    """Deterministic step-down order from the configured serving point.

    Order (each step only present when it changes something):
    fusion ``group→layer``, backend ``pallas→interpret``, backend
    ``→ref``, quant ``int8/fxp10→fp32``.  The last rung is always the
    ref/fp32/layer floor; a failure there (retried up to
    ``plan.max_retries`` total attempts) propagates to the caller.
    """
    rungs = [LadderVariant(backend, interpret, quant_on, fusion)]

    def push(step, **delta):
        prev = rungs[-1]
        nxt = LadderVariant(
            backend=delta.get("backend", prev.backend),
            interpret=delta.get("interpret", prev.interpret),
            quant=delta.get("quant", prev.quant),
            fusion=delta.get("fusion", prev.fusion),
            step=step,
        )
        if (nxt.backend, nxt.interpret, nxt.quant, nxt.fusion) != \
                (prev.backend, prev.interpret, prev.quant, prev.fusion):
            rungs.append(nxt)

    if fusion == "group":
        push("fusion:group->layer", fusion="layer")
    if backend == "pallas" and interpret is not True:
        push("backend:pallas->interpret", interpret=True)
    if backend != "ref":
        push("backend:->ref", backend="ref", interpret=None)
    if quant_on:
        push("quant:->fp32", quant=False)
    return tuple(rungs)


class ResilienceGuard:
    """Sticky degradation ladder + the serving-side event ledger.

    ``run(attempt, index)`` calls ``attempt(variant)`` at the current rung;
    on any exception other than `PoisonFrameError` it steps down (or, at the
    floor, retries in place) and records the event, up to ``max_retries``
    extra attempts per call.  All quarantine/retire/poison/watchdog events
    funnel through ``record`` so ``SREngine.summary()["degradations"]`` is
    one deterministic ledger.
    """

    def __init__(self, backend: str, interpret: Optional[bool], quant_on: bool,
                 fusion: str, max_retries: int = 2):
        self.ladder = build_ladder(backend, interpret, quant_on, fusion)
        self.level = 0
        self.max_retries = max_retries
        self.events: List[Dict[str, Any]] = []

    @property
    def variant(self) -> LadderVariant:
        return self.ladder[self.level]

    def record(self, index, kind: str, reason: str) -> None:
        self.events.append({"index": index, "kind": kind, "reason": reason})

    def run(self, attempt: Callable[[LadderVariant], Any], index) -> Tuple[Any, Tuple[str, ...]]:
        """Execute ``attempt`` under the ladder; returns (result, new steps)."""
        steps: List[str] = []
        tries = 0
        while True:
            try:
                return attempt(self.ladder[self.level]), tuple(steps)
            except PoisonFrameError:
                raise                      # policy verdicts are not launch failures
            except Exception as e:
                tries += 1
                if tries > self.max_retries:
                    self.record(index, "failure",
                                f"ladder exhausted after {tries} attempts: {e!r}")
                    raise
                if self.level + 1 < len(self.ladder):
                    self.level += 1
                    step = self.ladder[self.level].step
                else:
                    step = "retry"         # already at the ref/fp32/layer floor
                steps.append(step)
                self.record(index, "degrade", f"{step}: {e!r}")

    def note_watchdog(self, index, dt: float, limit: float) -> Tuple[str, ...]:
        """An admission tick exceeded ``plan.watchdog_s``: step the ladder."""
        if self.level + 1 < len(self.ladder):
            self.level += 1
            step = self.ladder[self.level].step
        else:
            step = "floor"
        self.record(index, "watchdog",
                    f"{step}: tick took {dt:.4f}s > watchdog_s={limit}")
        return (step,) if step != "floor" else ()

    def summary(self) -> Dict[str, Any]:
        """Deterministic ledger for ``SREngine.summary()["degradations"]``."""
        by_kind: Dict[str, int] = {}
        by_step: Dict[str, int] = {}
        for e in self.events:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
            if e["kind"] in ("degrade", "watchdog"):
                step = e["reason"].split(":", 1)[0]
                by_step[step] = by_step.get(step, 0) + 1
        return {
            "total": len(self.events),
            "by_kind": by_kind,
            "by_step": by_step,
            "level": self.level,
            "variant": self.variant.step or "as-planned",
            "events": list(self.events[-32:]),
        }
