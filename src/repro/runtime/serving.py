"""Retired serving shim — frame serving lives on `repro.api.SREngine`.

    from repro.api import SREngine, ExecutionPlan
    engine = SREngine(params, cfg, plan=ExecutionPlan(), switching=sw)
    for result in engine.stream(frames): ...          # one tenant
    for result in engine.serve_streams(iterables):    # N tenants, one fused
        ...                                           # dispatch per tick
        # (plan=ExecutionPlan(streams=N, dispatch="fused"))

`FrameServer` spent one release as a DeprecationWarning wrapper over
`SREngine`; it is now a raising alias so stale call sites fail loudly with
the migration path instead of silently forking serving behavior.
"""
from __future__ import annotations


def FrameServer(*args, **kwargs):
    raise RuntimeError(
        "runtime.serving.FrameServer was removed: construct repro.api.SREngine "
        "and use engine.stream(frames) for one tenant, or "
        "engine.serve_streams(iterables) with ExecutionPlan(streams=N, "
        "dispatch='fused') for multi-tenant serving (see docs/api.md "
        "'Multi-stream serving')")
