"""SR frame-serving runtime (the paper's deployment: 8K@30FPS, x4).

frame stream -> AdaptiveSwitcher (Algorithm 1) -> edge-selective SR ->
fused frame. Tracks the quantities the paper's hardware section reports:
per-subnet patch counts and cycle shares, MAC savings, deadline behaviour.

Straggler mitigation: if a frame exceeds its deadline budget, the switcher's
thresholds rise (demote future patches) — the paper's resource-adaptive
mechanism used as a runtime control loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional

import numpy as np
import jax

from repro.core.adaptive import AdaptiveSwitcher, SwitchingConfig
from repro.core.pipeline import edge_selective_sr
from repro.core import subnet_policy as sp
from repro.models.essr import ESSRConfig


@dataclasses.dataclass
class FrameStats:
    counts: tuple
    mac_saving: float
    latency_s: float
    thresholds: tuple
    deadline_missed: bool


class FrameServer:
    def __init__(self, params, cfg: ESSRConfig,
                 switching: SwitchingConfig = SwitchingConfig(),
                 patch: int = 32, overlap: int = 2,
                 deadline_s: Optional[float] = None):
        self.params = params
        self.cfg = cfg
        self.switcher = AdaptiveSwitcher(switching)
        self.patch, self.overlap = patch, overlap
        self.deadline_s = deadline_s
        self.stats: List[FrameStats] = []

    def serve_frame(self, frame) -> Any:
        from repro.core.patching import extract_patches
        from repro.core.edge_score import edge_score

        t0 = time.perf_counter()
        patches, _ = extract_patches(frame, self.patch, self.overlap)
        scores = np.asarray(edge_score(patches))
        ids = self.switcher.assign(scores)
        res = edge_selective_sr(self.params, frame, self.cfg,
                                patch=self.patch, overlap=self.overlap,
                                ids_override=ids)
        res.image.block_until_ready()
        dt = time.perf_counter() - t0
        missed = bool(self.deadline_s and dt > self.deadline_s)
        if missed:
            self.switcher.demote_for_straggler(severity=1.0)
        self.stats.append(FrameStats(res.counts, res.mac_saving, dt,
                                     self.switcher.thresholds, missed))
        return res.image

    def summary(self) -> Dict[str, Any]:
        if not self.stats:
            return {}
        counts = np.array([s.counts for s in self.stats])
        total = counts.sum()
        return {
            "frames": len(self.stats),
            "subnet_share": dict(zip(sp.SUBNET_NAMES, (counts.sum(0) / max(total, 1)).round(4).tolist())),
            "mean_mac_saving": float(np.mean([s.mac_saving for s in self.stats])),
            "mean_latency_s": float(np.mean([s.latency_s for s in self.stats])),
            "deadline_misses": int(sum(s.deadline_missed for s in self.stats)),
            "final_thresholds": self.stats[-1].thresholds,
        }
