"""SR frame-serving runtime — DEPRECATED shim over `repro.api.SREngine`.

The serving loop (frame stream -> AdaptiveSwitcher (Algorithm 1) ->
edge-selective SR -> fused frame, with deadline/straggler handling) now
lives in ``SREngine.stream`` / ``SREngine.serve``. `FrameServer` remains as
a thin compatibility wrapper so existing call sites keep working; new code
should construct an `SREngine` directly:

    from repro.api import SREngine, ExecutionPlan
    engine = SREngine(params, cfg, plan=ExecutionPlan(), switching=sw)
    for result in engine.stream(frames): ...
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional

from repro.api.engine import SREngine
from repro.api.plan import ExecutionPlan
from repro.api.result import summarize_stats
from repro.core.adaptive import AdaptiveSwitcher, SwitchingConfig
from repro.models.essr import ESSRConfig


@dataclasses.dataclass
class FrameStats:
    counts: tuple
    mac_saving: float
    latency_s: float
    thresholds: tuple
    deadline_missed: bool


class FrameServer:
    """Deprecated: use ``repro.api.SREngine`` (see module docstring)."""

    def __init__(self, params, cfg: ESSRConfig,
                 switching: Optional[SwitchingConfig] = None,
                 patch: int = 32, overlap: int = 2,
                 deadline_s: Optional[float] = None, shards: int = 1):
        warnings.warn(
            "FrameServer is deprecated; use repro.api.SREngine.stream()",
            DeprecationWarning, stacklevel=2)
        self.engine = SREngine(params, cfg,
                               plan=ExecutionPlan(patch=patch, overlap=overlap,
                                                  shards=shards),
                               switching=switching, deadline_s=deadline_s)
        self._stats: List[FrameStats] = []       # incremental mirror
        self._mirrored = 0                       # engine records consumed

    # old attribute surface, delegated ---------------------------------------

    @property
    def params(self):
        return self.engine.params

    @property
    def cfg(self) -> ESSRConfig:
        return self.engine.cfg

    @property
    def switcher(self) -> AdaptiveSwitcher:
        return self.engine.switcher

    @property
    def deadline_s(self) -> Optional[float]:
        return self.engine.deadline_s

    @property
    def patch(self) -> int:
        return self.engine.plan.patch

    @property
    def overlap(self) -> int:
        return self.engine.plan.overlap

    @property
    def stats(self) -> List[FrameStats]:
        # engine.stats is a bounded deque now (plan.stats_window); mirror by
        # the engine's monotone append counter, not by deque length — once
        # the deque rotates at its maxlen, length stops moving while records
        # keep arriving. Frames that rotated out between refreshes are gone
        # (serve_frame refreshes eagerly, so that needs a window-sized gap).
        fresh = self.engine.stats_total - self._mirrored
        new = list(self.engine.stats)[-fresh:] if fresh > 0 else []
        self._mirrored = self.engine.stats_total
        self._stats.extend(FrameStats(r.counts, r.mac_saving, r.latency_s,
                                      r.thresholds, r.deadline_missed)
                           for r in new)
        return self._stats

    @stats.setter
    def stats(self, value: List[FrameStats]) -> None:
        # old code allowed `server.stats = []` to reset a stats window
        self._stats = value if isinstance(value, list) else list(value)
        self._mirrored = self.engine.stats_total

    def serve_frame(self, frame) -> Any:
        image = self.engine.serve(frame).image
        _ = self.stats      # eager refresh: held references see the append,
        return image        # matching the old in-place list semantics

    def summary(self) -> Dict[str, Any]:
        # computed from self.stats (not engine.summary()) so old reset
        # patterns (`server.stats = []`) window the aggregate as before,
        # and without the post-SREngine "backend" key
        return summarize_stats(self.stats)
