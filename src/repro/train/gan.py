"""Perceptual-oriented (GAN) training phase — paper Sec. V-A.

Starts from the trained PSNR model; generator loss =
0.01*L1 + 1*artifact(LDL) + 1*perceptual + 0.005*adversarial, Adam 1e-4
MultiStepLR. A compact patch discriminator stands in for [24]'s.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.essr import ESSRConfig, essr_forward
from repro.train import losses as Ls
from repro.train import optimizer as O


def init_discriminator(key, channels=(32, 64, 64, 128)) -> Dict[str, Any]:
    ps, cin = [], 3
    for c in channels:
        key, k = jax.random.split(key)
        ps.append({"w": L.conv_init(k, (3, 3, cin, c)), "b": jnp.zeros(c)})
        cin = c
    key, k = jax.random.split(key)
    return {"convs": ps, "head": {"w": L.conv_init(k, (3, 3, cin, 1)), "b": jnp.zeros(1)}}


def discriminate(params, x: jax.Array) -> jax.Array:
    h = x
    for p in params["convs"]:
        h = jax.nn.leaky_relu(L.conv2d(h, p["w"], p["b"], stride=2), 0.2)
    return L.conv2d(h, params["head"]["w"], params["head"]["b"]).mean(axis=(1, 2, 3))


def make_gan_steps(cfg: ESSRConfig, g_opt: O.Optimizer, d_opt: O.Optimizer,
                   feat_params, weights=Ls.PERCEPTUAL_WEIGHTS):
    def g_loss(params, d_params, lr_img, hr_img, width: int):
        sr = essr_forward(params, lr_img, cfg, width=width)
        adv = Ls.g_adv_loss_fn(discriminate(d_params, sr))
        total = (weights["l1"] * Ls.l1_loss(sr, hr_img)
                 + weights["artifact"] * Ls.artifact_loss(sr, hr_img)
                 + weights["perceptual"] * Ls.perceptual_loss(feat_params, sr, hr_img)
                 + weights["adv"] * adv)
        return total, sr

    def d_loss(d_params, sr, hr_img):
        return Ls.d_loss_fn(discriminate(d_params, hr_img),
                            discriminate(d_params, jax.lax.stop_gradient(sr)))

    def g_step(params, g_state, d_params, lr_img, hr_img, *, width: int):
        (val, sr), grads = jax.value_and_grad(g_loss, has_aux=True)(
            params, d_params, lr_img, hr_img, width)
        upd, g_state = g_opt.update(grads, g_state, params)
        return O.apply_updates(params, upd), g_state, sr, val

    def d_step(d_params, d_state, sr, hr_img):
        val, grads = jax.value_and_grad(d_loss)(d_params, sr, hr_img)
        upd, d_state = d_opt.update(grads, d_state, d_params)
        return O.apply_updates(d_params, upd), d_state, val

    return (jax.jit(g_step, static_argnames=("width",)), jax.jit(d_step))


def train_essr_gan(params, cfg: ESSRConfig, data: Iterator, steps: int,
                   seed: int = 0, log_every: int = 50, log_fn=print):
    """Full perceptual phase driver (scaled-down schedule on CPU)."""
    key = jax.random.PRNGKey(seed)
    d_params = init_discriminator(key)
    feat_params = Ls.init_feature_net(jax.random.PRNGKey(7))
    g_opt = O.adam(O.multistep(1e-4, [steps // 2, 3 * steps // 4]))
    d_opt = O.adam(O.multistep(1e-4, [steps // 2, 3 * steps // 4]))
    g_state, d_state = g_opt.init(params), d_opt.init(d_params)
    g_step, d_step = make_gan_steps(cfg, g_opt, d_opt, feat_params)
    rng = np.random.default_rng(seed)
    from repro.core.supernet import subnet_sampling_probs
    widths = [w for w in cfg.subnet_widths() if w > 0]
    probs = subnet_sampling_probs(cfg)
    hist = []
    for i in range(steps):
        lr_img, hr_img = next(data)
        width = int(rng.choice(widths, p=probs))
        params, g_state, sr, gl = g_step(params, g_state, d_params, lr_img, hr_img,
                                         width=width)
        d_params, d_state, dl = d_step(d_params, d_state, sr, hr_img)
        hist.append((float(gl), float(dl)))
        if log_every and (i + 1) % log_every == 0:
            g_m = np.mean([h[0] for h in hist[-log_every:]])
            d_m = np.mean([h[1] for h in hist[-log_every:]])
            log_fn(f"gan step {i+1:5d}  G {g_m:.4f}  D {d_m:.4f}")
    return params, d_params, hist
