"""Hand-written optimizers (no optax in the container).

optax-compatible surface: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``. Provided: sgd, adam, adamw, lamb (paper's PSNR phase),
adafactor (for the 100B+ dry-run configs' optimizer-state math), schedules
(cosine / multistep / warmup), global-norm clipping, gradient accumulation.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]
tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, total_steps: int, final_scale: float = 0.0,
                 warmup: int = 0) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1.0, warmup)) if warmup else 1.0
        t = jnp.clip((step - warmup) / jnp.maximum(1.0, total_steps - warmup), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * warm * (final_scale + (1 - final_scale) * cos)
    return fn


def multistep(lr: float, milestones: Sequence[int], gamma: float = 0.5) -> Schedule:
    ms = jnp.asarray(list(milestones), jnp.float32)
    def fn(step):
        k = jnp.sum(jnp.asarray(step, jnp.float32)[None] >= ms)
        return lr * gamma ** k
    return fn


# ---------------------------------------------------------------------------
# optimizer core
# ---------------------------------------------------------------------------

class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params, updates):
    return tmap(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return tmap(lambda x: x * scale, grads), g


def sgd(lr: Schedule | float, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        mom = tmap(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(step)
        if momentum:
            mom = tmap(lambda m, g: momentum * m + g, state["mom"], grads)
            upd = tmap(lambda m: -lr_t * m, mom)
            return upd, {"step": step, "mom": mom}
        return tmap(lambda g: -lr_t * g, grads), {"step": step, "mom": None}

    return Optimizer(init, update)


def _adam_core(lr: Schedule | float, b1: float, b2: float, eps: float,
               weight_decay: float, lamb_trust: bool,
               moment_dtype=jnp.float32) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": tmap(lambda p: jnp.zeros_like(p, moment_dtype), params),
                "v": tmap(lambda p: jnp.zeros_like(p, moment_dtype), params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        t = step.astype(jnp.float32)
        bc1, bc2 = 1.0 - b1 ** t, 1.0 - b2 ** t

        m = tmap(lambda m_, g: (b1 * m_.astype(jnp.float32)
                                + (1 - b1) * g.astype(jnp.float32)).astype(m_.dtype),
                 state["m"], grads)
        v = tmap(lambda v_, g: (b2 * v_.astype(jnp.float32)
                                + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v_.dtype),
                 state["v"], grads)

        def upd_leaf(m_, v_, p):
            m_, v_ = m_.astype(jnp.float32), v_.astype(jnp.float32)
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            if lamb_trust:
                pn = jnp.linalg.norm(p.astype(jnp.float32).reshape(-1))
                un = jnp.linalg.norm(u.reshape(-1))
                trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
                u = trust * u
            return (-lr_t * u).astype(p.dtype)

        updates = tmap(upd_leaf, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, moment_dtype=jnp.float32) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=0.0, lamb_trust=False,
                      moment_dtype=moment_dtype)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=1e-2) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay, lamb_trust=False)


def lamb(lr, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.0) -> Optimizer:
    """LAMB — the paper's PSNR-phase optimizer (batch 256, lr 3e-3 cosine)."""
    return _adam_core(lr, b1, b2, eps, weight_decay, lamb_trust=True)


def adafactor(lr, decay: float = 0.8, eps: float = 1e-30) -> Optimizer:
    """Factored second moment (rank-1 for matrices) — O(n+m) state instead of
    O(nm); the optimizer-state footprint used in the dry-run math for the
    300B+ configs."""
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32), "f": tmap(leaf, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        flat_p = tdef.flatten_up_to(params)
        new_f, upds = [], []
        for g, f, p in zip(flat_g, flat_f, flat_p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                r = beta * f["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * f["c"] + (1 - beta) * g2.mean(axis=-2)
                vhat = r[..., None] * c[..., None, :] / jnp.maximum(
                    r.mean(axis=-1)[..., None, None], eps)
                new_f.append({"r": r, "c": c})
            else:
                vhat = beta * f["v"] + (1 - beta) * g2
                new_f.append({"v": vhat})
            u = g32 * jax.lax.rsqrt(jnp.maximum(vhat, eps))
            # update clipping (RMS<=1) as in the paper's Alg. 4
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            upds.append((-lr_t * u).astype(p.dtype))
        return (jax.tree_util.tree_unflatten(tdef, upds),
                {"step": step, "f": jax.tree_util.tree_unflatten(tdef, new_f)})

    return Optimizer(init, update)


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.update(grads, state, params)
    return Optimizer(opt.init, update)
