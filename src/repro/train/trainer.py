"""Training loop: supernet-sampled ESSR training (paper Sec. V-A recipe).

PSNR phase: L1, Lamb, lr 3e-3 cosine, batch 256, EMA 0.999, 200K iters —
all supported; examples run a scaled-down schedule on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import supernet
from repro.models.essr import ESSRConfig, essr_forward
from repro.train import losses as Ls
from repro.train import optimizer as O


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    ema: Any
    step: int = 0

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state,
                "ema": self.ema, "step": self.step}


def make_supernet_step(cfg: ESSRConfig, opt: O.Optimizer,
                       loss=Ls.l1_loss, ema_decay: float = 0.999):
    """Returns jitted ``step(params, opt_state, ema, lr, hr, width)`` with
    ``width`` static — two specializations (27, 54) get compiled."""

    def loss_fn(params, lr_img, hr_img, width: int):
        sr = essr_forward(params, lr_img, cfg, width=width)
        return loss(sr, hr_img)

    def step(params, opt_state, ema, lr_img, hr_img, *, width: int):
        val, grads = jax.value_and_grad(loss_fn)(params, lr_img, hr_img, width)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = O.apply_updates(params, updates)
        ema = supernet.ema_update(ema, params, ema_decay)
        return params, opt_state, ema, val

    return jax.jit(step, static_argnames=("width",))


def train_essr_supernet(params, cfg: ESSRConfig, data: Iterator,
                        steps: int, opt: Optional[O.Optimizer] = None,
                        seed: int = 0, log_every: int = 50,
                        log_fn: Callable[[str], None] = print) -> Tuple[Any, Any, list]:
    """ARM-style sampled-subnet training. Returns (params, ema, loss_history)."""
    opt = opt or O.lamb(O.cosine_decay(3e-3, steps))
    opt_state = opt.init(params)
    ema = supernet.ema_init(params)
    step_fn = make_supernet_step(cfg, opt)
    rng = np.random.default_rng(seed)
    widths = [w for w in cfg.subnet_widths() if w > 0]
    probs = supernet.subnet_sampling_probs(cfg)
    history = []
    for i in range(steps):
        lr_img, hr_img = next(data)
        width = int(rng.choice(widths, p=probs))
        params, opt_state, ema, val = step_fn(params, opt_state, ema, lr_img, hr_img,
                                              width=width)
        history.append(float(val))
        if log_every and (i + 1) % log_every == 0:
            log_fn(f"step {i+1:6d}  width C{width}  loss {np.mean(history[-log_every:]):.5f}")
    return params, ema, history


def make_grad_accum_step(loss_fn, opt: O.Optimizer, n_micro: int):
    """Gradient accumulation: one optimizer step from ``n_micro`` microbatches
    (batch axis folded as (n_micro, micro, ...)); lax.scan keeps HLO compact."""

    def step(params, opt_state, batch):
        def micro(accum, mb):
            val, grads = jax.value_and_grad(loss_fn)(params, *mb)
            return (jax.tree_util.tree_map(lambda a, g: a + g / n_micro, accum, grads),
                    val)
        zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        grads, vals = jax.lax.scan(micro, zeros, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state, vals.mean()

    return jax.jit(step)
