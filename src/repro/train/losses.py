"""Losses + image metrics.

PSNR-oriented phase: L1 (paper Sec. V-A).
Perceptual phase: 0.01*L1 + 1*artifact(LDL) + 1*perceptual + 0.005*adversarial.

Perceptual features use a FIXED random-init conv stack (offline container has
no pretrained VGG — documented substitute, DESIGN.md §8). The LDL artifact
loss is implemented from its definition (local-variance-weighted residual).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L


# ---------------------------------------------------------------------------
# pixel losses / metrics
# ---------------------------------------------------------------------------

def l1_loss(sr: jax.Array, hr: jax.Array) -> jax.Array:
    return jnp.mean(jnp.abs(sr - hr))


def charbonnier(sr: jax.Array, hr: jax.Array, eps: float = 1e-6) -> jax.Array:
    return jnp.mean(jnp.sqrt((sr - hr) ** 2 + eps * eps))


def psnr(sr: jax.Array, hr: jax.Array, peak: float = 1.0) -> jax.Array:
    mse = jnp.mean((sr - hr) ** 2)
    return 10.0 * jnp.log10(peak * peak / jnp.maximum(mse, 1e-12))


def psnr_y(sr: jax.Array, hr: jax.Array) -> jax.Array:
    """Y-channel PSNR (the SR literature convention the paper uses)."""
    ys = L.rgb_to_luma(jnp.clip(sr, 0, 1)) / 255.0
    yh = L.rgb_to_luma(jnp.clip(hr, 0, 1)) / 255.0
    return psnr(ys, yh)


def _gaussian_kernel(size: int = 11, sigma: float = 1.5) -> jax.Array:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x ** 2) / (2 * sigma ** 2))
    g = g / g.sum()
    return jnp.outer(g, g)


def ssim(sr: jax.Array, hr: jax.Array, peak: float = 1.0) -> jax.Array:
    """Single-scale SSIM on luma, 11x11 gaussian window (standard constants)."""
    c1, c2 = (0.01 * peak) ** 2, (0.03 * peak) ** 2
    x = L.rgb_to_luma(jnp.clip(sr, 0, 1))[..., None] / 255.0 if sr.shape[-1] == 3 else sr
    y = L.rgb_to_luma(jnp.clip(hr, 0, 1))[..., None] / 255.0 if hr.shape[-1] == 3 else hr
    if x.ndim == 3:
        x, y = x[None], y[None]
    k = _gaussian_kernel().reshape(11, 11, 1, 1)

    def f(z):
        return lax.conv_general_dilated(z, k, (1, 1), "VALID",
                                        dimension_numbers=("NHWC", "HWIO", "NHWC"))

    mx, my = f(x), f(y)
    sxx, syy, sxy = f(x * x) - mx * mx, f(y * y) - my * my, f(x * y) - mx * my
    s = ((2 * mx * my + c1) * (2 * sxy + c2)) / ((mx * mx + my * my + c1) * (sxx + syy + c2))
    return jnp.mean(s)


# ---------------------------------------------------------------------------
# perceptual distance (fixed random feature stack — LPIPS stand-in)
# ---------------------------------------------------------------------------

def init_feature_net(key: jax.Array, channels=(16, 32, 64)) -> Dict[str, Any]:
    ps, cin = [], 3
    for i, c in enumerate(channels):
        key, k = jax.random.split(key)
        ps.append({"w": L.conv_init(k, (3, 3, cin, c)), "b": jnp.zeros(c)})
        cin = c
    return {"convs": ps}


def feature_stack(params, x: jax.Array) -> Tuple[jax.Array, ...]:
    feats = []
    for p in params["convs"]:
        x = jax.nn.relu(L.conv2d(x, p["w"], p["b"], stride=2))
        feats.append(x)
    return tuple(feats)


def perceptual_loss(feat_params, sr: jax.Array, hr: jax.Array) -> jax.Array:
    fs, fh = feature_stack(feat_params, sr), feature_stack(feat_params, hr)
    def nrm(f):
        return f * lax.rsqrt(jnp.mean(f * f, axis=-1, keepdims=True) + 1e-8)
    return sum(jnp.mean(jnp.abs(nrm(a) - nrm(b))) for a, b in zip(fs, fh)) / len(fs)


def perceptual_distance(key_or_params, sr, hr):
    """LPIPS-like scalar for evaluation (lower = perceptually closer)."""
    params = init_feature_net(jax.random.PRNGKey(7)) if not isinstance(key_or_params, dict) else key_or_params
    return perceptual_loss(params, sr, hr)


# ---------------------------------------------------------------------------
# LDL artifact loss (Liang et al. 2022, paper ref [24]) — simplified faithful
# ---------------------------------------------------------------------------

def _local_var(x: jax.Array, k: int = 7) -> jax.Array:
    ones = jnp.ones((k, k, 1, 1), x.dtype) / (k * k)
    lum = x.mean(axis=-1, keepdims=True)
    f = lambda z: lax.conv_general_dilated(z, ones, (1, 1), "SAME",
                                           dimension_numbers=("NHWC", "HWIO", "NHWC"))
    mu = f(lum)
    return jnp.maximum(f(lum * lum) - mu * mu, 0.0)


def artifact_loss(sr: jax.Array, hr: jax.Array, gamma: float = 0.25) -> jax.Array:
    """Residuals are penalized where the *SR* image is locally unstable
    (variance-refined artifact map, stop-gradded as in LDL)."""
    resid = jnp.abs(sr - hr)
    amap = lax.stop_gradient(_local_var(sr) ** gamma * resid.mean(axis=-1, keepdims=True))
    amap = amap / (jnp.mean(amap) + 1e-8)
    return jnp.mean(amap * resid)


# ---------------------------------------------------------------------------
# GAN bits (vanilla non-saturating; discriminator in train/gan.py)
# ---------------------------------------------------------------------------

def d_loss_fn(real_logits: jax.Array, fake_logits: jax.Array) -> jax.Array:
    return (jnp.mean(jax.nn.softplus(-real_logits)) +
            jnp.mean(jax.nn.softplus(fake_logits)))


def g_adv_loss_fn(fake_logits: jax.Array) -> jax.Array:
    return jnp.mean(jax.nn.softplus(-fake_logits))


PERCEPTUAL_WEIGHTS = {"l1": 0.01, "artifact": 1.0, "perceptual": 1.0, "adv": 0.005}
