"""deepseek-v3-671b [moe] — 61L d7168 128H, MLA, d_ff(expert)=2048,
1 shared + 256 routed top-8, MTP, vocab 129280. [arXiv:2412.19437; hf]

MLA dims from the tech report: q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v_head 128. Decode runs in absorbed-latent form (the cache is
(B, S, 512+64) — constant in head count).
"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab_size=129280,
    n_experts=256, n_experts_per_tok=8, n_shared_experts=1, moe_d_ff=2048,
    moe_mode="ep_alltoall",        # E=256: experts sharded over 'model'
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mtp=True, act="silu",
)

SMOKE = LMConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, vocab_size=512,
    n_experts=8, n_experts_per_tok=2, n_shared_experts=1, moe_d_ff=96,
    moe_mode="ep_alltoall",
    use_mla=True, q_lora_rank=48, kv_lora_rank=32,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    mtp=True, act="silu", attn_chunk=32,
)
