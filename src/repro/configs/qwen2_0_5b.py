"""qwen2-0.5b [dense] — 24L d896 14H (GQA kv=2) d_ff 4864, vocab 151936,
QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, act="silu", rope_theta=1e6,
)

SMOKE = LMConfig(
    name="qwen2-0.5b-smoke", family="dense",
    n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, head_dim=14,
    d_ff=112, vocab_size=512,
    qkv_bias=True, tie_embeddings=True, act="silu", attn_chunk=32,
)
