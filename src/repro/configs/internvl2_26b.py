"""internvl2-26b [vlm] — InternLM2-20B backbone: 48L d6144 48H (kv=8)
d_ff 16384, vocab 92553; InternViT frontend is a STUB (input_specs provides
256 precomputed patch embeddings per image). [arXiv:2404.16821; hf]

This is the closest arch analog of the paper's technique: vision-token
compute routing by patch edge score (core/dynamic_width, DESIGN.md §5)."""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    frontend="vision", n_frontend_tokens=256, act="silu", rope_theta=1e6,
)

SMOKE = LMConfig(
    name="internvl2-26b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    frontend="vision", n_frontend_tokens=8, act="silu", attn_chunk=32,
)
