"""minitron-8b [dense] — pruned nemotron: 32L d4096 32H (kv=8) d_ff 16384,
vocab 256000, squared-ReLU MLP (nemotron lineage). [arXiv:2407.14679; hf]"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000, act="relu2", rope_theta=1e4,
)

SMOKE = LMConfig(
    name="minitron-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, act="relu2", attn_chunk=32,
)
