"""granite-8b [dense] — llama-arch code model: 36L d4096 32H (kv=8)
d_ff 14336, vocab 49152. [arXiv:2405.04324; hf]"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152, act="silu", rope_theta=1e4,
)

SMOKE = LMConfig(
    name="granite-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, act="silu", attn_chunk=32,
)

# ESSR-technique variant: dynamic-width FFN (DESIGN.md §5)
import dataclasses as _dc
FULL_DYNWIDTH = _dc.replace(FULL, name="granite-8b-dynwidth", dynamic_width=True)
SMOKE_DYNWIDTH = _dc.replace(SMOKE, name="granite-8b-smoke-dynwidth", dynamic_width=True)
