"""--arch registry: name -> (FULL config, SMOKE config)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import LMConfig
from repro.configs import (grok_1_314b, deepseek_v3_671b, seamless_m4t_medium,
                           granite_8b, qwen2_0_5b, minitron_8b, granite_3_2b,
                           falcon_mamba_7b, zamba2_1_2b, internvl2_26b)

_MODULES = {
    "grok-1-314b": grok_1_314b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "granite-8b": granite_8b,
    "qwen2-0.5b": qwen2_0_5b,
    "minitron-8b": minitron_8b,
    "granite-3-2b": granite_3_2b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "zamba2-1.2b": zamba2_1_2b,
    "internvl2-26b": internvl2_26b,
}

ARCH_NAMES = tuple(_MODULES.keys())


def get_config(name: str, smoke: bool = False) -> LMConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    m = _MODULES[name]
    return m.SMOKE if smoke else m.FULL


def all_configs(smoke: bool = False) -> Dict[str, LMConfig]:
    return {n: get_config(n, smoke) for n in ARCH_NAMES}
