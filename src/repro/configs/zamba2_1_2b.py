"""zamba2-1.2b [hybrid] — 38L mamba2 d2048 (d_inner 4096, state 64, head 64)
with a weight-SHARED attention+MLP block (32H kv=32, d_ff 8192) applied every
6 layers, vocab 32000. [arXiv:2411.15242; hf]

Simplifications vs the HF impl (noted per DESIGN.md §8): the shared block's
per-invocation LoRA adapters are omitted; the shared block consumes the
running hidden state (no concat-with-embedding projection)."""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_chunk=128,
    shared_attn_every=6, act="gelu",
)

SMOKE = LMConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    ssm_state=8, ssm_expand=2, ssm_conv=4, ssm_head_dim=16, ssm_chunk=16,
    shared_attn_every=2, act="gelu", attn_chunk=32,
)
