"""falcon-mamba-7b [ssm] — mamba1, attention-free: 64L d4096, d_inner 8192,
ssm_state 16, conv 4, dt_rank 256, vocab 65024. [arXiv:2410.05355; unverified]

The paper's edge-selective patch routing is N/A for an attention-free LM
(DESIGN.md §5 Arch-applicability) — implemented WITHOUT the technique."""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
)

SMOKE = LMConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state=8, ssm_expand=2, ssm_conv=4, ssm_chunk=16,
)
