"""seamless-m4t-medium [audio] — enc-dec, 12L each, d1024 16H (kv=16)
d_ff 4096, vocab 256206. Modality frontend is a STUB: input_specs provides
precomputed frame embeddings (assignment rule). [arXiv:2308.11596; hf]"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    is_encoder_decoder=True, n_encoder_layers=12,
    frontend="audio", act="gelu",
)

SMOKE = LMConfig(
    name="seamless-m4t-medium-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    is_encoder_decoder=True, n_encoder_layers=2,
    frontend="audio", act="gelu", attn_chunk=32,
)
