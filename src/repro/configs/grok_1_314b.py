"""grok-1-314b [moe] — 64L d6144 48H (GQA kv=8) d_ff=32768, MoE 8e top-2,
vocab 131072. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, n_experts_per_tok=2, moe_d_ff=32768,
    moe_mode="expert_tp",          # E=8 < mesh model=16: TP inside experts
    act="gelu", rope_theta=1e4,
)

SMOKE = LMConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    n_experts=4, n_experts_per_tok=2, moe_d_ff=128, moe_mode="expert_tp",
    act="gelu", attn_chunk=32, ssm_chunk=16,
)
