"""Config system: LM architecture configs + input-shape cells.

Every assigned architecture gets one frozen ``LMConfig`` (exact numbers from
the assignment) plus a ``smoke()`` reduced config of the same family for
CPU tests. Shape cells (train_4k / prefill_32k / decode_32k / long_500k) are
``ShapeSpec``s; (arch x shape) validity is computed here (long_500k only for
sub-quadratic archs — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0               # expert hidden size (deepseek: 2048)
    moe_mode: str = "expert_tp"     # expert_tp | ep_alltoall
    capacity_factor: float = 1.25
    # §Perf hillclimb knobs (baseline = False/einsum/scan; EXPERIMENTS.md §Perf)
    moe_dispatch_token_shard: bool = False   # shard dispatch capacity over dp
    moe_impl: str = "einsum"                # einsum | shard_map (explicit EP)
    mamba2_impl: str = "scan"               # scan | ssd (block-matmul form)
    mla_lazy_kv: bool = False               # D4 (refuted) lazy K/V expansion

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False               # multi-token-prediction extra head

    # --- SSM (mamba1/2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64          # mamba2
    ssm_dt_rank: int = 0            # mamba1; 0 -> ceil(d_model/16)
    ssm_chunk: int = 128            # chunked-scan length

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0      # apply the weight-shared attn block every N layers

    # --- enc-dec (seamless) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontend stubs ([audio]/[vlm]: backbone only) ---
    frontend: Optional[str] = None  # vision | audio
    n_frontend_tokens: int = 256

    # --- misc ---
    qkv_bias: bool = False
    act: str = "silu"               # silu | gelu | relu2
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_chunk: int = 512           # blockwise-attention kv-chunk
    dynamic_width: bool = False     # ESSR-style width-selective FFN (core/dynamic_width)

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 512 so the embedding/logits dims
        shard evenly on any mesh axis (MaxText-style logical vocab padding;
        labels never index the pad rows)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0 and self.family != "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch hold a 512K context? (ssm / hybrid-with-O(1)-mixer)"""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: LMConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason). long_500k is skipped for pure full-attention archs
    (assignment rule; the 512K KV build is quadratic and the cache is 10s of
    GB/sample) — recorded as an explicit skip row in EXPERIMENTS.md."""
    if shape is LONG_500K and not cfg.subquadratic:
        return False, "skip: full-attention arch at 512K context (quadratic prefill)"
    return True, "ok"


def param_count_estimate(cfg: LMConfig) -> int:
    """Closed-form parameter estimate (embeddings + layers), used in the
    roofline MODEL_FLOPS term and dry-run sanity checks."""
    d, v = cfg.d_model, cfg.vocab_size
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        hd = cfg.resolved_head_dim
        if cfg.use_mla:
            attn = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads *
                    (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                    + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d)
        else:
            attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
        if cfg.n_experts:
            f = cfg.moe_d_ff or cfg.d_ff
            ffn = cfg.n_experts * 3 * d * f + cfg.n_shared_experts * 3 * d * f + d * cfg.n_experts
        else:
            ffn = 3 * d * cfg.d_ff if cfg.act != "relu2" else 2 * d * cfg.d_ff
        per_layer = attn + ffn
    if cfg.family == "ssm":
        di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        per_layer = d * 2 * di + cfg.ssm_conv * di + di * (r + 2 * n) + r * di + di * n + di + di * d
    if cfg.family == "hybrid":
        di, n = cfg.d_inner, cfg.ssm_state
        heads = di // cfg.ssm_head_dim
        mamba2 = d * (2 * di + 2 * n * 1 + heads) + cfg.ssm_conv * (di + 2 * n) + di + di * d
        per_layer = mamba2
        # one shared attn+mlp block reused across the stack
        hd = cfg.resolved_head_dim
        shared = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d + 3 * d * cfg.d_ff
        emb += shared
    n_layers = cfg.n_layers + (cfg.n_encoder_layers if cfg.is_encoder_decoder else 0)
    return emb + n_layers * per_layer


def active_param_count_estimate(cfg: LMConfig) -> int:
    """Active (per-token) params — MoE counts only routed+shared experts."""
    if not cfg.n_experts:
        return param_count_estimate(cfg)
    full = param_count_estimate(cfg)
    f = cfg.moe_d_ff or cfg.d_ff
    d = cfg.d_model
    inactive = (cfg.n_experts - cfg.n_experts_per_tok) * 3 * d * f * cfg.n_layers
    return full - inactive
