"""granite-3-2b [dense] — 40L d2048 32H (GQA kv=8) d_ff 8192, vocab 49155.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import LMConfig

FULL = LMConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49155, act="silu", rope_theta=1e4,
)

SMOKE = LMConfig(
    name="granite-3-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=515,          # deliberately uneven (pad-sharding test)
    act="silu", attn_chunk=32,
)
