"""Pipeline parallelism over the 'pod' axis (GPipe-style, optional mode).

Default multi-pod strategy is pod-as-DP (DESIGN.md §6 — at 2 stages the
GPipe bubble is 1/(m+1) of the step, which napkin-math loses to pure DP for
the assigned shapes unless activations dominate the DCN). This module is the
opt-in alternative for deeper pod counts, demonstrated on reduced configs in
tests/test_pipeline.py.

Mechanics: layers are split into ``n_stages`` contiguous groups; microbatches
stream through stages with lax.scan over (n_micro + n_stages - 1) ticks; the
stage boundary hop is a collective-permute over 'pod'. All stages execute the
same program (SPMD) — stage identity comes from axis_index.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

# jax < 0.6 has no lax.pvary (no varying-manual-axes tracking) — identity is
# the correct degenerate form there
_pvary = getattr(lax, "pvary", lambda x, axes: x)


def pipelined_forward(mesh: Mesh, stage_fn: Callable, params_stacked: Any,
                      x_micro: jax.Array, n_stages: int):
    """x_micro: (n_micro, mb, S, D) microbatched inputs (replicated entering
    the pipe; each stage consumes/produces its slice via permute).

    ``params_stacked``: per-LAYER stacked params; layers are re-grouped as
    (n_stages, layers_per_stage, ...) and each pod shard keeps its stage's
    slice. ``stage_fn(stage_params, x) -> x`` runs the group's layers.
    Returns (n_micro, mb, S, D) outputs (valid on the LAST stage's shard)."""
    n_micro = x_micro.shape[0]
    layers = jax.tree_util.tree_map(
        lambda p: p.reshape((n_stages, p.shape[0] // n_stages) + p.shape[1:]),
        params_stacked)

    def local(stage_params, xs):
        stage_params = jax.tree_util.tree_map(lambda p: p[0], stage_params)
        stage = lax.axis_index("pod")
        # registers must be marked pod-varying up-front so scan/cond branches
        # agree on the manual-axes type (shard_map vma rules)
        state = _pvary(jnp.zeros_like(xs[0]), ("pod",))
        outputs = _pvary(jnp.zeros_like(xs), ("pod",))
        xs = _pvary(xs, ("pod",))

        def tick(carry, t):
            state, outputs = carry
            # stage 0 injects microbatch t (others get the permuted value)
            inject = xs[jnp.clip(t, 0, n_micro - 1)]
            cur = jnp.where(stage == 0, inject, state)
            y = stage_fn(stage_params, cur)
            # last stage records finished microbatch (t - n_stages + 1)
            done_idx = t - (n_stages - 1)
            outputs = lax.cond(
                (stage == n_stages - 1) & (done_idx >= 0),
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(done_idx, 0), 0),
                lambda o: o, outputs)
            # hop stage i -> i+1
            nxt = lax.ppermute(y, "pod",
                               [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outputs), None

        (_, outputs), _ = lax.scan(tick, (state, outputs),
                                   jnp.arange(n_micro + n_stages - 1))
        # broadcast final outputs from the last stage to all pods
        outputs = lax.ppermute(
            outputs, "pod",
            [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)])
        return outputs

    in_specs = (jax.tree_util.tree_map(lambda _: P("pod"), layers),
                P(None, None, None, None))
    # the trailing ppermute broadcast makes every pod hold identical outputs,
    # but the vma type system can't infer that replication -> check off
    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=P(None, None, None, None), check_rep=False)
    return fn(layers, x_micro)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe bubble = (S-1)/(M+S-1) — the napkin number behind pod-as-DP."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
