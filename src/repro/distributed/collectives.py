"""Explicit collective patterns (shard_map) — the §Perf comparison points
against GSPMD's automatic choices.

* ``flash_decode_attention``  — decode attention over a SEQUENCE-sharded KV
  cache with the flash-decoding (m, l, o) partial-softmax combine: each shard
  attends to its cache slice, then one psum pair merges the partials. This is
  the explicit form of what GSPMD does implicitly for GQA kv_heads < mesh.
* ``compressed_psum``         — int8-quantized gradient all-reduce with error
  feedback (gradient compression for cross-pod links).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash-decoding over a sequence-sharded cache
# ---------------------------------------------------------------------------

def flash_decode_attention(mesh: Mesh, axis: str, q, k_cache, v_cache, length):
    """q: (B,1,H,D); caches: (B,S,G,D) sharded on S over ``axis``;
    length: () global fill. Returns (B,1,H,D)."""
    b, _, h, d = q.shape
    s, g = k_cache.shape[1], k_cache.shape[2]
    rep = h // g
    n_shards = mesh.shape[axis]
    s_local = s // n_shards

    def local(q, k, v, length):
        idx = lax.axis_index(axis)
        qh = q.reshape(b, g, rep, d).astype(jnp.float32)
        scores = jnp.einsum("bgrd,bsgd->bgrs", qh, k.astype(jnp.float32)) * d ** -0.5
        pos = idx * s_local + jnp.arange(s_local)
        scores = jnp.where(pos[None, None, None, :] < length, scores, NEG_INF)
        m = scores.max(axis=-1)                               # (b,g,rep)
        p = jnp.exp(scores - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bgrs,bsgd->bgrd", p, v.astype(jnp.float32))
        # --- combine partials across shards (2 psums) ----------------------
        m_max = lax.pmax(m, axis)
        corr = jnp.exp(m - m_max)
        l_sum = lax.psum(l * corr, axis)
        o_sum = lax.psum(o * corr[..., None], axis)
        out = o_sum / jnp.maximum(l_sum[..., None], 1e-30)
        return out.reshape(b, 1, h, d).astype(q.dtype)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, None, None, None),
                             P(None, axis, None, None), P(None, axis, None, None),
                             P()),
                   out_specs=P(None, None, None, None))
    return fn(q, k_cache, v_cache, length)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------

def compressed_psum(mesh: Mesh, axis: str, grads, error_state):
    """All-reduce ``grads`` (pytree) over ``axis`` in int8 with per-tensor
    scales and error feedback: residual = g - dequant(quant(g)) carries to the
    next step, so compression error doesn't bias the trajectory.

    Returns (reduced_grads, new_error_state). 4x cheaper on the wire than
    fp32 psum; used for the cross-pod (DCN-like) axis in multi-pod training."""

    def one(g, err):
        def local(g, err):
            g = g + err                                     # error feedback
            scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            new_err = g - deq
            total = lax.psum(q.astype(jnp.float32) * scale, axis)
            n = lax.psum(jnp.ones((), jnp.float32), axis)
            return total / n, new_err

        return shard_map(local, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()))(g, err)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return red, err


def init_error_state(grads):
    return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
