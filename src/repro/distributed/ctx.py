"""Sharding context: lets model code place activation sharding constraints
without depending on a concrete mesh (no-op when unset, e.g. CPU smoke tests).

Model code says ``constrain(x, "dp", "mp", None)`` — symbolic axes:
  'dp' -> the data-parallel axes (('pod','data') multi-pod, ('data',) single)
  'mp' -> the model axis.
Dims that don't divide the named axis size silently drop the constraint
(defensive: qwen2's 14 heads, batch-1 decode, etc.).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    dp: Tuple[str, ...]               # data-parallel mesh axes
    mp: str                           # model axis

    def axis_size(self, sym: Axis) -> int:
        if sym is None:
            return 1
        names = self.dp if sym == "dp" else (self.mp,) if sym == "mp" else sym
        return math.prod(self.mesh.shape[n] for n in names)

    def resolve(self, sym: Axis):
        if sym is None:
            return None
        if sym == "dp":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if sym == "mp":
            return self.mp
        return sym

    def spec(self, x_shape, axes: Sequence[Axis]) -> P:
        entries = []
        for dim, sym in zip(x_shape, axes):
            if sym is not None and dim % self.axis_size(sym) == 0 and dim > 0:
                entries.append(self.resolve(sym))
            else:
                entries.append(None)
        return P(*entries)

    def constrain(self, x: jax.Array, *axes: Axis) -> jax.Array:
        spec = self.spec(x.shape, axes)
        return lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


_CURRENT: Optional[ShardCtx] = None


@contextlib.contextmanager
def use_ctx(ctx: Optional[ShardCtx]):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ctx
    try:
        yield
    finally:
        _CURRENT = prev


def current() -> Optional[ShardCtx]:
    return _CURRENT


def constrain(x: jax.Array, *axes: Axis) -> jax.Array:
    """Module-level hook used inside model code. No-op without a context."""
    if _CURRENT is None:
        return x
    return _CURRENT.constrain(x, *axes)
