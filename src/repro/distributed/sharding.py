"""PartitionSpec rules for every parameter / cache / input in the framework.

Strategy (DESIGN.md §6):
  * FSDP  — weights' d_model-like dims sharded over the data axes (ZeRO-3);
  * TP    — head / hidden / vocab / expert dims over 'model';
  * EP    — MoE expert dim over 'model' when n_experts >= mesh model size;
  * SP    — activations' sequence dim over 'model' (ctx.constrain in model);
  * caches— kv-heads over 'model' when divisible, else SEQUENCE over 'model'
            (GQA kv=8 < 16: the flash-decoding layout); batch over data axes
            when divisible (batch-1 long-context shards seq over data too).

Every rule is divisibility-guarded so reduced smoke configs and small test
meshes never produce invalid specs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig
from repro.distributed.ctx import ShardCtx


# ---------------------------------------------------------------------------
# mesh info
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    dp: Tuple[str, ...]
    mp: str

    @property
    def dp_size(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp)

    @property
    def mp_size(self) -> int:
        return self.mesh.shape[self.mp]

    @property
    def dp_resolved(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def ctx(self) -> ShardCtx:
        return ShardCtx(self.mesh, self.dp, self.mp)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def mesh_info(mesh: Mesh) -> MeshInfo:
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    return MeshInfo(mesh, dp, "model")


# ---------------------------------------------------------------------------
# SR patch-stream specs (the 1-D serving mesh of launch.mesh.make_patch_mesh)
# ---------------------------------------------------------------------------

def patch_batch_spec(mesh: Mesh) -> P:
    """Batch-of-patches spec: split the leading (patch) dim over the mesh's
    single axis. The SR forward is embarrassingly batch-parallel, so this is
    the whole sharding story for the patch stream."""
    if len(mesh.axis_names) != 1:
        raise ValueError(f"patch stream expects a 1-D mesh, got axes "
                         f"{mesh.axis_names}")
    return P(mesh.axis_names[0])


def patch_batch_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding form of :func:`patch_batch_spec` (device_put targets)."""
    return NamedSharding(mesh, patch_batch_spec(mesh))


# ---------------------------------------------------------------------------
# parameter specs (path-pattern rules)
# ---------------------------------------------------------------------------

def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _base_spec(path: str, shape: Tuple[int, ...], cfg: LMConfig, mi: MeshInfo) -> P:
    """Spec for the UNSTACKED parameter (no leading layer dim)."""
    dp, mp = mi.dp_resolved, mi.mp
    dpn, mpn = mi.dp_size, mi.mp_size
    fs = lambda n: dp if _div(n, dpn) else None          # fsdp if divisible
    tp = lambda n: mp if _div(n, mpn) else None

    leaf = path.split("/")[-1]

    # --- embeddings / heads -------------------------------------------------
    if leaf == "embed":
        return P(tp(shape[0]), fs(shape[1]))
    if leaf == "lm_head" or leaf == "vision_proj" or leaf == "proj":
        return P(fs(shape[0]), tp(shape[1]))

    # --- norms / scalars / small vectors ------------------------------------
    if len(shape) <= 1:
        return P(*([None] * len(shape)))

    # --- MoE ----------------------------------------------------------------
    if "/moe/" in path or path.endswith("router"):
        if leaf == "router":
            return P(fs(shape[0]), None)
        if leaf in ("w_in", "w_gate") and len(shape) == 3:
            if cfg.moe_mode == "ep_alltoall" and _div(shape[0], mpn):
                return P(mp, fs(shape[1]), None)
            return P(None, fs(shape[1]), tp(shape[2]))
        if leaf == "w_out" and len(shape) == 3:
            if cfg.moe_mode == "ep_alltoall" and _div(shape[0], mpn):
                return P(mp, None, fs(shape[2]))
            return P(None, tp(shape[1]), fs(shape[2]))
        # shared expert falls through to the mlp rules below

    # --- attention (GQA + MLA + cross) ---------------------------------------
    heads_ok = _div(cfg.n_heads * cfg.resolved_head_dim, mpn) and _div(cfg.n_heads, mpn)
    kv_ok = _div(cfg.n_kv_heads, mpn)
    if leaf in ("wq",):
        return P(fs(shape[0]), mp if heads_ok else None)
    if leaf in ("wk", "wv"):
        return P(fs(shape[0]), mp if kv_ok else None)
    if leaf == "wo":
        return P(mp if heads_ok else None, fs(shape[1]))
    if leaf in ("bq",):
        return P(mp if heads_ok else None)
    if leaf in ("bk", "bv"):
        return P(mp if kv_ok else None)
    if leaf in ("wdq", "wdkv", "wkr"):
        return P(fs(shape[0]), None)
    if leaf in ("wuq", "wukv"):
        return P(None, mp if _div(cfg.n_heads, mpn) else None)

    # --- dense MLP -----------------------------------------------------------
    if leaf in ("w_in", "w_gate"):
        return P(fs(shape[0]), tp(shape[1]))
    if leaf == "w_out":
        return P(tp(shape[0]), fs(shape[1]))

    # --- mamba ---------------------------------------------------------------
    if "/mamba/" in path:
        di = cfg.d_inner
        if leaf == "in_proj":
            # mamba1 (D, 2*di): aligned x/z halves -> TP ok.
            if shape[1] == 2 * di and _div(di, mpn):
                return P(fs(shape[0]), mp)
            return P(fs(shape[0]), None)
        if leaf in ("w_z", "w_x"):          # mamba2 split projections (§Perf Z4)
            return P(fs(shape[0]), tp(shape[1]))
        if leaf in ("w_bc",):               # (D, 2n): B/C are head-shared
            return P(fs(shape[0]), None)
        if leaf == "w_dt":                  # (D, H): dt heads follow x heads
            return P(fs(shape[0]), tp(shape[1]))
        if leaf == "conv_w":
            return P(None, mp if shape[1] == di and _div(di, mpn) else None)
        if leaf == "x_proj":
            return P(mp if _div(shape[0], mpn) else None, None)
        if leaf == "dt_proj":
            return P(None, tp(shape[1]))
        if leaf == "A_log" and len(shape) == 2:
            return P(tp(shape[0]), None)
        if leaf in ("A_log", "dt_bias", "D") and len(shape) == 1:
            return P(tp(shape[0]))          # per-head scalars follow the heads
        if leaf == "norm_w":
            return P(tp(shape[0]))
        if leaf == "out_proj":
            return P(tp(shape[0]), fs(shape[1]))
        return P(*([None] * len(shape)))

    # --- fallback: FSDP the largest dim --------------------------------------
    big = int(np.argmax(shape))
    spec = [None] * len(shape)
    if _div(shape[big], dpn):
        spec[big] = dp
    return P(*spec)


def param_specs(params: Any, cfg: LMConfig, mi: MeshInfo) -> Any:
    """Pytree of PartitionSpec matching ``params`` (stacked-layer aware)."""

    def visit(path_keys, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path_keys]
        path = "/".join(str(n) for n in names)
        stacked = names and names[0] in ("layers", "enc_layers", "dec_layers")
        shape = tuple(leaf.shape)
        base_shape = shape[1:] if stacked else shape
        spec = _base_spec(path, base_shape, cfg, mi)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# cache / input specs
# ---------------------------------------------------------------------------

def cache_specs(caches: Any, cfg: LMConfig, mi: MeshInfo, batch: int) -> Any:
    """KV/state cache PartitionSpecs. Heads over 'model' when divisible, else
    sequence over 'model'; batch over dp when divisible, else sequence also
    takes the data axes (512K batch-1 long-context)."""
    dp, mp = mi.dp_resolved, mi.mp
    batch_ok = _div(batch, mi.dp_size)

    def visit(path_keys, leaf):
        names = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path_keys)
        shape = tuple(leaf.shape)
        bdim = dp if batch_ok else None
        if names.endswith("ckv") or names.endswith("kr"):       # (L,B,S,r)
            seq_axes = mp if batch_ok else ((dp, mp) if _div(shape[2], mi.dp_size * mi.mp_size) else mp)
            return P(None, bdim, seq_axes if _div(shape[2], mi.mp_size) else None, None)
        if names.split("/")[-1] in ("k", "v"):                  # (L,B,S,G,hd)
            if _div(shape[3], mi.mp_size):
                seq = None if batch_ok else (dp if _div(shape[2], mi.dp_size) else None)
                return P(None, bdim, seq, mp, None)
            seq_axes = mp if batch_ok else ((dp, mp) if _div(shape[2], mi.dp_size * mi.mp_size) else mp)
            return P(None, bdim, seq_axes if _div(shape[2], mi.mp_size) else None, None, None)
        if "ssm/h" in names:                                    # (L,B,di,n) | (L,B,H,P,n)
            spec = [None, bdim] + [None] * (len(shape) - 2)
            if _div(shape[2], mi.mp_size):
                spec[2] = mp
            return P(*spec)
        if "ssm/conv" in names:                                 # (L,B,k-1,C)
            return P(None, bdim, None, mp if _div(shape[3], mi.mp_size) else None)
        spec = [None, bdim] + [None] * (len(shape) - 2)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, caches)


def batch_specs(batch_leaves: Any, mi: MeshInfo) -> Any:
    """Inputs: batch dim over dp when divisible; everything else replicated."""
    dp = mi.dp_resolved

    def visit(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        return P(dp if _div(b, mi.dp_size) else None, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(visit, batch_leaves)
