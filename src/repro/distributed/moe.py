"""Explicit shard_map MoE — the §Perf winner over GSPMD's auto-sharded
einsum dispatch (EXPERIMENTS.md §Perf iterations G2/D1).

Why: the einsum formulation leaves GSPMD to choose shardings for the
dispatch scatter and expert contractions; measured on grok-1/deepseek-v3
train_4k it picks TB-scale partial-sum all-reduces (baseline records).
Here every collective is explicit and minimal:

  expert_tp  (E < mesh):  tokens stay local to each (dp x mp) shard; every
      shard computes ALL experts on its own tokens with its F-slice of the
      expert weights (all-gathered over dp — ZeRO-3); one psum over mp
      combines the F-partial outputs.
  ep_alltoall (E >= mp):  experts partitioned over mp; local dispatch
      buffers exchanged with all_to_all, local expert FFN, all_to_all back.

Token routing is per-token, so local-shard routing == global routing;
capacity becomes per-shard (more realistic than a global capacity pool).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import LMConfig
from repro.models.lm.ffn import _act, mlp, moe_capacity


def _local_dispatch(xf, probs, cfg: LMConfig, cap: int):
    """Local tokens (t,d) -> dispatch (E, cap, d), combine weights, slots."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    gate, idx = lax.top_k(probs, k)                       # (t,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0), flat_e[:, None], 1)[:, 0] - 1
    valid = pos < cap
    slot = jnp.where(valid, flat_e * cap + pos, e * cap)
    tok = jnp.repeat(jnp.arange(t), k)
    disp = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].add(
        xf[tok] * valid[:, None])
    return disp[:-1].reshape(e, cap, d), gate, tok, slot, valid


def _combine(y_slots, gate, tok, slot, valid, t, d, dtype):
    y = jnp.concatenate([y_slots.reshape(-1, d),
                         jnp.zeros((1, d), y_slots.dtype)], axis=0)
    w = (gate.reshape(-1) * valid).astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[tok].add(y[slot] * w[:, None])
    return out.astype(dtype)


def moe_forward_shardmap(p: Dict[str, Any], x: jax.Array, cfg: LMConfig,
                         mesh: Mesh, dp, mp: str) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) sharded P(dp, mp, None). Returns (out, aux)."""
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    d = cfg.d_model
    mp_size = mesh.shape[mp]
    ep = cfg.moe_mode == "ep_alltoall" and e % mp_size == 0
    act = _act(cfg.act)

    # weight specs must match distributed.sharding rules
    if ep:
        w_spec = P(mp, dp, None)
        wo_spec = P(mp, None, dp)
    else:
        w_spec = P(None, dp, mp)
        wo_spec = P(None, mp, dp)

    def local(x, router, w_in, w_gate, w_out):
        b_l, s_l, _ = x.shape
        t = b_l * s_l
        xf = x.reshape(t, d)
        probs = jax.nn.softmax(xf.astype(jnp.float32) @ router, axis=-1)
        cap = moe_capacity(t, cfg)
        disp, gate, tok, slot, valid = _local_dispatch(xf, probs, cfg, cap)

        density = jnp.mean(jax.nn.one_hot(jnp.argmax(probs, -1), e), axis=0)
        aux = e * jnp.mean(density * jnp.mean(probs, axis=0))
        aux = lax.pmean(lax.pmean(aux, mp), dp)

        # ZeRO-3: gather the dp-sharded weight dim just-in-time
        w_in_g = lax.all_gather(w_in, dp, axis=1, tiled=True)      # (E?,D,F?)
        w_gate_g = lax.all_gather(w_gate, dp, axis=1, tiled=True)
        w_out_g = lax.all_gather(w_out, dp, axis=2, tiled=True)

        if ep:
            # experts over mp: exchange dispatch so each shard owns its E/mp
            e_l = e // mp_size
            disp = disp.reshape(mp_size, e_l, cap, d)
            recv = lax.all_to_all(disp, mp, split_axis=0, concat_axis=0,
                                  tiled=False)                      # (mp,e_l,cap,d)
            recv = recv.transpose(1, 0, 2, 3).reshape(e_l, mp_size * cap, d)
            h = jnp.einsum("ecd,edf->ecf", recv, w_in_g)
            h = h * act(jnp.einsum("ecd,edf->ecf", recv, w_gate_g))
            y = jnp.einsum("ecf,efd->ecd", h, w_out_g)              # (e_l,mp*cap,d)
            y = y.reshape(e_l, mp_size, cap, d).transpose(1, 0, 2, 3)
            y = lax.all_to_all(y, mp, split_axis=0, concat_axis=0, tiled=False)
            y_slots = y.reshape(e, cap, d)
            out = _combine(y_slots, gate, tok, slot, valid, t, d, x.dtype)
        else:
            # expert-TP: all experts local, F sliced over mp. The combine is
            # LINEAR in the slot outputs, so the F-partial psum commutes with
            # it — combining FIRST shrinks the psum operand from the slot
            # buffer (E*cap, d ~ 2 GB) to the token output (t, d ~ 0.8 GB)
            # (§Perf G4: 2.5x less all-reduce volume, zero math change).
            h = jnp.einsum("ecd,edf->ecf", disp, w_in_g)
            h = h * act(jnp.einsum("ecd,edf->ecf", disp, w_gate_g))
            y_partial = jnp.einsum("ecf,efd->ecd", h, w_out_g)
            out_partial = _combine(y_partial, gate, tok, slot, valid, t, d,
                                   jnp.bfloat16 if x.dtype == jnp.bfloat16 else x.dtype)
            out = lax.psum(out_partial, mp).astype(x.dtype)
        return out.reshape(b_l, s_l, d), aux[None]

    # expert_tp combines F-partials with a psum over mp — that is only sound
    # if every mp shard holds the SAME tokens, so the sequence enters
    # un-SP'd (P(dp, None, None)); the surrounding constraints re-shard.
    # ep_alltoall keeps tokens mp-sharded (each shard dispatches its own).
    x_spec = P(dp, mp, None) if ep else P(dp, None, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, wo_spec),
        out_specs=(x_spec, P(None)),
        check_rep=False)

    # pad B/S to mesh multiples (e.g. deepseek's MTP shifts S to 4095); the
    # pad tokens route like real ones but their outputs are sliced off.
    b0, s0, _ = x.shape
    import math
    dp_size = math.prod(mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,)))
    s_div = mp_size if ep else 1
    pad_b = (-b0) % dp_size
    pad_s = (-s0) % s_div
    if pad_b or pad_s:
        x = jnp.pad(x, ((0, pad_b), (0, pad_s), (0, 0)))
    out, aux = fn(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    if pad_b or pad_s:
        out = out[:b0, :s0]
        x = x[:b0, :s0]
    if "shared" in p:
        out = out + mlp(p["shared"], x.reshape(-1, d), cfg.act).reshape(b0, s0, d)
    return out, aux[0]
