"""Pass 2 — AST lint of repo conventions over ``src/`` (ESSR2xx).

Where the jaxpr audit checks what the compiler actually sees, this pass
checks what reviewers keep having to say in words:

  ESSR201  no new free-function inference entry points outside ``repro.api``
           (the ROADMAP convention: modes/backends plug into
           `ExecutionPlan`/`SREngine`). Detected as a module-level public
           function taking both ``params`` and ``frame``/``frames``.
  ESSR202  no ``numpy`` (``np.``) host ops inside traced bodies in ``core/``
           and ``kernels/`` — a np call under trace either crashes or, via
           ``__array__``, silently materializes the tracer on the host.
  ESSR203  no ``time`` module calls inside traced bodies there — wall-clock
           reads bake a compile-time constant and measure nothing.
  ESSR204  no ``.block_until_ready()`` / ``jax.device_get`` inside traced
           bodies there — a host sync inside the graph's staging path
           serializes the stream the async dispatch exists to overlap.
  ESSR205  no mutable or unhashable fields on frozen dataclasses (plans,
           configs, quant packs ride through jit as static arguments; one
           list-typed field makes the whole plan unhashable and every
           frame a cache miss). Frozen-with-``eq=False`` classes hash by
           identity and are exempt (that is `PatchGeometry`'s contract).
  ESSR206  no free-function STREAM-serving entry points outside ``repro.api``
           — multi/single-stream serving is an `SREngine` mode
           (``stream``/``serve_streams``); the multiplexer must not
           reintroduce the retired FrameServer shape. Detected as a
           module-level public function taking a stream bundle
           (``streams``/``frame_streams``/``stream_iters``/``iterables``)
           next to ``params`` or an ``engine``.
  ESSR207  no broad exception swallowing in ``runtime/`` / ``api/`` — a
           bare ``except``, ``except Exception`` or ``except BaseException``
           there must re-raise or record what it caught (a call whose name
           mentions record/warn/retire/quarantine/degrade/note_/fail);
           a silent handler in the serving path hides exactly the faults
           the resilience ledger (`runtime.guard`) exists to surface.

A "traced body" is resolved statically, at function granularity: a function
is traced when it is jit/pallas/shard_map-decorated, or its name is passed
into a ``jit`` / ``pallas_call`` / ``shard_map`` / ``vmap`` / ``scan`` /
``cond`` / ``while_loop`` / ``custom_jvp``-style call anywhere in the same
module (including through ``functools.partial``). Indirectly-traced helpers
are out of static reach — the jaxpr pass covers what actually lands in the
graph.

Suppression: a ``# essr: allow[ESSR201]`` comment on the flagged line or
the line directly above it waives that code at that site (multiple codes
comma-separate). Use it to grandfather documented legacy surfaces, never to
mute a new hazard.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.report import Violation

#: Call names that put their function-valued arguments on the traced path.
TRACER_CALLS = frozenset({
    "jit", "pallas_call", "shard_map", "vmap", "pmap", "scan", "while_loop",
    "cond", "switch", "remat", "checkpoint", "custom_jvp", "custom_vjp",
    "grad", "value_and_grad", "make_jaxpr", "eval_shape", "named_call",
})

#: Annotation tokens that sink a frozen dataclass's hashability (ESSR205).
_MUTABLE_ANN = re.compile(
    r"\b(list|dict|set|List|Dict|Set|DefaultDict|Deque|deque|bytearray|"
    r"ndarray|Array|MutableMapping|MutableSequence)\b")

_ALLOW = re.compile(r"essr:\s*allow\[([A-Za-z0-9_,\s]+)\]")

#: Directory scope (repo-relative prefixes) for the traced-body rules.
TRACED_BODY_SCOPE = ("src/repro/core/", "src/repro/kernels/")

#: The one package allowed to define free-function inference entry points.
ENTRY_POINT_EXEMPT = ("src/repro/api/",)

#: Directory scope for the swallowed-exception rule (ESSR207): the serving
#: runtime and the facade, where every fault must land on the guard ledger.
RESILIENCE_SCOPE = ("src/repro/runtime/", "src/repro/api/")

#: Call-name tokens that count as recording/handling a caught exception.
_RECOVERY_CALL = re.compile(
    r"(record|warn|retire|quarantine|degrad|note_|fail)", re.IGNORECASE)


def _suppressions(source: str) -> Dict[int, Set[str]]:
    """line number -> rule codes waived on that line (1-based)."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW.search(line)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def _is_suppressed(code: str, line: int,
                   suppressions: Dict[int, Set[str]]) -> bool:
    """A marker covers its own line and the line below (so long ``def``
    headers take the marker on the preceding line)."""
    return (code in suppressions.get(line, ()) or
            code in suppressions.get(line - 1, ()))


def _name_tokens(node: ast.AST) -> Set[str]:
    """Every bare-name and attribute-name token in an expression subtree."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def _collect_traced_names(tree: ast.Module) -> Set[str]:
    """Names of functions this module puts on a traced path (see module
    docstring for the resolution rules)."""
    defs = {n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    traced: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _name_tokens(dec) & TRACER_CALLS:
                    traced.add(node.name)
        elif isinstance(node, ast.Call):
            if _name_tokens(node.func) & TRACER_CALLS:
                for arg in list(node.args) + [kw.value for kw in
                                              node.keywords]:
                    traced.update(_name_tokens(arg) & defs)
    return traced


def _iter_traced_bodies(tree: ast.Module
                        ) -> Iterable[Tuple[str, ast.AST]]:
    traced = _collect_traced_names(tree)
    for node in ast.walk(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name in traced):
            yield node.name, node


def _lint_traced_body(name: str, fn: ast.AST, relpath: str
                      ) -> Iterable[Violation]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            base = node.value
            if isinstance(base, ast.Name) and base.id in ("np", "numpy"):
                yield Violation(
                    "ESSR202", f"{relpath}:{node.lineno}",
                    f"numpy op 'np.{node.attr}' inside traced body "
                    f"'{name}'")
            elif isinstance(base, ast.Name) and base.id == "time":
                yield Violation(
                    "ESSR203", f"{relpath}:{node.lineno}",
                    f"wall-clock call 'time.{node.attr}' inside traced "
                    f"body '{name}'")
            elif node.attr == "block_until_ready":
                yield Violation(
                    "ESSR204", f"{relpath}:{node.lineno}",
                    f"host sync '.block_until_ready()' inside traced body "
                    f"'{name}'")
            elif (node.attr == "device_get"
                  and isinstance(base, ast.Name) and base.id == "jax"):
                yield Violation(
                    "ESSR204", f"{relpath}:{node.lineno}",
                    f"host transfer 'jax.device_get' inside traced body "
                    f"'{name}'")


def _lint_entry_points(tree: ast.Module, relpath: str
                       ) -> Iterable[Violation]:
    for node in tree.body:                      # module level only
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name.startswith("_"):
            continue
        args = {a.arg for a in (node.args.posonlyargs + node.args.args
                                + node.args.kwonlyargs)}
        if "params" in args and ({"frame", "frames"} & args):
            yield Violation(
                "ESSR201", f"{relpath}:{node.lineno}",
                f"free-function inference entry point '{node.name}"
                f"(params, frame...)' outside repro.api — new modes plug "
                f"into ExecutionPlan/SREngine")
        stream_args = {"streams", "frame_streams", "stream_iters",
                       "iterables"} & args
        if stream_args and ({"params", "engine"} & args):
            yield Violation(
                "ESSR206", f"{relpath}:{node.lineno}",
                f"free-function stream-serving entry point '{node.name}"
                f"(..., {sorted(stream_args)[0]})' outside repro.api — "
                f"stream serving is an SREngine mode "
                f"(stream()/serve_streams())")


def _lint_swallowed_exceptions(tree: ast.Module, relpath: str
                               ) -> Iterable[Violation]:
    """ESSR207 — a broad except handler in the serving path must either
    re-raise or make a call that records the fault. Narrow handlers
    (``except StopIteration``, ``except OSError``) are out of scope: the
    rule targets catch-alls that can swallow injected faults whole."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        broad = (node.type is None or
                 bool(_name_tokens(node.type)
                      & {"Exception", "BaseException"}))
        if not broad:
            continue
        recovered = False
        for stmt in node.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Raise):
                    recovered = True
                elif (isinstance(sub, ast.Call)
                      and any(_RECOVERY_CALL.search(n)
                              for n in _name_tokens(sub.func))):
                    recovered = True
            if recovered:
                break
        if not recovered:
            caught = (ast.unparse(node.type) if node.type is not None
                      else "<bare>")
            yield Violation(
                "ESSR207", f"{relpath}:{node.lineno}",
                f"broad 'except {caught}' swallows the fault without "
                f"re-raising or recording it — serving-path handlers must "
                f"put what they caught on the resilience ledger "
                f"(guard.record / warnings.warn / ...)")


def _dataclass_flags(node: ast.ClassDef) -> Optional[Dict[str, bool]]:
    """None when not a dataclass; else {'frozen': ..., 'identity_eq': ...}."""
    for dec in node.decorator_list:
        tokens = _name_tokens(dec)
        if "dataclass" not in tokens:
            continue
        frozen = identity_eq = False
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if isinstance(kw.value, ast.Constant):
                    if kw.arg == "frozen":
                        frozen = bool(kw.value.value)
                    elif kw.arg == "eq":
                        identity_eq = not kw.value.value
        return {"frozen": frozen, "identity_eq": identity_eq}
    return None


def _lint_frozen_fields(tree: ast.Module, relpath: str
                        ) -> Iterable[Violation]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        flags = _dataclass_flags(node)
        if not flags or not flags["frozen"] or flags["identity_eq"]:
            continue
        for stmt in node.body:
            if not isinstance(stmt, ast.AnnAssign):
                continue
            ann = ast.unparse(stmt.annotation)
            m = _MUTABLE_ANN.search(ann)
            if m:
                yield Violation(
                    "ESSR205", f"{relpath}:{stmt.lineno}",
                    f"frozen dataclass '{node.name}' field "
                    f"'{ast.unparse(stmt.target)}: {ann}' is "
                    f"mutable/unhashable ('{m.group(1)}'); it rides "
                    f"through jit as a static argument")
            elif stmt.value is not None and isinstance(
                    stmt.value, (ast.List, ast.Dict, ast.Set)):
                yield Violation(
                    "ESSR205", f"{relpath}:{stmt.lineno}",
                    f"frozen dataclass '{node.name}' field "
                    f"'{ast.unparse(stmt.target)}' has a mutable literal "
                    f"default")


def lint_source(source: str, relpath: str) -> List[Violation]:
    """Lint one module's source. ``relpath`` is the repo-relative path used
    for rule scoping and violation sites (tests pass synthetic ones)."""
    tree = ast.parse(source)
    suppressions = _suppressions(source)
    found: List[Violation] = []
    if not relpath.startswith(ENTRY_POINT_EXEMPT):
        found.extend(_lint_entry_points(tree, relpath))
    if relpath.startswith(TRACED_BODY_SCOPE):
        for name, fn in _iter_traced_bodies(tree):
            found.extend(_lint_traced_body(name, fn, relpath))
    if relpath.startswith(RESILIENCE_SCOPE):
        found.extend(_lint_swallowed_exceptions(tree, relpath))
    found.extend(_lint_frozen_fields(tree, relpath))
    return [v for v in found
            if not _is_suppressed(v.code, int(v.site.rsplit(":", 1)[1]),
                                  suppressions)]


def lint_file(path: str, repo_root: str) -> List[Violation]:
    relpath = os.path.relpath(os.path.abspath(path),
                              os.path.abspath(repo_root))
    with open(path) as f:
        return lint_source(f.read(), relpath.replace(os.sep, "/"))


def default_src_root() -> str:
    """The repo root this installed tree lives in (…/src/repro/analysis/
    ast_lint.py -> repo root three levels up from the package)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def run_ast_lint(repo_root: Optional[str] = None) -> List[Violation]:
    """The whole pass: every ``.py`` under ``src/``."""
    root = repo_root if repo_root is not None else default_src_root()
    out: List[Violation] = []
    src = os.path.join(root, "src")
    for dirpath, _dirnames, filenames in os.walk(src):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.extend(lint_file(os.path.join(dirpath, fn), root))
    return out
