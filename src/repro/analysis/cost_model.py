"""Pass 4 — static MAC/byte cost model over the traced jaxprs.

The static counterpart of `launch/roofline.py`: where the roofline reads
compiled-HLO text for the executable that XLA *happened* to build, this pass
prices the traced graph itself — per audited entry point it counts

  * MACs: every ``dot_general`` contributes ``out_elems * K`` (K = product
    of contracting dims), every ``conv_general_dilated`` contributes
    ``out_elems * kernel_spatial * cin_per_group`` — split into integer MACs
    (both operands integer-dtyped: the PAMS int8/fxp10 datapath) and fp MACs;
  * HBM bytes: the entry point's own I/O (top-level invars + outvars +
    closed-over consts) plus, per ``pallas_call``, the operand/result blocks
    each kernel launch moves between HBM and VMEM, keyed per kernel so the
    fused-pipeline report shows which group moves what. The rank-4
    patch-batch operands/results of each launch are additionally broken out
    as ``feature_hbm_bytes`` — the inter-group activation traffic the
    paper's 79%-reduction claim is about, and the quantity the group-fused
    megakernel (`kernels/megakernel.py`) collapses to entry+exit only;
  * arithmetic intensity: MACs / HBM bytes.

``scan`` bodies are multiplied by their trip count; a ``while`` has no
static trip count, so its body is priced once and the entry is flagged
``"while_unbounded": true`` instead of silently under-counting. Costs are
structural (shapes and dtypes only — never data), so they are deterministic
across machines and safe to gate in CI: `bench_gate --audit` compares them
against the committed `ANALYSIS_baseline.json` via `report.gate_metrics`
and fails traffic regressions beyond tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np
import jax
from jax.core import ClosedJaxpr, Jaxpr

from repro.analysis.jaxpr_audit import _sub_jaxprs, entry_point_specs


def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _aval_bytes(aval) -> int:
    dtype = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", None)
    if dtype is None or shape is None:
        return 0
    return _nelems(shape) * np.dtype(dtype).itemsize


def _is_int(aval) -> bool:
    return np.dtype(getattr(aval, "dtype", np.float32)).kind in ("i", "u")


def _dot_macs(eqn) -> int:
    (lc, _rc), _ = eqn.params["dimension_numbers"]
    lshape = eqn.invars[0].aval.shape
    k = 1
    for ax in lc:
        k *= int(lshape[ax])
    return _nelems(eqn.outvars[0].aval.shape) * k


def _conv_macs(eqn) -> int:
    dnums = eqn.params["dimension_numbers"]
    rhs_spec = dnums.rhs_spec            # (out_feature, in_feature, *spatial)
    rhs_shape = eqn.invars[1].aval.shape
    k = int(rhs_shape[rhs_spec[1]])      # cin per feature group
    for ax in rhs_spec[2:]:
        k *= int(rhs_shape[ax])
    return _nelems(eqn.outvars[0].aval.shape) * k


@dataclasses.dataclass
class EntryCost:
    """Static cost of one traced entry point."""
    macs: int = 0
    int_macs: int = 0
    io_bytes: int = 0
    pallas_bytes: int = 0
    feature_bytes: int = 0
    pallas_traffic: Dict[str, int] = dataclasses.field(default_factory=dict)
    while_unbounded: bool = False

    @property
    def hbm_bytes(self) -> int:
        return self.io_bytes + self.pallas_bytes

    def to_dict(self, labels: Dict[str, str]) -> Dict[str, Any]:
        hbm = self.hbm_bytes
        return {
            **labels,
            "macs": self.macs,
            "int_macs": self.int_macs,
            "io_bytes": self.io_bytes,
            "pallas_bytes": self.pallas_bytes,
            "feature_hbm_bytes": self.feature_bytes,
            "hbm_bytes": hbm,
            "arith_intensity": (self.macs / hbm) if hbm else 0.0,
            "pallas_traffic": dict(sorted(self.pallas_traffic.items())),
            "while_unbounded": self.while_unbounded,
        }


#: every kernel in this repo streams its activations as rank-4 patch-batch
#: tensors (N, h, w, C) while weights/biases/scales ride as rank <= 3
#: stationary operands — rank is therefore the structural feature/weight
#: discriminator (block-shape vs array-shape would misclassify single-step
#: grids, where every block covers its whole array).
_FEATURE_RANK = 4


def _pallas_call_bytes(eqn) -> Tuple[str, int, int]:
    """(kernel name, total HBM<->VMEM bytes one launch of this pallas_call
    moves, the FEATURE subset of those bytes): the union of its operand and
    result arrays, with rank-4 patch-batch tensors counted as feature
    (activation) traffic — the inter-group bytes the paper's 79%-reduction
    claim is about. A layer-fused chain pays feature traffic at every group
    boundary; the group-fused megakernel holds features in VMEM scratch and
    pays it only at the chain's entry and exit."""
    gm = eqn.params["grid_mapping"]
    total = feat = 0
    for bm in getattr(gm, "block_mappings", ()):
        sds = getattr(bm, "array_shape_dtype", None)
        if sds is not None:
            b = _nelems(sds.shape) * np.dtype(sds.dtype).itemsize
            total += b
            if len(sds.shape) >= _FEATURE_RANK:
                feat += b
    if total == 0:                     # fallback: eqn-level avals
        for v in list(eqn.invars) + list(eqn.outvars):
            b = _aval_bytes(v.aval)
            total += b
            if len(getattr(v.aval, "shape", ())) >= _FEATURE_RANK:
                feat += b
    name_info = eqn.params.get("name_and_src_info")
    kname = getattr(name_info, "name", None) or str(name_info or "pallas")
    return kname, total, feat


def _walk(jaxpr: Jaxpr, mult: int, cost: EntryCost) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            m = _dot_macs(eqn) * mult
            cost.macs += m
            if _is_int(eqn.invars[0].aval) and _is_int(eqn.invars[1].aval):
                cost.int_macs += m
        elif name == "conv_general_dilated":
            m = _conv_macs(eqn) * mult
            cost.macs += m
            if _is_int(eqn.invars[0].aval) and _is_int(eqn.invars[1].aval):
                cost.int_macs += m
        elif name == "pallas_call":
            kname, nbytes, fbytes = _pallas_call_bytes(eqn)
            cost.pallas_bytes += nbytes * mult
            cost.feature_bytes += fbytes * mult
            cost.pallas_traffic[kname] = (
                cost.pallas_traffic.get(kname, 0) + nbytes * mult)
        sub_mult = mult
        if name == "scan":
            sub_mult = mult * int(eqn.params.get("length", 1))
        elif name == "while":
            cost.while_unbounded = True
        elif name == "pallas_call":
            # the kernel body's eqns run once PER GRID STEP over block-shaped
            # avals — without this multiplier a 3-step per-op launch would
            # report a third of the MACs its group-fused twin reports over
            # the same math, corrupting the layer-vs-group comparison.
            steps = 1
            for g in getattr(eqn.params["grid_mapping"], "grid", ()):
                steps *= int(g)
            sub_mult = mult * max(steps, 1)
        for sub in _sub_jaxprs(eqn.params):
            inner = sub.jaxpr if isinstance(sub, ClosedJaxpr) else sub
            _walk(inner, sub_mult, cost)


def price_jaxpr(closed: ClosedJaxpr) -> EntryCost:
    """Price one traced graph (shapes/dtypes only — no data dependence)."""
    cost = EntryCost()
    for var in list(closed.jaxpr.invars) + list(closed.jaxpr.outvars):
        cost.io_bytes += _aval_bytes(getattr(var, "aval", None))
    for const in closed.consts:
        cost.io_bytes += int(getattr(const, "nbytes", 0) or 0)
    _walk(closed.jaxpr, 1, cost)
    return cost


def run_cost_audit() -> Dict[str, Any]:
    """The whole pass: price every audited entry point. Returns the report's
    ``metrics["static_costs"]`` section; entries that fail to trace land in
    ``"errors"`` (and the baseline diff flags the coverage loss)."""
    entries: Dict[str, Any] = {}
    errors: Dict[str, str] = {}
    for name, spec in entry_point_specs().items():
        try:
            fn, args = spec.make()
            closed = jax.make_jaxpr(fn)(*args)
        except Exception as e:
            errors[name] = repr(e)
            continue
        entries[name] = price_jaxpr(closed).to_dict(spec.labels)
    out: Dict[str, Any] = {"entries": entries}
    if errors:
        out["errors"] = errors
    return out
