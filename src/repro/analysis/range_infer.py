"""Pass 3 — interval abstract interpretation over the traced jaxprs (ESSR3xx).

Where the jaxpr audit (pass 1) pattern-matches *hazards*, this pass computes
*guarantees*: a forward abstract interpretation that propagates value
intervals through every equation of an entry point's jaxpr — including
nested pjit / custom_jvp / shard_map bodies and the Pallas kernel jaxprs
themselves (refs modeled as cells, ``get``/``swap`` as reads/unions) — and
certifies the integer datapath the PAMS serving path runs on:

  ESSR301  an integer-valued site's interval exceeds its storage dtype (or
           a what-if accumulator budget passed by the caller): overflow is
           not provably absent. This is a proof failure, not a measurement.
  ESSR302  a fused group's minimal accumulator bit-width exceeds the bit
           budget (default 32 — the int32 accumulators the kernels declare
           via ``preferred_element_type``). Every group's minimal width is
           also *reported* against the paper's 24-bit ASIC accumulator
           chain (`PAPER_ACC_BITS`), as signed headroom.
  ESSR303  a degenerate quantization scale in a served `QuantPack`: an
           alpha below the step floor (``|alpha| < qmax * EPS``) collapses
           the site's codes — the lattice can no longer represent the
           activation distribution it was calibrated on.
  ESSR304  an interval-unsound op: the interpreter met a primitive it has
           no sound transfer rule for. It FAILS CLOSED — the op's outputs
           become unbounded and the violation is reported; the analyzer
           never guesses a range.

The domain is *mixed concrete/interval*: an equation whose inputs are all
concretely known (weights, geometry index maps, quant codes — everything
derived from the traced arguments that are not declared abstract) is folded
by executing the primitive for real, so the certified bounds are seeded from
the ACTUAL quantized weight codes and `QuantPack` alphas rather than worst
cases. Only the declared-abstract arguments (the frame in [0,1], the
Algorithm-1 thresholds) and everything data-dependent on them carry
intervals. This is what makes the dequant/requant chains analyzable at all:
``round(clip(w, -alpha, alpha) / step)`` folds exactly because ``alpha`` and
``step`` stay correlated through concrete evaluation, where a pure interval
domain would lose the relation and blow up.

Bounds are per-tensor scalar intervals (one (lo, hi) per value). Integer
matmuls and convolutions use a refined rule when one operand is concrete:
with activation codes in ``[l, h]`` and actual weight codes ``W``, the
accumulator is bounded by ``max_j(h*P_j + l*N_j)`` where ``P_j``/``N_j`` are
the per-output sums of positive/negative weights — the static analog of the
ASIC's worst-case-input sizing of its 24-bit accumulator chain.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.core import ClosedJaxpr, Jaxpr, Literal

from repro.analysis.jaxpr_audit import entry_point_specs
from repro.analysis.report import Violation

#: The ASIC accumulator chain the paper sizes (Sec. IV) — every group's
#: minimal bit-width is reported as signed headroom against this.
PAPER_ACC_BITS = 24

#: ESSR302 default budget: the int32 accumulators the kernel stack declares.
DEFAULT_BIT_BUDGET = 32

_INF = float("inf")


class UnsoundOpError(Exception):
    """Raised (and caught into ESSR304) when no sound transfer rule exists."""


@dataclasses.dataclass(frozen=True)
class Interval:
    """A per-tensor scalar interval: every element of the value lies in
    ``[lo, hi]``. ``Interval(-inf, inf)`` is TOP (nothing known)."""
    lo: float
    hi: float

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @property
    def finite(self) -> bool:
        return math.isfinite(self.lo) and math.isfinite(self.hi)

    def union(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))


TOP = Interval(-_INF, _INF)


def hull(v) -> Interval:
    """The interval hull of a value (identity on intervals; min/max of a
    concrete array)."""
    if isinstance(v, Interval):
        return v
    a = np.asarray(v)
    if a.size == 0:
        return Interval(0.0, 0.0)
    if a.dtype == bool:
        return Interval(float(a.min()), float(a.max()))
    return Interval(float(a.min()), float(a.max()))


def _is_concrete(v) -> bool:
    return not isinstance(v, (Interval, _Ref))


def bits_needed(lo: float, hi: float) -> Optional[int]:
    """Smallest two's-complement width representing every integer in
    [lo, hi]; None when unbounded."""
    if not (math.isfinite(lo) and math.isfinite(hi)):
        return None
    for b in range(1, 129):
        if -(2 ** (b - 1)) <= lo and hi <= 2 ** (b - 1) - 1:
            return b
    return None


def _dtype_bounds(dtype) -> Optional[Tuple[int, int]]:
    dt = np.dtype(dtype)
    if dt.kind in ("i", "u"):
        info = np.iinfo(dt)
        return int(info.min), int(info.max)
    return None


# ---------------------------------------------------------------------------
# interval arithmetic helpers
# ---------------------------------------------------------------------------

def _mul_bound(a: Interval, b: Interval) -> Interval:
    cands = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            if (x == 0.0 and math.isinf(y)) or (y == 0.0 and math.isinf(x)):
                cands.append(0.0)
            else:
                cands.append(x * y)
    return Interval(min(cands), max(cands))


def _monotone(fn: Callable[[float], float]) -> Callable:
    def rule(a: Interval) -> Interval:
        return Interval(float(fn(a.lo)), float(fn(a.hi)))
    return rule


# ---------------------------------------------------------------------------
# the interpreter
# ---------------------------------------------------------------------------

class _Ref:
    """A Pallas ref cell: the interval hull of everything ever stored."""

    def __init__(self, init=None):
        self.value = init       # None == never written (reads give TOP)

    def read(self):
        return TOP if self.value is None else self.value

    def store(self, v):
        self.value = v if self.value is None else \
            hull(self.value).union(hull(v))


@dataclasses.dataclass(frozen=True)
class SiteRecord:
    """One certified integer site (abstract integer arithmetic)."""
    group: str
    prim: str
    dtype: str
    lo: float
    hi: float
    bits: Optional[int]


#: Arithmetic primitives whose integer outputs are certified as accumulator
#: sites (data movement can never widen a value, so it is checked but not
#: tabulated).
_ACC_PRIMS = frozenset({
    "add", "sub", "mul", "dot_general", "conv_general_dilated",
    "reduce_sum", "cumsum", "scatter-add", "convert_element_type",
})


class RangeInterpreter:
    """Forward mixed concrete/interval interpretation of one entry point."""

    def __init__(self, entry: str, acc_bits: Optional[int] = None,
                 bit_budget: int = DEFAULT_BIT_BUDGET):
        self.entry = entry
        self.acc_bits = acc_bits          # what-if accumulator budget
        self.bit_budget = bit_budget
        self.sites: List[SiteRecord] = []
        self.violations: List[Violation] = []
        self._groups: List[str] = []      # pallas kernel name stack
        self._flagged: set = set()        # (code, site) dedup

    # -- bookkeeping --------------------------------------------------------

    @property
    def group(self) -> str:
        return self._groups[-1] if self._groups else "top"

    def _flag(self, code: str, site_tail: str, message: str) -> None:
        site = f"entrypoint:{self.entry}::{site_tail}"
        if (code, site) in self._flagged:
            return
        self._flagged.add((code, site))
        self.violations.append(Violation(code, site, message))

    # -- evaluation ---------------------------------------------------------

    def run_closed(self, closed: ClosedJaxpr, invals: Sequence[Any]) -> List:
        return self.run_jaxpr(closed.jaxpr, closed.consts, invals)

    def run_jaxpr(self, jaxpr: Jaxpr, consts: Sequence[Any],
                  invals: Sequence[Any]) -> List:
        env: Dict[Any, Any] = {}

        def read(var):
            if isinstance(var, Literal):
                return np.asarray(var.val)
            return env[var]

        if len(consts) != len(jaxpr.constvars) or \
                len(invals) != len(jaxpr.invars):
            raise UnsoundOpError("jaxpr arity mismatch")
        for cv, c in zip(jaxpr.constvars, consts):
            env[cv] = c if isinstance(c, (Interval, _Ref)) else np.asarray(c)
        for iv, v in zip(jaxpr.invars, invals):
            env[iv] = v
        for eqn in jaxpr.eqns:
            outs = self.eval_eqn(eqn, [read(v) for v in eqn.invars])
            for ov, o in zip(eqn.outvars, outs):
                env[ov] = o
        return [read(v) for v in jaxpr.outvars]

    def eval_eqn(self, eqn, invals: Sequence[Any]) -> List:
        name = eqn.primitive.name
        try:
            if name in _STRUCTURED:
                outs = _STRUCTURED[name](self, eqn, invals)
            elif all(_is_concrete(v) for v in invals):
                outs = self._concrete_bind(eqn, invals)
            else:
                rule = _RULES.get(name)
                if rule is None:
                    raise UnsoundOpError(name)
                outs = rule(self, eqn, invals)
        except UnsoundOpError as e:
            self._flag("ESSR304", f"{self.group}::{name}",
                       f"no sound transfer rule for primitive '{e}' — "
                       f"outputs treated as unbounded")
            outs = [TOP] * len(eqn.outvars)
        except Exception as e:   # a rule crash is an unsoundness, not a skip
            self._flag("ESSR304", f"{self.group}::{name}",
                       f"transfer rule for '{name}' failed closed: {e!r}")
            outs = [TOP] * len(eqn.outvars)
        if len(outs) != len(eqn.outvars):
            outs = list(outs) + [TOP] * (len(eqn.outvars) - len(outs))
        self._certify(eqn, invals, outs)
        return outs

    def _concrete_bind(self, eqn, invals: Sequence[Any]) -> List:
        out = eqn.primitive.bind(*(jnp.asarray(v) for v in invals),
                                 **eqn.params)
        outs = out if eqn.primitive.multiple_results else [out]
        return [np.asarray(o) for o in outs]

    # -- certification (ESSR301/302 raw material) ---------------------------

    def _certify(self, eqn, invals, outs) -> None:
        name = eqn.primitive.name
        if name in ("get", "swap", "addupdate"):
            # a ref read/write cannot overflow by itself — the value landing
            # in the ref was already certified at the cast that produced it,
            # and `swap`'s returned old value on a fresh output buffer is
            # discarded garbage, not a computed site
            return
        for var, out in zip(eqn.outvars, outs):
            aval = getattr(var, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is None:
                continue
            bounds = _dtype_bounds(dt)
            if bounds is None:
                continue
            # the mathematical (pre-wraparound) interval of this site: for a
            # cast it is the INPUT's hull — the cast itself is where an
            # out-of-range value becomes undefined behavior
            if name == "convert_element_type" and invals:
                mi = hull(invals[0])
            else:
                mi = hull(out)
            abstract = isinstance(out, Interval) or (
                name == "convert_element_type" and invals
                and not _is_concrete(invals[0]))
            if not abstract and name != "convert_element_type":
                continue            # concrete arithmetic is exact by fold
            budget_lo, budget_hi = bounds
            budget_bits = None
            if self.acc_bits is not None and abstract \
                    and name in _ACC_PRIMS:
                budget_bits = self.acc_bits
                budget_lo = max(budget_lo, -(2 ** (self.acc_bits - 1)))
                budget_hi = min(budget_hi, 2 ** (self.acc_bits - 1) - 1)
            if mi.lo < budget_lo or mi.hi > budget_hi:
                what = (f"the {budget_bits}-bit accumulator budget"
                        if budget_bits is not None else f"dtype {dt}")
                self._flag(
                    "ESSR301", f"{self.group}::{name}",
                    f"interval [{mi.lo:.4g}, {mi.hi:.4g}] of '{name}' "
                    f"({dt}) exceeds {what}: overflow not provably absent")
            if abstract and name in _ACC_PRIMS:
                self.sites.append(SiteRecord(
                    self.group, name, str(dt), mi.lo, mi.hi,
                    bits_needed(mi.lo, mi.hi)))


# ---------------------------------------------------------------------------
# structured primitives: calls, pallas, refs
# ---------------------------------------------------------------------------

def _call_sub(interp: RangeInterpreter, eqn, invals, key: str) -> List:
    sub = eqn.params[key]
    if isinstance(sub, ClosedJaxpr):
        jaxpr, consts = sub.jaxpr, sub.consts
    else:
        jaxpr, consts = sub, ()
    n = len(jaxpr.invars)
    if len(invals) == n:
        args = invals
    elif len(invals) > n:        # leading consts packed into invars
        args = invals[len(invals) - n:]
    else:
        raise UnsoundOpError(f"{eqn.primitive.name} arity")
    return interp.run_jaxpr(jaxpr, consts, args)


def _eval_scan(interp: RangeInterpreter, eqn, invals) -> List:
    """``lax.scan`` / ``lax.map``. Consts and the carry enter the body at
    full shape; each xs operand is sliced along its leading axis, so the
    stacked operand's hull (which covers every slice) is a sound
    per-iteration seed. The carry is widened by hull-union across body
    passes until it stops growing — every pass re-walks the body, so
    accumulator sites stay certified under the fixpoint seeds. The ys hulls
    from the converged pass bound every iteration's slice. Fails closed if
    the carry keeps growing past the round cap."""
    sub = eqn.params["jaxpr"]
    if isinstance(sub, ClosedJaxpr):
        jaxpr, consts = sub.jaxpr, sub.consts
    else:
        jaxpr, consts = sub, ()
    n_consts = eqn.params["num_consts"]
    n_carry = eqn.params["num_carry"]
    if len(jaxpr.invars) != len(invals):
        raise UnsoundOpError("scan arity")
    const_vals = list(invals[:n_consts])
    carry = [hull(v) for v in invals[n_consts:n_consts + n_carry]]
    xs = [hull(v) for v in invals[n_consts + n_carry:]]
    outs = interp.run_jaxpr(jaxpr, consts, const_vals + carry + xs)
    for _ in range(64):
        grown = [c.union(hull(o)) for c, o in zip(carry, outs[:n_carry])]
        if grown == carry:
            break
        carry = grown
        outs = interp.run_jaxpr(jaxpr, consts, const_vals + carry + xs)
    else:
        raise UnsoundOpError("scan carry did not converge")
    return list(carry) + [hull(o) for o in outs[n_carry:]]


def _eval_pallas(interp: RangeInterpreter, eqn, invals) -> List:
    gm = eqn.params["grid_mapping"]
    jaxpr = eqn.params["jaxpr"]
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    n_idx = getattr(gm, "num_index_operands", 0)
    n_in = gm.num_inputs
    n_out = gm.num_outputs
    n_scr = getattr(gm, "num_scratch_operands", 0)
    if len(jaxpr.invars) != n_in + n_out + n_scr:
        raise UnsoundOpError("pallas kernel arity")
    in_refs = [_Ref(v) for v in invals[n_idx:n_idx + n_in]]
    out_refs = [_Ref() for _ in range(n_out)]
    scr_refs = [_Ref() for _ in range(n_scr)]
    name_info = eqn.params.get("name_and_src_info")
    kname = getattr(name_info, "name", None) or str(name_info or "pallas")
    interp._groups.append(kname)
    try:
        interp.run_jaxpr(jaxpr, (), in_refs + out_refs + scr_refs)
    finally:
        interp._groups.pop()
    # all grid steps run the same abstract body; the union of stores bounds
    # every block of the output
    return [r.read() for r in out_refs]


def _eval_get(interp, eqn, invals):
    if not isinstance(invals[0], _Ref):
        raise UnsoundOpError("get on non-ref")
    return [invals[0].read()]


def _eval_swap(interp, eqn, invals):
    ref = invals[0]
    if not isinstance(ref, _Ref):
        raise UnsoundOpError("swap on non-ref")
    old = ref.read()
    ref.store(invals[1])
    return [old]


def _eval_addupdate(interp, eqn, invals):
    ref = invals[0]
    if not isinstance(ref, _Ref):
        raise UnsoundOpError("addupdate on non-ref")
    cur = hull(ref.read())
    add = hull(invals[1])
    ref.store(Interval(cur.lo + min(0.0, add.lo), cur.hi + max(0.0, add.hi)))
    return []


_STRUCTURED: Dict[str, Callable] = {
    "pjit": lambda i, e, v: _call_sub(i, e, v, "jaxpr"),
    "closed_call": lambda i, e, v: _call_sub(i, e, v, "call_jaxpr"),
    "core_call": lambda i, e, v: _call_sub(i, e, v, "call_jaxpr"),
    "remat2": lambda i, e, v: _call_sub(i, e, v, "jaxpr"),
    "custom_jvp_call": lambda i, e, v: _call_sub(i, e, v, "call_jaxpr"),
    "custom_vjp_call_jaxpr": lambda i, e, v: _call_sub(i, e, v, "fun_jaxpr"),
    "custom_vjp_call": lambda i, e, v: _call_sub(i, e, v, "call_jaxpr"),
    "shard_map": lambda i, e, v: _call_sub(i, e, v, "jaxpr"),
    "scan": _eval_scan,
    "pallas_call": _eval_pallas,
    "get": _eval_get,
    "swap": _eval_swap,
    "addupdate": _eval_addupdate,
}


# ---------------------------------------------------------------------------
# transfer rules (at least one operand abstract)
# ---------------------------------------------------------------------------

def _r(fn):
    """Adapt an Interval-only rule to the (interp, eqn, invals) signature."""
    def rule(interp, eqn, invals):
        return [fn(*(hull(v) for v in invals))]
    return rule


def _bool_out(interp, eqn, invals):
    return [Interval(0.0, 1.0)]


def _identity(interp, eqn, invals):
    return [hull(invals[0])]


def _union_all(interp, eqn, invals):
    out = hull(invals[0])
    for v in invals[1:]:
        out = out.union(hull(v))
    return [out]


def _select_n(interp, eqn, invals):
    return [_union_all(interp, eqn, invals[1:])[0]]


def _pad(interp, eqn, invals):
    return [hull(invals[0]).union(hull(invals[1]))]


def _gather(interp, eqn, invals):
    out = hull(invals[0])
    if "fill" in str(eqn.params.get("mode", "")).lower():
        out = out.union(Interval(0.0, 0.0))
    return [out]


def _scatter_add(interp, eqn, invals):
    op, upd = hull(invals[0]), hull(invals[2])
    n = max(1, int(np.prod(getattr(invals[2], "shape", ())
                           if _is_concrete(invals[2])
                           else eqn.invars[2].aval.shape)))
    return [Interval(op.lo + min(0.0, n * upd.lo),
                     op.hi + max(0.0, n * upd.hi))]


def _scatter_set(interp, eqn, invals):
    return [hull(invals[0]).union(hull(invals[2]))]


def _div(interp, eqn, invals):
    num, den = hull(invals[0]), hull(invals[1])
    if den.lo <= 0.0 <= den.hi:
        return [TOP]
    return [_mul_bound(num, Interval(1.0 / den.hi, 1.0 / den.lo))]


def _reduce_extent(eqn) -> int:
    axes = eqn.params.get("axes", ())
    shape = eqn.invars[0].aval.shape
    n = 1
    for ax in axes:
        n *= int(shape[ax])
    return max(1, n)


def _reduce_sum(interp, eqn, invals):
    a = hull(invals[0])
    n = _reduce_extent(eqn)
    return [Interval(min(n * a.lo, a.lo, 0.0), max(n * a.hi, a.hi, 0.0))]


def _cumsum(interp, eqn, invals):
    a = hull(invals[0])
    n = max(1, int(eqn.invars[0].aval.shape[eqn.params.get("axis", 0)]))
    return [Interval(min(a.lo, n * a.lo), max(a.hi, n * a.hi))]


def _argminmax(interp, eqn, invals):
    axes = eqn.params.get("axes", (0,))
    n = int(eqn.invars[0].aval.shape[axes[0]])
    return [Interval(0.0, float(max(0, n - 1)))]


def _convert(interp, eqn, invals):
    a = hull(invals[0])
    new_dtype = eqn.params.get("new_dtype")
    bounds = _dtype_bounds(new_dtype) if new_dtype is not None else None
    if bounds is not None:
        # once _certify reports an out-of-range cast, the landed value can
        # be anything in the dtype (wraparound) — clamp so one failure does
        # not cascade into fake downstream overflow proofs
        lo = max(a.lo, float(bounds[0]))
        hi = min(a.hi, float(bounds[1]))
        if lo > hi:
            return [Interval(float(bounds[0]), float(bounds[1]))]
        if a.lo < bounds[0] or a.hi > bounds[1]:
            return [Interval(float(bounds[0]), float(bounds[1]))]
        return [Interval(lo, hi)]
    return [a]


def _integer_pow(interp, eqn, invals):
    a = hull(invals[0])
    y = int(eqn.params["y"])
    if y < 0:
        return [_div(interp, eqn, [np.float64(1.0),
                                   _pow_iv(a, -y)])[0]]
    return [_pow_iv(a, y)]


def _pow_iv(a: Interval, y: int) -> Interval:
    cands = [a.lo ** y, a.hi ** y]
    if y % 2 == 0 and a.lo <= 0.0 <= a.hi:
        cands.append(0.0)
    return Interval(min(cands), max(cands))


def _contracted_sides(eqn, invals):
    """(abstract interval, concrete array, contracting axes of the concrete
    side, free axis to keep) — or None when both sides are abstract."""
    (lc, rc), _ = eqn.params["dimension_numbers"]
    lhs, rhs = invals[0], invals[1]
    if _is_concrete(rhs) and not _is_concrete(lhs):
        return hull(lhs), np.asarray(rhs, dtype=np.float64), tuple(rc)
    if _is_concrete(lhs) and not _is_concrete(rhs):
        return hull(rhs), np.asarray(lhs, dtype=np.float64), tuple(lc)
    return None


def _dot_general(interp, eqn, invals):
    refined = _contracted_sides(eqn, invals)
    if refined is not None:
        x, w, contract = refined
        pos = np.maximum(w, 0.0).sum(axis=contract)
        neg = np.minimum(w, 0.0).sum(axis=contract)
        hi = float(np.max(x.hi * pos + x.lo * neg)) if pos.size else 0.0
        lo = float(np.min(x.lo * pos + x.hi * neg)) if pos.size else 0.0
        return [Interval(lo, hi)]
    (lc, _rc), _ = eqn.params["dimension_numbers"]
    lshape = eqn.invars[0].aval.shape
    k = 1
    for ax in lc:
        k *= int(lshape[ax])
    p = _mul_bound(hull(invals[0]), hull(invals[1]))
    return [Interval(min(0.0, k * p.lo), max(0.0, k * p.hi))]


def _conv(interp, eqn, invals):
    lhs, rhs = invals[0], invals[1]
    dnums = eqn.params["dimension_numbers"]
    rhs_spec = dnums.rhs_spec          # (out_feature, in_feature, *spatial)
    x = hull(lhs)
    if any(p != (0, 0) for p in eqn.params.get("padding", ())):
        x = x.union(Interval(0.0, 0.0))   # zero padding enters the window
    if _is_concrete(rhs):
        w = np.asarray(rhs, dtype=np.float64)
        axes = tuple(ax for ax in range(w.ndim) if ax != rhs_spec[0])
        pos = np.maximum(w, 0.0).sum(axis=axes)
        neg = np.minimum(w, 0.0).sum(axis=axes)
        hi = float(np.max(x.hi * pos + x.lo * neg))
        lo = float(np.min(x.lo * pos + x.hi * neg))
        return [Interval(lo, hi)]
    w_shape = eqn.invars[1].aval.shape
    k = int(w_shape[rhs_spec[1]])
    for ax in rhs_spec[2:]:
        k *= int(w_shape[ax])
    p = _mul_bound(x, hull(rhs))
    return [Interval(min(0.0, k * p.lo), max(0.0, k * p.hi))]


_RULES: Dict[str, Callable] = {
    # elementwise arithmetic
    "add": _r(lambda a, b: Interval(a.lo + b.lo, a.hi + b.hi)),
    "sub": _r(lambda a, b: Interval(a.lo - b.hi, a.hi - b.lo)),
    "mul": _r(_mul_bound),
    "div": _div,
    "neg": _r(lambda a: Interval(-a.hi, -a.lo)),
    "abs": _r(lambda a: Interval(
        0.0 if a.lo <= 0.0 <= a.hi else min(abs(a.lo), abs(a.hi)),
        max(abs(a.lo), abs(a.hi)))),
    "max": _r(lambda a, b: Interval(max(a.lo, b.lo), max(a.hi, b.hi))),
    "min": _r(lambda a, b: Interval(min(a.lo, b.lo), min(a.hi, b.hi))),
    "clamp": _r(lambda lo, x, hi: Interval(
        min(max(x.lo, lo.lo), hi.hi), min(max(x.hi, lo.hi), hi.hi))),
    "round": _r(_monotone(np.rint)),
    "floor": _r(_monotone(math.floor)),
    "ceil": _r(_monotone(math.ceil)),
    "sign": _r(lambda a: Interval(-1.0, 1.0)),
    "sqrt": _r(lambda a: Interval(math.sqrt(max(a.lo, 0.0)),
                                  math.sqrt(max(a.hi, 0.0)))),
    "rsqrt": lambda i, e, v: (
        [TOP] if hull(v[0]).lo <= 0.0
        else [Interval(1.0 / math.sqrt(hull(v[0]).hi),
                       1.0 / math.sqrt(hull(v[0]).lo))]),
    "exp": _r(_monotone(math.exp)),
    "log": lambda i, e, v: (
        [TOP] if hull(v[0]).lo <= 0.0
        else [Interval(math.log(hull(v[0]).lo), math.log(hull(v[0]).hi))]),
    "log1p": lambda i, e, v: (
        [TOP] if hull(v[0]).lo <= -1.0
        else [Interval(math.log1p(hull(v[0]).lo),
                       math.log1p(hull(v[0]).hi))]),
    "expm1": _r(_monotone(math.expm1)),
    "tanh": _r(lambda a: Interval(math.tanh(a.lo), math.tanh(a.hi))),
    "logistic": _r(lambda a: Interval(1.0 / (1.0 + math.exp(-a.lo)),
                                      1.0 / (1.0 + math.exp(-a.hi)))),
    "sin": _r(lambda a: Interval(-1.0, 1.0)),
    "cos": _r(lambda a: Interval(-1.0, 1.0)),
    "integer_pow": _integer_pow,
    "square": _r(lambda a: _pow_iv(a, 2)),
    "stop_gradient": _identity,
    "copy": _identity,
    "is_finite": _bool_out,
    # comparisons / logic
    "lt": _bool_out, "le": _bool_out, "gt": _bool_out, "ge": _bool_out,
    "eq": _bool_out, "ne": _bool_out,
    "and": _bool_out, "or": _bool_out, "xor": _bool_out, "not": _bool_out,
    "select_n": _select_n,
    # shape / data movement (per-tensor hull is invariant)
    "reshape": _identity, "transpose": _identity, "squeeze": _identity,
    "expand_dims": _identity, "slice": _identity, "rev": _identity,
    "broadcast_in_dim": _identity, "dynamic_slice": _identity,
    "dynamic_update_slice": lambda i, e, v: [hull(v[0]).union(hull(v[1]))],
    "concatenate": _union_all,
    "pad": _pad,
    "gather": _gather,
    "sort": _identity,
    "convert_element_type": _convert,
    # reductions / scans
    "reduce_sum": _reduce_sum,
    "reduce_max": _identity, "reduce_min": _identity,
    "reduce_and": _bool_out, "reduce_or": _bool_out,
    "cumsum": _cumsum,
    "argmax": _argminmax, "argmin": _argminmax,
    # contractions
    "dot_general": _dot_general,
    "conv_general_dilated": _conv,
    # scatters
    "scatter-add": _scatter_add,
    "scatter": _scatter_set,
}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RangeResult:
    """Everything the range pass derives from one entry point."""
    entry: str
    outputs: Any                       # pytree of Interval / concrete values
    sites: List[SiteRecord]
    violations: List[Violation]

    def groups(self) -> Dict[str, Dict[str, Any]]:
        """Per fused group: minimal accumulator bit-width + headroom."""
        out: Dict[str, Dict[str, Any]] = {}
        for s in self.sites:
            g = out.setdefault(s.group, {"acc_bits": 0, "dominant": None,
                                         "n_sites": 0})
            g["n_sites"] += 1
            b = s.bits if s.bits is not None else 999
            if b > g["acc_bits"]:
                g["acc_bits"] = b
                g["dominant"] = {"prim": s.prim, "dtype": s.dtype,
                                 "lo": s.lo, "hi": s.hi}
        for g in out.values():
            g["headroom_vs_paper"] = PAPER_ACC_BITS - g["acc_bits"]
        return out


def seed_values(args: Tuple, abstract: Dict[int, Tuple[float, float]]
                ) -> List[Any]:
    """Flattened invar seeds for ``fn(*args)``: declared-abstract arguments
    become intervals, everything else keeps its concrete traced value."""
    seeds: List[Any] = []
    for i, a in enumerate(args):
        leaves = jax.tree_util.tree_leaves(a)
        if i in abstract:
            lo, hi = abstract[i]
            seeds.extend([Interval(float(lo), float(hi))] * len(leaves))
        else:
            seeds.extend(np.asarray(leaf) for leaf in leaves)
    return seeds


def infer_ranges(fn: Callable, args: Tuple,
                 abstract: Dict[int, Tuple[float, float]],
                 entry: str = "adhoc",
                 acc_bits: Optional[int] = None,
                 bit_budget: int = DEFAULT_BIT_BUDGET) -> RangeResult:
    """Trace ``fn(*args)`` and abstract-interpret the jaxpr.

    ``abstract`` maps top-level argument positions to seed intervals; every
    other argument is folded concretely. Returns per-output abstract values
    (in the function's output pytree structure), the certified integer
    sites, and any ESSR301/302/304 violations."""
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args)
    interp = RangeInterpreter(entry, acc_bits=acc_bits,
                              bit_budget=bit_budget)
    seeds = seed_values(args, abstract)
    if len(seeds) != len(closed.jaxpr.invars):
        raise ValueError(
            f"seed/invar arity mismatch: {len(seeds)} seeds for "
            f"{len(closed.jaxpr.invars)} invars")
    outvals = interp.run_closed(closed, seeds)
    outputs = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(out_shape), outvals)
    result = RangeResult(entry, outputs, interp.sites, interp.violations)
    for group, info in result.groups().items():
        if info["acc_bits"] > bit_budget:
            result.violations.append(Violation(
                "ESSR302", f"entrypoint:{entry}::{group}",
                f"fused group needs a {info['acc_bits']}-bit accumulator, "
                f"over the {bit_budget}-bit budget "
                f"(dominant: {info['dominant']})"))
    return result


def check_quant_scales(pack, label: str) -> List[Violation]:
    """ESSR303 over a served `QuantPack`: an alpha below the step floor
    (``|alpha| < qmax * EPS``) floors the quantization step, so the codes of
    that site collapse instead of spanning the lattice."""
    from repro.quant.pams import EPS
    out: List[Violation] = []
    floor = pack.qmax * EPS
    for width, sites in pack.scales:
        for site, alpha in sites:
            if abs(alpha) < floor:
                out.append(Violation(
                    "ESSR303", f"quantpack[{label}]:w{width}:{site}",
                    f"alpha {alpha:.3g} below the step floor "
                    f"{floor:.3g} (qmax*EPS): codes at this site collapse"))
    return out


def bitwidth_metrics(results: List[RangeResult]) -> Dict[str, Any]:
    """The report's ``metrics["bitwidth"]`` section."""
    entries: Dict[str, Any] = {}
    for r in results:
        entries[r.entry] = {"groups": r.groups()}
    return {"paper_acc_bits": PAPER_ACC_BITS, "entries": entries}


def run_range_audit(bit_budget: int = DEFAULT_BIT_BUDGET
                    ) -> Tuple[List[Violation], Dict[str, Any]]:
    """The whole pass: certify every audited entry point + the served quant
    packs. Returns (violations, bitwidth metrics section)."""
    from repro.analysis.jaxpr_audit import _audit_setup

    violations: List[Violation] = []
    results: List[RangeResult] = []
    for name, spec in entry_point_specs().items():
        try:
            fn, args = spec.make()
            res = infer_ranges(fn, args, spec.abstract, entry=name,
                               bit_budget=bit_budget)
        except Exception as e:
            violations.append(Violation(
                "ESSR304", f"entrypoint:{name}",
                f"entry point failed to trace/interpret: {e!r}"))
            continue
        results.append(res)
        violations.extend(res.violations)
    setup = _audit_setup()
    violations.extend(check_quant_scales(setup.pack, "int8"))
    violations.extend(check_quant_scales(setup.pack_fxp10, "fxp10"))
    return violations, bitwidth_metrics(results)
