"""Pass 1 — jaxpr audit of the real inference entry points (ESSR1xx).

Traces the engine's compiled surfaces (`core.pipeline.fused_frame_fn`, the
multi-stream admission tick `fused_stream_frame_fn`, the sharded shard_map
forward, the integer qconv kernel chain, `edge_score`)
with `jax.make_jaxpr` on a small-but-representative configuration and walks
every equation — including nested pjit / shard_map / pallas_call / control-
flow sub-jaxprs — for the graph hazards the 8K@30FPS budget cannot absorb:

  ESSR101  host callbacks / infeed-outfeed transfers inside the graph: a
           single one re-introduces the per-frame host round-trip the fused
           dispatch exists to eliminate.
  ESSR102  fp64/complex128 values or f32->f64 promotions anywhere, and
           weak-typed *outputs* of the whole graph: silent widening doubles
           the SRAM/HBM traffic budget the paper's dataflow argument rests
           on, and a weak-typed output re-promotes downstream consumers.
  ESSR103  scatters without a determinism guarantee: ``mode=None`` (backend-
           dependent out-of-bounds semantics), or set-semantics ``scatter``
           with ``unique_indices=False`` (which update wins on a duplicate
           index is undefined). The overlap-add fusion and capacity dispatch
           must stay bit-reproducible across backends.
  ESSR104  constants baked into the graph above a byte budget: the geometry
           index maps close over deliberately (small), but an accidentally
           captured weight tree or frame silently bloats every executable.
  ESSR105  recompile leaks: re-runs the fused executable with perturbed
           thresholds (traced arguments) and with a within-bucket capacity
           perturbation, and fails if either re-lowers — `ExecutionPlan`'s
           contract is that Algorithm-1 adaptation never recompiles and
           capacities snap to the bucket ladder.

Everything here is CPU-safe: Pallas enters the graph via ``interpret=True``
and the shard mesh is a single host device.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.core import ClosedJaxpr, Jaxpr, Literal

from repro.analysis.report import Violation

#: Primitives that put the host on the traced path (ESSR101). Matched by
#: exact name plus a "callback" substring catch-all for version drift.
HOST_PRIMITIVES = frozenset({
    "io_callback", "pure_callback", "python_callback", "debug_callback",
    "infeed", "outfeed", "host_local_array_to_global_array",
})

#: Default ESSR104 budget: the largest constant a graph may bake in. The
#: audit geometries keep legitimate index-map constants well under this;
#: a captured weight tree or frame blows straight past it.
DEFAULT_CONST_BUDGET = 1 << 20          # 1 MiB

_WIDE_DTYPES = ("float64", "complex128")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: Dict) -> Iterator[ClosedJaxpr | Jaxpr]:
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for vv in vs:
            if isinstance(vv, (ClosedJaxpr, Jaxpr)):
                yield vv


def iter_eqns(jaxpr: Jaxpr) -> Iterator:
    """Every equation of ``jaxpr``, recursing into sub-jaxprs (pjit bodies,
    shard_map bodies, pallas kernels, scan/cond/while branches, custom-vjp
    call jaxprs)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            inner = sub.jaxpr if isinstance(sub, ClosedJaxpr) else sub
            yield from iter_eqns(inner)


def iter_consts(closed: ClosedJaxpr) -> Iterator:
    """Every constant of ``closed`` and of any nested ClosedJaxpr, plus
    every Literal bound as an equation input."""
    yield from closed.consts
    for eqn in iter_eqns(closed.jaxpr):
        for var in eqn.invars:
            if isinstance(var, Literal):
                yield var.val
        for sub in _sub_jaxprs(eqn.params):
            if isinstance(sub, ClosedJaxpr):
                yield from sub.consts


# ---------------------------------------------------------------------------
# per-graph rules (ESSR101-104)
# ---------------------------------------------------------------------------

def audit_jaxpr(closed: ClosedJaxpr, entry: str,
                const_budget: int = DEFAULT_CONST_BUDGET) -> List[Violation]:
    """Walk one traced graph for ESSR101/102/103/104."""
    out: List[Violation] = []
    site = f"entrypoint:{entry}"

    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in HOST_PRIMITIVES or "callback" in name:
            out.append(Violation(
                "ESSR101", site,
                f"host primitive '{name}' inside the traced graph"))
        for var in eqn.outvars:
            dt = getattr(getattr(var, "aval", None), "dtype", None)
            if dt is not None and str(dt) in _WIDE_DTYPES:
                out.append(Violation(
                    "ESSR102", site,
                    f"'{name}' produces {dt} — wide-dtype promotion in the "
                    f"graph"))
        if name.startswith("scatter"):
            mode = eqn.params.get("mode")
            if mode is None:
                out.append(Violation(
                    "ESSR103", site,
                    f"'{name}' with mode=None: out-of-bounds semantics are "
                    f"backend-dependent"))
            if name == "scatter" and not eqn.params.get("unique_indices"):
                out.append(Violation(
                    "ESSR103", site,
                    "set-semantics scatter with unique_indices=False: which "
                    "update wins on a duplicate index is undefined"))

    for var in closed.jaxpr.outvars:
        aval = getattr(var, "aval", None)
        if getattr(aval, "weak_type", False):
            out.append(Violation(
                "ESSR102", site,
                f"graph output {aval} is weak-typed; downstream consumers "
                f"re-promote on contact"))

    for const in iter_consts(closed):
        nbytes = getattr(const, "nbytes", None)
        if nbytes is None:
            try:
                nbytes = np.asarray(const).nbytes
            except Exception:
                continue
        if nbytes > const_budget:
            shape = getattr(const, "shape", ())
            out.append(Violation(
                "ESSR104", site,
                f"baked-in constant of {nbytes} bytes (shape {shape}) "
                f"exceeds the {const_budget}-byte budget"))
    return out


# ---------------------------------------------------------------------------
# recompile-leak check (ESSR105)
# ---------------------------------------------------------------------------

def check_recompile(fn, args_a: Tuple, args_b: Tuple, entry: str,
                    expect: str = "perturbed traced arguments"
                    ) -> List[Violation]:
    """Run a jitted ``fn`` with two argument tuples that `ExecutionPlan`
    promises share one executable, and fail if the jit cache re-lowered.

    Relies on the jit cache-size introspection every supported jax version
    exposes; a jax build without it makes the check vacuous (reported as
    clean, not as a crash)."""
    cache_size = getattr(fn, "_cache_size", None)
    if cache_size is None:
        return []
    jax.block_until_ready(fn(*args_a))
    first = cache_size()
    jax.block_until_ready(fn(*args_b))
    second = cache_size()
    if second > first:
        return [Violation(
            "ESSR105", f"entrypoint:{entry}",
            f"{expect} re-lowered the executable "
            f"(jit cache grew {first} -> {second})")]
    return []


# ---------------------------------------------------------------------------
# the audited entry points
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class AuditSetup:
    """The shared toy-scale audit configuration (see `_audit_setup`)."""
    cfg: object
    params: object
    geom: object
    frame: jax.Array
    patches: jax.Array
    pack: object            # int8 QuantPack
    pack_fxp10: object      # paper-faithful FXP10 QuantPack


@dataclasses.dataclass(frozen=True, eq=False)
class EntrySpec:
    """One audited entry point, in the form every analysis pass consumes.

    ``make`` is a lazy thunk returning ``(fn, args)`` — lazy so a broken
    entry point reports as its own audit failure instead of killing the
    whole pass. ``abstract`` maps top-level argument positions to the
    interval the range pass seeds them with (the proof quantifies over these
    — frames over [0,1], thresholds over their plausible band); every other
    argument is seeded with its CONCRETE traced value (real weights, real
    quant codes). ``labels`` is the (backend, quant, dispatch) identity the
    cost report keys rows by.
    """
    name: str
    make: Callable[[], Tuple[Callable, Tuple]]
    abstract: Dict[int, Tuple[float, float]]
    labels: Dict[str, str]


def _audit_setup() -> AuditSetup:
    """Small-but-complete audit configuration: a 3-subnet supernet, a
    64x64 frame (3x3 patch grid with real overlap), and calibrated int8 +
    fxp10 packs — every routing/fusion/quant feature of the serving graph is
    exercised at toy scale."""
    from repro.core.patching import get_geometry
    from repro.models.essr import ESSRConfig, init_essr
    from repro.quant.pams import build_quant_pack

    cfg = ESSRConfig(scale=2, n_sfb=2, channels=8)
    params = init_essr(jax.random.PRNGKey(0), cfg)
    geom = get_geometry(64, 64, 32, 2, 2)
    frame = jnp.linspace(0.0, 1.0, 64 * 64 * 3,
                         dtype=jnp.float32).reshape(64, 64, 3)
    patches = geom.extract(frame)
    pack = build_quant_pack(params, cfg, "int8", patches)
    pack_fxp10 = build_quant_pack(params, cfg, "fxp10", patches)
    return AuditSetup(cfg, params, geom, frame, patches, pack, pack_fxp10)


#: Seed intervals: frames/patches live in [0,1]; Algorithm-1 thresholds stay
#: inside the edge-score band (edge scores of [0,1] frames are bounded far
#: below this).
_FRAME_IV = (0.0, 1.0)
_THRESH_IV = (0.0, 512.0)


def entry_point_specs() -> Dict[str, EntrySpec]:
    """Every audited entry point — the (backend, quant, dispatch) matrix the
    jaxpr audit walks, the range pass certifies, and the cost pass prices."""
    s = _audit_setup()
    cfg, params, frame, patches = s.cfg, s.params, s.frame, s.patches

    def fused(pack=None, backend="ref", interpret=None, fusion="layer"):
        def make():
            from repro.core.pipeline import fused_frame_fn
            fn = fused_frame_fn(s.geom, (0, 4, 4), cfg, backend, interpret,
                                None, pack, fusion)
            return fn, (params, frame, 8.0, 40.0)
        return make

    def mux(streams=2):
        def make():
            from repro.core.pipeline import fused_stream_frame_fn
            fn = fused_stream_frame_fn(s.geom, streams, (0, 8, 8), cfg,
                                       "ref", None, None, None)
            frames = jnp.stack([frame] * streams)
            ones = jnp.ones((streams,), jnp.float32)
            quotas = jnp.full((streams,), 4, jnp.int32)
            return fn, (params, frames, 8.0 * ones, 40.0 * ones, quotas)
        return make

    def sharded():
        from repro.core.pipeline import _sharded_forward_fn
        from repro.launch.mesh import make_patch_mesh
        fn = _sharded_forward_fn("ref", make_patch_mesh(1), cfg, 8, None,
                                 None)
        return fn, (params, patches)

    def qconv(pack, ref: bool):
        def make():
            from repro.kernels.qconv import (essr_forward_qkernels,
                                             essr_forward_qref)
            if ref:
                fn = lambda p, x: essr_forward_qref(p, x, cfg, width=8,
                                                    pack=pack)
            else:
                fn = lambda p, x: essr_forward_qkernels(
                    p, x, cfg, width=8, pack=pack, interpret=True)
            return fn, (params, patches)
        return make

    def perop():
        def make():
            from repro.kernels.ops import essr_forward_kernels
            fn = lambda p, x: essr_forward_kernels(p, x, cfg, width=8,
                                                   interpret=True)
            return fn, (params, patches)
        return make

    def mega(pack=None):
        def make():
            from repro.kernels.megakernel import (essr_forward_megakernel,
                                                  essr_forward_qmegakernel)
            if pack is None:
                fn = lambda p, x: essr_forward_megakernel(
                    p, x, cfg, width=8, interpret=True)
            else:
                fn = lambda p, x: essr_forward_qmegakernel(
                    p, x, cfg, width=8, pack=pack, interpret=True)
            return fn, (params, patches)
        return make

    def edge():
        from repro.core.edge_score import edge_score
        return edge_score, (patches,)

    fr, th = _FRAME_IV, _THRESH_IV
    specs = [
        EntrySpec("core.pipeline.fused_frame_fn[ref]",
                  fused(), {1: fr, 2: th, 3: th},
                  {"backend": "ref", "quant": "none", "dispatch": "fused"}),
        EntrySpec("core.pipeline.fused_frame_fn[pallas-int8]",
                  fused(s.pack, "pallas", True), {1: fr, 2: th, 3: th},
                  {"backend": "pallas", "quant": "int8",
                   "dispatch": "fused"}),
        # the multi-tenant admission tick: 2 streams, one shared pool; the
        # per-stream C54 quotas quantify over [1, pool] so the proof covers
        # every share rebalancing the StreamSwitcherBank can emit
        EntrySpec("core.pipeline.fused_stream_frame_fn[ref]",
                  mux(), {1: fr, 2: th, 3: th, 4: (1.0, 18.0)},
                  {"backend": "ref", "quant": "none", "dispatch": "mux"}),
        EntrySpec("core.pipeline.sharded_forward",
                  sharded, {1: fr},
                  {"backend": "ref", "quant": "none", "dispatch": "sharded"}),
        EntrySpec("kernels.qconv.essr_forward_qkernels[int8]",
                  qconv(s.pack, ref=False), {1: fr},
                  {"backend": "pallas", "quant": "int8", "dispatch": "host"}),
        EntrySpec("kernels.qconv.essr_forward_qkernels[fxp10]",
                  qconv(s.pack_fxp10, ref=False), {1: fr},
                  {"backend": "pallas", "quant": "fxp10",
                   "dispatch": "host"}),
        EntrySpec("kernels.qconv.essr_forward_qref[int8]",
                  qconv(s.pack, ref=True), {1: fr},
                  {"backend": "ref", "quant": "int8", "dispatch": "host"}),
        EntrySpec("kernels.qconv.essr_forward_qref[fxp10]",
                  qconv(s.pack_fxp10, ref=True), {1: fr},
                  {"backend": "ref", "quant": "fxp10", "dispatch": "host"}),
        EntrySpec("core.edge_score.edge_score",
                  edge, {0: fr},
                  {"backend": "ref", "quant": "none", "dispatch": "host"}),
        # the layer-fused per-op stack vs its group-fused megakernel twin:
        # the cost pass prices both, and the feature_hbm_bytes ratio between
        # them is the static form of the paper's 79% traffic-reduction claim
        # (gated end-to-end by bench_gate --audit).
        EntrySpec("kernels.ops.essr_forward_kernels",
                  perop(), {1: fr},
                  {"backend": "pallas", "quant": "none", "dispatch": "host"}),
        EntrySpec("kernels.megakernel.essr_forward_megakernel",
                  mega(), {1: fr},
                  {"backend": "pallas", "quant": "none", "dispatch": "host"}),
        EntrySpec("kernels.megakernel.essr_forward_qmegakernel[int8]",
                  mega(s.pack), {1: fr},
                  {"backend": "pallas", "quant": "int8", "dispatch": "host"}),
        EntrySpec("kernels.megakernel.essr_forward_qmegakernel[fxp10]",
                  mega(s.pack_fxp10), {1: fr},
                  {"backend": "pallas", "quant": "fxp10",
                   "dispatch": "host"}),
        EntrySpec("core.pipeline.fused_frame_fn[pallas-int8-group]",
                  fused(s.pack, "pallas", True, "group"),
                  {1: fr, 2: th, 3: th},
                  {"backend": "pallas", "quant": "int8",
                   "dispatch": "fused"}),
    ]
    return {spec.name: spec for spec in specs}


def entry_point_jaxprs() -> Dict[str, Callable[[], ClosedJaxpr]]:
    """name -> thunk tracing that entry point (the ESSR1xx walk's view of
    `entry_point_specs`)."""
    def tracer(spec: EntrySpec) -> Callable[[], ClosedJaxpr]:
        def thunk() -> ClosedJaxpr:
            fn, args = spec.make()
            return jax.make_jaxpr(fn)(*args)
        return thunk
    return {name: tracer(spec)
            for name, spec in entry_point_specs().items()}


def audit_recompile_leaks() -> List[Violation]:
    """ESSR105 over the fused frame executable:

    * threshold perturbation (traced arguments) must not re-lower;
    * a desired-capacity perturbation *within one bucket* must snap to the
      same profile and therefore the same cached executable (object
      identity through the `fused_frame_fn` LRU) — this is also the check
      that every static argument (ESSRConfig, QuantPack, geometry) stays
      hashable, because an unhashable one throws right here.
    """
    from repro.core.pipeline import fused_frame_fn, snap_capacity

    s = _audit_setup()
    cfg, params, geom, frame, pack = s.cfg, s.params, s.geom, s.frame, s.pack
    out: List[Violation] = []

    caps_a = (0, snap_capacity(3, n_total=geom.n),
              snap_capacity(3, n_total=geom.n))
    caps_b = (0, snap_capacity(4, n_total=geom.n),
              snap_capacity(4, n_total=geom.n))
    if caps_a != caps_b:
        out.append(Violation(
            "ESSR105", "entrypoint:core.pipeline.snap_capacity",
            f"within-bucket capacity perturbation changed the profile "
            f"{caps_a} -> {caps_b}: every demand delta would recompile"))

    fn_a = fused_frame_fn(geom, caps_a, cfg, "ref", None, None, None)
    fn_b = fused_frame_fn(geom, caps_b, cfg, "ref", None, None, None)
    if fn_a is not fn_b:
        out.append(Violation(
            "ESSR105", "entrypoint:core.pipeline.fused_frame_fn",
            "equal (geometry, caps, cfg, backend, interpret, mesh, quant) "
            "keys resolved to distinct executables: the LRU key leaks"))

    out.extend(check_recompile(
        fn_a, (params, frame, 8.0, 40.0), (params, frame, 9.5, 37.0),
        entry="core.pipeline.fused_frame_fn",
        expect="threshold perturbation (traced t1/t2)"))

    # quantized fused graph: QuantPack must behave as a hashable static —
    # same pack, perturbed thresholds, still one executable
    fn_q = fused_frame_fn(geom, caps_a, cfg, "pallas", True, None, pack)
    out.extend(check_recompile(
        fn_q, (params, frame, 8.0, 40.0), (params, frame, 10.0, 44.0),
        entry="core.pipeline.fused_frame_fn[pallas-int8]",
        expect="threshold perturbation (traced t1/t2)"))
    return out


def run_jaxpr_audit(const_budget: int = DEFAULT_CONST_BUDGET
                    ) -> List[Violation]:
    """The whole pass: trace+walk every entry point, then the recompile-leak
    checks. A trace failure is itself reported as an ESSR101 violation
    (an entry point the auditor cannot even trace is a hazard, not an
    excuse)."""
    out: List[Violation] = []
    for entry, thunk in entry_point_jaxprs().items():
        try:
            closed = thunk()
        except Exception as e:                          # pragma: no cover
            out.append(Violation(
                "ESSR101", f"entrypoint:{entry}",
                f"entry point failed to trace: {e!r}"))
            continue
        out.extend(audit_jaxpr(closed, entry, const_budget))
    out.extend(audit_recompile_leaks())
    return out
