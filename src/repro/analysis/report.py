"""Violation records + machine-readable reports for the ESSR static auditor.

One `Violation` is one (rule code, site) hazard; a `Report` aggregates the
violations of an audit run into the JSON shape the CLI emits, the committed
baseline (`ANALYSIS_baseline.json`) stores, and `scripts/bench_gate.py
--audit` diffs against. The rule catalog below is the single source of rule
codes and one-line descriptions — `docs/api.md` documents each at length.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Tuple

#: Rule catalog: code -> one-line description. ESSR1xx = jaxpr audit (graph
#: hazards of the traced entry points), ESSR2xx = AST lint (repo conventions
#: over the source tree).
RULES: Dict[str, str] = {
    "ESSR101": "host callback/transfer primitive inside a traced graph",
    "ESSR102": "fp64/complex128 value, f64 promotion, or weak-typed graph "
               "output",
    "ESSR103": "scatter without a determinism guarantee (mode=None, or "
               "set-semantics scatter with non-unique indices)",
    "ESSR104": "oversized constant baked into a traced graph",
    "ESSR105": "recompile leak: a traced-argument perturbation re-lowered "
               "the executable",
    "ESSR201": "free-function inference entry point outside repro.api",
    "ESSR202": "numpy host op inside a traced body",
    "ESSR203": "wall-clock (time module) call inside a traced body",
    "ESSR204": "host sync (.block_until_ready()/jax.device_get) inside a "
               "traced body",
    "ESSR205": "mutable or unhashable field on a frozen plan/config "
               "dataclass",
}

#: Which analysis pass owns each rule (drives the per-pass report sections).
PASS_OF_RULE: Dict[str, str] = {
    code: ("jaxpr" if code.startswith("ESSR1") else "ast") for code in RULES
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule hit at one site.

    ``site`` is ``<relpath>:<line>`` for AST findings and
    ``entrypoint:<name>`` for jaxpr findings (graphs have no source line).
    """
    code: str
    site: str
    message: str

    def __post_init__(self):
        if self.code not in RULES:
            raise ValueError(f"unknown rule code {self.code!r}; "
                             f"known: {sorted(RULES)}")

    @property
    def pass_name(self) -> str:
        return PASS_OF_RULE[self.code]

    @property
    def key(self) -> Tuple[str, str]:
        """Baseline identity: a violation is "new" when no committed
        violation shares its (code, site). Messages carry run-varying
        detail (byte counts, dtypes) and are excluded on purpose."""
        return (self.code, self.site)

    def to_dict(self) -> Dict[str, str]:
        return {"code": self.code, "site": self.site,
                "message": self.message, "pass": self.pass_name}

    @classmethod
    def from_dict(cls, d: Dict[str, str]) -> "Violation":
        return cls(code=d["code"], site=d["site"],
                   message=d.get("message", ""))


class Report:
    """An audit run's violations, with JSON (de)serialization and the
    baseline diff `bench_gate --audit` gates on."""

    def __init__(self, violations: Iterable[Violation] = ()):
        self.violations: List[Violation] = list(violations)

    def extend(self, violations: Iterable[Violation]) -> None:
        self.violations.extend(violations)

    def counts(self) -> Dict[str, int]:
        """Per-rule violation counts — every catalog rule appears, zero or
        not, so a consumer can tell "rule ran clean" from "rule unknown"."""
        out = {code: 0 for code in RULES}
        for v in self.violations:
            out[v.code] += 1
        return out

    def by_pass(self) -> Dict[str, List[Violation]]:
        out: Dict[str, List[Violation]] = {"jaxpr": [], "ast": []}
        for v in self.violations:
            out[v.pass_name].append(v)
        return out

    def new_vs(self, baseline: "Report") -> List[Violation]:
        """Violations of this run with no (code, site) match in ``baseline``
        — the set the audit gate hard-fails on. A shrinking violation list
        never fails the gate (fixes land freely; regenerate the baseline
        with ``essr_lint.py --fix-baseline`` to ratchet it down)."""
        seen = {v.key for v in baseline.violations}
        return [v for v in self.violations if v.key not in seen]

    def to_dict(self) -> Dict:
        return {
            "rules": {code: RULES[code] for code in sorted(RULES)},
            "counts": self.counts(),
            "total": len(self.violations),
            "violations": [v.to_dict() for v in sorted(
                self.violations, key=lambda v: (v.code, v.site))],
        }

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)
            f.write("\n")

    @classmethod
    def from_dict(cls, d: Dict) -> "Report":
        return cls(Violation.from_dict(v) for v in d.get("violations", []))

    @classmethod
    def from_json(cls, path: str) -> "Report":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def render(self) -> str:
        """Human-readable summary (the CLI's stdout)."""
        lines = []
        for pass_name, vs in self.by_pass().items():
            lines.append(f"[{pass_name}] {len(vs)} violation(s)")
            for v in sorted(vs, key=lambda v: (v.code, v.site)):
                lines.append(f"  {v.code} {v.site}: {v.message}")
        counts = {c: n for c, n in self.counts().items() if n}
        lines.append(f"total: {len(self.violations)} violation(s)"
                     + (f" {counts}" if counts else ""))
        return "\n".join(lines)
