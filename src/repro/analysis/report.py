"""Violation records + machine-readable reports for the ESSR static auditor.

One `Violation` is one (rule code, site) hazard; a `Report` aggregates the
violations of an audit run — plus the metrics payloads of the range/cost
passes — into the JSON shape the CLI emits, the committed baseline
(`ANALYSIS_baseline.json`) stores, and `scripts/bench_gate.py --audit` diffs
against. The rule registry below is the SINGLE source of rule codes,
pass ownership, and one-line descriptions: the CLI's ``--list-rules``, the
baseline's ``"rules"`` table, and the docs catalog check
(tests/test_analysis.py) all read it, so the three surfaces cannot drift.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Tuple

#: Rule registry: code -> (pass, one-line description). ESSR1xx = jaxpr audit
#: (graph hazards of the traced entry points), ESSR2xx = AST lint (repo
#: conventions over the source tree), ESSR3xx = range certification (interval
#: abstract interpretation of the integer datapath).
RULE_REGISTRY: Dict[str, Tuple[str, str]] = {
    "ESSR101": ("jaxpr", "host callback/transfer primitive inside a traced "
                         "graph"),
    "ESSR102": ("jaxpr", "fp64/complex128 value, f64 promotion, or "
                         "weak-typed graph output"),
    "ESSR103": ("jaxpr", "scatter without a determinism guarantee "
                         "(mode=None, or set-semantics scatter with "
                         "non-unique indices)"),
    "ESSR104": ("jaxpr", "oversized constant baked into a traced graph"),
    "ESSR105": ("jaxpr", "recompile leak: a traced-argument perturbation "
                         "re-lowered the executable"),
    "ESSR201": ("ast", "free-function inference entry point outside "
                       "repro.api"),
    "ESSR202": ("ast", "numpy host op inside a traced body"),
    "ESSR203": ("ast", "wall-clock (time module) call inside a traced body"),
    "ESSR204": ("ast", "host sync (.block_until_ready()/jax.device_get) "
                       "inside a traced body"),
    "ESSR205": ("ast", "mutable or unhashable field on a frozen plan/config "
                       "dataclass"),
    "ESSR206": ("ast", "free-function stream-serving entry point outside "
                       "repro.api"),
    "ESSR207": ("ast", "broad except handler in runtime//api/ swallows the "
                       "fault without re-raising or recording it"),
    "ESSR301": ("range", "integer site interval exceeds its storage dtype "
                         "(or the what-if accumulator budget): overflow is "
                         "not provably absent"),
    "ESSR302": ("range", "fused group's minimal accumulator bit-width "
                         "exceeds the bit budget"),
    "ESSR303": ("range", "degenerate quant scale: alpha below the step "
                         "floor collapses a site's codes"),
    "ESSR304": ("range", "interval-unsound op: the analyzer met a primitive "
                         "it has no sound transfer rule for (fails closed, "
                         "never guesses)"),
}

#: code -> one-line description (legacy view of the registry).
RULES: Dict[str, str] = {c: desc for c, (_, desc) in RULE_REGISTRY.items()}

#: Which analysis pass owns each rule (drives the per-pass report sections).
PASS_OF_RULE: Dict[str, str] = {
    c: pass_name for c, (pass_name, _) in RULE_REGISTRY.items()
}

#: Every pass, in report order. "cost" emits metrics only (no rules).
PASSES: Tuple[str, ...] = ("jaxpr", "ast", "range", "cost")


def rules_markdown() -> str:
    """The docs rule-catalog rows, rendered from the registry (docs/api.md
    embeds richer prose, but tests assert every code here appears there)."""
    lines = ["| code | pass | protects against |", "|---|---|---|"]
    for code in sorted(RULE_REGISTRY):
        pass_name, desc = RULE_REGISTRY[code]
        lines.append(f"| {code} | {pass_name} | {desc} |")
    return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule hit at one site.

    ``site`` is ``<relpath>:<line>`` for AST findings and
    ``entrypoint:<name>`` for jaxpr findings (graphs have no source line).
    """
    code: str
    site: str
    message: str

    def __post_init__(self):
        if self.code not in RULES:
            raise ValueError(f"unknown rule code {self.code!r}; "
                             f"known: {sorted(RULES)}")

    @property
    def pass_name(self) -> str:
        return PASS_OF_RULE[self.code]

    @property
    def key(self) -> Tuple[str, str]:
        """Baseline identity: a violation is "new" when no committed
        violation shares its (code, site). Messages carry run-varying
        detail (byte counts, dtypes) and are excluded on purpose."""
        return (self.code, self.site)

    def to_dict(self) -> Dict[str, str]:
        return {"code": self.code, "site": self.site,
                "message": self.message, "pass": self.pass_name}

    @classmethod
    def from_dict(cls, d: Dict[str, str]) -> "Violation":
        return cls(code=d["code"], site=d["site"],
                   message=d.get("message", ""))


class Report:
    """An audit run's violations + metrics, with JSON (de)serialization and
    the baseline diff `bench_gate --audit` gates on.

    ``metrics`` carries the machine-readable payloads of the quantitative
    passes, keyed by section: ``"bitwidth"`` (per-entry/per-group minimal
    accumulator bit-widths from the range certifier) and ``"static_costs"``
    (per-entry MACs / HBM bytes / arithmetic intensity from the cost model).
    Violations gate on (code, site) identity; metrics gate on regression
    (`gate_metrics`): traffic growing or overflow headroom shrinking vs the
    committed baseline blocks merge even though no rule fired.
    """

    def __init__(self, violations: Iterable[Violation] = (),
                 metrics: Dict[str, Any] = None):
        self.violations: List[Violation] = list(violations)
        self.metrics: Dict[str, Any] = dict(metrics or {})

    def extend(self, violations: Iterable[Violation]) -> None:
        self.violations.extend(violations)

    def merge_metrics(self, section: str, payload: Dict[str, Any]) -> None:
        self.metrics[section] = payload

    def counts(self) -> Dict[str, int]:
        """Per-rule violation counts — every catalog rule appears, zero or
        not, so a consumer can tell "rule ran clean" from "rule unknown"."""
        out = {code: 0 for code in RULES}
        for v in self.violations:
            out[v.code] += 1
        return out

    def by_pass(self) -> Dict[str, List[Violation]]:
        out: Dict[str, List[Violation]] = {
            p: [] for p in PASSES if p in PASS_OF_RULE.values()}
        for v in self.violations:
            out[v.pass_name].append(v)
        return out

    def new_vs(self, baseline: "Report") -> List[Violation]:
        """Violations of this run with no (code, site) match in ``baseline``
        — the set the audit gate hard-fails on. A shrinking violation list
        never fails the gate (fixes land freely; regenerate the baseline
        with ``essr_lint.py --fix-baseline`` to ratchet it down)."""
        seen = {v.key for v in baseline.violations}
        return [v for v in self.violations if v.key not in seen]

    def to_dict(self) -> Dict:
        out = {
            "rules": {code: RULES[code] for code in sorted(RULES)},
            "counts": self.counts(),
            "total": len(self.violations),
            "violations": [v.to_dict() for v in sorted(
                self.violations, key=lambda v: (v.code, v.site))],
        }
        if self.metrics:
            out["metrics"] = self.metrics
        return out

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)
            f.write("\n")

    @classmethod
    def from_dict(cls, d: Dict) -> "Report":
        return cls((Violation.from_dict(v) for v in d.get("violations", [])),
                   metrics=d.get("metrics", {}))

    @classmethod
    def from_json(cls, path: str) -> "Report":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def render(self) -> str:
        """Human-readable summary (the CLI's stdout)."""
        lines = []
        for pass_name, vs in self.by_pass().items():
            lines.append(f"[{pass_name}] {len(vs)} violation(s)")
            for v in sorted(vs, key=lambda v: (v.code, v.site)):
                lines.append(f"  {v.code} {v.site}: {v.message}")
        bw = self.metrics.get("bitwidth", {})
        for entry in sorted(bw.get("entries", {})):
            row = bw["entries"][entry]
            for group in sorted(row.get("groups", {})):
                g = row["groups"][group]
                lines.append(
                    f"  [bits] {entry} :: {group}: min acc bits "
                    f"{g['acc_bits']} (headroom vs {bw.get('paper_acc_bits', 24)}b: "
                    f"{g['headroom_vs_paper']:+d})")
        costs = self.metrics.get("static_costs", {})
        for entry in sorted(costs.get("entries", {})):
            c = costs["entries"][entry]
            lines.append(
                f"  [cost] {entry}: {c['macs']:.3e} MACs, "
                f"{c['hbm_bytes']:.3e} HBM bytes, "
                f"{c['arith_intensity']:.2f} MAC/byte")
        counts = {c: n for c, n in self.counts().items() if n}
        lines.append(f"total: {len(self.violations)} violation(s)"
                     + (f" {counts}" if counts else ""))
        return "\n".join(lines)


def gate_metrics(fresh: Report, baseline: Report,
                 traffic_tol: float = 0.10) -> List[str]:
    """Regression gate over the quantitative sections (the metrics analog of
    `Report.new_vs`): failure strings when, vs the committed baseline,

    * a per-entry static cost (MACs or HBM bytes) GROWS beyond
      ``traffic_tol`` (shrinking traffic never fails — optimizations land
      freely, regenerate the baseline to ratchet);
    * any fused group's minimal accumulator bit-width GROWS (overflow
      headroom shrank — bit-widths are integers, so any growth is real);
    * an entry/group present in the baseline disappears (coverage loss).

    New entries/groups (coverage growth) pass; commit them via the refreshed
    baseline like any new rule site.
    """
    fails: List[str] = []

    want_c = baseline.metrics.get("static_costs", {}).get("entries", {})
    got_c = fresh.metrics.get("static_costs", {}).get("entries", {})
    for entry, want in want_c.items():
        got = got_c.get(entry)
        if got is None:
            fails.append(f"static_costs[{entry}]: entry point no longer "
                         f"analyzed (was in baseline)")
            continue
        # feature_hbm_bytes: the megakernel's VMEM-residency win — a growth
        # here means features started crossing HBM between groups again.
        # Guarded with .get for baselines committed before the key existed.
        for key in ("macs", "hbm_bytes", "feature_hbm_bytes"):
            if key not in want or key not in got:
                continue
            if got[key] > want[key] * (1.0 + traffic_tol):
                fails.append(
                    f"static_costs[{entry}].{key}: {got[key]:.4g} > "
                    f"committed {want[key]:.4g} + {traffic_tol:.0%} band")

    want_b = baseline.metrics.get("bitwidth", {}).get("entries", {})
    got_b = fresh.metrics.get("bitwidth", {}).get("entries", {})
    for entry, want in want_b.items():
        got = got_b.get(entry)
        if got is None:
            fails.append(f"bitwidth[{entry}]: entry point no longer "
                         f"certified (was in baseline)")
            continue
        for group, wg in want.get("groups", {}).items():
            gg = got.get("groups", {}).get(group)
            if gg is None:
                fails.append(f"bitwidth[{entry}][{group}]: fused group no "
                             f"longer certified (was in baseline)")
            elif gg["acc_bits"] > wg["acc_bits"]:
                fails.append(
                    f"bitwidth[{entry}][{group}]: minimal accumulator "
                    f"bit-width grew {wg['acc_bits']} -> {gg['acc_bits']} "
                    f"(overflow headroom shrank)")
    return fails
