"""Static analysis for the ESSR repro: jaxpr graph audit + repo AST lint.

Two passes over two different artifacts:

- :mod:`repro.analysis.jaxpr_audit` traces the real engine entry points and
  walks the jaxprs for graph hazards (ESSR1xx), including the recompile-leak
  re-trace check.
- :mod:`repro.analysis.ast_lint` lints the source tree for repo conventions
  (ESSR2xx).

``scripts/essr_lint.py`` is the CLI; ``scripts/bench_gate.py --audit`` gates
on new violations vs the committed ``ANALYSIS_baseline.json``.
"""
from repro.analysis.ast_lint import lint_file, lint_source, run_ast_lint
from repro.analysis.jaxpr_audit import (
    audit_jaxpr,
    audit_recompile_leaks,
    check_recompile,
    entry_point_jaxprs,
    run_jaxpr_audit,
)
from repro.analysis.report import PASS_OF_RULE, RULES, Report, Violation

__all__ = [
    "PASS_OF_RULE",
    "RULES",
    "Report",
    "Violation",
    "audit_jaxpr",
    "audit_recompile_leaks",
    "check_recompile",
    "entry_point_jaxprs",
    "lint_file",
    "lint_source",
    "run_ast_lint",
    "run_jaxpr_audit",
]
