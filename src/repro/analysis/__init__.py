"""Static analysis for the ESSR repro: four passes over two artifacts.

Over the *traced graphs* of the real engine entry points
(:func:`repro.analysis.jaxpr_audit.entry_point_specs`):

- :mod:`repro.analysis.jaxpr_audit` walks the jaxprs for graph hazards
  (ESSR1xx), including the recompile-leak re-trace check.
- :mod:`repro.analysis.range_infer` abstract-interprets the same jaxprs over
  a mixed concrete/interval domain and certifies the integer datapath
  (ESSR3xx): overflow proofs, per-fused-group minimal accumulator
  bit-widths vs the paper's 24-bit chain, degenerate quant scales.
- :mod:`repro.analysis.cost_model` prices the same jaxprs statically:
  per-entry MACs, HBM bytes, arithmetic intensity — the static counterpart
  of ``launch/roofline.py``, deterministic enough to gate in CI.

Over the *source tree*:

- :mod:`repro.analysis.ast_lint` lints for repo conventions (ESSR2xx).

:data:`repro.analysis.report.RULE_REGISTRY` is the single source of rule
codes/ownership/descriptions. ``scripts/essr_lint.py`` is the CLI;
``scripts/bench_gate.py --audit`` gates on new violations *and* on metric
regressions (:func:`repro.analysis.report.gate_metrics`) vs the committed
``ANALYSIS_baseline.json``.
"""
from repro.analysis.ast_lint import lint_file, lint_source, run_ast_lint
from repro.analysis.cost_model import price_jaxpr, run_cost_audit
from repro.analysis.jaxpr_audit import (
    audit_jaxpr,
    audit_recompile_leaks,
    check_recompile,
    entry_point_jaxprs,
    entry_point_specs,
    run_jaxpr_audit,
)
from repro.analysis.range_infer import (
    Interval,
    check_quant_scales,
    infer_ranges,
    run_range_audit,
)
from repro.analysis.report import (
    PASS_OF_RULE,
    RULE_REGISTRY,
    RULES,
    Report,
    Violation,
    gate_metrics,
    rules_markdown,
)

__all__ = [
    "PASS_OF_RULE",
    "RULE_REGISTRY",
    "RULES",
    "Interval",
    "Report",
    "Violation",
    "audit_jaxpr",
    "audit_recompile_leaks",
    "check_quant_scales",
    "check_recompile",
    "entry_point_jaxprs",
    "entry_point_specs",
    "gate_metrics",
    "infer_ranges",
    "lint_file",
    "lint_source",
    "price_jaxpr",
    "rules_markdown",
    "run_ast_lint",
    "run_cost_audit",
    "run_jaxpr_audit",
    "run_range_audit",
]
