"""Subnet decision with input edge thresholds (paper Sec. II-C, Fig. 5).

Three subnets: 0 = bilinear, 1 = C27, 2 = C54.
    score <  t1        -> bilinear
    t1 <= score < t2   -> C27
    score >= t2        -> C54

MAC accounting follows the paper: savings are reported relative to running
every patch through the full C54 net.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.essr import ESSRConfig, essr_macs_per_lr_pixel

BILINEAR, C27, C54 = 0, 1, 2
SUBNET_NAMES = ("bilinear", "C27", "C54")

DEFAULT_T1 = 8.0
DEFAULT_T2 = 40.0


def decide(scores: jax.Array, t1: float = DEFAULT_T1, t2: float = DEFAULT_T2) -> jax.Array:
    """(N,) edge scores -> (N,) subnet ids in {0,1,2}."""
    return jnp.where(scores >= t2, C54, jnp.where(scores >= t1, C27, BILINEAR)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class SubnetMacs:
    """Per-patch MAC cost of each subnet for a given ESSR config / patch size."""
    per_patch: Tuple[int, int, int]

    @staticmethod
    def make(cfg: ESSRConfig, patch: int = 32) -> "SubnetMacs":
        area = patch * patch
        widths = cfg.subnet_widths()
        return SubnetMacs(tuple(essr_macs_per_lr_pixel(cfg, w) * area for w in widths))

    def total(self, counts) -> int:
        return int(sum(int(c) * m for c, m in zip(counts, self.per_patch)))

    def saving_vs_c54(self, counts) -> float:
        n = int(sum(int(c) for c in counts))
        full = n * self.per_patch[C54]
        return 1.0 - self.total(counts) / full if full else 0.0


def subnet_counts(ids) -> Tuple[int, int, int]:
    ids = np.asarray(ids)
    return tuple(int((ids == k).sum()) for k in (BILINEAR, C27, C54))


def mac_saving(scores, t1: float, t2: float, cfg: ESSRConfig,
               patch: int = 32) -> Dict[str, float]:
    ids = decide(jnp.asarray(scores), t1, t2)
    counts = subnet_counts(ids)
    m = SubnetMacs.make(cfg, patch)
    return {
        "counts": counts,
        "total_macs": m.total(counts),
        "saving_vs_c54": m.saving_vs_c54(counts),
    }


def thresholds_for_target_saving(scores, target: float, cfg: ESSRConfig,
                                 patch: int = 32,
                                 t1_grid=None, t2_grid=None) -> Tuple[float, float]:
    """Search (t1,t2) giving MAC saving closest to ``target`` (paper Table X's
    40/50/60% operating points). Coarse grid — the decision space is tiny."""
    scores = np.asarray(scores)
    t1_grid = t1_grid if t1_grid is not None else np.arange(0, 41, 2)
    t2_grid = t2_grid if t2_grid is not None else np.arange(10, 201, 5)
    best, best_err = (DEFAULT_T1, DEFAULT_T2), np.inf
    m = SubnetMacs.make(cfg, patch)
    for t1 in t1_grid:
        for t2 in t2_grid:
            if t2 <= t1:
                continue
            counts = subnet_counts(decide(jnp.asarray(scores), float(t1), float(t2)))
            err = abs(m.saving_vs_c54(counts) - target)
            if err < best_err:
                best, best_err = (float(t1), float(t2)), err
    return best
