"""BoundedCache — an ``functools.lru_cache`` workalike whose bound can be
resized at runtime and whose occupancy is inspectable.

The engine's three process-wide memo caches — the compiled frame executable
(`core.pipeline.fused_frame_fn`), the multi-tenant admission tick
(`core.pipeline.fused_stream_frame_fn`) and the host-side patch geometry
(`core.patching.get_geometry`) — used to be plain ``lru_cache(128)``s: the
bound was frozen at import, invisible at runtime, and not derivable from the
serving plan. Wrapping them in `BoundedCache` keeps the exact lru semantics
(same positional-key identity, thread-safe, `cache_info`/`cache_clear`) and
adds:

  * ``resize(n)`` — `SREngine` derives the bound from ``plan.stats_window``
    (`core.pipeline.configure_compiled_caches`), so a long-horizon stream
    keeps more warm executables and a tiny embedded plan keeps fewer;
  * ``occupancy()`` — a plain dict (size/maxsize/hits/misses/evictions)
    surfaced by ``FrameResult.summary()`` and ``SREngine.summary()``, so an
    operator can see eviction pressure (a nonzero eviction count under a
    steady geometry set means the bound is too small and executables are
    silently re-tracing).
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Tuple


class BoundedCache:
    """LRU memo over a function of hashable positional/keyword arguments.

    Key identity matches ``functools.lru_cache``: positional args tuple plus
    sorted kwargs items — callers mixing call styles for the same logical
    arguments get distinct entries, exactly like lru_cache (every repo call
    site is positional, so this never bites in practice).
    """

    def __init__(self, fn: Callable, maxsize: int = 128):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self._fn = fn
        self._maxsize = int(maxsize)
        self._data: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._lock = threading.RLock()
        self._hits = self._misses = self._evictions = 0
        functools.update_wrapper(self, fn)

    def __call__(self, *args, **kwargs):
        key = args + tuple(sorted(kwargs.items())) if kwargs else args
        with self._lock:
            if key in self._data:
                self._hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            self._misses += 1
        # build OUTSIDE the lock: tracing a frame executable can take
        # seconds, and concurrent misses on different keys must not
        # serialize. A racing duplicate build is benign (last write wins).
        value = self._fn(*args, **kwargs)
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self._maxsize:
                self._data.popitem(last=False)
                self._evictions += 1
        return value

    def resize(self, maxsize: int) -> None:
        """Change the bound; shrinking evicts oldest entries immediately."""
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        with self._lock:
            self._maxsize = int(maxsize)
            while len(self._data) > self._maxsize:
                self._data.popitem(last=False)
                self._evictions += 1

    def cache_clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._hits = self._misses = self._evictions = 0

    def cache_info(self):
        """lru_cache-shaped (hits, misses, maxsize, currsize) named tuple."""
        with self._lock:
            return functools._CacheInfo(self._hits, self._misses,
                                        self._maxsize, len(self._data))

    def occupancy(self) -> Dict[str, int]:
        """The runtime-telemetry dict `FrameResult.summary()` surfaces."""
        with self._lock:
            return {"size": len(self._data), "maxsize": self._maxsize,
                    "hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions}


def bounded_cache(maxsize: int = 128):
    """Decorator form: ``@bounded_cache(128)`` over a def, like lru_cache."""
    def wrap(fn: Callable) -> BoundedCache:
        return BoundedCache(fn, maxsize=maxsize)
    return wrap
