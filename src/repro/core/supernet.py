"""Weight-shared supernet training (paper Sec. V-A, following ARM).

One parameter set serves both C27 and C54: C27 is the first-27-channel slice
(``repro.models.essr.slice_width``). Training samples ONE subnet per
iteration with probability proportional to its MACs, computes the loss on
that subnet only, and updates the (shared) parameters — gradients flow only
into the selected slice, which is exactly ARM's update rule.

Bilinear has no parameters and is never sampled.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.essr import ESSRConfig, essr_forward, essr_macs_per_lr_pixel


def subnet_sampling_probs(cfg: ESSRConfig) -> np.ndarray:
    """p(subnet) ∝ MACs over the trainable subnets (C27, C54)."""
    widths = [w for w in cfg.subnet_widths() if w > 0]
    macs = np.array([essr_macs_per_lr_pixel(cfg, w) for w in widths], dtype=np.float64)
    return macs / macs.sum()


def sample_width(key: jax.Array, cfg: ESSRConfig) -> int:
    widths = [w for w in cfg.subnet_widths() if w > 0]
    p = subnet_sampling_probs(cfg)
    idx = int(jax.random.choice(key, len(widths), p=jnp.asarray(p)))
    return widths[idx]


def supernet_loss_fn(loss: Callable[[jax.Array, jax.Array], jax.Array],
                     cfg: ESSRConfig):
    """Build ``(params, batch, width) -> scalar`` for sampled-subnet training.

    ``width`` is static (two jit specializations: 27 and 54)."""

    def fn(params: Dict[str, Any], lr: jax.Array, hr: jax.Array, *, width: int):
        sr = essr_forward(params, lr, cfg, width=width)
        return loss(sr, hr)

    return fn


def ema_init(params) -> Any:
    return jax.tree_util.tree_map(lambda x: x, params)


def ema_update(ema, params, decay: float = 0.999):
    """Exponential moving average of weights (paper: decay 0.999)."""
    return jax.tree_util.tree_map(
        lambda e, p: decay * e + (1.0 - decay) * p, ema, params)
