"""Resource-adaptive model switching (paper Sec. IV-A, Algorithm 1).

Host-side feedback controller over the two edge thresholds:

  * hard compute ceiling: if the number of C54 patches this second exceeds
    ``c54_per_sec_budget`` (25 500 for 8K@30FPS on the paper's PE array), the
    *rest of the patches run with C27* — throughput guaranteed, quality floor
    kept at C27;
  * per-frame trim: > ``frame_high`` C54 patches in a frame  -> (t1,t2) += (1,5)
                    < ``frame_low``  C54 patches in a frame  -> (t1,t2) -= (1,5)

The same controller is reused by the serving runtime as *straggler
mitigation*: a shard that falls behind its deadline raises the local
thresholds, demoting its patches. `ShardSwitcherBank` implements that for
the sharded patch stream: one `AdaptiveSwitcher` per shard (budgets split
evenly), contiguous raster strips of each frame routed by each shard's local
thresholds. The miss signal is the frame's single wall-clock deadline;
*which* shards back off is attributed by a host-side load model — each
shard's estimated MAC cost vs the balanced share — not by per-device
timing (dispatch splits every subnet bucket evenly across devices, so no
device maps 1:1 to a routing strip). A missed frame demotes the shards
contributing the most compute, proportionally to their overload, shedding
load where the C54 work originates while lightly-loaded strips keep their
quality.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import subnet_policy as sp


@dataclasses.dataclass
class SwitchingConfig:
    t1: float = sp.DEFAULT_T1
    t2: float = sp.DEFAULT_T2
    c54_per_sec_budget: int = 25_500
    frame_high: int = 1000
    frame_low: int = 700
    fps: int = 30
    t1_step: float = 1.0
    t2_step: float = 5.0
    t1_bounds: Tuple[float, float] = (0.0, 255.0)
    t2_bounds: Tuple[float, float] = (1.0, 255.0)


class AdaptiveSwitcher:
    """Stateful Algorithm-1 controller. One instance per stream (or shard)."""

    def __init__(self, cfg: Optional[SwitchingConfig] = None):
        self.cfg = cfg = cfg if cfg is not None else SwitchingConfig()
        self.t1 = float(cfg.t1)
        self.t2 = float(cfg.t2)
        self._c54_this_second = 0
        self._frames_this_second = 0

    # -- public -------------------------------------------------------------

    def assign(self, scores: np.ndarray) -> np.ndarray:
        """Edge scores of one frame's patches (raster order) -> subnet ids.

        Applies the per-second C54 ceiling (demote overflow to C27 in raster
        order, exactly "the rest of the patches run with C27"), then the
        per-frame threshold adaptation.
        """
        scores = np.asarray(scores)
        ids = np.array(sp.decide(scores, self.t1, self.t2))  # writable copy

        # --- hard ceiling over the current second -------------------------
        budget_left = self.cfg.c54_per_sec_budget - self._c54_this_second
        c54_idx = np.flatnonzero(ids == sp.C54)
        if len(c54_idx) > budget_left:
            overflow = c54_idx[max(budget_left, 0):]
            ids[overflow] = sp.C27
        n_c54 = int((ids == sp.C54).sum())
        self.observe_frame(n_c54)
        return ids

    def observe_frame(self, n_c54: int) -> None:
        """Feed back one served frame's C54 count: the per-frame threshold
        trim (Algorithm 1's else-branch) plus the per-second bookkeeping.

        This is ``assign`` minus the routing itself — the fused-dispatch
        stream uses it because routing happened *in the frame executable*
        (the C54 capacity slots enforce the hard ceiling in-graph, the
        overflow spilling to C27 exactly as "the rest of the patches run
        with C27"); the host only adapts thresholds from the materialized
        counts, one frame behind under async streaming."""
        n_c54 = int(n_c54)
        self._c54_this_second += n_c54

        # --- per-frame threshold trim (Algorithm 1's else-branch) ---------
        if n_c54 > self.cfg.frame_high:
            self.t1 += self.cfg.t1_step
            self.t2 += self.cfg.t2_step
        elif n_c54 < self.cfg.frame_low:
            self.t1 -= self.cfg.t1_step
            self.t2 -= self.cfg.t2_step
        self._clamp()

        # --- second roll-over ---------------------------------------------
        self._frames_this_second += 1
        if self._frames_this_second >= self.cfg.fps:
            self._frames_this_second = 0
            self._c54_this_second = 0

    def demote_for_straggler(self, severity: float = 1.0) -> None:
        """Straggler hook: a late shard raises thresholds proportionally."""
        self.t1 += self.cfg.t1_step * severity
        self.t2 += self.cfg.t2_step * severity
        self._clamp()

    # -- internals ----------------------------------------------------------

    def _clamp(self) -> None:
        c = self.cfg
        self.t1 = float(np.clip(self.t1, *c.t1_bounds))
        self.t2 = float(np.clip(self.t2, *c.t2_bounds))
        if self.t2 <= self.t1:          # keep the decision boundary ordered
            self.t2 = self.t1 + 1.0

    @property
    def thresholds(self) -> Tuple[float, float]:
        return (self.t1, self.t2)


# ---------------------------------------------------------------------------
# sharded streaming: one Algorithm-1 controller per shard
# ---------------------------------------------------------------------------

def per_shard_config(cfg: SwitchingConfig, shards: int) -> SwitchingConfig:
    """Split a stream-level SwitchingConfig across ``shards`` equal shards.

    Each shard sees ~1/shards of every frame's patches, so the per-second C54
    budget and the per-frame trim bands scale down with it (positive values
    floored at 1 so a tiny shard still adapts; a 0 stays 0 — ``frame_low=0``
    means "never decay thresholds" and splitting must not re-enable it);
    thresholds, steps and bounds are per-controller quantities and stay
    as-is."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards == 1:
        return cfg
    split = lambda v: max(1, v // shards) if v > 0 else v
    return dataclasses.replace(
        cfg,
        c54_per_sec_budget=split(cfg.c54_per_sec_budget),
        frame_high=split(cfg.frame_high),
        frame_low=split(cfg.frame_low))


class ShardSwitcherBank:
    """Per-shard Algorithm-1 controllers + lock-step straggler mitigation.

    ``assign`` routes one frame: shard ``k`` decides its contiguous slice of
    the raster-order scores under its OWN live thresholds. ``note_frame``
    feeds back the frame outcome: on a missed (global wall-clock) deadline,
    the shards whose estimated MAC cost exceeds the balanced share are
    treated as the overload source and get ``demote_for_straggler`` with
    severity = overload ratio — a cost-model attribution, not a per-device
    measurement; a uniformly loaded frame demotes every shard (aggregate
    throughput must recover).
    """

    def __init__(self, cfg: Optional[SwitchingConfig] = None, shards: int = 1):
        cfg = cfg if cfg is not None else SwitchingConfig()
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.shards = shards
        self.switchers: List[AdaptiveSwitcher] = [
            AdaptiveSwitcher(per_shard_config(cfg, shards))
            for _ in range(shards)]

    def assign(self, scores: np.ndarray,
               slices: Sequence[slice]) -> np.ndarray:
        """Frame scores (raster order) + shard slices -> subnet ids."""
        if len(slices) != self.shards:
            raise ValueError(f"got {len(slices)} slices for "
                             f"{self.shards} shards")
        scores = np.asarray(scores)
        ids = np.empty(len(scores), dtype=np.int64)
        for sw, sl in zip(self.switchers, slices):
            ids[sl] = sw.assign(scores[sl])
        return ids

    def note_frame(self, missed: bool,
                   costs: Sequence[float]) -> Tuple[bool, ...]:
        """Feed back one frame's outcome; returns which shards were demoted.

        ``costs``: estimated per-shard MAC cost of the frame just served
        (`sp.SubnetMacs.total` over each shard's counts)."""
        if len(costs) != self.shards:
            raise ValueError(f"got {len(costs)} costs for "
                             f"{self.shards} shards")
        if not missed:
            return (False,) * self.shards
        costs = np.asarray(costs, np.float64)
        mean = float(costs.mean())
        if mean <= 0 or np.allclose(costs, mean):
            # no imbalance signal: global overload, every shard backs off
            demoted = [True] * self.shards
            severities = [1.0] * self.shards
        else:
            demoted = [bool(c > mean) for c in costs]
            # severity = how far past the balanced share, capped so one
            # pathological frame cannot slam thresholds to the bound
            severities = [min(float(c / mean), 3.0) for c in costs]
        for sw, d, sev in zip(self.switchers, demoted, severities):
            if d:
                sw.demote_for_straggler(severity=sev)
        return tuple(demoted)

    @property
    def thresholds(self) -> Tuple[Tuple[float, float], ...]:
        return tuple(sw.thresholds for sw in self.switchers)


# ---------------------------------------------------------------------------
# multi-stream serving: one Algorithm-1 controller per tenant stream
# ---------------------------------------------------------------------------

def per_stream_config(cfg: SwitchingConfig, share: float) -> SwitchingConfig:
    """Scale a stream-level SwitchingConfig down to one tenant's QoS share.

    ``share`` is the stream's normalized fraction of the aggregate (0, 1].
    The per-second C54 budget and the per-frame trim bands scale with it
    (positive values floored at 1 so a thin stream still adapts; 0 stays 0 —
    ``frame_low=0`` means "never decay thresholds" and splitting must not
    re-enable it); thresholds, steps and bounds are per-controller
    quantities and stay as-is. The same contract as :func:`per_shard_config`,
    with a real-valued weight instead of an even split."""
    if not (0.0 < share <= 1.0):
        raise ValueError(f"share must be in (0, 1], got {share}")
    if share == 1.0:
        return cfg
    split = lambda v: max(1, int(v * share)) if v > 0 else v
    return dataclasses.replace(
        cfg,
        c54_per_sec_budget=split(cfg.c54_per_sec_budget),
        frame_high=split(cfg.frame_high),
        frame_low=split(cfg.frame_low))


class StreamSwitcherBank:
    """Per-stream Algorithm-1 controllers + share-weighted QoS attribution.

    One `AdaptiveSwitcher` per tenant stream, each seeded with the
    stream-level config split by that stream's normalized share
    (:func:`per_stream_config`) — thresholds adapt independently, so one
    tenant's content can never move another tenant's decision boundary.
    ``tick_quotas`` turns each stream's split per-second budget into its
    per-admission-tick C54 slot quota (the traced ``quotas`` argument of the
    fused multi-stream executable). ``note_tick`` attributes a missed tick
    deadline by *share-weighted* cost — a stream is the overload source when
    its MAC cost exceeds what its share entitles it to — mirroring
    `ShardSwitcherBank.note_frame`'s cost-model attribution.
    """

    def __init__(self, cfg: Optional[SwitchingConfig] = None,
                 streams: int = 1,
                 shares: Optional[Sequence[float]] = None):
        cfg = cfg if cfg is not None else SwitchingConfig()
        if streams < 1:
            raise ValueError(f"streams must be >= 1, got {streams}")
        if shares is None:
            shares = (1.0,) * streams
        if len(shares) != streams:
            raise ValueError(f"got {len(shares)} shares for {streams} streams")
        total = float(sum(shares))
        if not (total > 0 and np.isfinite(total)):
            raise ValueError(f"shares must sum to a positive finite value, "
                             f"got {tuple(shares)}")
        self.streams = streams
        self.shares: Tuple[float, ...] = tuple(float(s) / total for s in shares)
        self.switchers: List[AdaptiveSwitcher] = [
            AdaptiveSwitcher(per_stream_config(cfg, sh))
            for sh in self.shares]

    def tick_quotas(self) -> Tuple[int, ...]:
        """Per-stream C54 slot quota for one admission tick: each tenant's
        split per-second budget spread over its fps, floored at 1 (a live
        stream always keeps at least one C54 slot — shares degrade quality,
        they never starve a tenant)."""
        return tuple(max(1, sw.cfg.c54_per_sec_budget // max(1, sw.cfg.fps))
                     for sw in self.switchers)

    def observe(self, stream: int, n_c54: int) -> None:
        """Feed one stream's served-frame C54 count to its own controller."""
        self.switchers[stream].observe_frame(n_c54)

    def note_tick(self, missed: bool, costs: Sequence[float],
                  streams: Optional[Sequence[int]] = None
                  ) -> Tuple[bool, ...]:
        """Feed back one tick's outcome; returns which streams were demoted.

        ``costs``: estimated per-stream MAC cost of the tick just served;
        ``streams``: the live stream indices those costs belong to (defaults
        to all). On a missed (shared wall-clock) deadline the streams whose
        *share-weighted* cost — cost divided by normalized share — exceeds
        the weighted mean are demoted with severity = overweight ratio; a
        tick loaded exactly in share proportion demotes every live stream
        (aggregate throughput must recover, and no tenant is entitled to the
        others' backing off alone)."""
        live = tuple(range(self.streams)) if streams is None else tuple(streams)
        if len(costs) != len(live):
            raise ValueError(f"got {len(costs)} costs for {len(live)} "
                             f"live streams")
        if not missed:
            return (False,) * self.streams
        weighted = np.asarray(
            [float(c) / self.shares[s] for c, s in zip(costs, live)],
            np.float64)
        mean = float(weighted.mean())
        demoted = [False] * self.streams
        if mean <= 0 or np.allclose(weighted, mean):
            # loaded exactly in share proportion: every live stream backs off
            for s in live:
                demoted[s] = True
                self.switchers[s].demote_for_straggler(severity=1.0)
        else:
            for w, s in zip(weighted, live):
                if w > mean:
                    demoted[s] = True
                    # severity capped like the shard bank: one pathological
                    # tick cannot slam a tenant's thresholds to the bound
                    self.switchers[s].demote_for_straggler(
                        severity=min(float(w / mean), 3.0))
        return tuple(demoted)

    @property
    def thresholds(self) -> Tuple[Tuple[float, float], ...]:
        return tuple(sw.thresholds for sw in self.switchers)
