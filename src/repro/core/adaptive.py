"""Resource-adaptive model switching (paper Sec. IV-A, Algorithm 1).

Host-side feedback controller over the two edge thresholds:

  * hard compute ceiling: if the number of C54 patches this second exceeds
    ``c54_per_sec_budget`` (25 500 for 8K@30FPS on the paper's PE array), the
    *rest of the patches run with C27* — throughput guaranteed, quality floor
    kept at C27;
  * per-frame trim: > ``frame_high`` C54 patches in a frame  -> (t1,t2) += (1,5)
                    < ``frame_low``  C54 patches in a frame  -> (t1,t2) -= (1,5)

The same controller is reused by the serving runtime as *straggler
mitigation*: a shard that falls behind its deadline raises the local
thresholds, demoting its patches (Sec. "runtime" in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import subnet_policy as sp


@dataclasses.dataclass
class SwitchingConfig:
    t1: float = sp.DEFAULT_T1
    t2: float = sp.DEFAULT_T2
    c54_per_sec_budget: int = 25_500
    frame_high: int = 1000
    frame_low: int = 700
    fps: int = 30
    t1_step: float = 1.0
    t2_step: float = 5.0
    t1_bounds: Tuple[float, float] = (0.0, 255.0)
    t2_bounds: Tuple[float, float] = (1.0, 255.0)


class AdaptiveSwitcher:
    """Stateful Algorithm-1 controller. One instance per stream (or shard)."""

    def __init__(self, cfg: Optional[SwitchingConfig] = None):
        self.cfg = cfg = cfg if cfg is not None else SwitchingConfig()
        self.t1 = float(cfg.t1)
        self.t2 = float(cfg.t2)
        self._c54_this_second = 0
        self._frames_this_second = 0

    # -- public -------------------------------------------------------------

    def assign(self, scores: np.ndarray) -> np.ndarray:
        """Edge scores of one frame's patches (raster order) -> subnet ids.

        Applies the per-second C54 ceiling (demote overflow to C27 in raster
        order, exactly "the rest of the patches run with C27"), then the
        per-frame threshold adaptation.
        """
        scores = np.asarray(scores)
        ids = np.array(sp.decide(scores, self.t1, self.t2))  # writable copy

        # --- hard ceiling over the current second -------------------------
        budget_left = self.cfg.c54_per_sec_budget - self._c54_this_second
        c54_idx = np.flatnonzero(ids == sp.C54)
        if len(c54_idx) > budget_left:
            overflow = c54_idx[max(budget_left, 0):]
            ids[overflow] = sp.C27
        n_c54 = int((ids == sp.C54).sum())
        self._c54_this_second += n_c54

        # --- per-frame threshold trim (Algorithm 1's else-branch) ---------
        if n_c54 > self.cfg.frame_high:
            self.t1 += self.cfg.t1_step
            self.t2 += self.cfg.t2_step
        elif n_c54 < self.cfg.frame_low:
            self.t1 -= self.cfg.t1_step
            self.t2 -= self.cfg.t2_step
        self._clamp()

        # --- second roll-over ---------------------------------------------
        self._frames_this_second += 1
        if self._frames_this_second >= self.cfg.fps:
            self._frames_this_second = 0
            self._c54_this_second = 0
        return ids

    def demote_for_straggler(self, severity: float = 1.0) -> None:
        """Straggler hook: a late shard raises thresholds proportionally."""
        self.t1 += self.cfg.t1_step * severity
        self.t2 += self.cfg.t2_step * severity
        self._clamp()

    # -- internals ----------------------------------------------------------

    def _clamp(self) -> None:
        c = self.cfg
        self.t1 = float(np.clip(self.t1, *c.t1_bounds))
        self.t2 = float(np.clip(self.t2, *c.t2_bounds))
        if self.t2 <= self.t1:          # keep the decision boundary ordered
            self.t2 = self.t1 + 1.0

    @property
    def thresholds(self) -> Tuple[float, float]:
        return (self.t1, self.t2)
