"""Edge score (paper Sec. II-A).

luma -> 3x3 Laplacian -> |.| clamped to [0,255] -> mean  ==> scalar per patch.

The Laplacian runs on the *interior* (VALID) so patch borders do not inject
fake edges; this matches computing the score before the slim-overlap halo is
attached. Scores live in [0, 255].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rgb_to_luma

# 4-neighbour Laplacian (the standard 3x3 form)
LAPLACIAN = jnp.array([[0.0, 1.0, 0.0],
                       [1.0, -4.0, 1.0],
                       [0.0, 1.0, 0.0]], dtype=jnp.float32)


def laplacian_response(luma: jax.Array) -> jax.Array:
    """(N,H,W) luma in [0,255] -> (N,H-2,W-2) |Laplacian| clamped to [0,255]."""
    k = LAPLACIAN.reshape(3, 3, 1, 1)
    y = lax.conv_general_dilated(
        luma[..., None], k, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[..., 0]
    return jnp.clip(jnp.abs(y), 0.0, 255.0)


@jax.jit
def edge_score(patches: jax.Array) -> jax.Array:
    """(N,h,w,3) RGB in [0,1]  ->  (N,) edge scores in [0,255].

    jit'd: the serving path scores every patch batch of a stream, and the
    shapes recur per geometry."""
    luma = rgb_to_luma(patches)
    resp = laplacian_response(luma)
    return resp.mean(axis=(1, 2))


@jax.jit
def edge_score_luma(luma: jax.Array) -> jax.Array:
    """(N,h,w) luma in [0,255] -> (N,) edge scores."""
    return laplacian_response(luma).mean(axis=(1, 2))
