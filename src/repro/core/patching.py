"""Slim-overlap patch extraction and thick-overlap boundary fusion (Sec. IV-I).

The paper's final choice: LR patches overlap by 2 px ("slim overlap block
convolution"); after x4 upsampling the SR patches overlap by 8 px ("thick
overlap"), and overlapped pixels are averaged ("overlap and average").

Also implements the alternatives of Table III for the boundary benchmark:
  - 'interpolate'  : non-overlapped patches, borders blended by interpolation
  - 'recompute'    : lossless halo recompute (== whole-image convolution)
  - 'overlap_avg'  : the paper's pick
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def grid_starts(size: int, patch: int, overlap: int) -> np.ndarray:
    """1-D tiling start offsets with ``overlap`` px shared between neighbours.

    Every pixel is covered; the final patch is clamped to end at the image
    edge (so its overlap with its neighbour may exceed ``overlap``).
    """
    if size <= patch:
        return np.array([0], dtype=np.int64)
    stride = patch - overlap
    starts = list(range(0, size - patch, stride))
    starts.append(size - patch)
    return np.array(sorted(set(starts)), dtype=np.int64)


def extract_patches(img: jax.Array, patch: int = 32, overlap: int = 2
                    ) -> Tuple[jax.Array, np.ndarray]:
    """(H,W,C) -> ((N,patch,patch,C), positions (N,2)).  Host-side grid, static."""
    h, w = int(img.shape[0]), int(img.shape[1])
    ys, xs = grid_starts(h, patch, overlap), grid_starts(w, patch, overlap)
    pos = np.array([(y, x) for y in ys for x in xs], dtype=np.int64)
    patches = jnp.stack([
        jax.lax.dynamic_slice(img, (int(y), int(x), 0), (patch, patch, img.shape[2]))
        for y, x in pos])
    return patches, pos


def fuse_patches_average(sr_patches: jax.Array, pos_lr: np.ndarray, scale: int,
                         out_hw: Tuple[int, int]) -> jax.Array:
    """Overlap-and-average fusion of SR patches (the paper's boundary method).

    sr_patches: (N, p*s, p*s, C); pos_lr: LR-space (y,x); out: (H*s, W*s, C).
    """
    ph = sr_patches.shape[1]
    out = jnp.zeros((out_hw[0], out_hw[1], sr_patches.shape[-1]), sr_patches.dtype)
    cnt = jnp.zeros((out_hw[0], out_hw[1], 1), sr_patches.dtype)
    ones = jnp.ones((ph, ph, 1), sr_patches.dtype)
    for i, (y, x) in enumerate(pos_lr):
        yy, xx = int(y) * scale, int(x) * scale
        out = jax.lax.dynamic_update_slice(
            out, jax.lax.dynamic_slice(out, (yy, xx, 0), (ph, ph, out.shape[2]))
            + sr_patches[i], (yy, xx, 0))
        cnt = jax.lax.dynamic_update_slice(
            cnt, jax.lax.dynamic_slice(cnt, (yy, xx, 0), (ph, ph, 1)) + ones,
            (yy, xx, 0))
    return out / cnt


def fuse_patches_crop(sr_patches: jax.Array, pos_lr: np.ndarray, scale: int,
                      out_hw: Tuple[int, int], overlap_lr: int = 0) -> jax.Array:
    """'Interpolation-free' naive fusion: later patches simply overwrite.

    Used as the cheap baseline ('Interpol.' row of Table III behaves like a
    non-overlap + border-fixup scheme; overwrite is its zero-cost floor).
    """
    ph = sr_patches.shape[1]
    out = jnp.zeros((out_hw[0], out_hw[1], sr_patches.shape[-1]), sr_patches.dtype)
    for i, (y, x) in enumerate(pos_lr):
        yy, xx = int(y) * scale, int(x) * scale
        out = jax.lax.dynamic_update_slice(out, sr_patches[i], (yy, xx, 0))
    return out


# ---------------------------------------------------------------------------
# cost accounting for the boundary benchmark (Tables III / IV)
# ---------------------------------------------------------------------------

def overlap_mac_overhead(patch: int, overlap: int) -> float:
    """MAC multiplier of slim-overlap tiling vs non-overlapped (Table IV)."""
    stride = patch - overlap
    return (patch / stride) ** 2


def boundary_sram_bytes(lr_w: int, overlap_lr: int, channels: int,
                        bytes_per: float = 1.25) -> float:
        """Boundary buffer estimate: one horizontal stripe of halo rows spanning
        the LR frame width across feature channels (FXP10 => 1.25 B)."""
        return lr_w * max(overlap_lr, 1) * channels * bytes_per * 2  # top+left stripes
