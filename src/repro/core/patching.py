"""Slim-overlap patch extraction and thick-overlap boundary fusion (Sec. IV-I).

The paper's final choice: LR patches overlap by 2 px ("slim overlap block
convolution"); after x4 upsampling the SR patches overlap by 8 px ("thick
overlap"), and overlapped pixels are averaged ("overlap and average").

Execution model: the hot path is device-resident. All per-patch index maps
(one gather map for extraction, one scatter map + overlap counts for fusion)
are computed ONCE per (H, W, patch, overlap, scale) geometry and LRU-cached
(:func:`get_geometry`), so repeated frames of a stream pay zero host-side
setup: extraction is a single device gather, fusion a single scatter-add.
The seed's per-patch ``dynamic_slice`` / ``dynamic_update_slice`` loops are
retained as ``*_loop`` reference oracles (equivalence-tested, and used by the
before/after measurement in benchmarks/table11_throughput.py).

Frames smaller than ``patch`` are reflect-padded up to the patch size (the
fused output is cropped back), instead of the seed's hard ``dynamic_slice``
failure.

Also implements the alternatives of Table III for the boundary benchmark:
  - 'interpolate'  : non-overlapped patches, borders blended by interpolation
  - 'recompute'    : lossless halo recompute (== whole-image convolution)
  - 'overlap_avg'  : the paper's pick
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.caching import bounded_cache


def grid_starts(size: int, patch: int, overlap: int) -> np.ndarray:
    """1-D tiling start offsets with ``overlap`` px shared between neighbours.

    Every pixel is covered; the final patch is clamped to end at the image
    edge (so its overlap with its neighbour may exceed ``overlap``).
    """
    if size <= patch:
        return np.array([0], dtype=np.int64)
    stride = patch - overlap
    starts = list(range(0, size - patch, stride))
    starts.append(size - patch)
    return np.array(sorted(set(starts)), dtype=np.int64)


def shard_slices(n: int, shards: int) -> Tuple[slice, ...]:
    """Partition ``n`` raster-order patches into ``shards`` contiguous slices.

    Balanced like ``np.array_split``: the first ``n % shards`` slices get one
    extra patch, so a frame whose patch count does not divide evenly is still
    covered exactly once. ``shards > n`` yields empty trailing slices (a
    shard with no patches this frame is legal — its switcher simply sees an
    empty score vector)."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    base, extra = divmod(n, shards)
    out, start = [], 0
    for k in range(shards):
        stop = start + base + (1 if k < extra else 0)
        out.append(slice(start, stop))
        start = stop
    return tuple(out)


def _reflect_pad_hw(img: jax.Array, pad_h: int, pad_w: int) -> jax.Array:
    """Reflect-pad the bottom/right of (H,W,C) ``img``; falls back to edge
    padding for the (degenerate) remainder when a dim is shorter than the
    reflection it needs (np/jnp reflect requires pad <= dim-1)."""
    h, w = int(img.shape[0]), int(img.shape[1])
    rh, rw = min(pad_h, max(h - 1, 0)), min(pad_w, max(w - 1, 0))
    if rh or rw:
        img = jnp.pad(img, ((0, rh), (0, rw), (0, 0)), mode="reflect")
    eh, ew = pad_h - rh, pad_w - rw
    if eh or ew:
        img = jnp.pad(img, ((0, eh), (0, ew), (0, 0)), mode="edge")
    return img


@dataclasses.dataclass(frozen=True, eq=False)     # identity eq: fields hold arrays
class PatchGeometry:
    """Device-resident index maps for one (H, W, patch, overlap, scale) tiling.

    Built once per geometry by :func:`get_geometry` (LRU-cached; also exposed
    as ``ExecutionPlan.geometry``). ``pos`` is in (possibly padded) LR
    coordinates; ``padded_hw >= hw`` only when the frame is smaller than the
    patch, in which case :meth:`fuse_average` crops back to ``hw * scale``.

    Fusion is *separable*: the grid is a cartesian product ``ys x xs``, so
    overlap-add runs as one row-slice scatter along y and one column scatter
    along x (``n_y*ps + n_x*ps`` fat slices instead of ``N*ps*ps`` scalar
    rows — ~2.5x faster than a flat scatter on CPU, and the shape XLA tiles
    well on TPU).
    """
    hw: Tuple[int, int]            # original LR frame size
    padded_hw: Tuple[int, int]     # reflect-padded (>= patch) LR size
    patch: int
    overlap: int
    scale: int
    pos: np.ndarray                # (N, 2) LR-space (y, x) patch starts
    grid_yx: Tuple[int, int]       # (n_y, n_x): pos is their cartesian product
    gather_idx: jax.Array          # (N*p*p,) linear indices into the LR plane
    y_idx: jax.Array               # (n_y*ps,) HR row index per patch row
    x_idx: jax.Array               # (n_x*ps,) HR col index per patch col
    # overlap multiplicity factors per axis (>= 1): the cartesian grid makes
    # the per-pixel count their outer product, so the cache holds two O(edge)
    # vectors instead of a full HR-resolution map (~100 KB vs ~133 MB for a
    # 1080p -> x4 geometry)
    y_cnt: jax.Array               # (Hp*s,)
    x_cnt: jax.Array               # (Wp*s,)

    @property
    def n(self) -> int:
        return len(self.pos)

    @property
    def cache_key(self) -> Tuple[int, int, int, int, int]:
        """Hashable identity of this tiling — ``(H, W, patch, overlap,
        scale)`` fully determines every index map. Used by the fused frame
        executable cache and the engine's warm-up bookkeeping (the object
        itself hashes by identity, which only coincides with this key while
        the `get_geometry` LRU retains the instance)."""
        return (*self.hw, self.patch, self.overlap, self.scale)

    def shard_slices(self, shards: int) -> Tuple[slice, ...]:
        """Contiguous raster-strip partition of this geometry's patches —
        the unit of per-shard routing/straggler control (see core.adaptive)."""
        return shard_slices(self.n, shards)

    def extract(self, img: jax.Array) -> jax.Array:
        """(H,W,C) -> (N,patch,patch,C): one device gather.

        Traceable: safe to call on a traced ``img`` inside an enclosing jit
        (the fused frame graph does) — the index maps close over as
        constants and the reflect-pad path is shape-static."""
        h, w = self.hw
        hp, wp = self.padded_hw
        if (hp, wp) != (h, w):
            img = _reflect_pad_hw(img, hp - h, wp - w)
        flat = img.reshape(hp * wp, img.shape[-1])
        p = self.patch
        return jnp.take(flat, self.gather_idx, axis=0).reshape(self.n, p, p, -1)

    def fuse_average(self, sr_patches: jax.Array) -> jax.Array:
        """(N, p*s, p*s, C) -> (H*s, W*s, C): separable scatter-add, then a
        precomputed per-pixel overlap division (overlap-and-average).

        Traceable like :meth:`extract`: the fused frame graph calls it on a
        traced patch tensor, inlining the (already jitted) separable fold."""
        hp, wp = self.padded_hw
        s = self.scale
        n_y, n_x = self.grid_yx
        out = _fuse_separable(sr_patches, self.y_idx, self.x_idx,
                              self.y_cnt, self.x_cnt,
                              n_y=n_y, n_x=n_x, ps=self.patch * s,
                              hh=hp * s, wh=wp * s)
        h, w = self.hw
        return out[:h * s, :w * s]


@functools.partial(jax.jit, static_argnames=("n_y", "n_x", "ps", "hh", "wh"))
def _fuse_separable(sr, y_idx, x_idx, y_cnt, x_cnt, *, n_y: int, n_x: int,
                    ps: int, hh: int, wh: int):
    """Overlap-and-average over a cartesian patch grid as two axis folds.

    The per-pixel overlap count is the outer product of the axis counts, so
    averaging is pre-applied as per-row/per-column reciprocal weights on the
    patch tensor — no HR-resolution count map is ever materialized outside
    the jit, and the scatters need no final divide."""
    c = sr.shape[-1]
    wy = jnp.take(1.0 / y_cnt, y_idx).astype(sr.dtype)
    wx = jnp.take(1.0 / x_cnt, x_idx).astype(sr.dtype)
    t = sr.reshape(n_y, n_x, ps, ps, c).transpose(0, 2, 1, 3, 4)
    t = t.reshape(n_y * ps, n_x, ps, c)
    t = t * wy[:, None, None, None] * wx.reshape(n_x, ps)[None, :, :, None]
    acc = jnp.zeros((hh, n_x, ps, c), sr.dtype).at[y_idx].add(
        t, mode="drop")
    return jnp.zeros((hh, wh, c), sr.dtype).at[:, x_idx].add(
        acc.reshape(hh, n_x * ps, c), mode="drop")


def _index_maps(pos: np.ndarray, patch: int, plane_w: int, scale: int
                ) -> np.ndarray:
    """(N,2) starts -> (N*ps*ps,) linear indices into the scaled plane."""
    ps = patch * scale
    ar = np.arange(ps)
    rows = pos[:, 0][:, None] * scale + ar                       # (N, ps)
    cols = pos[:, 1][:, None] * scale + ar                       # (N, ps)
    return (rows[:, :, None] * (plane_w * scale)
            + cols[:, None, :]).reshape(-1)


def _axis_idx(starts: np.ndarray, patch: int, scale: int) -> np.ndarray:
    """1-D starts -> (len(starts)*patch*scale,) scaled output offsets."""
    return (starts[:, None] * scale
            + np.arange(patch * scale)).reshape(-1)


@bounded_cache(maxsize=128)
def get_geometry(h: int, w: int, patch: int = 32, overlap: int = 2,
                 scale: int = 4) -> PatchGeometry:
    """The cached geometry for one frame shape — the hot path's only host
    work, paid once per (H, W, patch, overlap, scale).

    A `core.caching.BoundedCache` (lru semantics, runtime-resizable):
    `SREngine` sizes it together with the compiled-executable caches via
    `core.pipeline.configure_compiled_caches`, and its occupancy rides
    `FrameResult.summary()`."""
    pos, gather_idx, (hp, wp), (n_y, n_x) = _extract_maps(h, w, patch, overlap)
    ys, xs = np.unique(pos[:, 0]), np.unique(pos[:, 1])
    y_idx, x_idx, y_cnt, x_cnt = _cartesian_maps(
        ys.tobytes(), xs.tobytes(), patch, scale, hp, wp)
    return PatchGeometry(
        hw=(h, w), padded_hw=(hp, wp), patch=patch, overlap=overlap,
        scale=scale, pos=pos, grid_yx=(n_y, n_x),
        gather_idx=gather_idx,
        y_idx=y_idx, x_idx=x_idx, y_cnt=y_cnt, x_cnt=x_cnt)


@functools.lru_cache(maxsize=128)
def _extract_maps(h: int, w: int, patch: int, overlap: int):
    """Scale-independent LR-side maps: positions + gather index + padded dims.
    Shared by `get_geometry` (every scale) and standalone `extract_patches`,
    so the gather map exists once per (h, w, patch, overlap)."""
    hp, wp = max(h, patch), max(w, patch)
    ys, xs = grid_starts(hp, patch, overlap), grid_starts(wp, patch, overlap)
    pos = np.array([(y, x) for y in ys for x in xs], dtype=np.int64)
    pos.setflags(write=False)   # cached + shared: a mutating caller would
    return (pos, jnp.asarray(_index_maps(pos, patch, wp, 1), jnp.int32),
            (hp, wp), (len(ys), len(xs)))   # corrupt every later frame


def extract_patches(img: jax.Array, patch: int = 32, overlap: int = 2
                    ) -> Tuple[jax.Array, np.ndarray]:
    """(H,W,C) -> ((N,patch,patch,C), positions (N,2)): one device gather
    over the cached scale-independent LR maps."""
    h, w = int(img.shape[0]), int(img.shape[1])
    pos, gather_idx, (hp, wp), _ = _extract_maps(h, w, patch, overlap)
    if (hp, wp) != (h, w):
        img = _reflect_pad_hw(img, hp - h, wp - w)
    flat = img.reshape(hp * wp, img.shape[-1])
    return (jnp.take(flat, gather_idx, axis=0
                     ).reshape(len(pos), patch, patch, -1), pos)


def _axis_cnt(starts: np.ndarray, patch: int, scale: int,
              plane: int) -> np.ndarray:
    """Per-output-pixel coverage multiplicity along one axis (>= 1)."""
    cnt = np.zeros(plane * scale, np.float32)
    np.add.at(cnt, _axis_idx(starts, patch, scale), 1.0)
    return np.maximum(cnt, 1.0)          # pixels no patch covers: avoid 0/0


@functools.lru_cache(maxsize=128)
def _cartesian_maps(ys_bytes: bytes, xs_bytes: bytes, patch: int, scale: int,
                    plane_h: int, plane_w: int):
    """Axis index maps + per-axis overlap counts for a cartesian start grid
    (shared by `get_geometry` and the standalone `fuse_patches_average` fast
    path), cached per grid. The 2-D count is the outer product of the axis
    counts, so nothing HR-resolution is ever cached."""
    ys = np.frombuffer(ys_bytes, dtype=np.int64)
    xs = np.frombuffer(xs_bytes, dtype=np.int64)
    return (jnp.asarray(_axis_idx(ys, patch, scale), jnp.int32),
            jnp.asarray(_axis_idx(xs, patch, scale), jnp.int32),
            jnp.asarray(_axis_cnt(ys, patch, scale, plane_h)),
            jnp.asarray(_axis_cnt(xs, patch, scale, plane_w)))


@functools.lru_cache(maxsize=4)    # HR-sized entries: keep this tiny
def _fusion_maps(pos_bytes: bytes, n: int, patch: int, plane_w: int,
                 scale: int, plane_h: int) -> Tuple[jax.Array, jax.Array]:
    """Scatter map + overlap counts for an arbitrary NON-cartesian position
    list — the rare standalone-`fuse_patches_average` fallback. Unlike the
    cartesian maps these are full-plane arrays (the ~133 MB blow-up the
    separable path avoids), so only a few entries are retained."""
    pos = np.frombuffer(pos_bytes, dtype=np.int64).reshape(n, 2)
    lin = _index_maps(pos, patch, plane_w, scale)
    cnt = np.zeros(plane_h * scale * plane_w * scale, np.float32)
    np.add.at(cnt, lin, 1.0)
    cnt = np.maximum(cnt, 1.0)           # pixels no patch covers: avoid 0/0
    return jnp.asarray(lin, jnp.int32), jnp.asarray(cnt[:, None])


def _is_cartesian(pos: np.ndarray) -> bool:
    """True when ``pos`` is the row-major cartesian product of its unique
    y/x starts (every grid produced by ``grid_starts`` is)."""
    ys, xs = np.unique(pos[:, 0]), np.unique(pos[:, 1])
    if len(ys) * len(xs) != len(pos):
        return False
    grid = np.array([(y, x) for y in ys for x in xs], dtype=pos.dtype)
    return bool(np.array_equal(pos, grid))


def fuse_patches_average(sr_patches: jax.Array, pos_lr: np.ndarray, scale: int,
                         out_hw: Tuple[int, int]) -> jax.Array:
    """Overlap-and-average fusion of SR patches (the paper's boundary method).

    sr_patches: (N, p*s, p*s, C); pos_lr: LR-space (y,x); out: (H*s, W*s, C).
    Cartesian-grid positions (the ``grid_starts`` layout) take the separable
    two-fold scatter; arbitrary position lists fall back to one flat
    scatter-add over index maps cached per position list.
    """
    pos = np.asarray(pos_lr, dtype=np.int64)
    ph = int(sr_patches.shape[1])
    patch = ph // scale
    # LR canvas must hold every patch; exceeds out_hw only for the
    # reflect-padded sub-patch-size frames (cropped below).
    plane_h = max(-(-out_hw[0] // scale), int(pos[:, 0].max()) + patch)
    plane_w = max(-(-out_hw[1] // scale), int(pos[:, 1].max()) + patch)
    c = sr_patches.shape[-1]
    if _is_cartesian(pos):
        ys, xs = np.unique(pos[:, 0]), np.unique(pos[:, 1])
        y_idx, x_idx, y_cnt, x_cnt = _cartesian_maps(
            ys.tobytes(), xs.tobytes(), patch, scale, plane_h, plane_w)
        out = _fuse_separable(sr_patches, y_idx, x_idx, y_cnt, x_cnt,
                              n_y=len(ys), n_x=len(xs), ps=ph,
                              hh=plane_h * scale, wh=plane_w * scale)
        return out[:out_hw[0], :out_hw[1]]
    lin, cnt = _fusion_maps(pos.tobytes(), len(pos), patch, plane_w, scale,
                            plane_h)
    acc = jnp.zeros((plane_h * scale * plane_w * scale, c), sr_patches.dtype)
    acc = acc.at[lin].add(sr_patches.reshape(-1, c), mode="drop")
    out = (acc / cnt.astype(sr_patches.dtype)
           ).reshape(plane_h * scale, plane_w * scale, c)
    return out[:out_hw[0], :out_hw[1]]


def fuse_patches_crop(sr_patches: jax.Array, pos_lr: np.ndarray, scale: int,
                      out_hw: Tuple[int, int]) -> jax.Array:
    """'Interpolation-free' naive fusion: later patches simply overwrite.

    Used as the cheap baseline ('Interpol.' row of Table III behaves like a
    non-overlap + border-fixup scheme; overwrite is its zero-cost floor).
    Kept as a loop: XLA scatter does not guarantee last-write-wins on
    duplicate indices, and this baseline is not on the hot path.
    """
    out = jnp.zeros((out_hw[0], out_hw[1], sr_patches.shape[-1]), sr_patches.dtype)
    for i, (y, x) in enumerate(pos_lr):
        yy, xx = int(y) * scale, int(x) * scale
        out = jax.lax.dynamic_update_slice(out, sr_patches[i], (yy, xx, 0))
    return out


# ---------------------------------------------------------------------------
# seed loop implementations — kept as reference oracles (equivalence tests +
# the before/after host-loop-removal benchmark); NOT on the serving path
# ---------------------------------------------------------------------------

def extract_patches_loop(img: jax.Array, patch: int = 32, overlap: int = 2
                         ) -> Tuple[jax.Array, np.ndarray]:
    """Seed implementation: one traced ``dynamic_slice`` per patch."""
    h, w = int(img.shape[0]), int(img.shape[1])
    ys, xs = grid_starts(h, patch, overlap), grid_starts(w, patch, overlap)
    pos = np.array([(y, x) for y in ys for x in xs], dtype=np.int64)
    patches = jnp.stack([
        jax.lax.dynamic_slice(img, (int(y), int(x), 0), (patch, patch, img.shape[2]))
        for y, x in pos])
    return patches, pos


def fuse_patches_average_loop(sr_patches: jax.Array, pos_lr: np.ndarray,
                              scale: int, out_hw: Tuple[int, int]) -> jax.Array:
    """Seed implementation: two ``dynamic_update_slice`` per patch."""
    ph = sr_patches.shape[1]
    out = jnp.zeros((out_hw[0], out_hw[1], sr_patches.shape[-1]), sr_patches.dtype)
    cnt = jnp.zeros((out_hw[0], out_hw[1], 1), sr_patches.dtype)
    ones = jnp.ones((ph, ph, 1), sr_patches.dtype)
    for i, (y, x) in enumerate(pos_lr):
        yy, xx = int(y) * scale, int(x) * scale
        out = jax.lax.dynamic_update_slice(
            out, jax.lax.dynamic_slice(out, (yy, xx, 0), (ph, ph, out.shape[2]))
            + sr_patches[i], (yy, xx, 0))
        cnt = jax.lax.dynamic_update_slice(
            cnt, jax.lax.dynamic_slice(cnt, (yy, xx, 0), (ph, ph, 1)) + ones,
            (yy, xx, 0))
    return out / cnt


# ---------------------------------------------------------------------------
# cost accounting for the boundary benchmark (Tables III / IV)
# ---------------------------------------------------------------------------

def overlap_mac_overhead(patch: int, overlap: int) -> float:
    """MAC multiplier of slim-overlap tiling vs non-overlapped (Table IV)."""
    stride = patch - overlap
    return (patch / stride) ** 2


def boundary_sram_bytes(lr_w: int, overlap_lr: int, channels: int,
                        bytes_per: float = 1.25) -> float:
    """Boundary buffer estimate: one horizontal stripe of halo rows spanning
    the LR frame width across feature channels (FXP10 => 1.25 B)."""
    return lr_w * max(overlap_lr, 1) * channels * bytes_per * 2  # top+left stripes
