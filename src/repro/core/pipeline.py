"""End-to-end edge-selective SR of full frames (paper Fig. 1).

frame -> slim-overlap patches -> edge scores -> subnet decision ->
per-subnet batched forward -> thick-overlap overlap+average fusion.

Two execution styles:
  * ``edge_selective_sr``: host-grouped, jit-per-subnet — the serving path.
    Per-subnet batches are padded to bucketed sizes so jit recompilation is
    bounded (the shape-static analog of the GLNPU's fixed PE array).
  * ``sr_whole`` / ``sr_all_patches``: non-dynamic references for ablations.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import subnet_policy as sp
from repro.core.edge_score import edge_score
from repro.core.patching import extract_patches, fuse_patches_average
from repro.models.essr import ESSRConfig, essr_forward


def _bucket(n: int, buckets=(8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return int(np.ceil(n / buckets[-1]) * buckets[-1])


@functools.partial(jax.jit, static_argnames=("cfg", "width"))
def _forward_width(params, patches, cfg: ESSRConfig, width: int):
    return essr_forward(params, patches, cfg, width=width)


@dataclasses.dataclass
class SRResult:
    image: jax.Array
    ids: np.ndarray
    scores: np.ndarray
    counts: Tuple[int, int, int]
    mac_saving: float


def edge_selective_sr(params: Dict[str, Any], frame: jax.Array, cfg: ESSRConfig,
                      t1: float = sp.DEFAULT_T1, t2: float = sp.DEFAULT_T2,
                      patch: int = 32, overlap: int = 2,
                      ids_override: Optional[np.ndarray] = None) -> SRResult:
    """frame: (H,W,3) in [0,1] -> SRResult with (H*s, W*s, 3) image."""
    patches, pos = extract_patches(frame, patch=patch, overlap=overlap)
    scores = np.asarray(edge_score(patches))
    ids = ids_override if ids_override is not None else np.asarray(sp.decide(scores, t1, t2))

    s = cfg.scale
    out_patches = jnp.zeros((patches.shape[0], patch * s, patch * s, 3), patches.dtype)
    widths = cfg.subnet_widths()
    for k, width in enumerate(widths):
        idx = np.flatnonzero(ids == k)
        if idx.size == 0:
            continue
        cap = _bucket(idx.size)
        pad = np.concatenate([idx, np.zeros(cap - idx.size, dtype=idx.dtype)])
        sr = _forward_width(params, patches[pad], cfg, width)[: idx.size]
        out_patches = out_patches.at[idx].set(sr)

    h, w = int(frame.shape[0]) * s, int(frame.shape[1]) * s
    img = fuse_patches_average(out_patches, pos, s, (h, w))
    counts = sp.subnet_counts(ids)
    saving = sp.SubnetMacs.make(cfg, patch).saving_vs_c54(counts)
    return SRResult(image=img, ids=ids, scores=scores, counts=counts, mac_saving=saving)


def sr_all_patches(params, frame, cfg: ESSRConfig, width: int,
                   patch: int = 32, overlap: int = 2) -> jax.Array:
    """Every patch through one subnet (the non-edge-selective reference)."""
    n = frame.shape[0]
    res = edge_selective_sr(params, frame, cfg, patch=patch, overlap=overlap,
                            ids_override=np.full((len(extract_patches(frame, patch, overlap)[1]),),
                                                 {0: 0, cfg.channels // 2: 1, cfg.channels: 2}[width],
                                                 dtype=np.int64))
    return res.image


def sr_whole(params, frame, cfg: ESSRConfig, width: Optional[int] = None) -> jax.Array:
    """Whole-image convolution (the lossless 'software' reference of Table III)."""
    return essr_forward(params, frame[None], cfg, width=width)[0]
