"""End-to-end edge-selective SR of full frames (paper Fig. 1).

frame -> slim-overlap patches -> edge scores -> subnet decision ->
per-subnet batched forward -> thick-overlap overlap+average fusion.

Two execution styles:
  * ``edge_selective_sr``: device-resident serving path. Patch extraction is
    one cached-index gather, fusion one scatter-add (`PatchGeometry`, cached
    per frame shape); per-subnet batches are padded to bucketed sizes so jit
    recompilation is bounded (the shape-static analog of the GLNPU's fixed PE
    array). Routing itself stays host-side: which subnet a patch takes is
    data-dependent, and the host grouping is what keeps each subnet batch
    shape-static.
  * ``sr_whole`` / ``sr_all_patches``: non-dynamic references for ablations.

``backend`` picks the per-subnet forward: "ref" (pure-JAX jit) or "pallas"
(fused kernel groups); ``interpret`` (None/True/False) selects compiled vs
interpreter Pallas — None auto-compiles on TPU/GPU and falls back to the
interpreter on CPU (see repro.kernels.dispatch).

``quant`` (a `repro.quant.pams.QuantPack`, or None for fp32) swaps the
per-subnet forward for the quantized serving path: PAMS fake-quant emulation
on the "ref" backend, the integer-domain kernel stack (`kernels/qconv.py`:
integer codes between fused groups, int32-accumulate matmuls,
requantize-on-output) on the "pallas" backend. Routing, patch geometry and
fusion are untouched — edge scores are computed on the fp input frame, so a
quant mode can never shift the C54/C27/bilinear routing decision. Bilinear
patches (width 0) bypass the conv lattice entirely, exactly as on the ASIC.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import subnet_policy as sp
from repro.core.edge_score import edge_score
from repro.core.patching import (PatchGeometry, extract_patches_loop,
                                 fuse_patches_average_loop, get_geometry)
from repro.models.essr import ESSRConfig, essr_forward


DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def _bucket(n: int, buckets=DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return int(np.ceil(n / buckets[-1]) * buckets[-1])


@functools.partial(jax.jit, static_argnames=("cfg", "width"))
def _forward_width_jit(params, patches, cfg: ESSRConfig, width: int):
    return essr_forward(params, patches, cfg, width=width)


def _forward_width(params, patches, cfg: ESSRConfig, width: int,
                   interpret: Optional[bool] = None):
    # pure-JAX path has no interpret knob; accepted for a uniform signature
    return _forward_width_jit(params, patches, cfg, width)


def _forward_width_pallas(params, patches, cfg: ESSRConfig, width: int,
                          interpret: Optional[bool] = None):
    """Fused-kernel backend: same contract as ``_forward_width``.

    Bilinear patches never reach the conv kernels (handled by the router on
    the ASIC), so width 0 falls back to the reference resize."""
    from repro.kernels.ops import essr_forward_kernels
    from repro.models.layers import bilinear_resize
    if width == 0:
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_kernels(params, patches, cfg, width=width,
                                interpret=interpret)


BACKENDS = {"ref": _forward_width, "pallas": _forward_width_pallas}


def resolve_backend(name: str):
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; choose from {sorted(BACKENDS)}")


# ---------------------------------------------------------------------------
# quantized per-subnet forwards (ExecutionPlan.quant = "fxp10" | "int8")
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "width", "quant"))
def _forward_width_quant_ref_jit(params, patches, cfg: ESSRConfig, width: int,
                                 quant):
    from repro.quant.pams import quantized_essr_forward
    if width == 0:
        from repro.models.layers import bilinear_resize
        return bilinear_resize(patches, cfg.scale)
    scales = {k: jnp.asarray(v, jnp.float32)
              for k, v in quant.act_scales(width).items()}
    return quantized_essr_forward(params, scales, patches, cfg, quant.qcfg,
                                  width=width)


def _forward_width_quant_ref(params, patches, cfg: ESSRConfig, width: int,
                             interpret: Optional[bool] = None, *, quant):
    """PAMS fake-quant emulation of the whole forward (W/A quantized at every
    conv boundary with the pack's PTQ alphas) — the "ref" quant backend."""
    return _forward_width_quant_ref_jit(params, patches, cfg, width, quant)


def _forward_width_quant_pallas(params, patches, cfg: ESSRConfig, width: int,
                                interpret: Optional[bool] = None, *, quant):
    """Integer-domain quantized kernel stack — the "pallas" quant backend."""
    from repro.kernels.qconv import essr_forward_qkernels
    if width == 0:
        from repro.models.layers import bilinear_resize
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_qkernels(params, patches, cfg, width=width,
                                 pack=quant, interpret=interpret)


QUANT_BACKENDS = {"ref": _forward_width_quant_ref,
                  "pallas": _forward_width_quant_pallas}


def resolve_forward(backend: str, quant=None):
    """(backend, QuantPack-or-None) -> the per-subnet forward callable with
    the uniform ``(params, patches, cfg, width, interpret=)`` signature."""
    resolve_backend(backend)            # single source of name validation
    if quant is None:
        return BACKENDS[backend]
    return functools.partial(QUANT_BACKENDS[backend], quant=quant)


# ---------------------------------------------------------------------------
# data-parallel per-subnet forward (the sharded patch stream)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _sharded_forward_fn(backend: str, mesh, cfg: ESSRConfig, width: int,
                        interpret: Optional[bool], quant=None):
    """jit(shard_map(forward)) splitting the patch batch over ``mesh``'s single
    axis, params replicated. Cached per (backend, mesh, cfg, width, interpret,
    quant) so the shard_map callable (and its compiled executable) is built
    once per routing regime (`QuantPack` is frozen/hashable for exactly this).
    ``check_rep=False``: pallas_call has no replication rule, and the batch
    axis carries no collectives anyway."""
    from repro.distributed.sharding import patch_batch_spec
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    forward = resolve_forward(backend, quant)
    spec = patch_batch_spec(mesh)

    def local(params, patches):
        return forward(params, patches, cfg, width, interpret=interpret)

    return jax.jit(shard_map(local, mesh=mesh, in_specs=(P(), spec),
                             out_specs=spec, check_rep=False))


def sharded_forward(params, patches: jax.Array, cfg: ESSRConfig, width: int,
                    *, mesh, backend: str = "ref",
                    interpret: Optional[bool] = None,
                    quant=None) -> jax.Array:
    """Run one subnet's patch batch data-parallel across ``mesh`` devices.

    Pads the batch up to a multiple of the mesh size by repeating the last
    patch (cache-friendly duplicate work, never another subnet's patch) and
    slices the output back, so callers need no divisibility guarantees."""
    n = int(patches.shape[0])
    k = int(mesh.size)
    pad = (-n) % k
    if pad:
        patches = jnp.concatenate(
            [patches, jnp.repeat(patches[-1:], pad, axis=0)], axis=0)
    out = _sharded_forward_fn(backend, mesh, cfg, width, interpret, quant)(
        params, patches)
    return out[:n] if pad else out


@dataclasses.dataclass
class SRResult:
    image: jax.Array
    ids: np.ndarray
    scores: np.ndarray
    counts: Tuple[int, int, int]
    mac_saving: float


def edge_selective_sr(params: Dict[str, Any], frame: jax.Array, cfg: ESSRConfig,
                      t1: float = sp.DEFAULT_T1, t2: float = sp.DEFAULT_T2,
                      patch: int = 32, overlap: int = 2,
                      ids_override: Optional[np.ndarray] = None,
                      buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                      backend: str = "ref",
                      interpret: Optional[bool] = None,
                      geometry: Optional[PatchGeometry] = None,
                      precomputed: Optional[Tuple[jax.Array, np.ndarray,
                                                  np.ndarray]] = None,
                      mesh=None,
                      quant=None,
                      use_loop_reference: bool = False) -> SRResult:
    """frame: (H,W,3) in [0,1] -> SRResult with (H*s, W*s, 3) image.

    ``geometry``: optional pre-fetched `PatchGeometry` (SREngine passes its
    plan's); resolved from the cache otherwise — either way the per-frame
    host work is index-free.

    ``mesh``: optional 1-D device mesh (``launch.mesh.make_patch_mesh``).
    When given with size > 1, every per-subnet batch is split across its
    devices (shard_map data parallel, params replicated) and fused back
    through the same scatter-add geometry — numerically identical to the
    single-device path. ``None`` or size 1 is exactly the old path.

    ``precomputed``: optional (patches, pos, scores) from a caller that
    already extracted/scored this frame (the streaming path scores patches
    for the adaptive switcher) — avoids doing that work twice per frame.

    ``quant``: optional `repro.quant.pams.QuantPack` — serve this frame
    through the quantized forward of the chosen backend (see module
    docstring). Edge scoring/routing stay fp either way.

    ``use_loop_reference``: run the seed per-patch extract/fuse loops instead
    of the vectorized gather/scatter — the equivalence oracle for tests and
    the "before" side of benchmarks/table11_throughput.py. Never the serving
    path.
    """
    forward = resolve_forward(backend, quant)
    if mesh is not None and int(mesh.size) > 1:
        def forward(params, patches, cfg, width, interpret=None):
            return sharded_forward(params, patches, cfg, width, mesh=mesh,
                                   backend=backend, interpret=interpret,
                                   quant=quant)
    s = cfg.scale
    h, w = int(frame.shape[0]), int(frame.shape[1])
    g = geometry if geometry is not None else get_geometry(h, w, patch,
                                                           overlap, s)
    if precomputed is not None:
        patches, pos, scores = precomputed
        scores = np.asarray(scores)
    else:
        if use_loop_reference:
            patches, pos = extract_patches_loop(frame, patch, overlap)
        else:
            patches, pos = g.extract(frame), g.pos
        if ids_override is None:
            scores = np.asarray(edge_score(patches))
        else:
            # forced routing never consults the edge unit (as on the ASIC);
            # scores are reported as zeros rather than computed and discarded
            scores = np.zeros(len(pos), np.float32)
    ids = ids_override if ids_override is not None else np.asarray(sp.decide(scores, t1, t2))

    out_patches = jnp.zeros((patches.shape[0], patch * s, patch * s, 3),
                            patches.dtype)
    widths = cfg.subnet_widths()
    for k, width in enumerate(widths):
        idx = np.flatnonzero(ids == k)
        if idx.size == 0:
            continue
        if idx.size == len(ids):
            # one subnet took the whole frame: no gather/scatter, and no
            # bucket padding (the full-batch shape recurs per geometry, so
            # compilation stays bounded without it)
            out_patches = forward(params, patches, cfg, width,
                                  interpret=interpret)
            continue
        cap = _bucket(idx.size, buckets)
        # pad with the bucket's own last index (not patch 0): the duplicate
        # work is cache-friendly and never re-runs another subnet's patch
        pad = np.concatenate([idx, np.full(cap - idx.size, idx[-1], idx.dtype)])
        sr = forward(params, jnp.take(patches, jnp.asarray(pad), axis=0),
                     cfg, width, interpret=interpret)[: idx.size]
        out_patches = out_patches.at[jnp.asarray(idx)].set(sr)

    if use_loop_reference:
        img = fuse_patches_average_loop(out_patches, pos, s, (h * s, w * s))
    else:
        img = g.fuse_average(out_patches)
    counts = sp.subnet_counts(ids)
    saving = sp.SubnetMacs.make(cfg, patch).saving_vs_c54(counts)
    return SRResult(image=img, ids=ids, scores=scores, counts=counts, mac_saving=saving)


def sr_all_patches_result(params, frame, cfg: ESSRConfig, width: int,
                          patch: int = 32, overlap: int = 2,
                          buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                          backend: str = "ref",
                          interpret: Optional[bool] = None,
                          geometry: Optional[PatchGeometry] = None,
                          mesh=None, quant=None) -> SRResult:
    """Every patch through one subnet (the non-edge-selective reference).

    The single implementation of forced routing — the edge-score pass is
    skipped entirely (scores are reported as zeros)."""
    widths = cfg.subnet_widths()
    if width not in widths:
        raise ValueError(f"width {width} not one of the subnet widths {widths}")
    g = geometry if geometry is not None else get_geometry(
        int(frame.shape[0]), int(frame.shape[1]), patch, overlap, cfg.scale)
    patches, pos = g.extract(frame), g.pos
    ids = np.full((len(pos),), widths.index(width), dtype=np.int64)
    return edge_selective_sr(params, frame, cfg, patch=patch, overlap=overlap,
                             ids_override=ids, buckets=buckets, backend=backend,
                             interpret=interpret, geometry=g, mesh=mesh,
                             quant=quant,
                             precomputed=(patches, pos,
                                          np.zeros(len(pos), np.float32)))


def sr_all_patches(params, frame, cfg: ESSRConfig, width: int,
                   patch: int = 32, overlap: int = 2,
                   backend: str = "ref") -> jax.Array:
    """Image-only wrapper over ``sr_all_patches_result``."""
    return sr_all_patches_result(params, frame, cfg, width,
                                 patch=patch, overlap=overlap,
                                 backend=backend).image


def sr_whole(params, frame, cfg: ESSRConfig, width: Optional[int] = None) -> jax.Array:
    """Whole-image convolution (the lossless 'software' reference of Table III)."""
    return essr_forward(params, frame[None], cfg, width=width)[0]
