"""End-to-end edge-selective SR of full frames (paper Fig. 1).

frame -> slim-overlap patches -> edge scores -> subnet decision ->
per-subnet batched forward -> thick-overlap overlap+average fusion.

Two execution styles:
  * ``edge_selective_sr``: device-resident serving path. Patch extraction is
    one cached-index gather, fusion one scatter-add (`PatchGeometry`, cached
    per frame shape); per-subnet batches are padded to bucketed sizes so jit
    recompilation is bounded (the shape-static analog of the GLNPU's fixed PE
    array). Routing itself stays host-side: which subnet a patch takes is
    data-dependent, and the host grouping is what keeps each subnet batch
    shape-static.
  * ``sr_whole`` / ``sr_all_patches``: non-dynamic references for ablations.

``backend`` picks the per-subnet forward: "ref" (pure-JAX jit) or "pallas"
(fused kernel groups); ``interpret`` (None/True/False) selects compiled vs
interpreter Pallas — None auto-compiles on TPU/GPU and falls back to the
interpreter on CPU (see repro.kernels.dispatch).

``quant`` (a `repro.quant.pams.QuantPack`, or None for fp32) swaps the
per-subnet forward for the quantized serving path: PAMS fake-quant emulation
on the "ref" backend, the integer-domain kernel stack (`kernels/qconv.py`:
integer codes between fused groups, int32-accumulate matmuls,
requantize-on-output) on the "pallas" backend. Routing, patch geometry and
fusion are untouched — edge scores are computed on the fp input frame, so a
quant mode can never shift the C54/C27/bilinear routing decision. Bilinear
patches (width 0) bypass the conv lattice entirely, exactly as on the ASIC.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import subnet_policy as sp
from repro.core.caching import bounded_cache
from repro.core.edge_score import edge_score
from repro.core.patching import (PatchGeometry, extract_patches_loop,
                                 fuse_patches_average_loop, get_geometry)
from repro.models.essr import ESSRConfig, essr_forward


DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def _bucket(n: int, buckets=DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return int(np.ceil(n / buckets[-1]) * buckets[-1])


@functools.partial(jax.jit, static_argnames=("cfg", "width"))
def _forward_width_jit(params, patches, cfg: ESSRConfig, width: int):
    return essr_forward(params, patches, cfg, width=width)


def _forward_width(params, patches, cfg: ESSRConfig, width: int,
                   interpret: Optional[bool] = None):
    # pure-JAX path has no interpret knob; accepted for a uniform signature
    return _forward_width_jit(params, patches, cfg, width)


def _forward_width_pallas(params, patches, cfg: ESSRConfig, width: int,
                          interpret: Optional[bool] = None):
    """Fused-kernel backend: same contract as ``_forward_width``.

    Bilinear patches never reach the conv kernels (handled by the router on
    the ASIC), so width 0 falls back to the reference resize."""
    from repro.kernels.ops import essr_forward_kernels
    from repro.models.layers import bilinear_resize
    if width == 0:
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_kernels(params, patches, cfg, width=width,
                                interpret=interpret)


def _forward_width_mega(params, patches, cfg: ESSRConfig, width: int,
                        interpret: Optional[bool] = None):
    """Group-fused Pallas backend (``ExecutionPlan.fusion="group"``): the
    whole subnet layer group in ONE pallas_call, features VMEM-resident
    between groups (`kernels.megakernel`). Same contract as
    ``_forward_width_pallas``; width 0 is the same bilinear bypass."""
    from repro.kernels.megakernel import essr_forward_megakernel
    from repro.models.layers import bilinear_resize
    if width == 0:
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_megakernel(params, patches, cfg, width=width,
                                   interpret=interpret)


BACKENDS = {"ref": _forward_width, "pallas": _forward_width_pallas}

#: Kernel fusion granularity of the "pallas" backend (`ExecutionPlan.fusion`):
#: "layer" — one pallas_call per layer group (BSConv / SFB / DSConv), the
#:           feature map round-trips HBM between groups;
#: "group" — ONE pallas_call per subnet running the full group chain with the
#:           feature (and, under quant, the integer codes) in VMEM scratch —
#:           the TPU analog of the paper's 79% feature-SRAM-access saving.
#: The "ref" backend has no kernels to fuse; it accepts both values and runs
#: identically (so plans stay backend-portable).
FUSION_MODES = ("layer", "group")


def resolve_backend(name: str):
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; choose from {sorted(BACKENDS)}")


# ---------------------------------------------------------------------------
# quantized per-subnet forwards (ExecutionPlan.quant = "fxp10" | "int8")
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "width", "quant"))
def _forward_width_quant_ref_jit(params, patches, cfg: ESSRConfig, width: int,
                                 quant):
    from repro.quant.pams import quantized_essr_forward
    if width == 0:
        from repro.models.layers import bilinear_resize
        return bilinear_resize(patches, cfg.scale)
    scales = {k: jnp.asarray(v, jnp.float32)
              for k, v in quant.act_scales(width).items()}
    return quantized_essr_forward(params, scales, patches, cfg, quant.qcfg,
                                  width=width)


def _forward_width_quant_ref(params, patches, cfg: ESSRConfig, width: int,
                             interpret: Optional[bool] = None, *, quant):
    """PAMS fake-quant emulation of the whole forward (W/A quantized at every
    conv boundary with the pack's PTQ alphas) — the "ref" quant backend."""
    return _forward_width_quant_ref_jit(params, patches, cfg, width, quant)


def _forward_width_quant_pallas(params, patches, cfg: ESSRConfig, width: int,
                                interpret: Optional[bool] = None, *, quant):
    """Integer-domain quantized kernel stack — the "pallas" quant backend."""
    from repro.kernels.qconv import essr_forward_qkernels
    if width == 0:
        from repro.models.layers import bilinear_resize
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_qkernels(params, patches, cfg, width=width,
                                 pack=quant, interpret=interpret)


def _forward_width_quant_mega(params, patches, cfg: ESSRConfig, width: int,
                              interpret: Optional[bool] = None, *, quant):
    """Group-fused integer megakernel (quant x fusion="group"): bit-exact vs
    the per-op quant stack, with the inter-group lattice codes VMEM-resident
    (they never touch HBM between layer groups)."""
    from repro.kernels.megakernel import essr_forward_qmegakernel
    if width == 0:
        from repro.models.layers import bilinear_resize
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_qmegakernel(params, patches, cfg, width=width,
                                    pack=quant, interpret=interpret)


QUANT_BACKENDS = {"ref": _forward_width_quant_ref,
                  "pallas": _forward_width_quant_pallas}


def resolve_forward(backend: str, quant=None, fusion: str = "layer"):
    """(backend, QuantPack-or-None, fusion) -> the per-subnet forward
    callable with the uniform ``(params, patches, cfg, width, interpret=)``
    signature.

    ``fusion`` (see `FUSION_MODES`) selects the "pallas" backend's kernel
    granularity; the "ref" backend is already one jit graph per subnet, so
    both values resolve to the same forward there."""
    resolve_backend(backend)            # single source of name validation
    if fusion not in FUSION_MODES:
        raise ValueError(f"unknown fusion {fusion!r}; choose from "
                         f"{FUSION_MODES}")
    if backend == "pallas" and fusion == "group":
        if quant is None:
            return _forward_width_mega
        return functools.partial(_forward_width_quant_mega, quant=quant)
    if quant is None:
        return BACKENDS[backend]
    return functools.partial(QUANT_BACKENDS[backend], quant=quant)


# ---------------------------------------------------------------------------
# data-parallel per-subnet forward (the sharded patch stream)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _sharded_forward_fn(backend: str, mesh, cfg: ESSRConfig, width: int,
                        interpret: Optional[bool], quant=None,
                        fusion: str = "layer"):
    """jit(shard_map(forward)) splitting the patch batch over ``mesh``'s single
    axis, params replicated. Cached per (backend, mesh, cfg, width, interpret,
    quant, fusion) so the shard_map callable (and its compiled executable) is
    built once per routing regime (`QuantPack` is frozen/hashable for exactly
    this). ``check_rep=False``: pallas_call has no replication rule, and the
    batch axis carries no collectives anyway."""
    from repro.distributed.sharding import patch_batch_spec
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    forward = resolve_forward(backend, quant, fusion)
    spec = patch_batch_spec(mesh)

    def local(params, patches):
        return forward(params, patches, cfg, width, interpret=interpret)

    return jax.jit(shard_map(local, mesh=mesh, in_specs=(P(), spec),
                             out_specs=spec, check_rep=False))


def sharded_forward(params, patches: jax.Array, cfg: ESSRConfig, width: int,
                    *, mesh, backend: str = "ref",
                    interpret: Optional[bool] = None,
                    quant=None, fusion: str = "layer") -> jax.Array:
    """Run one subnet's patch batch data-parallel across ``mesh`` devices.

    Pads the batch up to a multiple of the mesh size by repeating the last
    patch (cache-friendly duplicate work, never another subnet's patch) and
    slices the output back, so callers need no divisibility guarantees."""
    n = int(patches.shape[0])
    k = int(mesh.size)
    pad = (-n) % k
    if pad:
        patches = jnp.concatenate(
            [patches, jnp.repeat(patches[-1:], pad, axis=0)], axis=0)
    out = _sharded_forward_fn(backend, mesh, cfg, width, interpret, quant,
                              fusion)(params, patches)
    return out[:n] if pad else out


# ---------------------------------------------------------------------------
# fused single-dispatch frame graph (ExecutionPlan.dispatch = "fused")
#
# The host-dispatch path above keeps routing on the host: a per-frame
# ``np.asarray(edge_score(...))`` sync, a Python loop over subnet buckets,
# and a trailing ``block_until_ready`` — so frame N+1 cannot start until
# frame N's full host round-trip completes. The fused path collapses
# extract -> edge-score -> threshold routing -> capacity-slotted per-subnet
# forward -> scatter-add fusion into ONE jitted executable per
# (geometry, capacity profile): patches are one-hot dispatched into fixed
# per-subnet capacity slots (the same slot-dispatch shape as
# distributed/moe.py, and the shape-static analog of the ASIC's fixed PE
# array / "configurable group of layer mapping"). Capacities are snapped to
# the plan's bucket ladder so recompilation stays bounded; patches beyond a
# subnet's capacity spill deterministically (raster order) to the next
# cheaper subnet, with subnet 0 (bilinear) as the dense floor that never
# overflows. Thresholds are traced arguments, so Algorithm-1 adaptation
# never recompiles the frame.
# ---------------------------------------------------------------------------

#: ``ExecutionPlan.on_poison`` — what serving does about a frame that fails
#: its health verdict (any NaN/Inf/out-of-[0,1] pixel):
#:   "off"      — verdicts not computed (the unguarded baseline; FrameResult
#:                .health is None);
#:   "raise"    — verdict computed in-graph, `PoisonFrameError` raised at
#:                materialize time (multi-tenant serving quarantines the
#:                stream instead — the per-tenant analog of raising);
#:   "sanitize" — nan_to_num + clamp to [0,1] in-graph before routing.
#:                Bit-identical on clean in-range frames;
#:   "bilinear" — sanitize, then force the poisoned frame's patches to the
#:                dense bilinear floor lane (subnet 0) in-graph.
#: All variants are branch-free in the traced graph — the verdict is three
#: int32 reduces riding the existing outputs, no host sync (ESSR1xx-clean).
HEALTH_POLICIES = ("off", "raise", "sanitize", "bilinear")


def _health_counts(frame: jax.Array) -> jax.Array:
    """(nan, inf, out-of-[0,1]) pixel counts of one frame — int32 (3,)."""
    nan = jnp.sum(jnp.isnan(frame))
    inf = jnp.sum(jnp.isinf(frame))
    oob = jnp.sum(jnp.isfinite(frame) & ((frame < 0.0) | (frame > 1.0)))
    return jnp.stack([nan, inf, oob]).astype(jnp.int32)


def _sanitize(frame: jax.Array) -> jax.Array:
    """nan->0, +/-inf->1/0, clamp to [0,1]. Identity (bit-exact) on clean
    in-range frames — the sanitize/bilinear policies apply it unconditionally
    so the traced graph stays branch-free."""
    return jnp.clip(jnp.nan_to_num(frame, nan=0.0, posinf=1.0, neginf=0.0),
                    0.0, 1.0)


@functools.lru_cache(maxsize=1)
def _health_jit():
    return jax.jit(_health_counts)


@functools.lru_cache(maxsize=1)
def _sanitize_jit():
    return jax.jit(_sanitize)


def frame_health(frame: jax.Array) -> jax.Array:
    """Jitted health verdict for the host-dispatch paths (which already sync
    per frame; the fused paths compute the same counts in-graph instead)."""
    return _health_jit()(frame)


def sanitize_frame(frame: jax.Array) -> jax.Array:
    """Jitted sanitize for the host-dispatch paths."""
    return _sanitize_jit()(frame)


def snap_capacity(n: int, buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                  n_total: Optional[int] = None) -> int:
    """Desired slot count -> capacity: 0 stays 0 (the subnet lane is elided
    from the graph), otherwise the bucket ceiling, clamped to ``n_total``
    (the full patch count recurs per geometry, so an all-one-subnet frame
    compiles the exact full-batch shape instead of a padded bucket)."""
    if n <= 0:
        return 0
    cap = _bucket(n, buckets)
    return min(cap, n_total) if n_total is not None else cap


def capacity_route(ids: jax.Array, caps: Tuple[int, ...]
                   ) -> Tuple[jax.Array, jax.Array]:
    """In-graph capacity routing: (N,) subnet ids + static per-subnet slot
    capacities -> (effective ids, per-subnet spill counts).

    Processed priciest-first: the patches of subnet ``k`` beyond ``caps[k]``
    (raster order — deterministic, matching the paper's "the rest of the
    patches run with C27") are demoted to subnet ``k-1``, where they compete
    for slots in raster order together with that subnet's native patches.
    Subnet 0 (bilinear) is the dense floor and never spills; ``caps[0]`` is
    ignored. ``spills[k]`` counts the patches that wanted ``k`` (natively or
    by spill-in) but ran ``k-1``."""
    spills = [jnp.zeros((), jnp.int32)]          # subnet 0 never spills
    eff = ids
    for k in range(len(caps) - 1, 0, -1):
        member = eff == k
        pos = jnp.cumsum(member.astype(jnp.int32)) - 1
        over = member & (pos >= caps[k])
        spills.append(jnp.sum(over).astype(jnp.int32))
        eff = jnp.where(over, k - 1, eff)
    spills = spills[:1] + spills[1:][::-1]       # ascending subnet order
    return eff, jnp.stack(spills)


def capacity_dispatch(patches: jax.Array, eff_ids: jax.Array, subnet: int,
                      cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-hot dispatch of subnet ``subnet``'s patches into ``cap`` fixed
    slots (raster order). Returns (slot batch (cap, p, p, C), per-patch slot
    index with ``cap`` as the non-member dustbin, membership mask).

    Callers must have routed ``eff_ids`` through :func:`capacity_route`
    first — post-spill every member's raster rank is < ``cap``."""
    member = eff_ids == subnet
    pos = jnp.cumsum(member.astype(jnp.int32)) - 1
    slot = jnp.where(member, pos, cap)
    disp = jnp.zeros((cap + 1,) + patches.shape[1:], patches.dtype)
    disp = disp.at[slot].add(
        jnp.where(member[:, None, None, None], patches, 0), mode="drop")
    return disp[:cap], slot, member


def capacity_combine(out_patches: jax.Array, sr_slots: jax.Array,
                     slot: jax.Array, member: jax.Array) -> jax.Array:
    """Scatter one subnet's slot outputs back over the patch axis: patch n
    takes ``sr_slots[slot[n]]`` where it is a member (the dustbin row reads
    zeros and is masked off)."""
    y = jnp.concatenate(
        [sr_slots, jnp.zeros((1,) + sr_slots.shape[1:], sr_slots.dtype)], 0)
    return jnp.where(member[:, None, None, None], jnp.take(y, slot, axis=0),
                     out_patches)


@bounded_cache(maxsize=128)            # sized with get_geometry's cache: an
                                       # evicted executable would silently
                                       # re-trace under SREngine's warm-key
                                       # bookkeeping. BoundedCache: the
                                       # engine resizes all three together
                                       # (configure_compiled_caches).
def fused_frame_fn(geometry: PatchGeometry, caps: Tuple[int, ...],
                   cfg: ESSRConfig, backend: str,
                   interpret: Optional[bool], mesh, quant,
                   fusion: str = "layer", on_poison: str = "raise"):
    """The compiled frame executable: one per (geometry, capacity profile,
    backend, interpret, mesh, quant, fusion, on_poison). Signature of the
    returned callable:

        (params, frame, t1, t2)
            -> (image, eff_ids, scores, counts, spills, health)

    ``t1``/``t2`` are traced (threshold adaptation never recompiles); every
    other knob is static. All six outputs are device arrays — callers
    materialize them lazily (the async stream reads routing telemetry one
    frame behind). ``health`` is the (nan, inf, oob) int32 verdict of the
    *raw* input frame (all zeros under ``on_poison="off"``, where the checks
    are elided); the ``on_poison`` policy (see `HEALTH_POLICIES`) is applied
    in-graph, branch-free, with no host sync."""
    from repro.models.layers import bilinear_resize

    if on_poison not in HEALTH_POLICIES:
        raise ValueError(f"unknown on_poison {on_poison!r}; choose from "
                         f"{HEALTH_POLICIES}")
    base_forward = resolve_forward(backend, quant, fusion)
    if mesh is not None and int(mesh.size) > 1:
        def forward(params, patches, cfg, width, interpret=None):
            return sharded_forward(params, patches, cfg, width, mesh=mesh,
                                   backend=backend, interpret=interpret,
                                   quant=quant, fusion=fusion)
    else:
        forward = base_forward
    widths = cfg.subnet_widths()
    if len(caps) != len(widths):
        raise ValueError(f"capacity profile {caps} must have one entry per "
                         f"subnet width {widths}")

    def run(params, frame, t1, t2):
        if on_poison == "off":
            health = jnp.zeros((3,), jnp.int32)
        else:
            health = _health_counts(frame)
            if on_poison in ("sanitize", "bilinear"):
                frame = _sanitize(frame)
        patches = geometry.extract(frame)
        scores = edge_score(patches)
        eff, spills = capacity_route(sp.decide(scores, t1, t2), caps)
        if on_poison == "bilinear":
            # poisoned frame -> dense fallback lane: every patch serves from
            # the bilinear floor (branch-free demotion; the conv lanes still
            # run on their now-empty slots, keeping the graph shape-static)
            eff = jnp.where(jnp.any(health > 0), jnp.zeros_like(eff), eff)
        # subnet 0 is the dense floor: bilinear for every patch (it is the
        # spill target of last resort and costs no conv — the ASIC's router
        # bypass), overwritten wherever a conv subnet owns the patch
        out = bilinear_resize(patches, cfg.scale)
        for k in range(1, len(widths)):
            if caps[k] == 0:
                continue                         # lane elided from the graph
            disp, slot, member = capacity_dispatch(patches, eff, k, caps[k])
            sr = forward(params, disp, cfg, widths[k], interpret=interpret)
            out = capacity_combine(out, sr, slot, member)
        counts = jnp.stack([jnp.sum(eff == k).astype(jnp.int32)
                            for k in range(len(widths))])
        return geometry.fuse_average(out), eff, scores, counts, spills, health

    return jax.jit(run)


@bounded_cache(maxsize=128)
def fused_stream_frame_fn(geometry: PatchGeometry, streams: int,
                          caps: Tuple[int, ...], cfg: ESSRConfig,
                          backend: str, interpret: Optional[bool],
                          mesh, quant, fusion: str = "layer",
                          on_poison: str = "raise"):
    """The compiled multi-tenant admission-tick executable: ``streams``
    same-geometry frames (one per live tenant stream) through ONE
    capacity-slotted dispatch. Signature of the returned callable:

        (params, frames, t1s, t2s, quotas)
            -> (images, eff_ids, scores, counts, spills, health)

    ``health`` is the per-stream (S, 3) int32 (nan, inf, oob) verdict of the
    raw input frames (zeros under ``on_poison="off"``); the policy (see
    `HEALTH_POLICIES`) is applied per stream, in-graph and branch-free —
    under "bilinear" only the poisoned streams' patches demote to the dense
    floor, healthy tenants route normally.

    ``frames`` is (S, H, W, C); ``t1s``/``t2s``/``quotas`` are (S,) traced
    arrays — per-stream Algorithm-1 adaptation and share rebalancing never
    recompile the tick. ``quotas`` is each stream's top-subnet (C54) slot
    share for this tick: the router demotes a stream's top-subnet patches
    beyond its quota to the next subnet in raster order *before* the
    aggregate capacity cascade, so under aggregate overload degradation is
    share-weighted and raster-deterministic — frames are never dropped.

    Patch provenance is positional: the flat patch axis is stream-major
    (``stream_id = i // geometry.n``, ``patch_id = i % geometry.n``), so
    ``capacity_route``/``capacity_dispatch``/``capacity_combine`` run on the
    shared pool unchanged and the scatter-back fuses each stream's frame
    independently. Outputs: ``images`` (S, sH, sW, C); ``eff_ids``/``scores``
    flat (S*N,); ``counts``/``spills`` per-stream (S, n_subnets), where
    ``spills[s, k]`` counts stream s's patches that wanted subnet ``k``
    (pre-quota) but ran below it — quota demotions and aggregate spill
    cascade land in the same ledger, exactly like the solo streaming path's
    budget-clamped capacity."""
    from repro.models.layers import bilinear_resize

    base_forward = resolve_forward(backend, quant, fusion)
    if mesh is not None and int(mesh.size) > 1:
        def forward(params, patches, cfg, width, interpret=None):
            return sharded_forward(params, patches, cfg, width, mesh=mesh,
                                   backend=backend, interpret=interpret,
                                   quant=quant, fusion=fusion)
    else:
        forward = base_forward
    widths = cfg.subnet_widths()
    if len(caps) != len(widths):
        raise ValueError(f"capacity profile {caps} must have one entry per "
                         f"subnet width {widths}")
    if streams < 1:
        raise ValueError(f"streams must be >= 1, got {streams}")
    if on_poison not in HEALTH_POLICIES:
        raise ValueError(f"unknown on_poison {on_poison!r}; choose from "
                         f"{HEALTH_POLICIES}")
    top = len(widths) - 1
    n = geometry.n
    # On CPU the aggregate pool's conv batch (streams x per-stream slots)
    # falls out of cache and runs ~1.4x slower than per-stream batches, so
    # the shared lanes are chunked stream-count-wise through lax.map (the
    # fp32 conv forward is row-wise bit-identical across batch sizes — the
    # packing stays conformant, see tests/test_multiplex.py). Accelerator
    # backends keep the single dense batch (the MXU wants it as wide as the
    # pool allows), sharded forward is never chunked (shard_map owns the
    # batch axis), and quantized graphs keep it too: the fake-quant chain's
    # fp rounding is not bit-stable across the scan boundary, and the quant
    # conformance contract is bit-oriented.
    chunks = (streams if (streams > 1 and mesh is None and quant is None
                          and jax.default_backend() == "cpu") else 1)

    def run(params, frames, t1s, t2s, quotas):
        if on_poison == "off":
            health = jnp.zeros((streams, 3), jnp.int32)
        else:
            health = jax.vmap(_health_counts)(frames)       # (S, 3)
            if on_poison in ("sanitize", "bilinear"):
                frames = _sanitize(frames)
        patches = jax.vmap(geometry.extract)(frames)        # (S, N, p, p, C)
        flat = patches.reshape((streams * n,) + patches.shape[2:])
        scores = edge_score(flat)
        want = sp.decide(scores, jnp.repeat(t1s, n), jnp.repeat(t2s, n))
        want2 = want.reshape(streams, n)
        routed2 = want2
        if top > 0:
            # per-stream C54 quota: the share-weighted per-tick ceiling —
            # overflow demotes in raster order, like the solo budget clamp
            member = want2 == top
            pos = jnp.cumsum(member.astype(jnp.int32), axis=1) - 1
            over = member & (pos >= quotas[:, None])
            routed2 = jnp.where(over, top - 1, want2)
        if on_poison == "bilinear":
            # per-stream dense-fallback demotion: only the poisoned streams'
            # patches drop to the bilinear floor, healthy tenants untouched
            poisoned = jnp.any(health > 0, axis=1)          # (S,)
            routed2 = jnp.where(poisoned[:, None],
                                jnp.zeros_like(routed2), routed2)
        eff, _ = capacity_route(routed2.reshape(-1), caps)
        out = bilinear_resize(flat, cfg.scale)
        for k in range(1, len(widths)):
            if caps[k] == 0:
                continue                         # lane elided from the graph
            disp, slot, memberk = capacity_dispatch(flat, eff, k, caps[k])
            if chunks > 1:
                pad = (-caps[k]) % chunks
                disp_p = jnp.pad(
                    disp, ((0, pad),) + ((0, 0),) * (disp.ndim - 1))
                sr = jax.lax.map(
                    functools.partial(forward, params, cfg=cfg,
                                      width=widths[k], interpret=interpret),
                    disp_p.reshape((chunks, -1) + disp.shape[1:]))
                sr = sr.reshape((-1,) + sr.shape[2:])[:caps[k]]
            else:
                sr = forward(params, disp, cfg, widths[k],
                             interpret=interpret)
            out = capacity_combine(out, sr, slot, memberk)
        images = jax.vmap(geometry.fuse_average)(
            out.reshape((streams, n) + out.shape[1:]))
        eff2 = eff.reshape(streams, n)
        counts = jnp.stack(
            [jnp.sum(eff2 == k, axis=1) for k in range(len(widths))],
            axis=1).astype(jnp.int32)
        # hop ledger: wanted >= k but ran < k — transitive, so the aggregate
        # cascade's spill-throughs and the quota demotions both register
        spills = jnp.stack(
            [jnp.zeros((streams,), jnp.int32)] +
            [jnp.sum((want2 >= k) & (eff2 < k), axis=1).astype(jnp.int32)
             for k in range(1, len(widths))], axis=1)
        return images, eff, scores, counts, spills, health

    return jax.jit(run)


# essr: allow[ESSR201] — legacy surface kept for tests/benches; new modes go through SREngine
def fused_frame_forward(params, frame, cfg: ESSRConfig, *,
                        geometry: PatchGeometry, caps: Tuple[int, ...],
                        t1: float = sp.DEFAULT_T1, t2: float = sp.DEFAULT_T2,
                        backend: str = "ref",
                        interpret: Optional[bool] = None,
                        mesh=None, quant=None, fusion: str = "layer",
                        on_poison: str = "raise"):
    """One frame through the fused single-dispatch graph (see
    :func:`fused_frame_fn`). Returns the raw device-array six-tuple
    (..., health); the engine wraps it into a `FrameResult` and owns
    capacity-profile and on_poison policy."""
    return fused_frame_fn(geometry, tuple(int(c) for c in caps), cfg,
                          backend, interpret, mesh, quant, fusion,
                          on_poison)(params, frame, t1, t2)


# ---------------------------------------------------------------------------
# bounded compiled-object caches (runtime-sized, occupancy-observable)
# ---------------------------------------------------------------------------

#: The process-wide `BoundedCache`s holding compiled/prepared per-frame
#: objects. Keyed by what each cache memoizes; the geometry cache lives in
#: core.patching but is sized and surfaced together with the executables
#: (an evicted geometry would re-key — and silently re-trace — the frame
#: executables built on its identity).
COMPILED_CACHES = {
    "fused_frame_fn": fused_frame_fn,
    "fused_stream_frame_fn": fused_stream_frame_fn,
    "get_geometry": get_geometry,
}


def configure_compiled_caches(maxsize: int) -> None:
    """Resize every compiled-object cache to ``maxsize`` entries (lru
    eviction; shrinking evicts immediately). `SREngine` derives the bound
    from ``plan.stats_window`` at construction so cache depth follows the
    serving horizon; call directly to pin it."""
    for cache in COMPILED_CACHES.values():
        cache.resize(maxsize)


def compiled_cache_occupancy() -> Dict[str, Dict[str, int]]:
    """{cache: {size, maxsize, hits, misses, evictions}} over the
    compiled-object caches — the snapshot `FrameResult.summary()` and
    `SREngine.summary()` surface. Nonzero evictions under a steady set of
    geometries/plans means the bound is too small and executables are being
    silently re-traced."""
    return {name: cache.occupancy()
            for name, cache in COMPILED_CACHES.items()}


@dataclasses.dataclass
class SRResult:
    image: jax.Array
    ids: np.ndarray
    scores: np.ndarray
    counts: Tuple[int, int, int]
    mac_saving: float


# essr: allow[ESSR201] — legacy surface kept for tests/benches; new modes go through SREngine
def edge_selective_sr(params: Dict[str, Any], frame: jax.Array, cfg: ESSRConfig,
                      t1: float = sp.DEFAULT_T1, t2: float = sp.DEFAULT_T2,
                      patch: int = 32, overlap: int = 2,
                      ids_override: Optional[np.ndarray] = None,
                      buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                      backend: str = "ref",
                      interpret: Optional[bool] = None,
                      geometry: Optional[PatchGeometry] = None,
                      precomputed: Optional[Tuple[jax.Array, np.ndarray,
                                                  np.ndarray]] = None,
                      mesh=None,
                      quant=None,
                      fusion: str = "layer",
                      use_loop_reference: bool = False) -> SRResult:
    """frame: (H,W,3) in [0,1] -> SRResult with (H*s, W*s, 3) image.

    ``geometry``: optional pre-fetched `PatchGeometry` (SREngine passes its
    plan's); resolved from the cache otherwise — either way the per-frame
    host work is index-free.

    ``mesh``: optional 1-D device mesh (``launch.mesh.make_patch_mesh``).
    When given with size > 1, every per-subnet batch is split across its
    devices (shard_map data parallel, params replicated) and fused back
    through the same scatter-add geometry — numerically identical to the
    single-device path. ``None`` or size 1 is exactly the old path.

    ``precomputed``: optional (patches, pos, scores) from a caller that
    already extracted/scored this frame (the streaming path scores patches
    for the adaptive switcher) — avoids doing that work twice per frame.

    ``quant``: optional `repro.quant.pams.QuantPack` — serve this frame
    through the quantized forward of the chosen backend (see module
    docstring). Edge scoring/routing stay fp either way.

    ``use_loop_reference``: run the seed per-patch extract/fuse loops instead
    of the vectorized gather/scatter — the equivalence oracle for tests and
    the "before" side of benchmarks/table11_throughput.py. Never the serving
    path.
    """
    forward = resolve_forward(backend, quant, fusion)
    if mesh is not None and int(mesh.size) > 1:
        def forward(params, patches, cfg, width, interpret=None):
            return sharded_forward(params, patches, cfg, width, mesh=mesh,
                                   backend=backend, interpret=interpret,
                                   quant=quant, fusion=fusion)
    s = cfg.scale
    h, w = int(frame.shape[0]), int(frame.shape[1])
    g = geometry if geometry is not None else get_geometry(h, w, patch,
                                                           overlap, s)
    if precomputed is not None:
        patches, pos, scores = precomputed
        scores = np.asarray(scores)
    else:
        if use_loop_reference:
            patches, pos = extract_patches_loop(frame, patch, overlap)
        else:
            patches, pos = g.extract(frame), g.pos
        if ids_override is None:
            scores = np.asarray(edge_score(patches))
        else:
            # forced routing never consults the edge unit (as on the ASIC);
            # scores are reported as zeros rather than computed and discarded
            scores = np.zeros(len(pos), np.float32)
    ids = ids_override if ids_override is not None else np.asarray(sp.decide(scores, t1, t2))

    out_patches = jnp.zeros((patches.shape[0], patch * s, patch * s, 3),
                            patches.dtype)
    widths = cfg.subnet_widths()
    for k, width in enumerate(widths):
        idx = np.flatnonzero(ids == k)
        if idx.size == 0:
            continue
        if idx.size == len(ids):
            # one subnet took the whole frame: no gather/scatter, and no
            # bucket padding (the full-batch shape recurs per geometry, so
            # compilation stays bounded without it)
            out_patches = forward(params, patches, cfg, width,
                                  interpret=interpret)
            continue
        cap = _bucket(idx.size, buckets)
        # pad with the bucket's own last index (not patch 0): the duplicate
        # work is cache-friendly and never re-runs another subnet's patch
        pad = np.concatenate([idx, np.full(cap - idx.size, idx[-1], idx.dtype)])
        sr = forward(params, jnp.take(patches, jnp.asarray(pad), axis=0),
                     cfg, width, interpret=interpret)[: idx.size]
        # idx is np.flatnonzero output: strictly increasing, so the set-
        # scatter is unique by construction and deterministic
        out_patches = out_patches.at[jnp.asarray(idx)].set(
            sr, unique_indices=True, mode="drop")

    if use_loop_reference:
        img = fuse_patches_average_loop(out_patches, pos, s, (h * s, w * s))
    else:
        img = g.fuse_average(out_patches)
    counts = sp.subnet_counts(ids)
    saving = sp.SubnetMacs.make(cfg, patch).saving_vs_c54(counts)
    return SRResult(image=img, ids=ids, scores=scores, counts=counts, mac_saving=saving)


# essr: allow[ESSR201] — legacy surface kept for tests/benches; new modes go through SREngine
def sr_all_patches_result(params, frame, cfg: ESSRConfig, width: int,
                          patch: int = 32, overlap: int = 2,
                          buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                          backend: str = "ref",
                          interpret: Optional[bool] = None,
                          geometry: Optional[PatchGeometry] = None,
                          mesh=None, quant=None,
                          fusion: str = "layer") -> SRResult:
    """Every patch through one subnet (the non-edge-selective reference).

    The single implementation of forced routing — the edge-score pass is
    skipped entirely (scores are reported as zeros)."""
    widths = cfg.subnet_widths()
    if width not in widths:
        raise ValueError(f"width {width} not one of the subnet widths {widths}")
    g = geometry if geometry is not None else get_geometry(
        int(frame.shape[0]), int(frame.shape[1]), patch, overlap, cfg.scale)
    patches, pos = g.extract(frame), g.pos
    ids = np.full((len(pos),), widths.index(width), dtype=np.int64)
    return edge_selective_sr(params, frame, cfg, patch=patch, overlap=overlap,
                             ids_override=ids, buckets=buckets, backend=backend,
                             interpret=interpret, geometry=g, mesh=mesh,
                             quant=quant, fusion=fusion,
                             precomputed=(patches, pos,
                                          np.zeros(len(pos), np.float32)))


# essr: allow[ESSR201] — legacy surface kept for tests/benches; new modes go through SREngine
def sr_all_patches(params, frame, cfg: ESSRConfig, width: int,
                   patch: int = 32, overlap: int = 2,
                   backend: str = "ref") -> jax.Array:
    """Image-only wrapper over ``sr_all_patches_result``."""
    return sr_all_patches_result(params, frame, cfg, width,
                                 patch=patch, overlap=overlap,
                                 backend=backend).image


# essr: allow[ESSR201] — legacy surface kept for tests/benches; new modes go through SREngine
def sr_whole(params, frame, cfg: ESSRConfig, width: Optional[int] = None) -> jax.Array:
    """Whole-image convolution (the lossless 'software' reference of Table III)."""
    return essr_forward(params, frame[None], cfg, width=width)[0]
