"""End-to-end edge-selective SR of full frames (paper Fig. 1).

frame -> slim-overlap patches -> edge scores -> subnet decision ->
per-subnet batched forward -> thick-overlap overlap+average fusion.

Two execution styles:
  * ``edge_selective_sr``: host-grouped, jit-per-subnet — the serving path.
    Per-subnet batches are padded to bucketed sizes so jit recompilation is
    bounded (the shape-static analog of the GLNPU's fixed PE array).
  * ``sr_whole`` / ``sr_all_patches``: non-dynamic references for ablations.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import subnet_policy as sp
from repro.core.edge_score import edge_score
from repro.core.patching import extract_patches, fuse_patches_average
from repro.models.essr import ESSRConfig, essr_forward


DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def _bucket(n: int, buckets=DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return int(np.ceil(n / buckets[-1]) * buckets[-1])


@functools.partial(jax.jit, static_argnames=("cfg", "width"))
def _forward_width(params, patches, cfg: ESSRConfig, width: int):
    return essr_forward(params, patches, cfg, width=width)


def _forward_width_pallas(params, patches, cfg: ESSRConfig, width: int):
    """Fused-kernel backend: same contract as ``_forward_width``.

    Bilinear patches never reach the conv kernels (handled by the router on
    the ASIC), so width 0 falls back to the reference resize."""
    from repro.kernels.ops import essr_forward_kernels
    from repro.models.layers import bilinear_resize
    if width == 0:
        return bilinear_resize(patches, cfg.scale)
    return essr_forward_kernels(params, patches, cfg, width=width)


BACKENDS = {"ref": _forward_width, "pallas": _forward_width_pallas}


def resolve_backend(name: str):
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; choose from {sorted(BACKENDS)}")


@dataclasses.dataclass
class SRResult:
    image: jax.Array
    ids: np.ndarray
    scores: np.ndarray
    counts: Tuple[int, int, int]
    mac_saving: float


def edge_selective_sr(params: Dict[str, Any], frame: jax.Array, cfg: ESSRConfig,
                      t1: float = sp.DEFAULT_T1, t2: float = sp.DEFAULT_T2,
                      patch: int = 32, overlap: int = 2,
                      ids_override: Optional[np.ndarray] = None,
                      buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                      backend: str = "ref",
                      precomputed: Optional[Tuple[jax.Array, np.ndarray,
                                                  np.ndarray]] = None) -> SRResult:
    """frame: (H,W,3) in [0,1] -> SRResult with (H*s, W*s, 3) image.

    ``precomputed``: optional (patches, pos, scores) from a caller that
    already extracted/scored this frame (the streaming path scores patches
    for the adaptive switcher) — avoids doing that work twice per frame.
    """
    forward = resolve_backend(backend)
    if precomputed is not None:
        patches, pos, scores = precomputed
        scores = np.asarray(scores)
    else:
        patches, pos = extract_patches(frame, patch=patch, overlap=overlap)
        scores = np.asarray(edge_score(patches))
    ids = ids_override if ids_override is not None else np.asarray(sp.decide(scores, t1, t2))

    s = cfg.scale
    out_patches = jnp.zeros((patches.shape[0], patch * s, patch * s, 3), patches.dtype)
    widths = cfg.subnet_widths()
    for k, width in enumerate(widths):
        idx = np.flatnonzero(ids == k)
        if idx.size == 0:
            continue
        cap = _bucket(idx.size, buckets)
        pad = np.concatenate([idx, np.zeros(cap - idx.size, dtype=idx.dtype)])
        sr = forward(params, patches[pad], cfg, width)[: idx.size]
        out_patches = out_patches.at[idx].set(sr)

    h, w = int(frame.shape[0]) * s, int(frame.shape[1]) * s
    img = fuse_patches_average(out_patches, pos, s, (h, w))
    counts = sp.subnet_counts(ids)
    saving = sp.SubnetMacs.make(cfg, patch).saving_vs_c54(counts)
    return SRResult(image=img, ids=ids, scores=scores, counts=counts, mac_saving=saving)


def sr_all_patches_result(params, frame, cfg: ESSRConfig, width: int,
                          patch: int = 32, overlap: int = 2,
                          buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                          backend: str = "ref") -> SRResult:
    """Every patch through one subnet (the non-edge-selective reference).

    The single implementation of forced routing — the edge-score pass is
    skipped entirely (scores are reported as zeros)."""
    widths = cfg.subnet_widths()
    if width not in widths:
        raise ValueError(f"width {width} not one of the subnet widths {widths}")
    patches, pos = extract_patches(frame, patch, overlap)
    ids = np.full((len(pos),), widths.index(width), dtype=np.int64)
    return edge_selective_sr(params, frame, cfg, patch=patch, overlap=overlap,
                             ids_override=ids, buckets=buckets, backend=backend,
                             precomputed=(patches, pos,
                                          np.zeros(len(pos), np.float32)))


def sr_all_patches(params, frame, cfg: ESSRConfig, width: int,
                   patch: int = 32, overlap: int = 2,
                   backend: str = "ref") -> jax.Array:
    """Image-only wrapper over ``sr_all_patches_result``."""
    return sr_all_patches_result(params, frame, cfg, width,
                                 patch=patch, overlap=overlap,
                                 backend=backend).image


def sr_whole(params, frame, cfg: ESSRConfig, width: Optional[int] = None) -> jax.Array:
    """Whole-image convolution (the lossless 'software' reference of Table III)."""
    return essr_forward(params, frame[None], cfg, width=width)[0]
