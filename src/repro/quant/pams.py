"""PAMS quantization (paper Sec. IV-H; ref [21]).

PAMS = symmetric uniform quantization with a *parameterized (learnable) max
scale* alpha per tensor, trained with STE. The paper quantizes the WHOLE
model (unlike PAMS' fp first/last layers) at FXP10 W/A (-0.03 dB).

Two target modes:
  * ``bits=10``: the paper-faithful FXP10 simulation;
  * ``bits=8`` : TPU-native int8 (the MXU has an int8 datapath; DESIGN.md §3).

Provides fake-quant training ops, PTQ calibration (percentile), a quantized
ESSR forward, an integer-consistency check used by tests, and the frozen
`QuantPack` that carries PTQ-calibrated per-subnet activation alphas through
the serving path (`ExecutionPlan.quant` -> `SREngine` -> `core/pipeline`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.essr import ESSRConfig, slice_width

#: Serving quant modes (`ExecutionPlan.quant`) -> bit width.
#: "fxp10" is the paper-faithful whole-model FXP10; "int8" is the TPU-native
#: MXU datapath.
QUANT_MODES: Dict[str, int] = {"fxp10": 10, "int8": 8}

#: Quantization-step floor: alphas below ``qmax * EPS`` collapse every code
#: to 0 instead of dividing by a mismatched epsilon (see ``quantize``).
EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 10          # FXP10 (paper) | 8 (TPU int8)
    per_channel_weights: bool = True
    act_percentile: float = 99.9

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def step_size(alpha, qmax: int):
    """The quantization step actually used by ``quantize``/``int_codes``.

    The epsilon floor applies to the step used on BOTH the divide and the
    multiply side, so degenerate alphas (alpha -> 0) stay idempotent: the old
    form divided by ``max(s, eps)`` but multiplied back by ``s``, which made
    ``quantize`` non-idempotent (and dequant inconsistent with the codes)
    whenever ``alpha < qmax * eps``."""
    return jnp.maximum(alpha / qmax, EPS)


def quantize(x: jax.Array, alpha: jax.Array, qmax: int) -> jax.Array:
    """Fake-quant with STE: forward = dequant(round(clip(x)/s)), grad = identity
    inside the clip range (PAMS' straight-through rule)."""
    s = step_size(alpha, qmax)
    xc = jnp.clip(x, -alpha, alpha)
    q = jnp.round(xc / s) * s
    return xc + jax.lax.stop_gradient(q - xc)


def int_codes(x: jax.Array, alpha: jax.Array, qmax: int) -> jax.Array:
    """The integer lattice codes (the integer-consistency oracle: the Pallas
    qconv kernels must reproduce these bit-exactly)."""
    return jnp.round(jnp.clip(x, -alpha, alpha)
                     / step_size(alpha, qmax)).astype(jnp.int32)


def weight_alpha(w: jax.Array, per_channel: bool) -> jax.Array:
    if per_channel and w.ndim == 4:
        return jnp.max(jnp.abs(w), axis=(0, 1, 2), keepdims=True) + 1e-8
    return jnp.max(jnp.abs(w)) + 1e-8


def quantize_weight_tree(params, qcfg: QuantConfig):
    """Fake-quantize every conv weight/bias-free leaf in an ESSR param tree."""
    def q(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name.endswith("_b") or x.ndim < 2:
            return x  # biases stay wide (they feed the 24b accumulator on HW)
        return quantize(x, weight_alpha(x, qcfg.per_channel_weights), qcfg.qmax)
    return jax.tree_util.tree_map_with_path(q, params)


# ---------------------------------------------------------------------------
# activation scales: PTQ calibration + learnable container
# ---------------------------------------------------------------------------

def _act_points(cfg: ESSRConfig) -> list:
    """Names of activation-quant sites: after every conv group."""
    pts = ["in", "first"]
    for i in range(cfg.n_sfb):
        pts += [f"sfb{i}_b1", f"sfb{i}_b2", f"sfb{i}_out"]
    pts += ["recon"]
    return pts


def init_act_scales(cfg: ESSRConfig, init: float = 2.0) -> Dict[str, jax.Array]:
    return {k: jnp.asarray(init, jnp.float32) for k in _act_points(cfg)}


def effective_alpha(alpha):
    """Stored/learned alpha -> the clip range the forward actually uses.

    Single source of truth shared by the fake-quant forward and the
    integer-domain kernel stack (kernels/qconv.py), so both paths clip and
    step identically."""
    return jnp.abs(alpha) + 1e-8


def quantized_essr_forward(params, act_scales: Dict[str, jax.Array], x: jax.Array,
                           cfg: ESSRConfig, qcfg: QuantConfig = QuantConfig(),
                           width: Optional[int] = None) -> jax.Array:
    """ESSR forward with W/A fake-quant at every conv boundary (whole model,
    as the paper does — no fp first/last exception)."""
    if width == 0:
        return L.bilinear_resize(x, cfg.scale)
    if width is not None and width != cfg.channels:
        params = slice_width(params, width)
    params = quantize_weight_tree(params, qcfg)
    qa = lambda name, t: quantize(t, effective_alpha(act_scales[name]), qcfg.qmax)

    f = qa("in", x)
    f = qa("first", L.bsconv(params["first"], f))
    for i, p in enumerate(params["sfbs"]):
        y = qa(f"sfb{i}_b1", jax.nn.relu(L.bsconv(p["b1"], f)))
        y = qa(f"sfb{i}_b2", jax.nn.relu(L.bsconv(p["b2"], y)))
        y = L.pointwise(y + f, p["fuse"], p.get("fuse_b"))
        f = qa(f"sfb{i}_out", jax.nn.relu(y))
    up = qa("recon", L.dsconv(params["recon"], f))
    return L.pixel_shuffle(up, cfg.scale)


def calibrate_act_scales(params, cfg: ESSRConfig, sample: jax.Array,
                         qcfg: QuantConfig = QuantConfig(),
                         n_valid: Optional[int] = None) -> Dict[str, jax.Array]:
    """PTQ: run fp forward on a calibration batch, set alpha = percentile(|act|).

    ``n_valid``: number of REAL patches at the front of ``sample``. The patch
    pipeline pads routed buckets by repeating the bucket's last patch; feeding
    such a padded batch here would weight the repeated patch's activations
    ``pad + 1`` times in the percentile and bias the alphas toward whatever
    content happened to sit last. The percentile is therefore computed over
    ``sample[:n_valid]`` only (``None`` = the whole batch is real)."""
    scales: Dict[str, jax.Array] = {}
    pct = qcfg.act_percentile
    nv = sample.shape[0] if n_valid is None else int(n_valid)
    if not 0 < nv <= sample.shape[0]:
        raise ValueError(f"n_valid {n_valid} must be in 1..{sample.shape[0]}")

    def rec(name, t):
        scales[name] = jnp.percentile(jnp.abs(t[:nv]), pct) + 1e-8
        return t

    f = rec("in", sample)
    f = rec("first", L.bsconv(params["first"], f))
    for i, p in enumerate(params["sfbs"]):
        y = rec(f"sfb{i}_b1", jax.nn.relu(L.bsconv(p["b1"], f)))
        y = rec(f"sfb{i}_b2", jax.nn.relu(L.bsconv(p["b2"], y)))
        y = L.pointwise(y + f, p["fuse"], p.get("fuse_b"))
        f = rec(f"sfb{i}_out", jax.nn.relu(y))
    rec("recon", L.dsconv(params["recon"], f))
    return scales


# ---------------------------------------------------------------------------
# serving-path quantization state: per-subnet alphas, frozen + hashable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantPack:
    """Everything the serving path needs to run one quant mode, frozen and
    hashable so it rides through ``jax.jit``/``shard_map`` as a static
    argument (one compiled executable per (mode, calibration) regime).

    ``scales``: per-subnet activation alphas keyed by the conv routing
    buckets — ``((width, ((site, alpha), ...)), ...)`` for every conv width
    of the supernet (the bilinear width-0 bucket never touches the conv
    lattice and needs no alphas). Alphas are plain floats: hashability, and
    exact round-trips through the JSON cache."""
    mode: str                   # "fxp10" | "int8"
    bits: int
    per_channel_weights: bool
    act_percentile: float
    scales: Tuple[Tuple[int, Tuple[Tuple[str, float], ...]], ...]

    def __post_init__(self):
        if self.mode not in QUANT_MODES:
            raise ValueError(f"quant mode {self.mode!r} not in "
                             f"{sorted(QUANT_MODES)}")

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.bits,
                           per_channel_weights=self.per_channel_weights,
                           act_percentile=self.act_percentile)

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    def widths(self) -> Tuple[int, ...]:
        return tuple(w for w, _ in self.scales)

    def act_scales(self, width: int) -> Dict[str, float]:
        for w, sites in self.scales:
            if w == width:
                return dict(sites)
        raise KeyError(f"no calibrated alphas for width {width} "
                       f"(have {self.widths()})")


def code_dtype(bits: int):
    """Storage dtype of the integer lattice codes: int8 is the MXU-native
    datapath; FXP10 codes (±511) need the wider int32."""
    return jnp.int8 if bits <= 8 else jnp.int32


def calibrate_subnet_scales(params, cfg: ESSRConfig, sample: jax.Array,
                            qcfg: QuantConfig = QuantConfig(),
                            n_valid: Optional[int] = None
                            ) -> Dict[int, Dict[str, float]]:
    """PTQ alphas for EVERY conv subnet of the supernet (C54 and C27 see
    different activation ranges through the shared weights, so each routing
    bucket gets its own alpha set)."""
    out: Dict[int, Dict[str, float]] = {}
    for w in cfg.subnet_widths():
        if w == 0:
            continue                      # bilinear: no conv, no lattice
        p = params if w == cfg.channels else slice_width(params, w)
        scales = calibrate_act_scales(p, cfg, sample, qcfg, n_valid=n_valid)
        out[w] = {k: float(v) for k, v in scales.items()}
    return out


def build_quant_pack(params, cfg: ESSRConfig, mode: str, sample: jax.Array,
                     *, per_channel_weights: bool = True,
                     act_percentile: float = 99.9,
                     n_valid: Optional[int] = None) -> QuantPack:
    """Calibrate a serving `QuantPack` from a calibration batch (PTQ)."""
    if mode not in QUANT_MODES:
        raise ValueError(f"quant mode {mode!r} not in {sorted(QUANT_MODES)}")
    qcfg = QuantConfig(bits=QUANT_MODES[mode],
                       per_channel_weights=per_channel_weights,
                       act_percentile=act_percentile)
    by_width = calibrate_subnet_scales(params, cfg, sample, qcfg,
                                       n_valid=n_valid)
    scales = tuple((w, tuple(sorted(by_width[w].items())))
                   for w in sorted(by_width))
    return QuantPack(mode=mode, bits=qcfg.bits,
                     per_channel_weights=per_channel_weights,
                     act_percentile=act_percentile, scales=scales)


# ---------------------------------------------------------------------------
# alpha cache (alongside the bench-model cache): calibration is a full fp
# forward per subnet, so repeated engine constructions reuse the JSON record
# ---------------------------------------------------------------------------

def params_fingerprint(params) -> str:
    """Short stable fingerprint of a param tree (content hash of the leaf
    bytes) — keys the alpha cache so stale alphas never serve new weights."""
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()[:16]


def _payload_checksum(payload: dict) -> str:
    """Content checksum over the canonical (sorted-key, checksum-free) JSON
    encoding — a truncated or bit-flipped cache file fails verification
    instead of silently serving garbage scales."""
    body = {k: v for k, v in payload.items() if k != "checksum"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode()).hexdigest()[:16]


def save_quant_pack(path: str, pack: QuantPack, fingerprint: str) -> None:
    payload = {
        "mode": pack.mode, "bits": pack.bits,
        "per_channel_weights": pack.per_channel_weights,
        "act_percentile": pack.act_percentile,
        "fingerprint": fingerprint,
        "scales": {str(w): dict(sites) for w, sites in pack.scales},
    }
    payload["checksum"] = _payload_checksum(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def load_quant_pack(path: str, fingerprint: str) -> Optional[QuantPack]:
    """Load a cached pack; None when missing, corrupted, or calibrated for
    different weights. A missing file and a fingerprint mismatch are the
    quiet recalibration cases — as is a pack from before checksums were
    recorded; a file that EXISTS but is unparseable, fails its integrity
    checksum, or breaks the schema warns before falling back — that
    cache was damaged, not merely stale."""
    try:
        with open(path) as f:
            raw = f.read()
    except OSError:
        return None
    try:
        d = json.loads(raw)
        if "checksum" not in d:
            return None
        if d["checksum"] != _payload_checksum(d):
            raise ValueError("integrity checksum mismatch")
        if d.get("fingerprint") != fingerprint:
            return None
        scales = tuple((int(w), tuple(sorted(
            (str(k), float(v)) for k, v in sites.items())))
            for w, sites in sorted(d["scales"].items(),
                                   key=lambda kv: int(kv[0])))
        return QuantPack(mode=d["mode"], bits=int(d["bits"]),
                         per_channel_weights=bool(d["per_channel_weights"]),
                         act_percentile=float(d["act_percentile"]),
                         scales=scales)
    except (ValueError, KeyError, TypeError, AttributeError) as e:
        warnings.warn(f"quant-pack cache {path} is corrupted ({e!r}); "
                      f"ignoring it and recalibrating", stacklevel=2)
        return None
