"""PAMS quantization (paper Sec. IV-H; ref [21]).

PAMS = symmetric uniform quantization with a *parameterized (learnable) max
scale* alpha per tensor, trained with STE. The paper quantizes the WHOLE
model (unlike PAMS' fp first/last layers) at FXP10 W/A (-0.03 dB).

Two target modes:
  * ``bits=10``: the paper-faithful FXP10 simulation;
  * ``bits=8`` : TPU-native int8 (the MXU has an int8 datapath; DESIGN.md §3).

Provides fake-quant training ops, PTQ calibration (percentile), a quantized
ESSR forward, and an integer-consistency check used by tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.essr import ESSRConfig, slice_width


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 10          # FXP10 (paper) | 8 (TPU int8)
    per_channel_weights: bool = True
    act_percentile: float = 99.9

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def quantize(x: jax.Array, alpha: jax.Array, qmax: int) -> jax.Array:
    """Fake-quant with STE: forward = dequant(round(clip(x)/s)), grad = identity
    inside the clip range (PAMS' straight-through rule)."""
    s = alpha / qmax
    xc = jnp.clip(x, -alpha, alpha)
    q = jnp.round(xc / jnp.maximum(s, 1e-12)) * s
    return xc + jax.lax.stop_gradient(q - xc)


def int_codes(x: jax.Array, alpha: jax.Array, qmax: int) -> jax.Array:
    """The integer lattice codes (for the integer-consistency test)."""
    s = alpha / qmax
    return jnp.round(jnp.clip(x, -alpha, alpha) / jnp.maximum(s, 1e-12)).astype(jnp.int32)


def weight_alpha(w: jax.Array, per_channel: bool) -> jax.Array:
    if per_channel and w.ndim == 4:
        return jnp.max(jnp.abs(w), axis=(0, 1, 2), keepdims=True) + 1e-8
    return jnp.max(jnp.abs(w)) + 1e-8


def quantize_weight_tree(params, qcfg: QuantConfig):
    """Fake-quantize every conv weight/bias-free leaf in an ESSR param tree."""
    def q(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name.endswith("_b") or x.ndim < 2:
            return x  # biases stay wide (they feed the 24b accumulator on HW)
        return quantize(x, weight_alpha(x, qcfg.per_channel_weights), qcfg.qmax)
    return jax.tree_util.tree_map_with_path(q, params)


# ---------------------------------------------------------------------------
# activation scales: PTQ calibration + learnable container
# ---------------------------------------------------------------------------

def _act_points(cfg: ESSRConfig) -> list:
    """Names of activation-quant sites: after every conv group."""
    pts = ["in", "first"]
    for i in range(cfg.n_sfb):
        pts += [f"sfb{i}_b1", f"sfb{i}_b2", f"sfb{i}_out"]
    pts += ["recon"]
    return pts


def init_act_scales(cfg: ESSRConfig, init: float = 2.0) -> Dict[str, jax.Array]:
    return {k: jnp.asarray(init, jnp.float32) for k in _act_points(cfg)}


def quantized_essr_forward(params, act_scales: Dict[str, jax.Array], x: jax.Array,
                           cfg: ESSRConfig, qcfg: QuantConfig = QuantConfig(),
                           width: Optional[int] = None) -> jax.Array:
    """ESSR forward with W/A fake-quant at every conv boundary (whole model,
    as the paper does — no fp first/last exception)."""
    if width == 0:
        return L.bilinear_resize(x, cfg.scale)
    if width is not None and width != cfg.channels:
        params = slice_width(params, width)
    params = quantize_weight_tree(params, qcfg)
    qa = lambda name, t: quantize(t, jnp.abs(act_scales[name]) + 1e-8, qcfg.qmax)

    f = qa("in", x)
    f = qa("first", L.bsconv(params["first"], f))
    for i, p in enumerate(params["sfbs"]):
        y = qa(f"sfb{i}_b1", jax.nn.relu(L.bsconv(p["b1"], f)))
        y = qa(f"sfb{i}_b2", jax.nn.relu(L.bsconv(p["b2"], y)))
        y = L.pointwise(y + f, p["fuse"], p.get("fuse_b"))
        f = qa(f"sfb{i}_out", jax.nn.relu(y))
    up = qa("recon", L.dsconv(params["recon"], f))
    return L.pixel_shuffle(up, cfg.scale)


def calibrate_act_scales(params, cfg: ESSRConfig, sample: jax.Array,
                         qcfg: QuantConfig = QuantConfig()) -> Dict[str, jax.Array]:
    """PTQ: run fp forward on a calibration batch, set alpha = percentile(|act|)."""
    scales: Dict[str, jax.Array] = {}
    pct = qcfg.act_percentile

    def rec(name, t):
        scales[name] = jnp.percentile(jnp.abs(t), pct) + 1e-8
        return t

    f = rec("in", sample)
    f = rec("first", L.bsconv(params["first"], f))
    for i, p in enumerate(params["sfbs"]):
        y = rec(f"sfb{i}_b1", jax.nn.relu(L.bsconv(p["b1"], f)))
        y = rec(f"sfb{i}_b2", jax.nn.relu(L.bsconv(p["b2"], y)))
        y = L.pointwise(y + f, p["fuse"], p.get("fuse_b"))
        f = rec(f"sfb{i}_out", jax.nn.relu(y))
    rec("recon", L.dsconv(params["recon"], f))
    return scales
