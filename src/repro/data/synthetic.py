"""Procedural DIV2K-stand-in dataset (container is offline — DESIGN.md §8).

Images are mixtures of the three content classes the edge-selective router
discriminates (paper Fig. 1):
  * plain  : smooth low-frequency gradients            -> low edge score
  * texture: band-limited sinusoid/noise fields        -> mid edge score
  * edges  : lines, rectangles, text-like strokes      -> high edge score

HR images in [0,1] RGB; LR by bicubic downsampling (the standard SR
degradation). Deterministic given the seed.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def _smooth_field(rng: np.random.Generator, h: int, w: int, grid: int = 4) -> np.ndarray:
    coarse = rng.uniform(0, 1, size=(grid, grid, 3)).astype(np.float32)
    return np.asarray(jax.image.resize(jnp.asarray(coarse), (h, w, 3), method="cubic"))


def _texture(rng: np.random.Generator, h: int, w: int) -> np.ndarray:
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    img = np.zeros((h, w, 3), np.float32)
    for _ in range(rng.integers(2, 5)):
        f = rng.uniform(0.05, 0.45)
        theta = rng.uniform(0, np.pi)
        phase = rng.uniform(0, 2 * np.pi)
        wave = 0.5 + 0.5 * np.sin(2 * np.pi * f * (np.cos(theta) * xx + np.sin(theta) * yy) + phase)
        img += wave[..., None] * rng.uniform(0.2, 0.6, size=3).astype(np.float32)
    img /= max(1e-6, img.max())
    return img


def _strokes(rng: np.random.Generator, h: int, w: int) -> np.ndarray:
    img = np.full((h, w, 3), rng.uniform(0.6, 1.0), np.float32)
    n = int(rng.integers(6, 18))
    for _ in range(n):
        color = rng.uniform(0, 0.35, size=3).astype(np.float32)
        if rng.uniform() < 0.5:  # line
            y0, x0 = rng.integers(0, h), rng.integers(0, w)
            length = int(rng.integers(max(4, h // 8), h))
            thick = int(rng.integers(1, 3))
            if rng.uniform() < 0.5:
                img[y0:y0 + thick, max(0, x0 - length):x0] = color
            else:
                img[max(0, y0 - length):y0, x0:x0 + thick] = color
        else:     # rectangle outline
            y0, x0 = rng.integers(0, max(1, h - 8)), rng.integers(0, max(1, w - 8))
            hh, ww = int(rng.integers(4, h // 2)), int(rng.integers(4, w // 2))
            y1, x1 = min(h - 1, y0 + hh), min(w - 1, x0 + ww)
            img[y0:y1, x0] = color
            img[y0:y1, x1] = color
            img[y0, x0:x1] = color
            img[y1, x0:x1] = color
    return img


def random_image(seed: int, h: int, w: int, tile: int = 32) -> np.ndarray:
    """Tiled composition of the three content classes. (h,w,3) in [0,1]."""
    rng = np.random.default_rng(seed)
    img = np.zeros((h, w, 3), np.float32)
    gens = (_smooth_field, _texture, _strokes)
    for y in range(0, h, tile):
        for x in range(0, w, tile):
            th, tw = min(tile, h - y), min(tile, w - x)
            k = int(rng.integers(0, 3))
            img[y:y + th, x:x + tw] = gens[k](rng, th, tw)[:th, :tw]
    return np.clip(img, 0.0, 1.0)


def degrade(hr: jax.Array, scale: int) -> jax.Array:
    """Bicubic downsample (N,H,W,3) or (H,W,3)."""
    single = hr.ndim == 3
    if single:
        hr = hr[None]
    n, h, w, c = hr.shape
    lr = jax.image.resize(hr, (n, h // scale, w // scale, c), method="cubic")
    lr = jnp.clip(lr, 0.0, 1.0)
    return lr[0] if single else lr


def make_eval_set(seed: int, n: int, hr: int = 128) -> Tuple[jax.Array, jax.Array]:
    """n HR images + their x4-ready LR counterparts (scale applied by caller)."""
    imgs = np.stack([random_image(seed + i, hr, hr) for i in range(n)])
    return jnp.asarray(imgs)


def patch_batches(seed: int, batch: int, lr_patch: int, scale: int,
                  pool: int = 16, pool_hw: int = 256) -> Iterator[Tuple[jax.Array, jax.Array]]:
    """Infinite iterator of (lr (B,p,p,3), hr (B,p*s,p*s,3)) training pairs.

    A small pool of HR images is generated once; batches crop random aligned
    patch pairs from it — the shape of a real SR input pipeline without disk.
    """
    rng = np.random.default_rng(seed)
    hr_pool = np.stack([random_image(seed + 1000 + i, pool_hw, pool_hw) for i in range(pool)])
    lr_pool = np.asarray(degrade(jnp.asarray(hr_pool), scale))
    lp = lr_patch
    while True:
        idx = rng.integers(0, pool, size=batch)
        ys = rng.integers(0, lr_pool.shape[1] - lp + 1, size=batch)
        xs = rng.integers(0, lr_pool.shape[2] - lp + 1, size=batch)
        lr = np.stack([lr_pool[i, y:y + lp, x:x + lp] for i, y, x in zip(idx, ys, xs)])
        hr = np.stack([hr_pool[i, y * scale:(y + lp) * scale, x * scale:(x + lp) * scale]
                       for i, y, x in zip(idx, ys, xs)])
        yield jnp.asarray(lr), jnp.asarray(hr)
