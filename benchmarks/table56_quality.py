"""Paper Tables V/VI (+ Fig. 18): size/MACs/quality landscape.

Exact param+MAC identities vs the paper for ESSR, pruned RLFN, FSRCNN;
PSNR/SSIM measured on synthetic eval (absolute values differ from Set5 by
dataset, orderings are the claim under test). The ESSR rows run through
`SREngine.reference` (whole-frame convolution, per subnet width)."""
import jax
import numpy as np

from benchmarks.common import emit, eval_frames, get_engine
from repro.models.essr import ESSR_X2, ESSR_X4, essr_macs, essr_param_count
from repro.models.layers import bicubic_resize, bilinear_resize, count_params
from repro.models.rlfn import RLFN_PRUNED_X4, init_rlfn, rlfn_macs_per_lr_pixel
from repro.train.losses import psnr_y, ssim


def main():
    frames = eval_frames(n=3, hw=64)
    scale = 4

    # exact identities (Tables V/VI)
    assert essr_param_count(ESSR_X2) == 51_906            # "51K"
    assert essr_param_count(ESSR_X4) == 53_886            # "53K"
    assert abs(essr_macs(ESSR_X2, (540, 960)) / 1e9 - 26.1) < 0.3   # "26G"
    assert abs(essr_macs(ESSR_X4, (270, 480)) / 1e9 - 6.8) < 0.2    # "7G"
    rlfn_p = count_params(init_rlfn(jax.random.PRNGKey(0), RLFN_PRUNED_X4))
    reduction_p = 1 - essr_param_count(ESSR_X4) / rlfn_p
    reduction_m = 1 - (essr_macs(ESSR_X4, (100, 100)) /
                       (rlfn_macs_per_lr_pixel(RLFN_PRUNED_X4) * 100 * 100))
    emit("table56_identities", 0.0,
         f"essr_x2=51906;essr_x4=53886;rlfn_pruned={rlfn_p};"
         f"param_reduction={reduction_p:.3f}(paper 0.84);"
         f"mac_reduction={reduction_m:.3f}(paper 0.83)")

    # quality ladder on synthetic eval
    engine = get_engine(scale=scale)
    rows = {}
    for name, fn in [
        ("bilinear", lambda lr: bilinear_resize(lr[None], scale)[0]),
        ("bicubic", lambda lr: bicubic_resize(lr[None], (lr.shape[0] * scale,
                                                         lr.shape[1] * scale))[0]),
        ("essr_c27", lambda lr: engine.reference(lr, width=27).image),
        ("essr_c54", lambda lr: engine.reference(lr, width=54).image),
    ]:
        ps = [float(psnr_y(fn(lr), hr)) for lr, hr in frames]
        ss = [float(ssim(fn(lr), hr)) for lr, hr in frames]
        rows[name] = (np.mean(ps), np.mean(ss))
        emit(f"table56_{name}", 0.0, f"psnr_y={np.mean(ps):.2f};ssim={np.mean(ss):.3f}")

    # the orderings the paper's tables assert
    assert rows["essr_c54"][0] >= rows["essr_c27"][0] - 0.3, "C54 must be >= C27"
    emit("table56_ordering", 0.0,
         f"c54_minus_c27={rows['essr_c54'][0]-rows['essr_c27'][0]:.2f};"
         f"c54_minus_bilinear={rows['essr_c54'][0]-rows['bilinear'][0]:.2f}")


if __name__ == "__main__":
    main()
