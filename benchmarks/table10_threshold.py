"""Paper Table X: PSNR at fixed MAC-reduction operating points (40/50/60%),
thresholds found by the same grid search a deployment would run."""
import numpy as np

from benchmarks.common import emit, eval_frames, get_trained_essr, \
    mean_psnr_edge_selective
from repro.core.edge_score import edge_score
from repro.core.patching import extract_patches
from repro.core.subnet_policy import thresholds_for_target_saving


def main():
    params, cfg = get_trained_essr(scale=4)
    frames = eval_frames(n=3, hw=96)
    scores = np.concatenate([
        np.asarray(edge_score(extract_patches(lr, 32, 2)[0])) for lr, _ in frames])
    base, _ = mean_psnr_edge_selective(params, cfg, frames, t1=0, t2=0)
    for target in (0.4, 0.5, 0.6):
        t1, t2 = thresholds_for_target_saving(scores, target, cfg)
        p, s = mean_psnr_edge_selective(params, cfg, frames, t1=t1, t2=t2)
        emit(f"table10_saving{int(target*100)}", 0.0,
             f"t1={t1};t2={t2};mac_saving={s:.3f};psnr_y={p:.3f};"
             f"drop={base - p:.3f}")


if __name__ == "__main__":
    main()
