"""Paper Table XII: PE utilization per layer/subnet.

TPU analog: per-layer MXU-utilization bound = arithmetic intensity /
machine balance (197 TFLOP/s / 819 GB/s = 241 FLOP/B), capped at the lane
padding efficiency (54 of 64 padded channels = 84%). The weighted average
uses the measured subnet cycle shares from a Test8K-like synthetic frame
mix — mirroring the paper's 77.1% weighted-PE-utilization calculation.
"""
import numpy as np

from benchmarks.common import emit, eval_frames, get_trained_essr
from repro.core.edge_score import edge_score
from repro.core.patching import extract_patches
from repro.core.subnet_policy import SubnetMacs, decide, subnet_counts
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

BALANCE = PEAK_FLOPS / HBM_BW                  # FLOP/B machine balance
LANE_EFF = {54: 54 / 64, 27: 27 / 32}          # channel padding to the VPU/MXU lanes


def layer_ai(cin, cout, dw_taps=0, pixels=32 * 32, bytes_per=2):
    """Arithmetic intensity of a (fused) layer on one patch."""
    flops = 2 * pixels * (cin * cout + dw_taps * cout)
    bts = bytes_per * pixels * (cin + cout) + bytes_per * (cin * cout + 9 * cout)
    return flops / bts


def main():
    rows = {
        "first_bsconv": layer_ai(3, 54, 9),
        "sfb_fused": layer_ai(54, 54 * 3, 18),     # 3 matmuls + 2 dw in one pass
        "dsconv": layer_ai(54, 48, 9),
    }
    for name, ai in rows.items():
        util = min(1.0, ai / BALANCE) * LANE_EFF[54]
        emit(f"table12_{name}", 0.0, f"arith_intensity={ai:.1f};mxu_util_bound={util:.3f}")

    # measured subnet shares on a synthetic frame mix (paper: 5.6/20.7/73.8% cycles)
    params, cfg = get_trained_essr(scale=4)
    frames = eval_frames(n=3, hw=96)
    counts = np.zeros(3)
    for lr, _ in frames:
        patches, _ = extract_patches(lr, 32, 2)
        ids = decide(edge_score(patches), 8, 40)
        counts += np.array(subnet_counts(ids))
    m = SubnetMacs.make(cfg)
    cycles = counts * np.array([m.per_patch[0], m.per_patch[1], m.per_patch[2]], float)
    share = cycles / cycles.sum()
    # per-subnet utilization analog: bilinear is VPU-only (low), C27 fills the
    # array with 2x patches (ops.default_block_patches), C54 full.
    per_subnet = np.array([0.15, LANE_EFF[27] * 0.93, LANE_EFF[54] * 0.95])
    weighted = float((share * per_subnet).sum())
    emit("table12_weighted", 0.0,
         f"cycle_share_bilinear={share[0]:.3f};c27={share[1]:.3f};c54={share[2]:.3f};"
         f"weighted_util={weighted:.3f};paper=0.771")


if __name__ == "__main__":
    main()
