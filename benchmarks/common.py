"""Shared benchmark utilities: a briefly-trained ESSR supernet (cached on
disk so the table benches don't retrain), synthetic eval sets, timers."""
from __future__ import annotations

import os
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecutionPlan, SREngine
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.synthetic import degrade, patch_batches, random_image
from repro.models.essr import ESSRConfig, init_essr
from repro.train import optimizer as O
from repro.train.losses import psnr_y
from repro.train.trainer import train_essr_supernet

from repro.api.engine import DEFAULT_BENCH_CACHE as CACHE  # single source
BENCH_STEPS = int(os.environ.get("BENCH_STEPS", "6000"))


def get_trained_essr(scale: int = 4, n_sfb: int = 5, steps: Optional[int] = None,
                     tag: str = "") -> Tuple[dict, ESSRConfig]:
    """Train (once, cached on disk) a reduced-schedule ESSR supernet on the
    synthetic dataset. The paper's recipe scaled down: Lamb, cosine 3e-3,
    MACs-proportional subnet sampling. RAW weights are benchmarked (EMA 0.999
    is still init-biased at bench-scale step counts)."""
    steps = steps or (BENCH_STEPS if n_sfb == 5 else 1500)
    cfg = ESSRConfig(scale=scale, n_sfb=n_sfb)
    name = f"essr_x{scale}_sfb{n_sfb}_{steps}{tag}"
    cm = CheckpointManager(os.path.join(CACHE, name), keep=1)
    params = init_essr(jax.random.PRNGKey(0), cfg)
    if cm.latest_step() is not None:
        restored, _ = cm.restore({"params": params})
        return restored["params"], cfg
    data = patch_batches(0, batch=16, lr_patch=16, scale=scale, pool=16,
                         pool_hw=64 * scale)
    params, _, _ = train_essr_supernet(
        params, cfg, data, steps=steps,
        opt=O.lamb(O.cosine_decay(3e-3, steps, warmup=100)), log_every=0)
    cm.save(steps, {"params": params}, blocking=True)
    return params, cfg


def get_engine(scale: int = 4, n_sfb: int = 5, steps: Optional[int] = None,
               tag: str = "", plan: Optional[ExecutionPlan] = None,
               backend: str = "ref") -> SREngine:
    """`SREngine` over the cached briefly-trained benchmark supernet — the
    one constructor every table benchmark shares."""
    params, cfg = get_trained_essr(scale=scale, n_sfb=n_sfb, steps=steps, tag=tag)
    return SREngine(params, cfg, plan=plan, backend=backend)


def eval_frames(n: int = 3, hw: int = 96, scale: int = 4, seed: int = 777):
    """Held-out synthetic (lr, hr) frame pairs.

    Content tiles are sized to one LR patch's HR footprint (32*scale) so a
    32x32 LR patch sees ONE content class — the regime the edge router
    discriminates (tiles smaller than a patch make every patch mixed-class
    and score high, collapsing the routing distribution)."""
    out = []
    for i in range(n):
        hr = jnp.asarray(random_image(seed + i, hw * scale, hw * scale,
                                      tile=32 * scale))
        out.append((degrade(hr, scale), hr))
    return out


def mean_psnr_engine(engine: SREngine, frames,
                     plan: Optional[ExecutionPlan] = None) -> Tuple[float, float]:
    """(mean PSNR_Y, mean MAC saving) of the engine's edge-selective path."""
    ps, sv = [], []
    for lr, hr in frames:
        res = engine.upscale(lr, plan=plan)
        ps.append(float(psnr_y(res.image, hr)))
        sv.append(res.mac_saving)
    return float(np.mean(ps)), float(np.mean(sv))


def mean_psnr_edge_selective(params, cfg, frames, t1=8.0, t2=40.0,
                             patch=32, overlap=2) -> Tuple[float, float]:
    """Back-compat shim over the old free-function surface. Unlike
    ``ExecutionPlan`` it stays permissive about inverted thresholds (t1 > t2),
    exactly as the pre-SREngine code was; new code should use
    ``get_engine()`` + ``mean_psnr_engine()``."""
    from repro.core.pipeline import edge_selective_sr
    ps, sv = [], []
    for lr, hr in frames:
        res = edge_selective_sr(params, lr, cfg, t1=t1, t2=t2,
                                patch=patch, overlap=overlap)
        ps.append(float(psnr_y(res.image, hr)))
        sv.append(res.mac_saving)
    return float(np.mean(ps)), float(np.mean(sv))


def timed(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """us per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
