"""GLNPU group-of-layer fusion: feature-traffic accounting (paper claims
43% feature-SRAM-access saving for BSConv fusion, 79% for whole-SFB fusion).

On TPU the saving is HBM round-trips: layer-by-layer = every intermediate
written+read; fused kernel = input read + output written, intermediates in
VMEM. Exact byte accounting below (weights counted in both)."""
from benchmarks.common import emit

BYTES = 1.25          # FXP10, matching the paper's SRAM numbers
PIX = 32 * 32
C = 54


def traffic_layer_by_layer_bsconv(cin, cout):
    # pw: r in + w mid ; dw: r mid + w out
    return BYTES * PIX * ((cin + cout) + (cout + cout))


def traffic_fused_bsconv(cin, cout):
    return BYTES * PIX * (cin + cout)


def traffic_layer_by_layer_sfb():
    t = traffic_layer_by_layer_bsconv(C, C) * 2           # two BSConvs
    t += BYTES * PIX * (C + C + C)                        # shortcut add r2 w1
    t += BYTES * PIX * (C + C)                            # fuse 1x1
    return t


def traffic_fused_sfb():
    return BYTES * PIX * (C + C)                          # read x, write out


def main():
    lb = traffic_layer_by_layer_bsconv(C, C)
    f = traffic_fused_bsconv(C, C)
    emit("fusion_bsconv", 0.0,
         f"layer_by_layer_kb={lb/1024:.1f};fused_kb={f/1024:.1f};"
         f"saving={1-f/lb:.3f};paper=0.43")
    lbs, fs = traffic_layer_by_layer_sfb(), traffic_fused_sfb()
    emit("fusion_sfb", 0.0,
         f"layer_by_layer_kb={lbs/1024:.1f};fused_kb={fs/1024:.1f};"
         f"saving={1-fs/lbs:.3f};paper=0.79")


if __name__ == "__main__":
    main()
