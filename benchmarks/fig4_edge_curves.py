"""Paper Fig. 4: quality-vs-edge-score curves per subnet — the evidence that
a plain input-edge threshold separates the regimes (low-edge: bilinear is
enough; high-edge: C54 pays off)."""
import numpy as np

from benchmarks.common import emit, eval_frames, get_trained_essr
from repro.core.edge_score import edge_score
from repro.core.patching import extract_patches
from repro.models.essr import essr_forward
from repro.models.layers import bilinear_resize
from repro.train.losses import psnr_y

BINS = [(0, 8), (8, 25), (25, 60), (60, 255)]


def main():
    params, cfg = get_trained_essr(scale=4)
    frames = eval_frames(n=4, hw=96)
    pp, hh = [], []
    for lr, hr in frames:
        p, pos = extract_patches(lr, 32, 2)
        h, _ = extract_patches(hr, 32 * cfg.scale, 2 * cfg.scale)
        pp.append(np.asarray(p))
        hh.append(np.asarray(h))
    patches = np.concatenate(pp)
    hrs = np.concatenate(hh)
    scores = np.asarray(edge_score(patches))

    import jax.numpy as jnp
    sr = {0: np.asarray(bilinear_resize(jnp.asarray(patches), cfg.scale)),
          27: np.asarray(essr_forward(params, jnp.asarray(patches), cfg, width=27)),
          54: np.asarray(essr_forward(params, jnp.asarray(patches), cfg, width=54))}

    gains = {}
    for lo, hi in BINS:
        sel = (scores >= lo) & (scores < hi)
        if sel.sum() == 0:
            continue
        row = {}
        for w, imgs in sr.items():
            ps = [float(psnr_y(jnp.asarray(imgs[i]), jnp.asarray(hrs[i])))
                  for i in np.flatnonzero(sel)[:12]]
            row[w] = float(np.mean(ps))
        gains[(lo, hi)] = row
        emit(f"fig4_bin{lo}-{hi}", 0.0,
             f"n={int(sel.sum())};bilinear={row[0]:.2f};c27={row[27]:.2f};c54={row[54]:.2f}")

    # the claim: the C54-over-bilinear gain GROWS with edge score
    keys = sorted(gains)
    if len(keys) >= 2:
        g_low = gains[keys[0]][54] - gains[keys[0]][0]
        g_high = gains[keys[-1]][54] - gains[keys[-1]][0]
        emit("fig4_gain_monotonicity", 0.0,
             f"c54_gain_low_edge={g_low:.2f};c54_gain_high_edge={g_high:.2f}")


if __name__ == "__main__":
    main()
