"""Paper Tables III + IV: boundary-processing strategies and overlap width.

Strategies measured on synthetic eval frames with the trained supernet:
  whole      — whole-frame convolution (the lossless software reference;
               == SRAM/DRAM+recompute quality at unbounded cost)
  interp     — non-overlapped patches, naive stitch (cheap floor)
  overlap+avg— the paper's pick (2-px LR overlap -> 8-px HR at x4)

Derived columns reconstruct the paper's cost model: boundary SRAM for
overlap o (HR px) ~ o/8 * 114KB; MACs multiplier = (32/(32-o_lr))^2.
"""
import numpy as np

from benchmarks.common import emit, eval_frames, get_trained_essr
from repro.core.patching import extract_patches, fuse_patches_average, \
    fuse_patches_crop, overlap_mac_overhead
from repro.core.pipeline import sr_whole
from repro.train.losses import psnr_y

PAPER_T4 = {16: (243, 1.31), 12: (176, 1.22), 8: (114, 1.14),
            4: (55, 1.07), 0: (0, 1.00)}


def _psnr_for_overlap(params, cfg, frames, overlap_lr, average=True):
    ps = []
    for lr, hr in frames:
        if overlap_lr < 0:                       # whole-frame reference
            sr = sr_whole(params, lr, cfg)
        else:
            patches, pos = extract_patches(lr, 32, overlap_lr)
            from repro.models.essr import essr_forward
            srp = essr_forward(params, patches, cfg)
            fuse = fuse_patches_average if average else fuse_patches_crop
            sr = fuse(srp, pos, cfg.scale, (hr.shape[0], hr.shape[1]))
        ps.append(float(psnr_y(sr, hr)))
    return float(np.mean(ps))


def main():
    params, cfg = get_trained_essr(scale=4)
    frames = eval_frames(n=2, hw=96)

    whole = _psnr_for_overlap(params, cfg, frames, -1)
    emit("table3_whole_reference", 0.0, f"psnr_y={whole:.3f};paper_row=SRAM+Recomp")
    naive = _psnr_for_overlap(params, cfg, frames, 0, average=False)
    emit("table3_interpolation", 0.0,
         f"psnr_y={naive:.3f};drop_vs_whole={whole-naive:.3f};paper_row=Interpol")
    oavg = _psnr_for_overlap(params, cfg, frames, 2, average=True)
    emit("table3_overlap_avg", 0.0,
         f"psnr_y={oavg:.3f};drop_vs_whole={whole-oavg:.3f};boundary_sram_kb=114;"
         f"paper_drop=0.05")

    # Table IV sweep: overlap in HR pixels (LR overlap * scale)
    for olr in (4, 3, 2, 1, 0):
        ohr = olr * cfg.scale
        sram = 114 * ohr / 8.0
        macs = overlap_mac_overhead(32, olr)
        p = _psnr_for_overlap(params, cfg, frames, olr, average=olr > 0)
        paper = PAPER_T4.get(ohr, (None, None))
        emit(f"table4_overlap{ohr}px", 0.0,
             f"psnr_y={p:.3f};macs_x={macs:.2f};boundary_sram_kb={sram:.0f};"
             f"paper_sram={paper[0]};paper_macs={paper[1]}")


if __name__ == "__main__":
    main()
