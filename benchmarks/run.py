"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. First run trains+caches the small
benchmark supernet (~minutes on 1 CPU core); subsequent runs reuse it.

    PYTHONPATH=src python -m benchmarks.run [--only tableX]
"""
import argparse
import sys
import time
import traceback

MODULES = [
    "table1_patch_size",
    "table2_sfb",
    "table34_boundary",
    "table56_quality",
    "table7_gan",
    "table9_dynamic",
    "table10_threshold",
    "table11_throughput",
    "table12_utilization",
    "fig4_edge_curves",
    "table_fusion",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:                                # noqa: BLE001
            failures.append(mod_name)
            print(f"# {mod_name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks passed")


if __name__ == "__main__":
    main()
