"""Paper Table XI: throughput/energy. No silicon here — we report
(a) measured CPU patch throughput per subnet (pure-JAX and fused-kernel
    paths), and
(b) the TPU-side projection from the dry-run roofline (results/dryrun),
    i.e. the frames/s one v5e chip supports at the measured bytes/flops.
Power/gate count are N/A on CPU and stated as such."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_trained_essr, timed
from repro.kernels.ops import essr_forward_kernels
from repro.models.essr import essr_forward


def main():
    params, cfg = get_trained_essr(scale=4)
    x = jax.random.uniform(jax.random.PRNGKey(0), (32, 32, 32, 3))

    for width in (27, 54):
        us = timed(lambda: essr_forward(params, x, cfg, width=width), reps=3)
        pix = 32 * 32 * 32 * 16  # HR pixels per call (x4)
        emit(f"table11_cpu_jax_c{width}", us, f"mpixels_per_s={pix/us:.2f}")
        us_k = timed(lambda: essr_forward_kernels(params, x, cfg, width=width),
                     reps=1)
        emit(f"table11_cpu_kernels_c{width}", us_k,
             f"mpixels_per_s={pix/us_k:.2f};note=interpret-mode(correctness path)")

    # TPU projection from the dry-run artifact
    f = "/root/repo/results/dryrun/single/essr-x4__serve_8k.json"
    if os.path.exists(f):
        d = json.load(open(f))
        r = d["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        hr_pixels = 2304 * 128 * 128          # one 8K frame's worth of patches
        fps_mesh = 1.0 / step_s if step_s > 0 else float("inf")
        emit("table11_tpu_projection", 0.0,
             f"dominant={r['dominant']};frame_step_s={step_s:.2e};"
             f"fps_on_256chips={fps_mesh:.0f};"
             f"mpixels_per_j=NA(no power on CPU);paper=4797")


if __name__ == "__main__":
    main()
