"""Paper Table XI: throughput/energy. No silicon here — we report
(a) measured CPU frame throughput per subnet through `SREngine`, once per
    backend ("ref" pure-JAX jit vs "pallas" fused kernel groups, interpret
    mode on CPU), exercising the full patch->route->batch->fuse pipeline, and
(b) the TPU-side projection from the dry-run roofline (results/dryrun),
    i.e. the frames/s one v5e chip supports at the measured bytes/flops.
Power/gate count are N/A on CPU and stated as such."""
import json
import os

import jax

from benchmarks.common import emit, get_trained_essr, timed
from repro.api import SREngine


def main():
    hw, scale = 96, 4
    frame = jax.random.uniform(jax.random.PRNGKey(0), (hw, hw, 3))
    hr_pix = (hw * scale) ** 2
    params, cfg = get_trained_essr(scale=scale)     # restore weights once
    engines = {"jax": SREngine(params, cfg, backend="ref"),
               "kernels": SREngine(params, cfg, backend="pallas")}

    for name, engine in engines.items():
        for width in (27, 54):
            reps = 3 if name == "jax" else 1
            us = timed(lambda: engine.upscale(frame, mode="all_patches",
                                              width=width).image, reps=reps)
            note = "" if name == "jax" else ";note=interpret-mode(correctness path)"
            emit(f"table11_cpu_{name}_c{width}", us,
                 f"mpixels_per_s={hr_pix / us:.2f}{note}")

    # TPU projection from the dry-run artifact
    f = "/root/repo/results/dryrun/single/essr-x4__serve_8k.json"
    if os.path.exists(f):
        d = json.load(open(f))
        r = d["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        hr_pixels = 2304 * 128 * 128          # one 8K frame's worth of patches
        fps_mesh = 1.0 / step_s if step_s > 0 else float("inf")
        emit("table11_tpu_projection", 0.0,
             f"dominant={r['dominant']};frame_step_s={step_s:.2e};"
             f"fps_on_256chips={fps_mesh:.0f};"
             f"mpixels_per_j=NA(no power on CPU);paper=4797")


if __name__ == "__main__":
    main()
