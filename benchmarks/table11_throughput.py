"""Paper Table XI: throughput/energy. No silicon here — we report
(a) before/after frames-per-second of the patch pipeline itself: the seed's
    host-side per-patch extract/fuse loops vs the device-resident
    gather/scatter paths, written to BENCH_table11_throughput.json so the
    perf trajectory is tracked across PRs,
(b) a ``--shards`` sweep of the data-parallel patch stream (shard_map over
    the 1-D patch mesh) on the same micro-config frame, recorded into the
    same JSON — on CPU the virtual devices share cores so this measures
    dispatch overhead + correctness, on real hardware it measures scaling,
(c) a quant sweep (``ExecutionPlan.quant``-style serving through the same
    pipeline): per PAMS mode (fxp10/int8) the ref-backend fake-quant frame
    fps and its SNR vs the fp32 pipeline, plus a pallas-int8
    integer-consistency flag (kernel codes bit-exact vs the jnp integer
    reference on one patch batch) — all recorded into the same JSON,
(d) a dispatch sweep on the same mixed frame: host dispatch (per-frame
    edge-score sync + Python bucket loop) vs the fused single-dispatch frame
    executable (``ExecutionPlan.dispatch="fused"``), single-frame and
    streamed (double-buffered ``inflight=2``), plus fused-vs-host allclose
    conformance across backends and int8 quant — recorded into the same
    JSON and gated by scripts/bench_gate.py (fused must never be slower
    than host beyond tolerance),
(e) a fusion sweep (``ExecutionPlan.fusion``): the layer-fused per-op
    kernel stack vs the group-fused subnet megakernel on the same mixed
    frame — interleaved best-of wall time (group must never lose beyond
    tolerance) plus the static ``feature_hbm_bytes`` of both traced chains
    (priced by analysis/cost_model.py; the >= 50% reduction the gate
    enforces is the portable form of the paper's 79% claim),
(f) a multi-stream sweep (``ExecutionPlan.streams``): N tenant streams
    packed into ONE fused dispatch per admission tick
    (``SREngine.serve_streams``) vs N solo fused engines serving the same
    frames — aggregate fps both ways, the mux/solo ratio, and a
    zero-tolerance conformance flag (capacity pinned identically on both
    sides, so the multiplexed outputs must match the solo engines exactly)
    — recorded into the same JSON and gated by scripts/bench_gate.py,
(g) measured CPU frame throughput per subnet through `SREngine`, once per
    backend ("ref" pure-JAX jit vs "pallas" fused kernel groups, interpret
    mode on CPU), exercising the full patch->route->batch->fuse pipeline, and
(h) the TPU-side projection from the dry-run roofline (results/dryrun),
    i.e. the frames/s one v5e chip supports at the measured bytes/flops.
Power/gate count are N/A on CPU and stated as such."""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_trained_essr, timed
from repro.api import ExecutionPlan, SREngine
from repro.core.adaptive import SwitchingConfig
from repro.core.pipeline import edge_selective_sr
from repro.launch.mesh import make_patch_mesh
from repro.models.essr import ESSRConfig, init_essr
from repro.runtime.guard import FaultPlan

BENCH_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_table11_throughput.json")


def _best_of(fn, reps: int) -> float:
    """us per call, minimum over ``reps`` — the noise-robust estimator for a
    deterministic computation on a shared CPU (means smear scheduler jitter
    into the ratio)."""
    import time
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _measure_frame(params, cfg, frame, label: str) -> dict:
    """One frame, both ways: seed per-patch loops ("before") vs vectorized
    gather/scatter ("after"), identical weights and routing; the subnet
    forward is byte-for-byte the same code on both sides."""
    run_new = lambda: edge_selective_sr(params, frame, cfg, backend="ref").image
    run_loop = lambda: edge_selective_sr(params, frame, cfg, backend="ref",
                                         use_loop_reference=True).image
    img_new = jax.block_until_ready(run_new())      # warm jit + geometry cache
    img_loop = jax.block_until_ready(run_loop())
    allclose = bool(np.allclose(np.asarray(img_new), np.asarray(img_loop),
                                rtol=1e-5, atol=1e-5))
    us_new = _best_of(run_new, reps=5)
    us_loop = _best_of(run_loop, reps=3)
    emit(f"table11_patch_pipeline_{label}_before_loop", us_loop,
         f"fps={1e6 / us_loop:.3f}")
    emit(f"table11_patch_pipeline_{label}_after_vectorized", us_new,
         f"fps={1e6 / us_new:.3f};speedup_x={us_loop / us_new:.2f};"
         f"allclose={allclose}")
    return {
        "before_seed_loop": {"us_per_frame": round(us_loop, 1),
                             "fps": round(1e6 / us_loop, 3)},
        "after_vectorized": {"us_per_frame": round(us_new, 1),
                             "fps": round(1e6 / us_new, 3)},
        "speedup_x": round(us_loop / us_new, 2),
        "allclose_vs_seed_loop": allclose,
    }


def _measure_shards(params, cfg, frame, shard_counts) -> dict:
    """The ``--shards`` sweep: the same micro-config frame through the
    data-parallel patch stream at each shard count. Counts beyond the
    visible device count are recorded as skipped (never silently dropped);
    every row is checked against the UNSHARDED pipeline (computed up front,
    so a ``--shards 4,2`` sweep order cannot silently compare sharded vs
    sharded)."""
    ref_img = np.asarray(jax.block_until_ready(
        edge_selective_sr(params, frame, cfg, backend="ref").image))
    rows = {}
    for s in shard_counts:
        if s > jax.device_count():
            rows[str(s)] = {"skipped": f"{jax.device_count()} devices visible"}
            emit(f"table11_shard_sweep_{s}", 0.0,
                 f"skipped;devices={jax.device_count()}")
            continue
        mesh = make_patch_mesh(s) if s > 1 else None
        run = lambda: edge_selective_sr(params, frame, cfg, backend="ref",
                                        mesh=mesh).image
        img = jax.block_until_ready(run())       # warm jit + shard_map cache
        allclose = bool(np.allclose(np.asarray(img), ref_img,
                                    rtol=1e-5, atol=1e-5))
        us = _best_of(run, reps=5)
        emit(f"table11_shard_sweep_{s}", us,
             f"fps={1e6 / us:.3f};allclose_vs_1shard={allclose}")
        rows[str(s)] = {"us_per_frame": round(us, 1),
                        "fps": round(1e6 / us, 3),
                        "allclose_vs_1shard": allclose}
    return rows


def _measure_quant(params, cfg, frame) -> dict:
    """The quant sweep: the mixed-content frame through the quantized
    serving path per PAMS mode. Alphas are PTQ-calibrated from the frame's
    own patch batch (the content being served is the honest calibration
    set for a single-frame micro-benchmark). SNR is measured against the
    fp32 pipeline output — the machine-portable accuracy signal the bench
    gate defends (absolute PSNR would move with the random-init weights)."""
    from repro.core.patching import get_geometry
    from repro.kernels.qconv import essr_forward_qkernels, essr_forward_qref
    from repro.quant.pams import build_quant_pack

    h, w = int(frame.shape[0]), int(frame.shape[1])
    g = get_geometry(h, w, 32, 2, cfg.scale)
    sample = g.extract(frame)[:16]
    fp_img = np.asarray(jax.block_until_ready(
        edge_selective_sr(params, frame, cfg, backend="ref").image))
    rows = {}
    packs = {}
    for mode in ("fxp10", "int8"):
        pack = packs[mode] = build_quant_pack(params, cfg, mode, sample)
        run = lambda: edge_selective_sr(params, frame, cfg, backend="ref",
                                        quant=pack).image
        img = np.asarray(jax.block_until_ready(run()))    # warm quant jits
        err = img - fp_img
        snr_db = float(10 * np.log10(np.mean(fp_img ** 2)
                                     / max(np.mean(err ** 2), 1e-20)))
        us = _best_of(run, reps=3)
        emit(f"table11_quant_{mode}", us,
             f"fps={1e6 / us:.3f};snr_db_vs_fp32={snr_db:.2f}")
        rows[mode] = {"us_per_frame": round(us, 1),
                      "fps": round(1e6 / us, 3),
                      "snr_db_vs_fp32": round(snr_db, 2)}

    # integer-consistency spot check (cheap, hard-gated in CI): the pallas
    # int8 kernel chain must be bit-exact vs the jnp integer reference
    batch = g.extract(frame)[:8]
    ker = essr_forward_qkernels(params, batch, cfg, width=cfg.channels,
                                pack=packs["int8"])
    ref = essr_forward_qref(params, batch, cfg, cfg.channels,
                            pack=packs["int8"])
    bitexact = bool(np.array_equal(np.asarray(ker), np.asarray(ref)))
    emit("table11_quant_pallas_int8_bitexact", 0.0, f"bitexact={bitexact}")
    return {"modes": rows, "pallas_int8_bitexact": bitexact}


def _stable_switching() -> SwitchingConfig:
    """Threshold adaptation frozen (never raise, never decay): the stream
    rows below measure dispatch rate, not Algorithm-1 behaviour — moving
    thresholds would change routing (and recompile fused capacity profiles)
    mid-measurement."""
    return SwitchingConfig(frame_high=10 ** 9, frame_low=0)


def _measure_dispatch(params, cfg, frame, stream_frames: int = 6) -> dict:
    """Host vs fused dispatch on the steady-state mixed-routing frame.

    The single-frame rows time post-warmup ``upscale`` calls with host and
    fused reps INTERLEAVED (best-of each): machine-load drift then shifts
    both sides together instead of masquerading as a dispatch speedup —
    ``fused_speedup_x`` is the ratio the CI gate defends. The stream rows
    time ``SREngine.stream`` end-to-end over ``stream_frames`` identical
    frames (host dispatch vs the double-buffered fused executor at
    ``inflight=2``), thresholds frozen so every frame routes identically."""
    host = SREngine(params, cfg)
    fused = SREngine(params, cfg, plan=ExecutionPlan(dispatch="fused"))
    img_h = np.asarray(jax.block_until_ready(host.upscale(frame).image))
    r_f = fused.upscale(frame)                   # warm: probe + compile
    allclose = bool(np.allclose(np.asarray(r_f.image), img_h,
                                rtol=1e-5, atol=1e-5))
    spilled = list(r_f.spill_counts)
    us_host = us_fused = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(host.upscale(frame).image)
        us_host = min(us_host, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(fused.upscale(frame).image)
        us_fused = min(us_fused, (time.perf_counter() - t0) * 1e6)

    def stream_rate(plan) -> float:
        eng = SREngine(params, cfg, plan=plan, switching=_stable_switching())
        list(eng.stream([frame] * 2))            # warm compile + capacity
        best = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            list(eng.stream([frame] * stream_frames))
            best = min(best, (time.perf_counter() - t0) / stream_frames)
        return best * 1e6

    us_stream_host = stream_rate(ExecutionPlan())
    us_stream_async = stream_rate(ExecutionPlan(dispatch="fused", inflight=2))

    speedup = us_host / us_fused
    async_speedup = us_stream_host / us_stream_async
    emit("table11_dispatch_host", us_host, f"fps={1e6 / us_host:.3f}")
    emit("table11_dispatch_fused", us_fused,
         f"fps={1e6 / us_fused:.3f};speedup_x={speedup:.2f};"
         f"allclose={allclose}")
    emit("table11_dispatch_fused_async", us_stream_async,
         f"fps={1e6 / us_stream_async:.3f};"
         f"stream_speedup_x={async_speedup:.2f}")
    return {
        "host": {"us_per_frame": round(us_host, 1),
                 "fps": round(1e6 / us_host, 3)},
        "fused": {"us_per_frame": round(us_fused, 1),
                  "fps": round(1e6 / us_fused, 3),
                  "allclose_vs_host": allclose,
                  "spilled_patches": spilled},
        "host_stream": {"us_per_frame": round(us_stream_host, 1),
                        "fps": round(1e6 / us_stream_host, 3)},
        "fused_async_inflight2": {"us_per_frame": round(us_stream_async, 1),
                                  "fps": round(1e6 / us_stream_async, 3)},
        # the headline ratios: single-frame dispatch win + streamed
        # double-buffered win, both measured back-to-back on this machine
        "fused_speedup_x": round(speedup, 2),
        "fused_async_stream_speedup_x": round(async_speedup, 2),
    }


def _measure_streams(params, cfg, frame, n_streams: int = 4,
                     ticks: int = 3) -> dict:
    """Multi-stream continuous batching (``ExecutionPlan.streams``): N
    tenant streams through ONE fused dispatch per admission tick vs N solo
    fused engines serving the same frames back-to-back. Capacity is PINNED
    identically on both sides — with auto-probed capacity the shared pool
    lends a tenant the other streams' slack (statistical multiplexing, a
    feature), which makes exact solo-conformance ill-posed. Per-stream
    content differs (rolled copies of the mixed frame), so a cross-stream
    scatter-back mixup cannot hide behind identical tenants. The
    ``mux_vs_solo_x`` ratio is measured in the SAME run on the SAME
    machine, so it travels across hosts; the CI gate floors it at 0.9x and
    zero-tolerates conformance drift. Both sides serve at the recommended
    streaming config (``inflight=2``): double-buffering overlaps each
    side's host-side control work with device compute, which is exactly
    the steady state a deployment runs in."""
    h, w = int(frame.shape[0]), int(frame.shape[1])
    geom = ExecutionPlan().geometry(h, w, cfg.scale)
    cap = (0, geom.n, geom.n)                    # per-stream; spill-free
    streams = [[jnp.roll(frame, 17 * (s + 1) * (t + 1), axis=1)
                for t in range(ticks)] for s in range(n_streams)]

    solo_plan = ExecutionPlan(dispatch="fused", capacity=cap, inflight=2)
    solos = [SREngine(params, cfg, plan=solo_plan,
                      switching=_stable_switching())
             for _ in range(n_streams)]
    solo_imgs = [[np.asarray(r.image) for r in eng.stream(fs)]     # warm
                 for eng, fs in zip(solos, streams)]

    mux = SREngine(params, cfg, switching=_stable_switching(),
                   plan=ExecutionPlan(dispatch="fused", capacity=cap,
                                      streams=n_streams, inflight=2))
    results = list(mux.serve_streams([list(fs) for fs in streams]))  # warm
    allclose = all(
        np.allclose(np.asarray(r.image),
                    solo_imgs[r.stream_id][i // n_streams],
                    rtol=1e-5, atol=1e-5)
        for i, r in enumerate(results))
    bit_equal = all(
        np.array_equal(np.asarray(r.image),
                       solo_imgs[r.stream_id][i // n_streams])
        for i, r in enumerate(results))
    # interleaved best-of-5: solo and mux alternate within each round so a
    # slow machine phase (allocator churn, background load) penalizes both
    # sides, and the min of 5 lets each reach its floor — separate
    # best-of-2 loops made the ratio swing ~15% run to run
    t_solo = t_mux = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for eng, fs in zip(solos, streams):
            list(eng.stream(fs))
        t_solo = min(t_solo, time.perf_counter() - t0)
        t0 = time.perf_counter()
        list(mux.serve_streams([list(fs) for fs in streams]))
        t_mux = min(t_mux, time.perf_counter() - t0)

    one = SREngine(params, cfg, switching=_stable_switching(),
                   plan=ExecutionPlan(dispatch="fused", capacity=cap,
                                      inflight=2))
    list(one.serve_streams([list(streams[0])]))                      # warm
    t_one = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        list(one.serve_streams([list(streams[0])]))
        t_one = min(t_one, time.perf_counter() - t0)

    total = n_streams * ticks
    fps_solo, fps_mux = total / t_solo, total / t_mux
    ratio = fps_mux / fps_solo
    emit("table11_multi_stream_solo_aggregate", t_solo / total * 1e6,
         f"fps={fps_solo:.3f};engines={n_streams}")
    emit("table11_multi_stream_mux_aggregate", t_mux / total * 1e6,
         f"fps={fps_mux:.3f};mux_vs_solo_x={ratio:.3f};"
         f"allclose={allclose};bit_equal={bit_equal}")
    return {
        "streams": n_streams, "ticks": ticks,
        "capacity_per_stream": list(cap),
        "solo_aggregate": {"fps": round(fps_solo, 3),
                           "engines": n_streams},
        "mux_aggregate": {"fps": round(fps_mux, 3),
                          "allclose_vs_solo": allclose,
                          "bit_equal_vs_solo": bit_equal},
        "single_stream": {"fps": round(ticks / t_one, 3)},
        "mux_vs_solo_x": round(ratio, 3),
    }


def _measure_resilience(params, cfg, frame, reps: int = 5) -> dict:
    """Cost and conformance of the serving guard (``plan.on_poison`` /
    `repro.runtime.guard`):

      * ``guarded_vs_unguarded_x`` — fused-dispatch fps with in-graph
        health verdicts + sanitize vs verdicts off, INTERLEAVED best-of
        like the dispatch sweep so load drift cancels. The CI gate floors
        this at 0.95x: the verdict is three fused reductions and must stay
        under a 5% tax.
      * ``clean_bit_equal`` — on a clean frame the sanitize path must be a
        bit-level no-op (zero tolerance: a guarded server that perturbs
        healthy output is wrong, not slow).
      * ``chaos`` — a seeded `FaultPlan` storm through ``serve_streams``
        (poison + injected backend failures + quarantine): the run must
        finish without an escaped exception and two identical runs must
        produce identical degradation ledgers (zero tolerance on both)."""
    off = SREngine(params, cfg, switching=_stable_switching(),
                   plan=ExecutionPlan(dispatch="fused", on_poison="off"))
    on = SREngine(params, cfg, switching=_stable_switching(),
                  plan=ExecutionPlan(dispatch="fused",
                                     on_poison="sanitize"))
    img_off = np.asarray(jax.block_until_ready(off.upscale(frame).image))
    img_on = np.asarray(jax.block_until_ready(on.upscale(frame).image))
    clean_bit_equal = bool(np.array_equal(img_off, img_on))
    us_off = us_on = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(off.upscale(frame).image)
        us_off = min(us_off, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(on.upscale(frame).image)
        us_on = min(us_on, (time.perf_counter() - t0) * 1e6)
    ratio = us_off / us_on                       # guarded fps / unguarded fps

    def chaos_run():
        fp = FaultPlan(seed=7, poison_rate=0.5, poison_kinds=("nan", "inf"),
                       backend_failure_rate=0.2, target_streams=(1,))
        h, w = int(frame.shape[0]), int(frame.shape[1])
        geom = ExecutionPlan().geometry(h, w, cfg.scale)
        eng = SREngine(params, cfg, switching=_stable_switching(),
                       plan=ExecutionPlan(dispatch="fused", streams=3,
                                          capacity=(0, geom.n, geom.n),
                                          on_poison="raise",
                                          quarantine_ticks=1, faults=fp))
        streams = [[jnp.roll(frame, 13 * (s + 1) * (t + 1), axis=1)
                    for t in range(3)] for s in range(3)]
        outs = list(eng.serve_streams(streams))
        trace = [(o.stream_id, o.health, o.degraded) for o in outs]
        return trace, eng.summary().get("degradations", {}).get("by_kind",
                                                                {})

    crash_free = True
    deterministic = False
    by_kind = {}
    try:
        t1, k1 = chaos_run()
        t2, k2 = chaos_run()
        deterministic = (t1 == t2 and k1 == k2)
        by_kind = k1
    except Exception as e:
        crash_free = False
        by_kind = {"escaped": repr(e)}
    emit("table11_resilience_guarded", us_on,
         f"fps={1e6 / us_on:.3f};guarded_vs_unguarded_x={ratio:.3f};"
         f"clean_bit_equal={clean_bit_equal};crash_free={crash_free};"
         f"deterministic={deterministic}")
    return {
        "unguarded": {"us_per_frame": round(us_off, 1),
                      "fps": round(1e6 / us_off, 3)},
        "guarded_sanitize": {"us_per_frame": round(us_on, 1),
                             "fps": round(1e6 / us_on, 3)},
        "guarded_vs_unguarded_x": round(ratio, 3),
        "clean_bit_equal": clean_bit_equal,
        "chaos": {"crash_free": crash_free,
                  "deterministic": deterministic,
                  "by_kind": by_kind},
    }


def _measure_fusion(params, cfg, frame) -> dict:
    """Layer fusion (per-op kernel stack: BSConv -> 5xSFB -> DSConv, features
    crossing HBM at every group boundary) vs group fusion (the
    `kernels/megakernel.py` single launch, features resident in VMEM scratch)
    on the mixed-routing frame.

    Two signals, both gated by scripts/bench_gate.py:

      * measured: interleaved best-of wall time of the edge-selective frame
        with ``fusion="layer"`` vs ``fusion="group"`` on the pallas backend —
        group must never be slower beyond tolerance;
      * static: `analysis.cost_model.price_jaxpr` over the traced all-C54
        patch batch through both chains — ``feature_hbm_bytes`` (rank-4
        activation traffic across HBM) must shrink by >= 50%, the
        machine-portable form of the paper's 79% inter-group traffic
        reduction (Table XI rides on exactly this VMEM residency).
    """
    from repro.analysis.cost_model import price_jaxpr
    from repro.core.patching import get_geometry
    from repro.kernels.megakernel import (autotune_report,
                                          essr_forward_megakernel,
                                          essr_forward_qmegakernel)
    from repro.kernels.ops import essr_forward_kernels
    from repro.kernels.qconv import essr_forward_qkernels
    from repro.quant.pams import build_quant_pack

    h, w = int(frame.shape[0]), int(frame.shape[1])
    g = get_geometry(h, w, 32, 2, cfg.scale)
    batch = g.extract(frame)          # every patch as C54: the traffic ceiling
    pack = build_quant_pack(params, cfg, "int8", batch[:16])
    chains = {
        "layer": lambda p, x: essr_forward_kernels(p, x, cfg, interpret=True),
        "group": lambda p, x: essr_forward_megakernel(p, x, cfg,
                                                      interpret=True),
        "layer-int8": lambda p, x: essr_forward_qkernels(
            p, x, cfg, pack=pack, interpret=True),
        "group-int8": lambda p, x: essr_forward_qmegakernel(
            p, x, cfg, pack=pack, interpret=True),
    }
    static = {}
    for label, fn in chains.items():
        c = price_jaxpr(jax.make_jaxpr(fn)(params, batch))
        static[label] = {"macs": c.macs, "hbm_bytes": c.hbm_bytes,
                         "feature_hbm_bytes": c.feature_bytes}
    red_fp = 1.0 - (static["group"]["feature_hbm_bytes"]
                    / max(static["layer"]["feature_hbm_bytes"], 1))
    red_q = 1.0 - (static["group-int8"]["feature_hbm_bytes"]
                   / max(static["layer-int8"]["feature_hbm_bytes"], 1))

    run_layer = lambda: edge_selective_sr(params, frame, cfg,
                                          backend="pallas",
                                          fusion="layer").image
    run_group = lambda: edge_selective_sr(params, frame, cfg,
                                          backend="pallas",
                                          fusion="group").image
    img_l = jax.block_until_ready(run_layer())          # warm both jits
    img_g = jax.block_until_ready(run_group())
    allclose = bool(np.allclose(np.asarray(img_l), np.asarray(img_g),
                                rtol=1e-5, atol=1e-5))
    # interleaved best-of: machine-load drift shifts both fusion modes
    # together instead of masquerading as a fusion speedup (same estimator
    # as the dispatch sweep)
    us_layer = us_group = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(run_layer())
        us_layer = min(us_layer, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(run_group())
        us_group = min(us_group, (time.perf_counter() - t0) * 1e6)
    speedup = us_layer / us_group
    tune = autotune_report(cfg.channels, 32, cfg.scale, cfg.n_sfb)
    emit("table11_fusion_layer", us_layer, f"fps={1e6 / us_layer:.3f}")
    emit("table11_fusion_group", us_group,
         f"fps={1e6 / us_group:.3f};speedup_x={speedup:.2f};"
         f"allclose={allclose};feature_reduction={red_fp:.3f}")
    return {
        "layer": {"us_per_frame": round(us_layer, 1),
                  "fps": round(1e6 / us_layer, 3)},
        "group": {"us_per_frame": round(us_group, 1),
                  "fps": round(1e6 / us_group, 3),
                  "allclose_vs_layer": allclose},
        "group_speedup_x": round(speedup, 2),
        "static_costs": static,
        # the headline ratios the gate floors at 0.5 (paper: 0.79)
        "feature_hbm_reduction": round(red_fp, 4),
        "feature_hbm_reduction_int8": round(red_q, 4),
        "paper_feature_hbm_reduction": 0.79,
        # the roofline-driven block pick the megakernel launches with
        "autotune": {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in tune.items()},
    }


def _dispatch_conformance(params, cfg, hw: int = 96) -> dict:
    """Fused-vs-host allclose across backends and quant on a small mixed
    frame (small because pallas-interpret is the CPU correctness path, not
    a fast one): the zero-tolerance flags the bench gate enforces."""
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw), jnp.linspace(0, 1, hw),
                          indexing="ij")
    smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
    noise = jax.random.uniform(jax.random.PRNGKey(3), (hw, hw, 3))
    frame = jnp.where((yy < 0.5)[..., None], smooth, noise)
    rows = {}
    for backend in ("ref", "pallas"):
        for quant in (None, "int8"):
            plan_h = ExecutionPlan(quant=quant)
            host = SREngine(params, cfg, plan=plan_h, backend=backend)
            fused = SREngine(params, cfg,
                             plan=plan_h.replace(dispatch="fused"),
                             backend=backend)
            r_h, r_f = host.upscale(frame), fused.upscale(frame)
            ok = bool(np.allclose(np.asarray(r_h.image),
                                  np.asarray(r_f.image),
                                  rtol=1e-5, atol=1e-5)
                      and np.array_equal(np.asarray(r_h.ids),
                                         np.asarray(r_f.ids)))
            # key by the REQUESTED backend+quant, not the served label: the
            # served label carries the platform-dependent "-interpret"
            # suffix, which would make a CPU-committed baseline structurally
            # unmatchable on accelerator hardware in bench_gate
            label = backend + ("" if quant is None else f"-{quant}")
            rows[label] = ok
            emit(f"table11_dispatch_conformance_{label}", 0.0,
                 f"allclose={ok};served={r_f.backend}")
    return rows


def bench_patch_pipeline(out_json: str = BENCH_JSON,
                         shard_counts=(1, 2, 4)) -> dict:
    """Host-loop removal, measured on one 480x270 -> x4 frame through the
    full edge-selective pipeline (threshold routing):

      * "smooth" — a gradient frame every patch of which routes to bilinear,
        the content the paper's edge-selective premise optimizes for; frame
        time is the patch pipeline itself, so this row isolates the
        extract/route/fuse speedup;
      * "noise"  — uniform noise routes everything to C54, so the (unchanged,
        shared) conv forward dominates and bounds the frame-level gain.

    Fresh-init weights: routing depends only on frame content, and the
    forward pass is identical on both sides of the comparison."""
    lr_h, lr_w, scale = 270, 480, 4
    cfg = ESSRConfig(scale=scale)
    params = init_essr(jax.random.PRNGKey(0), cfg)
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, lr_h), jnp.linspace(0, 1, lr_w),
                          indexing="ij")
    smooth = jnp.stack([yy, xx, (yy + xx) / 2], axis=-1)
    noise = jax.random.uniform(jax.random.PRNGKey(1), (lr_h, lr_w, 3))

    rows = {"smooth_all_bilinear": _measure_frame(params, cfg, smooth,
                                                  "smooth"),
            "noise_all_c54": _measure_frame(params, cfg, noise, "noise")}
    mixed = jnp.where((yy < 0.5)[..., None], smooth, noise)
    shard_rows = _measure_shards(params, cfg, mixed, shard_counts)
    # how much slower the sharded dispatch runs on THIS host relative to the
    # same run's single-device path: > 1 on virtual CPU meshes, where the
    # "devices" share cores and shard_map only adds partition overhead (see
    # docs/api.md — a real accelerator mesh is where shards>1 pays off)
    fps1 = shard_rows.get("1", {}).get("fps")
    sharded_fps = [r["fps"] for s, r in shard_rows.items()
                   if s != "1" and "fps" in r]
    shard_overhead = (round(fps1 / min(sharded_fps), 2)
                      if fps1 and sharded_fps else None)
    payload = {
        "bench": "table11_patch_pipeline",
        "frame_lr_hw": [lr_h, lr_w], "scale": scale, "backend": "ref",
        "patch": 32, "overlap": 2,
        # headline: the host-loop-removal speedup this PR targets (the smooth
        # frame, where the patch pipeline IS the frame cost); the noise row
        # reports the conv-bound worst case alongside
        "speedup_x": rows["smooth_all_bilinear"]["speedup_x"],
        "frames": rows,
        # the mixed-content frame routes to all three subnets, so the sweep
        # exercises sharded dispatch of every bucket
        "shard_sweep": shard_rows,
        "shard_sweep_devices": jax.device_count(),
        "shard_overhead_x": shard_overhead,
        # same mixed frame through the PAMS quantized serving path
        "quant_sweep": _measure_quant(params, cfg, mixed),
        # host vs fused single-dispatch frame executable (+ async stream)
        # on the same mixed-routing frame, post-warmup
        "dispatch_sweep": _measure_dispatch(params, cfg, mixed),
        # layer-fused per-op stack vs the group-fused megakernel on the
        # same mixed frame: measured wall time + static feature-HBM traffic
        "fusion_sweep": _measure_fusion(params, cfg, mixed),
        "dispatch_conformance": _dispatch_conformance(params, cfg),
        # N tenant streams through one fused dispatch vs N solo engines.
        # Cropped frame: the full mixed frame puts ~113 MB of patch
        # buffers in flight per conv lane, and the ratio of two such runs
        # inside one long-lived process is dominated by allocator/cache
        # noise, not packing cost. The crop keeps every subnet routed
        # (it straddles the smooth/noise boundary) with a working set
        # small enough that repeated measurements agree.
        "multi_stream": _measure_streams(params, cfg, mixed[:192, :192]),
        # serving-guard tax (in-graph health verdicts) + chaos conformance
        # on the same cropped mixed frame as the multi-stream rows
        "resilience": _measure_resilience(params, cfg, mixed[:192, :192]),
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", default="1,2,4",
                    help="comma-separated shard counts for the sharded patch "
                         "stream sweep (counts beyond the visible devices are "
                         "recorded as skipped)")
    ap.add_argument("--out-json", default=BENCH_JSON,
                    help="where the patch-pipeline/shard-sweep record lands")
    ap.add_argument("--pipeline-only", action="store_true",
                    help="only the JSON-recorded pipeline + shard benches "
                         "(skip the trained-supernet CPU table and the TPU "
                         "projection; what scripts/bench_gate.py runs)")
    args = ap.parse_args()
    shard_counts = tuple(int(s) for s in args.shards.split(","))

    bench_patch_pipeline(out_json=args.out_json, shard_counts=shard_counts)
    if args.pipeline_only:
        return
    hw, scale = 96, 4
    frame = jax.random.uniform(jax.random.PRNGKey(0), (hw, hw, 3))
    hr_pix = (hw * scale) ** 2
    params, cfg = get_trained_essr(scale=scale)     # restore weights once
    engines = {"jax": SREngine(params, cfg, backend="ref"),
               "kernels": SREngine(params, cfg, backend="pallas")}

    for name, engine in engines.items():
        for width in (27, 54):
            reps = 3 if name == "jax" else 1
            us = timed(lambda: engine.upscale(frame, mode="all_patches",
                                              width=width).image, reps=reps)
            note = ("" if engine.backend_label != "pallas-interpret"
                    else ";note=interpret-mode(correctness path)")
            emit(f"table11_cpu_{name}_c{width}", us,
                 f"mpixels_per_s={hr_pix / us:.2f}{note}")

    # TPU projection from the dry-run artifact
    f = "/root/repo/results/dryrun/single/essr-x4__serve_8k.json"
    if os.path.exists(f):
        d = json.load(open(f))
        r = d["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        fps_mesh = 1.0 / step_s if step_s > 0 else float("inf")
        emit("table11_tpu_projection", 0.0,
             f"dominant={r['dominant']};frame_step_s={step_s:.2e};"
             f"fps_on_256chips={fps_mesh:.0f};"
             f"mpixels_per_j=NA(no power on CPU);paper=4797")


if __name__ == "__main__":
    main()
