"""Paper Table VII / Fig. 23 analog: perceptual-oriented (GAN) phase.

Loads the trained PSNR model, runs a short perceptual phase (L1 + LDL
artifact + perceptual + adversarial at the paper's 0.01/1/1/0.005 weights,
Adam 1e-4), and reports the PSNR-vs-perceptual trade: the GAN model should
lower the perceptual distance (LPIPS stand-in) while giving up a little
PSNR — the direction Table VII documents for ESSR-GAN."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_frames, get_trained_essr
from repro.train.gan import train_essr_gan
from repro.data.synthetic import patch_batches
from repro.models.essr import essr_forward
from repro.train.losses import init_feature_net, perceptual_loss, psnr_y, ssim

GAN_STEPS = int(os.environ.get("BENCH_GAN_STEPS", "60"))


def _metrics(params, cfg, frames, feat):
    ps, ss, lp = [], [], []
    for lr, hr in frames:
        sr = jnp.clip(essr_forward(params, lr[None], cfg)[0], 0, 1)
        ps.append(float(psnr_y(sr, hr)))
        ss.append(float(ssim(sr, hr)))
        lp.append(float(perceptual_loss(feat, sr[None], hr[None])))
    return float(np.mean(ps)), float(np.mean(ss)), float(np.mean(lp))


def main():
    params, cfg = get_trained_essr(scale=4)
    frames = eval_frames(n=2, hw=64)
    feat = init_feature_net(jax.random.PRNGKey(7))

    p0, s0, l0 = _metrics(params, cfg, frames, feat)
    emit("table7_psnr_model", 0.0, f"psnr_y={p0:.2f};ssim={s0:.3f};lpips_proxy={l0:.4f}")

    data = patch_batches(1, batch=4, lr_patch=16, scale=4, pool=8, pool_hw=128)
    gan_params, _, hist = train_essr_gan(params, cfg, data, steps=GAN_STEPS,
                                         log_every=0)
    p1, s1, l1 = _metrics(gan_params, cfg, frames, feat)
    emit("table7_gan_model", 0.0,
         f"psnr_y={p1:.2f};ssim={s1:.3f};lpips_proxy={l1:.4f};"
         f"g_loss={hist[0][0]:.3f}->{hist[-1][0]:.3f};gan_steps={GAN_STEPS}")
    emit("table7_trade", 0.0,
         f"d_psnr={p1-p0:+.2f};d_lpips_proxy={l1-l0:+.4f};"
         f"paper_direction=lpips_down_psnr_flat_or_down")


if __name__ == "__main__":
    main()
