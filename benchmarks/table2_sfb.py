"""Paper Table II: #SFB ablation — exact parameter identities + short-train
quality ordering on synthetic data."""
from benchmarks.common import (emit, eval_frames, get_trained_essr,
                               mean_psnr_edge_selective)
from repro.models.essr import ESSRConfig, essr_param_count

PAPER_PARAMS = {4: 43_896, 5: 53_886, 6: 63_876}


def main():
    frames = eval_frames(n=2, hw=64)
    for n_sfb in (4, 5, 6):
        cfg = ESSRConfig(scale=4, n_sfb=n_sfb)
        n = essr_param_count(cfg)
        assert n == PAPER_PARAMS[n_sfb], f"Table II params mismatch: {n}"
        params, cfg = get_trained_essr(scale=4, n_sfb=n_sfb)
        psnr, _ = mean_psnr_edge_selective(params, cfg, frames, t1=0, t2=0)  # all C54
        emit(f"table2_sfb{n_sfb}", 0.0,
             f"params={n};paper_params={PAPER_PARAMS[n_sfb]};psnr_y={psnr:.2f}")
    # w/o-bias identity (Table II row 3): fuse+final-pw biases = 318 params
    assert 53_886 - (5 * 54 + 48) == 53_568
    emit("table2_wo_bias_identity", 0.0, "params=53568;paper=53.6K")


if __name__ == "__main__":
    main()
