"""Paper Table IX + the headline 50%-MACs/-0.1dB claim: MAC saving vs PSNR
drop for threshold combinations, relative to the all-C54 pipeline. All rows
run through one `SREngine`; per-row thresholds are plan overrides."""
from benchmarks.common import emit, eval_frames, get_engine, mean_psnr_engine

COMBOS = [(8, 40), (8, 20), (8, 60), (8, 80), (15, 60), (15, 80)]


def main():
    engine = get_engine(scale=4)
    frames = eval_frames(n=3, hw=96)
    base_psnr, _ = mean_psnr_engine(engine, frames,
                                    plan=engine.plan.replace(t1=0, t2=0))
    emit("table9_all_c54_baseline", 0.0, f"psnr_y={base_psnr:.3f};saving=0")
    for t1, t2 in COMBOS:
        p, s = mean_psnr_engine(engine, frames,
                                plan=engine.plan.replace(t1=t1, t2=t2))
        emit(f"table9_essr_{t1}+{t2}", 0.0,
             f"mac_saving={s:.3f};psnr_drop={base_psnr - p:.3f};psnr_y={p:.3f}")


if __name__ == "__main__":
    main()
