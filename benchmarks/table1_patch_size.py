"""Paper Table I: patch size vs PSNR / line buffer / feature SRAM.

The SRAM columns are exact reconstructions of the paper's numbers
(feature SRAM = patch^2 * 54ch * 1.25B FXP10; line buffer = 2 halo rows x
patch x 54 x 1.25B + 200B control) — asserted against Table I. PSNR is
measured on synthetic eval frames with the edge-selective pipeline.
"""
from benchmarks.common import (emit, eval_frames, get_trained_essr,
                               mean_psnr_edge_selective, timed)

PAPER = {16: (2.36, 17), 32: (4.52, 69), 48: (6.68, 156), 64: (8.84, 276)}


def feature_sram_kb(patch: int, c: int = 54, bytes_per: float = 1.25) -> float:
    return patch * patch * c * bytes_per / 1000     # paper reports decimal kB


def line_buffer_kb(patch: int, c: int = 54, bytes_per: float = 1.25) -> float:
    return (2 * patch * c * bytes_per + 200) / 1000


def main():
    params, cfg = get_trained_essr(scale=4)
    frames = eval_frames(n=2, hw=96)
    for patch in (16, 32, 48, 64):
        lb, fs = line_buffer_kb(patch), feature_sram_kb(patch)
        plb, pfs = PAPER[patch]
        assert abs(fs - pfs) / pfs < 0.02, f"feature SRAM mismatch @{patch}"
        assert abs(lb - plb) / plb < 0.12, f"line buffer mismatch @{patch}"
        us = timed(lambda: mean_psnr_edge_selective(params, cfg, frames[:1],
                                                    patch=patch), reps=1)
        psnr, saving = mean_psnr_edge_selective(params, cfg, frames, patch=patch)
        emit(f"table1_patch{patch}", us,
             f"psnr_y={psnr:.2f};line_buffer_kb={lb:.2f};feature_sram_kb={fs:.0f};"
             f"paper_kb={plb}/{pfs};mac_saving={saving:.3f}")


if __name__ == "__main__":
    main()
