"""The paper's technique transplanted to an LM: edge-selective DYNAMIC WIDTH.

    PYTHONPATH=src python examples/dynamic_width_lm.py

ESSR routes image patches by edge score to weight-shared C27/C54 subnets.
Here, tokens are routed by an input statistic (RMS of the pre-FFN hidden —
the 'edge score' analog) to the full-width or half-width slice of ONE
weight-shared FFN (granite-8b reduced config). We train both the static and
dynamic-width variants for a few steps and compare loss + FLOPs/token.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import granite_8b
from repro.models.lm import transformer as T
from repro.train import optimizer as O


def run(cfg, steps=30, seed=0):
    key = jax.random.PRNGKey(seed)
    params = T.init_lm(key, cfg)
    opt = O.chain_clip(O.adam(3e-3), 1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, toks):
        loss, g = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, toks, toks, remat=False))(params)
        upd, state = opt.update(g, state, params)
        return O.apply_updates(params, upd), state, loss

    rng = np.random.default_rng(seed)
    losses = []
    for i in range(steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
        params, state, loss = step(params, state, toks)
        losses.append(float(loss))
    return losses


def main():
    static_cfg = granite_8b.SMOKE
    dyn_cfg = dataclasses.replace(static_cfg, dynamic_width=True)
    print("training 30 steps each on synthetic tokens (granite-8b reduced)...")
    ls = run(static_cfg)
    ld = run(dyn_cfg)
    # FLOPs/token of the FFN: full width F vs 50% tokens at F + 50% at F/2
    f = static_cfg.d_ff
    print(f"static  FFN width {f:4d}: loss {ls[0]:.3f} -> {np.mean(ls[-5:]):.3f}")
    print(f"dynamic (50% @F, 50% @F/2): loss {ld[0]:.3f} -> {np.mean(ld[-5:]):.3f}")
    print(f"FFN MAC saving: {1 - (0.5 + 0.5 * 0.5):.0%} "
          f"(the LM analog of the paper's 50% MAC reduction)")
    print("token 'edge score' = RMS of the pre-FFN hidden state; "
          "width slices share weights exactly like C27 c C54.")


if __name__ == "__main__":
    main()
