"""Serving simulation: the paper's deployment loop (Algorithm 1).

    PYTHONPATH=src python examples/serve_8k.py --frames 4 --hw 96

Streams synthetic frames through ``SREngine`` (constructed by the launcher
via ``SREngine.from_checkpoint``): per-frame edge scores, resource-adaptive
thresholds (the C54/sec ceiling demotes overflow patches to C27 — throughput
guaranteed, quality floor kept), per-subnet batched execution,
overlap+average fusion. Prints Table-XI-style summary. Accepts every
``repro.launch.serve`` flag (--ckpt, --budget, --backend, --deadline-ms,
--shards, --quant, --dispatch, --inflight).

Fused dispatch: ``--dispatch fused`` collapses each frame into ONE compiled
executable — extract, edge scoring, threshold routing into fixed capacity
slots (overflow spills to the next-cheaper subnet), per-subnet forward and
overlap fusion all run on device with no host in the loop; ``--inflight 2``
additionally double-buffers the stream (frame N's compute overlaps frame
N+1's ingest; Algorithm-1 reads routing telemetry one frame behind):

    PYTHONPATH=src python examples/serve_8k.py --frames 8 --hw 96 \\
      --dispatch fused --inflight 2

Quantized serving: ``--quant fxp10`` streams every frame through the
paper's whole-model FXP10 PAMS lattice (fake-quant emulation on the "ref"
backend); ``--quant int8 --backend pallas`` serves the integer-domain int8
kernel stack (int8 codes between fused groups, int32-accumulate matmuls).
Alphas PTQ-calibrate once at engine construction and the served datapath is
visible in the printed backend label ("ref-fxp10", "pallas-int8", ...):

    PYTHONPATH=src python examples/serve_8k.py --frames 4 --hw 96 \\
      --quant fxp10
    PYTHONPATH=src python examples/serve_8k.py --frames 4 --hw 96 \\
      --quant int8 --backend pallas

Sharded streaming: ``--shards N`` splits each frame's routed patch buckets
across up to N devices (one Algorithm-1 controller per raster-strip shard;
on a missed frame deadline the shards carrying the most estimated MAC cost
are demoted C54->C27 so aggregate FPS holds). Run with 4 virtual CPU
devices to try it without hardware:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      PYTHONPATH=src python examples/serve_8k.py --frames 4 --hw 96 --shards 4
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
