"""End-to-end training driver: the paper's PSNR phase (scaled down), with
checkpointing, then edge-selective evaluation per subnet.

    PYTHONPATH=src python examples/train_essr.py --steps 300
    PYTHONPATH=src python examples/train_essr.py --steps 300 --gan-steps 50

Full recipe knobs (Lamb 3e-3 cosine, batch 256, 200K iters, EMA 0.999,
MACs-proportional subnet sampling) live in repro.train.trainer /
repro.launch.train; this example uses a CPU-sized schedule.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "300"]
    main()
