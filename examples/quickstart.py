"""Quickstart: edge-selective super-resolution of one synthetic frame.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's Fig. 1 inference path end-to-end through the `SREngine`
facade: slim-overlap patches -> edge scores -> threshold routing (bilinear /
C27 / C54, shared weights) -> overlap+average fusion — and prints the
per-subnet routing + MAC saving.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.api import ExecutionPlan, SREngine
from repro.core.subnet_policy import SUBNET_NAMES
from repro.data.synthetic import degrade, random_image
from repro.models.essr import ESSR_X4
from repro.train.losses import psnr_y


def main():
    hr = jnp.asarray(random_image(0, 256, 256))
    lr = degrade(hr, 4)
    print(f"LR {lr.shape} -> SR x4 (paper's ESSR, C={ESSR_X4.channels}, "
          f"{ESSR_X4.n_sfb} SFBs, 53,886 params)")

    # untrained demo weights; SREngine.from_checkpoint loads trained ones
    engine = SREngine.from_config(ESSR_X4, plan=ExecutionPlan(t1=8, t2=40))
    res = engine.upscale(lr)

    print(f"patches: {res.n_patches}  routing: "
          + ", ".join(f"{n}={c}" for n, c in zip(SUBNET_NAMES, res.counts)))
    print(f"MAC saving vs all-C54: {res.mac_saving:.1%} "
          f"(paper: ~50% on Test8K at thresholds 8/40)")
    print(f"SR image: {res.image.shape}, "
          f"PSNR_Y vs ground truth {float(psnr_y(res.image, hr)):.2f} dB "
          f"(untrained weights — see examples/train_essr.py)")
    bilinear = engine.reference(lr, width=0)     # whole-frame bilinear
    print(f"bilinear reference:      "
          f"{float(psnr_y(bilinear.image, hr)):.2f} dB")


if __name__ == "__main__":
    main()
